//! Heterogeneous-fleet scenario: the paper's §III-A setting in miniature.
//!
//! Samples a fleet with the paper's resource ranges (memory U[2,16] GB,
//! latency U[20,200] ms), shows the Eq. 1 subnetwork allocation, then runs
//! SuperSFL vs the two baselines on the *same* fleet/seed and compares
//! rounds-to-target, communication and simulated training time — a
//! one-screen version of Table I.
//!
//! ```bash
//! cargo run --release --example heterogeneous_fleet
//! ```

use supersfl::config::{ExperimentConfig, Method};
use supersfl::metrics::Table;
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;

fn base_cfg(method: Method) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default()
        .with_name("het_fleet")
        .with_method(method)
        .with_clients(12)
        .with_rounds(20)
        .with_seed(11);
    cfg.data.train_per_class = 120;
    cfg.train.local_steps = 2;
    cfg.train.eval_samples = 300;
    cfg.train.target_accuracy = Some(0.70);
    cfg
}

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);

    println!("== fleet & allocation (Eq. 1) ==");
    let probe = run_experiment(&rt, &base_cfg(Method::SuperSfl).with_rounds(1))?;
    let mut hist = vec![0usize; rt.model().depth];
    for &d in &probe.depths {
        hist[d] += 1;
    }
    println!("client depths: {:?}", probe.depths);
    println!("depth histogram (1..L-1): {:?}\n", &hist[1..]);

    println!("== method comparison on the identical fleet ==");
    let mut table = Table::new(&[
        "method",
        "rounds→70%",
        "comm MB",
        "sim time s",
        "final acc",
        "W/%",
    ]);
    for method in [Method::Sfl, Method::Dfl, Method::SuperSfl] {
        let res = run_experiment(&rt, &base_cfg(method))?;
        let m = &res.metrics;
        table.row(&[
            method.as_str().to_uppercase(),
            m.rounds_to_target
                .map(|r| r.to_string())
                .unwrap_or_else(|| format!(">{}", m.rounds.len())),
            format!(
                "{:.0}",
                m.comm_mb_to_target.unwrap_or(m.total_comm_mb)
            ),
            format!(
                "{:.0}",
                m.sim_time_to_target.unwrap_or(m.total_sim_time_s)
            ),
            format!("{:.3}", m.best_accuracy),
            format!("{:.2}", m.power_per_acc),
        ]);
    }
    println!("{}", table.render());
    println!("(SSFL should need the fewest rounds and the least communication; \
              see `cargo bench --bench table1_efficiency` for the full grid)");
    Ok(())
}
