//! Quickstart: train SuperSFL on a small heterogeneous fleet.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Runs 10 federated rounds with 8 heterogeneous clients on the synthetic
//! CIFAR-10-like task and prints the accuracy/communication trajectory.

use supersfl::config::ExperimentConfig;
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;

fn main() -> supersfl::Result<()> {
    let mut cfg = ExperimentConfig::default()
        .with_name("quickstart")
        .with_clients(8)
        .with_rounds(10)
        .with_seed(1);
    cfg.data.train_per_class = 100;
    cfg.train.local_steps = 2;
    cfg.train.eval_samples = 300;

    let rt = Runtime::load_if_available(&cfg.artifacts_dir);
    println!(
        "backend: {} | model: {} params, {} layers, {} tokens",
        rt.backend_name(),
        rt.model().enc_full_size,
        rt.model().depth,
        rt.model().tokens
    );

    let res = run_experiment(&rt, &cfg)?;
    println!("\nclient depths (Eq. 1 allocation): {:?}", res.depths);
    println!("round  accuracy  comm(MB)  sim-time(s)");
    for r in &res.metrics.rounds {
        println!(
            "{:>5}  {:>8.3}  {:>8.1}  {:>11.1}",
            r.round, r.accuracy, r.cum_comm_mb, r.sim_time_s
        );
    }
    println!(
        "\nfinal accuracy {:.3} | total comm {:.1} MB | avg power {:.0} W",
        res.metrics.final_accuracy,
        res.metrics.total_comm_mb,
        res.metrics.avg_power_w
    );
    Ok(())
}
