//! End-to-end driver: the full SuperSFL system on a real (synthetic)
//! workload, proving all three layers compose — Pallas kernels inside the
//! AOT-compiled JAX model, executed from the Rust coordinator, under the
//! complete federated split-learning protocol with heterogeneous clients,
//! non-IID data, fault injection, TPGF and collaborative aggregation.
//!
//! Defaults: 24 heterogeneous clients, Dirichlet(0.5) non-IID, 60 rounds,
//! 95% server availability — several thousand training steps end to end.
//! The loss/accuracy trajectory is logged to results/e2e_train.csv and
//! recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example e2e_train            # full run
//! cargo run --release --example e2e_train -- --quick # 8 clients, 12 rounds
//! ```

use std::time::Instant;

use supersfl::config::ExperimentConfig;
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;

fn main() -> supersfl::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut cfg = ExperimentConfig::default()
        .with_name(if quick { "e2e_quick" } else { "e2e_train" })
        .with_clients(if quick { 8 } else { 24 })
        .with_rounds(if quick { 12 } else { 60 })
        .with_seed(2026);
    cfg.data.train_per_class = if quick { 100 } else { 400 };
    cfg.data.test_total = 1000;
    cfg.train.local_steps = 2;
    cfg.train.eval_samples = if quick { 300 } else { 1000 };
    cfg.net.server_availability = 0.95; // realistic intermittent outages

    println!("== SuperSFL end-to-end driver ==");
    let rt = Runtime::load_if_available(&cfg.artifacts_dir);
    let m = rt.model();
    println!(
        "model: {} encoder params over {} layers | {} clients | {} rounds | Dir({}) non-IID",
        m.enc_full_size,
        m.depth,
        cfg.fleet.clients,
        cfg.train.rounds,
        cfg.data.dirichlet_alpha
    );

    let t0 = Instant::now();
    let res = run_experiment(&rt, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nround  acc     loss(client)  loss(server)  fallback  comm(MB)");
    for r in &res.metrics.rounds {
        if r.round % 5 == 0 || r.round <= 3 || r.round == res.metrics.rounds.len() {
            println!(
                "{:>5}  {:.3}   {:>12.4}  {:>12.4}  {:>8}  {:>8.1}",
                r.round,
                r.accuracy,
                r.mean_client_loss,
                r.mean_server_loss,
                r.fallback_steps,
                r.cum_comm_mb
            );
        }
    }

    let st = rt.stats();
    let steps: usize = res.metrics.rounds.iter().map(|r| r.fallback_steps + r.server_steps).sum();
    println!("\n== summary ==");
    println!("final accuracy   : {:.3}", res.metrics.final_accuracy);
    println!("best accuracy    : {:.3}", res.metrics.best_accuracy);
    println!("client steps     : {steps}");
    println!("total comm       : {:.1} MB", res.metrics.total_comm_mb);
    println!("simulated time   : {:.1} s", res.metrics.total_sim_time_s);
    println!("avg power        : {:.0} W", res.metrics.avg_power_w);
    println!("CO2              : {:.1} g", res.metrics.co2_g);
    println!(
        "XLA executions   : {} ({:.1}s exec, {:.1}s marshal, {} compiles)",
        st.executions, st.exec_time_s, st.marshal_time_s, st.compile_count
    );
    println!("wall clock       : {wall:.1} s");

    let out = std::path::PathBuf::from("results");
    res.metrics.write_csv(&out.join(format!("{}.csv", cfg.name)))?;
    res.metrics.write_json(&out.join(format!("{}.json", cfg.name)))?;
    println!("trajectory written to results/{}.csv", cfg.name);

    if res.metrics.best_accuracy <= 1.5 / cfg.data.classes as f64 {
        return Err(supersfl::Error::Config(format!(
            "model failed to learn (best acc {:.3})",
            res.metrics.best_accuracy
        )));
    }
    Ok(())
}
