//! Fault-tolerance scenario (paper §II-C / Table III).
//!
//! Sweeps server-gradient availability from 100% down to fully serverless
//! and shows that SuperSFL degrades gracefully (the client-side classifier
//! keeps training during outages) while the SFL baseline stalls.
//!
//! ```bash
//! cargo run --release --example fault_tolerance
//! ```

use supersfl::config::{ExperimentConfig, Method};
use supersfl::metrics::Table;
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;

fn cfg(method: Method, availability: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default()
        .with_name("fault_tolerance")
        .with_method(method)
        .with_clients(8)
        .with_rounds(15)
        .with_seed(5);
    cfg.net.server_availability = availability;
    cfg.data.train_per_class = 100;
    cfg.train.local_steps = 2;
    cfg.train.eval_samples = 300;
    cfg
}

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);

    let mut table = Table::new(&[
        "availability",
        "SSFL acc",
        "SSFL fallback steps",
        "SFL acc",
        "SFL stalled steps",
    ]);
    for avail in [1.0, 0.7, 0.5, 0.2, 0.0] {
        let ssfl = run_experiment(&rt, &cfg(Method::SuperSfl, avail))?;
        let sfl = run_experiment(&rt, &cfg(Method::Sfl, avail))?;
        let fb: usize = ssfl.metrics.rounds.iter().map(|r| r.fallback_steps).sum();
        let st: usize = sfl.metrics.rounds.iter().map(|r| r.fallback_steps).sum();
        table.row(&[
            format!("{:.0}%", avail * 100.0),
            format!("{:.3}", ssfl.metrics.best_accuracy),
            fb.to_string(),
            format!("{:.3}", sfl.metrics.best_accuracy),
            st.to_string(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "SuperSFL keeps learning through outages via Alg. 3 fallback; \
         SFL loses every stalled step. Full sweep: cargo bench --bench table3_availability"
    );
    Ok(())
}
