"""L1 correctness: the TPGF fused-update Pallas kernel vs the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.tpgf import tpgf_update

jax.config.update("jax_platform_name", "cpu")


def _vecs(seed, n):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    return (
        jax.random.normal(k1, (n,), jnp.float32),
        jax.random.normal(k2, (n,), jnp.float32),
        jax.random.normal(k3, (n,), jnp.float32),
    )


def test_matches_ref_basic():
    theta, gc, gs = _vecs(0, 10_000)
    lc, ls, lr = jnp.float32(1.2), jnp.float32(0.7), jnp.float32(0.01)
    out = tpgf_update(theta, gc, gs, lc, ls, lr, 3, 5, block=1024)
    exp = ref.tpgf_update_ref(theta, gc, gs, lc, ls, lr, 3, 5)
    assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 5000),
    block=st.sampled_from([64, 256, 4096]),
    d_i=st.integers(1, 7),
    lc=st.floats(1e-4, 10.0),
    ls=st.floats(1e-4, 10.0),
    lr=st.floats(1e-5, 1.0),
    seed=st.integers(0, 2**16),
)
def test_matches_ref_hypothesis(n, block, d_i, lc, ls, lr, seed):
    theta, gc, gs = _vecs(seed, n)
    d_s = 8 - d_i
    out = tpgf_update(theta, gc, gs, jnp.float32(lc), jnp.float32(ls),
                      jnp.float32(lr), d_i, d_s, block=block)
    exp = ref.tpgf_update_ref(theta, gc, gs, jnp.float32(lc), jnp.float32(ls),
                              jnp.float32(lr), d_i, d_s)
    assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5, rtol=1e-5)


def test_zero_lr_is_identity():
    theta, gc, gs = _vecs(1, 777)
    out = tpgf_update(theta, gc, gs, jnp.float32(1.0), jnp.float32(1.0),
                      jnp.float32(0.0), 4, 4, block=256)
    assert_allclose(np.asarray(out), np.asarray(theta), atol=0)


def test_equal_losses_equal_depth_is_half_mix():
    # L_c == L_s and d_i == d_s ⇒ w_client = 0.5 · 0.5 = 0.25 (Eq. 3).
    n = 512
    theta = jnp.zeros((n,), jnp.float32)
    gc = jnp.ones((n,), jnp.float32)
    gs = jnp.zeros((n,), jnp.float32)
    out = tpgf_update(theta, gc, gs, jnp.float32(2.0), jnp.float32(2.0),
                      jnp.float32(1.0), 4, 4, block=256)
    assert_allclose(np.asarray(out), np.full(n, -0.25, np.float32), atol=1e-6)


def test_low_client_loss_shifts_weight_to_client():
    # Lower client loss ⇒ larger w_client ⇒ update tracks g_client more.
    n = 256
    theta = jnp.zeros((n,), jnp.float32)
    gc = jnp.ones((n,), jnp.float32)
    gs = -jnp.ones((n,), jnp.float32)
    low = tpgf_update(theta, gc, gs, jnp.float32(0.1), jnp.float32(5.0),
                      jnp.float32(1.0), 4, 4, block=256)
    high = tpgf_update(theta, gc, gs, jnp.float32(5.0), jnp.float32(0.1),
                       jnp.float32(1.0), 4, 4, block=256)
    assert float(low[0]) < float(high[0])


def test_depth_ratio_caps_client_weight():
    # Even with negligible client loss, w_client <= d_i/(d_i+d_s) (Eq. 3).
    n = 128
    theta = jnp.zeros((n,), jnp.float32)
    gc = jnp.ones((n,), jnp.float32)
    gs = jnp.zeros((n,), jnp.float32)
    out = tpgf_update(theta, gc, gs, jnp.float32(1e-8), jnp.float32(100.0),
                      jnp.float32(1.0), 1, 7, block=128)
    # theta' = -w_c·1, and w_c → 1/8 as the loss ratio saturates.
    assert float(out[0]) >= -(1.0 / 8.0) - 1e-5


def test_weights_sum_to_one_property():
    # g_c == g_s == g ⇒ fused gradient must equal g regardless of losses.
    theta, g, _ = _vecs(2, 333)
    for lc, ls, d_i in [(0.5, 3.0, 2), (4.0, 0.2, 6), (1.0, 1.0, 1)]:
        out = tpgf_update(theta, g, g, jnp.float32(lc), jnp.float32(ls),
                          jnp.float32(0.1), d_i, 8 - d_i, block=256)
        exp = theta - 0.1 * g
        assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6, rtol=1e-5)


def test_clip_by_l2_property():
    for seed in range(5):
        (g, _, _) = _vecs(seed, 2048)
        clipped = ref.clip_by_l2(g, 0.5)
        assert float(jnp.linalg.norm(clipped)) <= 0.5 + 1e-5
    small = jnp.full((16,), 1e-4, jnp.float32)
    assert_allclose(np.asarray(ref.clip_by_l2(small, 0.5)), np.asarray(small),
                    rtol=1e-4)


def test_client_weight_bounds():
    # 0 < w_client < d_i/(d_i+d_s) for all positive losses.
    for d_i in range(1, 8):
        for lc, ls in [(0.01, 10.0), (10.0, 0.01), (1.0, 1.0)]:
            w = ref.tpgf_client_weight(jnp.float32(lc), jnp.float32(ls), d_i, 8 - d_i)
            assert 0.0 < float(w) < d_i / 8.0 + 1e-6
