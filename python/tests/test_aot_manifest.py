"""Build-output contract tests: manifest ↔ model geometry ↔ files on disk.

These validate the interchange contract the Rust runtime depends on. They
run against `artifacts/` produced by `make artifacts` and are skipped when
the artifacts have not been built yet.
"""

import json
import os

import numpy as np
import pytest

from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built (run `make artifacts`)"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_geometry_matches_model(manifest):
    cfg = manifest["build"]
    m = manifest["model"]
    assert m["tokens"] == M.tokens(cfg)
    assert m["embed_size"] == M.embed_size(cfg)
    assert m["block_size"] == M.block_size(cfg)
    assert m["enc_layer_sizes"] == M.enc_layer_sizes(cfg)
    assert m["enc_full_size"] == M.enc_size(cfg, cfg["depth"])
    assert sum(m["enc_layer_sizes"]) == m["enc_full_size"]


def test_all_artifact_files_exist_and_parse(manifest):
    for name, a in manifest["artifacts"].items():
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), name
        head = open(path).read(200)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_expected_artifact_set_complete(manifest):
    cfg = manifest["build"]
    L = cfg["depth"]
    names = set(manifest["artifacts"])
    for d in range(1, L):
        for base in ("client_fwd", "client_bwd", "tpgf_update"):
            assert f"{base}_d{d}" in names
        for c in cfg["classes_variants"]:
            assert f"client_local_d{d}_c{c}" in names
            assert f"server_step_d{d}_c{c}" in names
    for c in cfg["classes_variants"]:
        assert f"eval_c{c}" in names


def test_artifact_io_shapes_consistent(manifest):
    cfg = manifest["build"]
    for d in range(1, cfg["depth"]):
        a = manifest["artifacts"][f"client_bwd_d{d}"]
        enc_in = next(i for i in a["inputs"] if i["name"] == "enc")
        g_out = next(o for o in a["outputs"] if o["name"] == "g_enc")
        assert enc_in["shape"] == [M.enc_size(cfg, d)]
        assert g_out["shape"] == enc_in["shape"]
        s = manifest["artifacts"][f"server_step_d{d}_c{cfg['classes_variants'][0]}"]
        srv_in = next(i for i in s["inputs"] if i["name"] == "srv")
        assert srv_in["shape"] == [M.srv_size(cfg, d)]


def test_init_blobs_match_sizes(manifest):
    cfg = manifest["build"]
    for c in cfg["classes_variants"]:
        info = manifest["init"][f"init_enc_c{c}"]
        arr = np.fromfile(os.path.join(ART, info["file"]), dtype="<f4")
        assert arr.size == info["len"] == M.enc_size(cfg, cfg["depth"])
        assert np.isfinite(arr).all()
        info_s = manifest["init"][f"init_clf_s_c{c}"]
        arr_s = np.fromfile(os.path.join(ART, info_s["file"]), dtype="<f4")
        assert arr_s.size == M.clf_server_size(cfg, c)


def test_init_blob_deterministic(manifest):
    cfg = manifest["build"]
    c = cfg["classes_variants"][0]
    enc, _, _ = M.init_params(cfg, c, cfg["seed"])
    on_disk = np.fromfile(
        os.path.join(ART, manifest["init"][f"init_enc_c{c}"]["file"]), dtype="<f4"
    )
    np.testing.assert_allclose(np.asarray(enc), on_disk, atol=0)
