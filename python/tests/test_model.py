"""L2 correctness: split consistency of the super-network.

The defining property of the weight-sharing super-network: for every split
depth d, client-prefix(d) ∘ server-suffix(d) must equal the full model, and
the gradient that flows through the split boundary (g_z) must reproduce the
end-to-end gradient.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.load_build_config()
# A slimmer profile keeps the full-depth sweep fast under pytest.
CFG = {**CFG, "dim": 32, "heads": 2, "depth": 4, "mlp_ratio": 2,
       "batch": 4, "eval_batch": 4, "attn_block_q": 32}
CLASSES = 10


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, CLASSES, seed=7)


@pytest.fixture(scope="module")
def batch():
    key = jax.random.PRNGKey(3)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (CFG["batch"], CFG["image_size"],
                               CFG["image_size"], CFG["channels"]), jnp.float32)
    y = jax.random.randint(ky, (CFG["batch"],), 0, CLASSES)
    return x, y


def test_layer_sizes_partition_encoder(params):
    enc, _, _ = params
    assert sum(M.enc_layer_sizes(CFG)) == enc.size == M.enc_size(CFG, CFG["depth"])


def test_enc_srv_sizes_complementary():
    for d in range(1, CFG["depth"]):
        assert M.enc_size(CFG, d) + M.srv_size(CFG, d) == M.enc_size(CFG, CFG["depth"])


@pytest.mark.parametrize("d", range(1, 4))
def test_split_forward_equals_full_forward(params, batch, d):
    enc, clf_s, _ = params
    x, _ = batch
    z = M.client_fwd(CFG, d, enc[:M.enc_size(CFG, d)], x)
    h_split = M.server_apply(CFG, d, enc[M.enc_size(CFG, d):], z)
    h_full = M.client_fwd(CFG, CFG["depth"], enc, x)
    assert_allclose(np.asarray(h_split), np.asarray(h_full), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("d", [1, 2, 3])
def test_chained_gradient_equals_end_to_end(params, batch, d):
    """client_bwd(g_z from server_step) == d(full loss)/d(enc prefix)."""
    enc, clf_s, _ = params
    x, y = batch
    ne = M.enc_size(CFG, d)
    enc_d, srv = enc[:ne], enc[ne:]

    # Chained path (what the Rust coordinator executes).
    z = M.client_fwd(CFG, d, enc_d, x)
    step = M.make_server_step(CFG, d, CLASSES)
    _, _, _, g_z = step(srv, clf_s, z, y)
    (g_enc_chained,) = M.make_client_bwd(CFG, d)(enc_d, x, g_z)

    # End-to-end reference.
    def full_loss(enc_d_):
        z_ = M.client_fwd(CFG, d, enc_d_, x)
        h = M.server_apply(CFG, d, srv, z_)
        return M.cross_entropy(M.server_head(CFG, CLASSES, clf_s, h), y)

    g_ref = jax.grad(full_loss)(enc_d)
    assert_allclose(np.asarray(g_enc_chained), np.asarray(g_ref),
                    atol=1e-5, rtol=1e-4)


def test_client_local_clips_encoder_grad(params, batch):
    enc, _, clf_c = params
    x, y = batch
    d = 2
    fn = M.make_client_local(CFG, d, CLASSES)
    z, loss, g_enc, g_clf = fn(enc[:M.enc_size(CFG, d)], clf_c, x, y)
    assert z.shape == (CFG["batch"], M.tokens(CFG), CFG["dim"])
    assert float(loss) > 0.0
    assert float(jnp.linalg.norm(g_enc)) <= CFG["clip_tau"] + 1e-5
    assert g_clf.shape == (M.clf_client_size(CFG, CLASSES),)


def test_client_local_loss_matches_manual(params, batch):
    enc, _, clf_c = params
    x, y = batch
    d = 1
    fn = M.make_client_local(CFG, d, CLASSES)
    z, loss, _, _ = fn(enc[:M.enc_size(CFG, d)], clf_c, x, y)
    logits = M.client_head(CFG, CLASSES, clf_c, z)
    assert_allclose(float(loss), float(M.cross_entropy(logits, y)), rtol=1e-6)


def test_eval_matches_split_path(params, batch):
    enc, clf_s, _ = params
    x, _ = batch
    (logits,) = M.make_eval(CFG, CLASSES)(enc, clf_s, x)
    h = M.client_fwd(CFG, CFG["depth"], enc, x)
    exp = M.server_head(CFG, CLASSES, clf_s, h)
    assert_allclose(np.asarray(logits), np.asarray(exp), atol=1e-6)
    assert logits.shape == (CFG["batch"], CLASSES)


def test_init_deterministic():
    a = M.init_params(CFG, CLASSES, seed=11)
    b = M.init_params(CFG, CLASSES, seed=11)
    c = M.init_params(CFG, CLASSES, seed=12)
    for x, y in zip(a, b):
        assert_allclose(np.asarray(x), np.asarray(y), atol=0)
    assert float(jnp.max(jnp.abs(a[0] - c[0]))) > 0.0


def test_init_layernorm_gains_are_one(params):
    enc, _, _ = params
    # First LN gain of block 1 sits right after the embed params.
    off = M.embed_size(CFG)
    ln1_g = enc[off:off + CFG["dim"]]
    assert_allclose(np.asarray(ln1_g), np.ones(CFG["dim"], np.float32), atol=0)


def test_training_reduces_local_loss(params, batch):
    """A few Phase-1 SGD steps on one batch must reduce the local loss."""
    enc, _, clf_c = params
    x, y = batch
    d = 2
    ne = M.enc_size(CFG, d)
    enc_d = enc[:ne]
    fn = jax.jit(M.make_client_local(CFG, d, CLASSES))
    lr = 0.5
    losses = []
    for _ in range(8):
        _, loss, g_enc, g_clf = fn(enc_d, clf_c, x, y)
        losses.append(float(loss))
        enc_d = enc_d - lr * g_enc
        clf_c = clf_c - lr * g_clf
    assert losses[-1] < losses[0]


def test_tpgf_artifact_fn_matches_ref(params):
    from compile.kernels import ref as R
    enc, _, _ = params
    d = 2
    ne = M.enc_size(CFG, d)
    theta = enc[:ne]
    key = jax.random.PRNGKey(0)
    gc = jax.random.normal(key, (ne,), jnp.float32)
    gs = gc[::-1]
    lc, ls, lr = jnp.float32(0.9), jnp.float32(1.7), jnp.float32(0.05)
    (out,) = M.make_tpgf(CFG, d)(theta, gc, gs, lc, ls, lr)
    exp = R.tpgf_update_ref(theta, gc, gs, lc, ls, lr, d, CFG["depth"] - d)
    assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6, rtol=1e-5)
