"""L1 correctness: Pallas flash-attention vs the pure-jnp oracle.

Hypothesis sweeps shapes and q-tile sizes (including non-dividing tiles that
force padding + masking) for both the forward pass and the custom-vjp
backward kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.attention import attention

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def test_fwd_matches_ref_basic():
    q, k, v = (_rand(i, (4, 65, 32)) for i in range(3))
    out = attention(q, k, v, 16)
    assert_allclose(np.asarray(out), np.asarray(ref.attention_ref(q, k, v)),
                    atol=2e-5, rtol=2e-5)


def test_fwd_single_tile_covers_sequence():
    # block_q >= T: one q-tile, pure padding-mask path.
    q, k, v = (_rand(i, (2, 7, 8)) for i in range(3))
    out = attention(q, k, v, 128)
    assert_allclose(np.asarray(out), np.asarray(ref.attention_ref(q, k, v)),
                    atol=2e-5, rtol=2e-5)


def test_fwd_tile_exactly_divides():
    q, k, v = (_rand(i, (2, 64, 16)) for i in range(3))
    out = attention(q, k, v, 16)
    assert_allclose(np.asarray(out), np.asarray(ref.attention_ref(q, k, v)),
                    atol=2e-5, rtol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    bh=st.integers(1, 4),
    t=st.integers(2, 40),
    hd=st.sampled_from([4, 8, 16]),
    bq=st.sampled_from([4, 8, 16, 64]),
    seed=st.integers(0, 2**16),
)
def test_fwd_matches_ref_hypothesis(bh, t, hd, bq, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (bh, t, hd), jnp.float32)
    k = jax.random.normal(kk, (bh, t, hd), jnp.float32)
    v = jax.random.normal(kv, (bh, t, hd), jnp.float32)
    out = attention(q, k, v, bq)
    assert_allclose(np.asarray(out), np.asarray(ref.attention_ref(q, k, v)),
                    atol=3e-5, rtol=3e-5)


def test_fwd_softmax_rows_weighted_average():
    # Attention output rows lie in the convex hull of V rows: with constant
    # V the output must be exactly that constant.
    q, k = (_rand(i, (2, 10, 8)) for i in range(2))
    v = jnp.ones((2, 10, 8), jnp.float32) * 3.5
    out = attention(q, k, v, 4)
    assert_allclose(np.asarray(out), np.full((2, 10, 8), 3.5), atol=1e-5)


def test_bwd_matches_ref_grads():
    q, k, v = (_rand(i + 10, (3, 33, 16)) for i in range(3))

    def loss_kernel(q, k, v):
        return jnp.sum(jnp.tanh(attention(q, k, v, 8)))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.tanh(ref.attention_ref(q, k, v)))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5, rtol=5e-5)


@settings(max_examples=8, deadline=None)
@given(
    bh=st.integers(1, 3),
    t=st.integers(2, 24),
    hd=st.sampled_from([4, 8]),
    bq=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**16),
)
def test_bwd_matches_ref_hypothesis(bh, t, hd, bq, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kw = jax.random.split(key, 4)
    q = jax.random.normal(kq, (bh, t, hd), jnp.float32)
    k = jax.random.normal(kk, (bh, t, hd), jnp.float32)
    v = jax.random.normal(kv, (bh, t, hd), jnp.float32)
    w = jax.random.normal(kw, (bh, t, hd), jnp.float32)

    gk = jax.grad(lambda *a: jnp.sum(attention(*a, bq) * w), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: jnp.sum(ref.attention_ref(*a) * w), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_bwd_zero_cotangent_gives_zero_grads():
    q, k, v = (_rand(i, (2, 9, 4)) for i in range(3))
    g = jax.grad(lambda *a: jnp.sum(attention(*a, 4) * 0.0), argnums=(0, 1, 2))(q, k, v)
    for a in g:
        assert float(jnp.max(jnp.abs(a))) == 0.0


def test_fwd_jit_and_nojit_agree():
    q, k, v = (_rand(i, (2, 17, 8)) for i in range(3))
    eager = attention(q, k, v, 8)
    jitted = jax.jit(lambda q, k, v: attention(q, k, v, 8))(q, k, v)
    assert_allclose(np.asarray(eager), np.asarray(jitted), atol=1e-6)


def test_fwd_rejects_scale_dependence():
    # Doubling head_dim scaling: output must equal softmax(QK^T/sqrt(hd))V,
    # i.e. multiplying Q by c and K by 1/c leaves the output unchanged.
    q, k, v = (_rand(i, (1, 12, 8)) for i in range(3))
    o1 = attention(q, k, v, 4)
    o2 = attention(q * 2.0, k / 2.0, v, 4)
    assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5, rtol=2e-5)
