"""AOT pipeline: lower every L2 entry point to HLO *text* + manifest.

Build-time only (``make artifacts``). For each legal split depth d ∈ [1, L-1]
(and each class-count variant) this emits one HLO text file per entry point
listed in DESIGN.md §3, plus:

  * ``manifest.json``  — model geometry, per-layer encoder segmentation,
    and the full artifact table (file, inputs, outputs with shapes/dtypes)
    that the Rust runtime loads at startup;
  * ``init_*.bin``     — deterministic initial parameters as raw
    little-endian f32, so Rust and Python start from identical weights.

HLO **text** (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(fn, *specs) -> str:
    """jit → lower → StableHLO → XlaComputation → HLO text."""
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def build_artifacts(cfg, out_dir: str, verbose: bool = True):
    """Lower the full artifact set for the given build profile."""
    os.makedirs(out_dir, exist_ok=True)
    L = cfg["depth"]
    B = cfg["batch"]
    BE = cfg["eval_batch"]
    T = M.tokens(cfg)
    D = cfg["dim"]
    img = (cfg["image_size"], cfg["image_size"], cfg["channels"])

    artifacts = {}

    def emit(name, fn, specs, inputs, outputs):
        t0 = time.time()
        text = to_hlo_text(fn, *specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        artifacts[name] = {"file": fname, "inputs": inputs, "outputs": outputs}
        if verbose:
            print(f"  {name}: {len(text)/1e3:.0f} kB in {time.time()-t0:.1f}s",
                  flush=True)

    x_spec = _spec((B,) + img)
    y_spec = _spec((B,), jnp.int32)
    z_shape = (B, T, D)

    for d in range(1, L):
        ne = M.enc_size(cfg, d)
        ns = M.srv_size(cfg, d)

        emit(
            f"client_fwd_d{d}",
            M.make_client_fwd(cfg, d),
            (_spec((ne,)), x_spec),
            [_io("enc", (ne,)), _io("x", (B,) + img)],
            [_io("z", z_shape)],
        )
        emit(
            f"client_bwd_d{d}",
            M.make_client_bwd(cfg, d),
            (_spec((ne,)), x_spec, _spec(z_shape)),
            [_io("enc", (ne,)), _io("x", (B,) + img), _io("g_z", z_shape)],
            [_io("g_enc", (ne,))],
        )
        emit(
            f"tpgf_update_d{d}",
            M.make_tpgf(cfg, d),
            (_spec((ne,)), _spec((ne,)), _spec((ne,)),
             _spec(()), _spec(()), _spec(())),
            [_io("theta", (ne,)), _io("g_c", (ne,)), _io("g_s", (ne,)),
             _io("l_c", ()), _io("l_s", ()), _io("lr", ())],
            [_io("theta_new", (ne,))],
        )
        for c in cfg["classes_variants"]:
            ncc = M.clf_client_size(cfg, c)
            ncs = M.clf_server_size(cfg, c)
            emit(
                f"client_local_d{d}_c{c}",
                M.make_client_local(cfg, d, c),
                (_spec((ne,)), _spec((ncc,)), x_spec, y_spec),
                [_io("enc", (ne,)), _io("clf", (ncc,)),
                 _io("x", (B,) + img), _io("y", (B,), "i32")],
                [_io("z", z_shape), _io("loss", ()),
                 _io("g_enc", (ne,)), _io("g_clf", (ncc,))],
            )
            emit(
                f"server_step_d{d}_c{c}",
                M.make_server_step(cfg, d, c),
                (_spec((ns,)), _spec((ncs,)), _spec(z_shape), y_spec),
                [_io("srv", (ns,)), _io("clf_s", (ncs,)),
                 _io("z", z_shape), _io("y", (B,), "i32")],
                [_io("loss", ()), _io("g_srv", (ns,)),
                 _io("g_clf_s", (ncs,)), _io("g_z", z_shape)],
            )

    for c in cfg["classes_variants"]:
        ncs = M.clf_server_size(cfg, c)
        nef = M.enc_size(cfg, L)
        emit(
            f"eval_c{c}",
            M.make_eval(cfg, c),
            (_spec((nef,)), _spec((ncs,)), _spec((BE,) + img)),
            [_io("enc_full", (nef,)), _io("clf_s", (ncs,)),
             _io("x", (BE,) + img)],
            [_io("logits", (BE, c))],
        )

    # Deterministic initial parameters (shared Rust/Python starting point).
    init_files = {}
    for c in cfg["classes_variants"]:
        enc, clf_s, clf_c = M.init_params(cfg, c, cfg["seed"])
        for tag, arr in [
            (f"init_enc_c{c}", enc),
            (f"init_clf_s_c{c}", clf_s),
            (f"init_clf_client_c{c}", clf_c),
        ]:
            fname = f"{tag}.bin"
            np.asarray(arr, dtype="<f4").tofile(os.path.join(out_dir, fname))
            init_files[tag] = {"file": fname, "len": int(arr.size)}

    manifest = {
        "build": cfg,
        "model": {
            "tokens": T,
            "dim": D,
            "depth": L,
            "batch": B,
            "eval_batch": BE,
            "embed_size": M.embed_size(cfg),
            "block_size": M.block_size(cfg),
            "enc_layer_sizes": M.enc_layer_sizes(cfg),
            "enc_full_size": M.enc_size(cfg, L),
            "srv_sizes": {str(d): M.srv_size(cfg, d) for d in range(1, L)},
            "enc_sizes": {str(d): M.enc_size(cfg, d) for d in range(1, L + 1)},
            "clf_client_sizes": {str(c): M.clf_client_size(cfg, c)
                                 for c in cfg["classes_variants"]},
            "clf_server_sizes": {str(c): M.clf_server_size(cfg, c)
                                 for c in cfg["classes_variants"]},
        },
        "init": init_files,
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {len(artifacts)} artifacts + manifest to {out_dir}")
    return manifest


def main():
    ap = argparse.ArgumentParser(description="SuperSFL AOT artifact builder")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--config", default=None, help="build_config.json override")
    args = ap.parse_args()
    cfg = M.load_build_config(args.config)
    build_artifacts(cfg, args.out)


if __name__ == "__main__":
    main()
