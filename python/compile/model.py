"""L2: the weight-sharing super-network — a split-aware ViT in JAX.

The global backbone is a Vision Transformer whose *splitting unit* is the
transformer block: layer 1 bundles the patch embedding with block 1, layers
2..L are blocks 2..L. A client of depth ``d`` runs layers 1..d (a contiguous
prefix, paper §II-A); the server runs blocks d+1..L plus the final
LayerNorm + CLS head. Each client additionally carries a lightweight local
classifier (LayerNorm + mean-pool + linear over the smashed data) used for
TPGF Phase 1 and for fault-tolerant fallback (paper §II-B/§II-C).

Everything operates on **flat f32 parameter vectors** — the calling
convention shared with the Rust coordinator (DESIGN.md §3). The per-layer
segmentation of the encoder vector (needed by the Rust side for
layer-aligned aggregation, Eq. 8) is exported via :func:`enc_layer_sizes`.

All entry points are pure functions built by ``make_*`` factories; they are
traced and AOT-lowered once by ``aot.py`` and never run in the request path.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import attention
from .kernels.ref import clip_by_l2
from .kernels.tpgf import tpgf_update

Shape = Tuple[int, ...]

_HERE = os.path.dirname(__file__)


def load_build_config(path: str | None = None) -> Dict[str, Any]:
    """Load the build-time model profile (shapes are static per build)."""
    with open(path or os.path.join(_HERE, "build_config.json")) as f:
        return json.load(f)


# --------------------------------------------------------------------------
# Parameter layout
# --------------------------------------------------------------------------

def tokens(cfg) -> int:
    """Sequence length: (img/patch)² patches + 1 CLS token."""
    n = (cfg["image_size"] // cfg["patch_size"]) ** 2
    return n + 1


def embed_shapes(cfg) -> List[Tuple[str, Shape]]:
    p, c, d = cfg["patch_size"], cfg["channels"], cfg["dim"]
    return [
        ("wpatch", (p * p * c, d)),
        ("bpatch", (d,)),
        ("cls", (d,)),
        ("pos", (tokens(cfg), d)),
    ]


def block_shapes(cfg) -> List[Tuple[str, Shape]]:
    d = cfg["dim"]
    m = cfg["mlp_ratio"] * d
    return [
        ("ln1_g", (d,)), ("ln1_b", (d,)),
        ("wqkv", (d, 3 * d)), ("bqkv", (3 * d,)),
        ("wo", (d, d)), ("bo", (d,)),
        ("ln2_g", (d,)), ("ln2_b", (d,)),
        ("w1", (d, m)), ("b1", (m,)),
        ("w2", (m, d)), ("b2", (d,)),
    ]


def clf_client_shapes(cfg, classes: int) -> List[Tuple[str, Shape]]:
    d = cfg["dim"]
    return [("ln_g", (d,)), ("ln_b", (d,)), ("w", (d, classes)), ("b", (classes,))]


def clf_server_shapes(cfg, classes: int) -> List[Tuple[str, Shape]]:
    d = cfg["dim"]
    return [("lnf_g", (d,)), ("lnf_b", (d,)), ("w", (d, classes)), ("b", (classes,))]


def _size(shapes) -> int:
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s in shapes)


def embed_size(cfg) -> int:
    return _size(embed_shapes(cfg))


def block_size(cfg) -> int:
    return _size(block_shapes(cfg))


def enc_size(cfg, depth: int) -> int:
    """Flat size of a depth-``depth`` encoder prefix."""
    return embed_size(cfg) + depth * block_size(cfg)


def srv_size(cfg, depth: int) -> int:
    """Flat size of the server suffix for client depth ``depth``."""
    return (cfg["depth"] - depth) * block_size(cfg)


def clf_client_size(cfg, classes: int) -> int:
    return _size(clf_client_shapes(cfg, classes))


def clf_server_size(cfg, classes: int) -> int:
    return _size(clf_server_shapes(cfg, classes))


def enc_layer_sizes(cfg) -> List[int]:
    """Per-layer segment lengths of the full encoder flat vector.

    Layer 1 = patch embedding + block 1; layers 2..L = one block each.
    The Rust fed-server uses these offsets for layer-aligned aggregation.
    """
    bs = block_size(cfg)
    return [embed_size(cfg) + bs] + [bs] * (cfg["depth"] - 1)


def _unflatten(flat: jax.Array, shapes: List[Tuple[str, Shape]], off: int = 0):
    """Slice a flat vector into named arrays (static offsets; jit-friendly)."""
    out = {}
    for name, shp in shapes:
        n = 1
        for s in shp:
            n *= s
        out[name] = flat[off:off + n].reshape(shp)
        off += n
    return out, off


# --------------------------------------------------------------------------
# Forward pieces
# --------------------------------------------------------------------------

def _layernorm(x, g, b, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _patchify(cfg, x):
    """[B, H, W, C] → [B, T-1, P·P·C] row-major patch extraction."""
    b = x.shape[0]
    hw = cfg["image_size"]
    p = cfg["patch_size"]
    c = cfg["channels"]
    g = hw // p
    x = x.reshape(b, g, p, g, p, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, g * g, p * p * c)


def _embed(cfg, ep, x):
    tok = _patchify(cfg, x) @ ep["wpatch"] + ep["bpatch"]
    b = tok.shape[0]
    cls = jnp.broadcast_to(ep["cls"], (b, 1, cfg["dim"]))
    tok = jnp.concatenate([cls, tok], axis=1)
    return tok + ep["pos"]


def _block(cfg, bp, x):
    b, t, d = x.shape
    h = cfg["heads"]
    hd = d // h
    y = _layernorm(x, bp["ln1_g"], bp["ln1_b"])
    qkv = y @ bp["wqkv"] + bp["bqkv"]                     # [B, T, 3D]
    qkv = qkv.reshape(b, t, 3, h, hd).transpose(2, 0, 3, 1, 4)  # [3, B, H, T, hd]
    q, k, v = (a.reshape(b * h, t, hd) for a in (qkv[0], qkv[1], qkv[2]))
    # L1 Pallas kernel. block_bh=0 → one panel-sized grid step: under
    # interpret=True each grid step lowers to a while-loop iteration of
    # plain HLO, so the AOT build uses the fewest, largest steps (see
    # kernels/attention.py docstring; real-TPU tiling analysed in
    # DESIGN.md §Perf).
    att = attention(q, k, v, cfg["attn_block_q"], cfg.get("attn_block_bh", 0))
    att = att.reshape(b, h, t, hd).transpose(0, 2, 1, 3).reshape(b, t, d)
    x = x + att @ bp["wo"] + bp["bo"]
    y = _layernorm(x, bp["ln2_g"], bp["ln2_b"])
    x = x + jax.nn.gelu(y @ bp["w1"] + bp["b1"]) @ bp["w2"] + bp["b2"]
    return x


def _apply_blocks(cfg, flat, n_blocks: int, x, off: int = 0):
    for _ in range(n_blocks):
        bp, off = _unflatten(flat, block_shapes(cfg), off)
        x = _block(cfg, bp, x)
    return x


def client_fwd(cfg, depth: int, enc_flat, x):
    """Layers 1..depth: patch embed + ``depth`` blocks → smashed data z."""
    ep, off = _unflatten(enc_flat, embed_shapes(cfg))
    z = _embed(cfg, ep, x)
    return _apply_blocks(cfg, enc_flat, depth, z, off)


def client_head(cfg, classes: int, clf_flat, z):
    """Local classifier h_φᵢ: LayerNorm → mean-pool → linear (paper Eq. 5)."""
    cp, _ = _unflatten(clf_flat, clf_client_shapes(cfg, classes))
    h = _layernorm(z, cp["ln_g"], cp["ln_b"])
    h = jnp.mean(h, axis=1)
    return h @ cp["w"] + cp["b"]


def server_apply(cfg, depth: int, srv_flat, z):
    """Server suffix: blocks depth+1..L over the smashed data."""
    return _apply_blocks(cfg, srv_flat, cfg["depth"] - depth, z)


def server_head(cfg, classes: int, clf_s_flat, h):
    """Server classifier h_φₛ: final LayerNorm → CLS token → linear."""
    cp, _ = _unflatten(clf_s_flat, clf_server_shapes(cfg, classes))
    h = _layernorm(h, cp["lnf_g"], cp["lnf_b"])
    return h[:, 0, :] @ cp["w"] + cp["b"]


def cross_entropy(logits, y):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


# --------------------------------------------------------------------------
# AOT entry-point factories (one artifact each; see DESIGN.md §3)
# --------------------------------------------------------------------------

def make_client_fwd(cfg, depth: int):
    """(enc_d, x) → (z,) — plain split-learning client forward (SFL/DFL)."""
    def fn(enc, x):
        return (client_fwd(cfg, depth, enc, x),)
    return fn


def make_client_local(cfg, depth: int, classes: int):
    """(enc_d, clf, x, y) → (z, L_client, g_enc_clipped, g_clf).

    TPGF Phase 1 (Alg. 2 lines 3-7) and the entire fallback step (Alg. 3):
    smashed data, local loss, τ-clipped encoder gradient, classifier grad.
    """
    tau = cfg["clip_tau"]

    def fn(enc, clf, x, y):
        def lossfn(enc_, clf_):
            z = client_fwd(cfg, depth, enc_, x)
            logits = client_head(cfg, classes, clf_, z)
            return cross_entropy(logits, y), z

        (loss, z), (g_enc, g_clf) = jax.value_and_grad(
            lossfn, argnums=(0, 1), has_aux=True
        )(enc, clf)
        return z, loss, clip_by_l2(g_enc, tau), g_clf
    return fn


def make_client_bwd(cfg, depth: int):
    """(enc_d, x, g_z) → (g_enc,) — TPGF Phase 2 client-side backprop."""
    def fn(enc, x, g_z):
        _, vjp = jax.vjp(lambda e: client_fwd(cfg, depth, e, x), enc)
        (g_enc,) = vjp(g_z)
        return (g_enc,)
    return fn


def make_server_step(cfg, depth: int, classes: int):
    """(srv_d, clf_s, z, y) → (L_server, g_srv, g_clf_s, g_z).

    TPGF Phase 2 server side (Alg. 2 lines 9-12): deep forward, loss,
    gradients for the server suffix + head, and the smashed-data gradient
    returned to the client.
    """
    def fn(srv, clf_s, z, y):
        def lossfn(srv_, clf_s_, z_):
            h = server_apply(cfg, depth, srv_, z_)
            logits = server_head(cfg, classes, clf_s_, h)
            return cross_entropy(logits, y)

        loss, (g_srv, g_clf_s, g_z) = jax.value_and_grad(
            lossfn, argnums=(0, 1, 2)
        )(srv, clf_s, z)
        return loss, g_srv, g_clf_s, g_z
    return fn


def make_eval(cfg, classes: int):
    """(enc_full, clf_s, x) → (logits,) — full-model test-set forward."""
    depth = cfg["depth"]

    def fn(enc_full, clf_s, x):
        h = client_fwd(cfg, depth, enc_full, x)
        return (server_head(cfg, classes, clf_s, h),)
    return fn


def make_tpgf(cfg, depth: int):
    """(θ, g_c, g_s, L_c, L_s, lr) → (θ',) — Phase 3 via the Pallas kernel."""
    d_s = cfg["depth"] - depth

    def fn(theta, g_c, g_s, l_c, l_s, lr):
        return (tpgf_update(theta, g_c, g_s, l_c, l_s, lr, depth, d_s),)
    return fn


# --------------------------------------------------------------------------
# Initialization (written to artifacts/*.bin for the Rust side)
# --------------------------------------------------------------------------

def _init_shapes(key, shapes: List[Tuple[str, Shape]]) -> jax.Array:
    """LeCun-normal weights, zero biases, unit LN gains — flattened."""
    chunks = []
    for name, shp in shapes:
        key, sub = jax.random.split(key)
        if name.startswith(("ln", "lnf")) and name.endswith("_g"):
            a = jnp.ones(shp, jnp.float32)
        elif len(shp) == 1 and name != "cls":
            a = jnp.zeros(shp, jnp.float32)
        elif name == "pos" or name == "cls":
            a = 0.02 * jax.random.normal(sub, shp, jnp.float32)
        else:
            fan_in = shp[0] if len(shp) > 1 else 1
            a = jax.random.normal(sub, shp, jnp.float32) / jnp.sqrt(
                jnp.float32(max(fan_in, 1))
            )
        chunks.append(a.reshape(-1))
    return jnp.concatenate(chunks)


def init_params(cfg, classes: int, seed: int):
    """Initial global parameters: full encoder, server head, client head."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    shapes = list(embed_shapes(cfg))
    for _ in range(cfg["depth"]):
        shapes += block_shapes(cfg)
    enc = _init_shapes(k1, shapes)
    clf_s = _init_shapes(k2, clf_server_shapes(cfg, classes))
    clf_c = _init_shapes(k3, clf_client_shapes(cfg, classes))
    return enc, clf_s, clf_c
