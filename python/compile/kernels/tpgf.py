"""L1: the TPGF fused encoder update (paper Eq. 3-4) as a Pallas kernel.

Phase 3 of Three-Phase Gradient Fusion combines the clipped Phase-1 local
gradient with the Phase-2 server-originated gradient using a
depth-aware × inverse-loss weighting, then applies the SGD step — all in a
single pass over the flat encoder parameter vector:

    w_c = d_i/(d_i+d_s) · (L_c+ε)⁻¹ / ((L_c+ε)⁻¹ + (L_s+ε)⁻¹)
    θ' = θ − lr · (w_c·g_c + (1−w_c)·g_s)

TPU adaptation: a pure element-wise VPU kernel over 1-D tiles of the flat
vector; the scalar operands (losses, lr) enter as ``(1, 1)`` SMEM-style
blocks broadcast to every tile, and the depth ratio is a compile-time
constant (one artifact per split depth). Fusing weight-computation, blend
and SGD into one kernel means θ, g_c, g_s are each read exactly once from
HBM and θ' written once — the minimum possible traffic (4N floats) for this
update. ``interpret=True`` for CPU-PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-8


def _tpgf_kernel(theta_ref, gc_ref, gs_ref, lc_ref, ls_ref, lr_ref, out_ref,
                 *, depth_ratio: float, eps: float):
    """One 1-D tile: blend the two gradients and take the SGD step."""
    l_c = lc_ref[0, 0]
    l_s = ls_ref[0, 0]
    lr = lr_ref[0, 0]
    inv_c = 1.0 / (l_c + eps)
    inv_s = 1.0 / (l_s + eps)
    w_c = depth_ratio * inv_c / (inv_c + inv_s)
    g = w_c * gc_ref[...] + (1.0 - w_c) * gs_ref[...]
    out_ref[...] = theta_ref[...] - lr * g


def tpgf_update(
    theta: jax.Array,
    g_client: jax.Array,
    g_server: jax.Array,
    l_client: jax.Array,
    l_server: jax.Array,
    lr: jax.Array,
    d_i: int,
    d_s: int,
    block: int = 65536,
    eps: float = EPS,
) -> jax.Array:
    """Fused TPGF update over a flat ``[N]`` f32 parameter vector.

    ``d_i``/``d_s`` (client/server depths) are static — the AOT step emits
    one artifact per legal split depth. Scalars ``l_client``, ``l_server``,
    ``lr`` are 0-d arrays. Matches :func:`.ref.tpgf_update_ref`.
    """
    n = theta.shape[0]
    npad = ((n + block - 1) // block) * block
    nblk = npad // block

    def pad(x):
        return jnp.pad(x, (0, npad - n)) if npad != n else x

    theta_p, gc_p, gs_p = pad(theta), pad(g_client), pad(g_server)
    lc2 = jnp.reshape(l_client.astype(jnp.float32), (1, 1))
    ls2 = jnp.reshape(l_server.astype(jnp.float32), (1, 1))
    lr2 = jnp.reshape(jnp.asarray(lr, jnp.float32), (1, 1))

    depth_ratio = float(d_i) / float(d_i + d_s)
    out = pl.pallas_call(
        functools.partial(_tpgf_kernel, depth_ratio=depth_ratio, eps=eps),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=True,
    )(theta_p, gc_p, gs_p, lc2, ls2, lr2)
    return out[:n]
