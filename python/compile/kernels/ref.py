"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` ops only. pytest (and hypothesis sweeps)
assert ``assert_allclose(kernel(...), ref(...))`` over shapes/dtypes; the
reference is also what the L2 model would compute if the kernels were
disabled, so any divergence is a kernel bug by definition.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Scaled dot-product attention oracle.

    Args:
      q, k, v: ``[BH, T, hd]`` — batch*heads folded into the leading dim.

    Returns:
      ``[BH, T, hd]`` attention output, f32.
    """
    hd = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(jnp.float32(hd))
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def clip_by_l2(g: jax.Array, tau: float, eps: float = 1e-12) -> jax.Array:
    """l2-norm gradient clipping (paper §II-B Phase 1, tau = 0.5).

    ``g`` is scaled by ``min(1, tau / ||g||_2)``; identical semantics to
    ``torch.nn.utils.clip_grad_norm_`` on a single flat vector.
    """
    norm = jnp.sqrt(jnp.sum(g * g) + eps)
    scale = jnp.minimum(1.0, tau / jnp.maximum(norm, eps))
    return g * scale


def tpgf_client_weight(
    l_client: jax.Array,
    l_server: jax.Array,
    d_i: int,
    d_s: int,
    eps: float = 1e-8,
):
    """TPGF fusion weight, Eq. (3) of the paper.

    w_client = d_i/(d_i+d_s)
             * inv(L_client+eps) / (inv(L_client+eps) + inv(L_server+eps))
    """
    depth = jnp.float32(d_i) / jnp.float32(d_i + d_s)
    inv_c = 1.0 / (l_client + eps)
    inv_s = 1.0 / (l_server + eps)
    return depth * inv_c / (inv_c + inv_s)


def tpgf_update_ref(
    theta: jax.Array,
    g_client: jax.Array,
    g_server: jax.Array,
    l_client: jax.Array,
    l_server: jax.Array,
    lr: jax.Array,
    d_i: int,
    d_s: int,
    eps: float = 1e-8,
) -> jax.Array:
    """Fused TPGF encoder update, Eq. (3)-(4): theta' = theta - lr * g_fused.

    ``g_client`` is assumed to be the already-clipped Phase-1 gradient (the
    clip happens inside the ``client_local`` artifact via :func:`clip_by_l2`).
    """
    w_c = tpgf_client_weight(l_client, l_server, d_i, d_s, eps)
    g = w_c * g_client + (1.0 - w_c) * g_server
    return theta - lr * g


def sgd_ref(theta: jax.Array, g: jax.Array, lr: jax.Array) -> jax.Array:
    """Plain SGD step oracle (used for classifier / server-suffix updates)."""
    return theta - lr * g


def layernorm_ref(x: jax.Array, g: jax.Array, b: jax.Array, eps: float = 1e-6) -> jax.Array:
    """LayerNorm over the trailing feature dim (oracle for model tests)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b
