"""L1: tiled (flash-style) multi-head attention as Pallas kernels.

The ViT backbone's compute hot-spot. Implements the numerically-stable
streaming-softmax attention in the forward pass and the standard
flash-attention backward (recompute-P from the saved logsumexp) — both as
Pallas kernels, stitched together with ``jax.custom_vjp`` so the L2 model
can differentiate straight through them.

TPU adaptation of the paper's GPU setting (see DESIGN.md §6):
  * the grid walks ``(batch·head tiles, q tiles)``; each step sees a
    ``(block_bh, block_q, head_dim)`` Q tile against the K/V panels for its
    batch·head tile, held in VMEM via ``BlockSpec`` and reused across all
    q-tiles — the Pallas analogue of a CUDA kernel parking K/V in
    L2/shared memory;
  * both tile contractions (QKᵀ and PV) are batched f32 MXU matmuls
    (``preferred_element_type=float32``);
  * sequence and batch·head dims are padded to tile multiples; padded keys
    are masked with −inf inside the tile so no attention weight leaks.

``block_bh`` trades grid-step count against per-step working-set size. On
real TPU hardware small tiles keep the working set inside VMEM; under
``interpret=True`` on CPU (mandatory here — the CPU PJRT plugin cannot run
Mosaic custom-calls) every grid step lowers to one while-loop iteration of
plain HLO, so the AOT build uses one panel-sized step (``block_bh = BH``)
and the hypothesis suite sweeps small tiles to validate the tiling logic.
Real-TPU perf is estimated from the block shapes in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _pad_to(x: jax.Array, axis: int, target: int) -> jax.Array:
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _bdot(a, b, contract, batch=((0,), (0,))):
    """Batched f32 contraction on the MXU."""
    return jax.lax.dot_general(
        a, b, (contract, batch), preferred_element_type=jnp.float32
    )


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, seq_len: int, scale: float):
    """One (bh-tile, q-tile) grid step of the forward pass.

    Block shapes: q ``(bbh, bq, hd)``; k/v ``(bbh, Tp, hd)`` (full key
    panel); o ``(bbh, bq, hd)``; lse ``(bbh, bq)``.
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]

    # s[b, i, j] = q[b, i, :] · k[b, j, :]  — QKᵀ on the MXU.
    s = _bdot(q, k, ((2,), (2,))) * scale  # [bbh, bq, Tp]

    # Mask padded key positions (>= seq_len) so they carry zero weight.
    tp = k.shape[1]
    key_idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    s = jnp.where(key_idx < seq_len, s, NEG_INF)

    m = jnp.max(s, axis=-1, keepdims=True)  # [bbh, bq, 1]
    m = jnp.maximum(m, -1e30)  # keep padded q-rows finite
    p = jnp.exp(s - m)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)

    o = _bdot(p, v, ((2,), (1,)))  # [bbh, bq, hd] — PV on the MXU
    o_ref[...] = o / l
    lse_ref[...] = (m + jnp.log(l))[:, :, 0]


def _bwd_kernel(
    q_ref, k_ref, v_ref, o_ref, do_ref, lse_ref,
    dq_ref, dk_ref, dv_ref, *, seq_len: int, scale: float,
):
    """One (bh-tile, q-tile) grid step of the backward pass.

    dK/dV blocks are indexed only by the bh grid dim, so they are
    revisited by every q-tile step and accumulated in place; they are
    zeroed on the first q-tile (``pl.when(j == 0)``).
    """
    j = pl.program_id(1)

    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    o = o_ref[...]
    do = do_ref[...]
    lse = lse_ref[...]  # [bbh, bq]

    @pl.when(j == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref[...])
        dv_ref[...] = jnp.zeros_like(dv_ref[...])

    s = _bdot(q, k, ((2,), (2,))) * scale  # [bbh, bq, Tp]
    key_idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    s = jnp.where(key_idx < seq_len, s, NEG_INF)

    p = jnp.exp(s - lse[:, :, None])  # recomputed softmax  [bbh, bq, Tp]

    # dv += pᵀ · do  (contract the q dim)
    dv_ref[...] += _bdot(p, do, ((1,), (1,)))
    # dp = do · vᵀ ; ds = p ⊙ (dp − Δ), Δ_r = Σ_d do_{rd} o_{rd}
    dp = _bdot(do, v, ((2,), (2,)))  # [bbh, bq, Tp]
    delta = jnp.sum(do * o, axis=-1, keepdims=True)  # [bbh, bq, 1]
    ds = p * (dp - delta) * scale

    # dq = ds · k ; dk += dsᵀ · q
    dq_ref[...] = _bdot(ds, k, ((2,), (1,)))
    dk_ref[...] += _bdot(ds, q, ((1,), (1,)))


def _tiles(n: int, block: int) -> int:
    return (n + block - 1) // block


def _resolve_blocks(bh: int, t: int, block_q: int, block_bh: int):
    """0 or oversized blocks clamp to the full dim (panel mode)."""
    bq = t if block_q <= 0 else min(block_q, max(t, 1))
    bbh = bh if block_bh <= 0 else min(block_bh, bh)
    tp = _tiles(t, bq) * bq
    bhp = _tiles(bh, bbh) * bbh
    return bq, bbh, tp, bhp


def _attention_fwd_impl(q, k, v, block_q: int, block_bh: int):
    bh, t, hd = q.shape
    scale = 1.0 / (hd ** 0.5)
    bq, bbh, tp, bhp = _resolve_blocks(bh, t, block_q, block_bh)
    nq = tp // bq
    nbh = bhp // bbh

    qp = _pad_to(_pad_to(q, 1, tp), 0, bhp)
    kp = _pad_to(_pad_to(k, 1, tp), 0, bhp)
    vp = _pad_to(_pad_to(v, 1, tp), 0, bhp)

    o, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, seq_len=t, scale=scale),
        grid=(nbh, nq),
        in_specs=[
            pl.BlockSpec((bbh, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bbh, tp, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bbh, tp, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bbh, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bbh, bq), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bhp, tp, hd), jnp.float32),
            jax.ShapeDtypeStruct((bhp, tp), jnp.float32),
        ],
        interpret=True,
    )(qp, kp, vp)
    return o[:bh, :t, :], lse[:bh, :t]


def _attention_bwd_impl(q, k, v, o, lse, do, block_q: int, block_bh: int):
    bh, t, hd = q.shape
    scale = 1.0 / (hd ** 0.5)
    bq, bbh, tp, bhp = _resolve_blocks(bh, t, block_q, block_bh)
    nq = tp // bq
    nbh = bhp // bbh

    qp = _pad_to(_pad_to(q, 1, tp), 0, bhp)
    kp = _pad_to(_pad_to(k, 1, tp), 0, bhp)
    vp = _pad_to(_pad_to(v, 1, tp), 0, bhp)
    op = _pad_to(_pad_to(o, 1, tp), 0, bhp)
    dop = _pad_to(_pad_to(do, 1, tp), 0, bhp)
    # Padded q-rows have garbage lse but zero do, so ds = 0 and nothing
    # leaks into dk/dv. Pad lse with zeros to keep exp() finite.
    lsep = _pad_to(_pad_to(lse, 1, tp), 0, bhp)

    dq, dk, dv = pl.pallas_call(
        functools.partial(_bwd_kernel, seq_len=t, scale=scale),
        grid=(nbh, nq),
        in_specs=[
            pl.BlockSpec((bbh, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bbh, tp, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bbh, tp, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bbh, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bbh, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bbh, bq), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bbh, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bbh, tp, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bbh, tp, hd), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bhp, tp, hd), jnp.float32),
            jax.ShapeDtypeStruct((bhp, tp, hd), jnp.float32),
            jax.ShapeDtypeStruct((bhp, tp, hd), jnp.float32),
        ],
        interpret=True,
    )(qp, kp, vp, op, dop, lsep)
    return dq[:bh, :t, :], dk[:bh, :t, :], dv[:bh, :t, :]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    block_q: int = 128,
    block_bh: int = 0,
) -> jax.Array:
    """Flash-style attention over ``[BH, T, hd]`` with Pallas fwd+bwd kernels.

    Matches :func:`.ref.attention_ref` to ~1e-5. ``block_q`` is the q-tile
    height, ``block_bh`` the batch·head tile (0 = whole dim, panel mode);
    both are static and the inputs are padded up to tile multiples.
    """
    o, _ = _attention_fwd_impl(q, k, v, block_q, block_bh)
    return o


def _attention_vjp_fwd(q, k, v, block_q, block_bh):
    o, lse = _attention_fwd_impl(q, k, v, block_q, block_bh)
    return o, (q, k, v, o, lse)


def _attention_vjp_bwd(block_q, block_bh, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _attention_bwd_impl(q, k, v, o, lse, do, block_q, block_bh)
    return dq, dk, dv


attention.defvjp(_attention_vjp_fwd, _attention_vjp_bwd)
