//! Zero-dependency stand-in for the PJRT `xla` bindings.
//!
//! The supersfl coordinator talks to its AOT-compiled artifacts through a
//! small slice of the `xla` crate surface (PJRT CPU client, HLO-proto
//! compilation, literal marshalling). The real bindings link the PJRT C
//! API library, which is not part of the offline build image — so this
//! crate provides the exact same API shape with a backend that fails fast
//! at *client construction* with an explanatory error.
//!
//! The contract this preserves:
//!
//! * Everything downstream of `PjRtClient::cpu()` is unreachable when the
//!   stub is active, because `PjrtBackend::load` propagates the
//!   construction error — and the runtime's `auto` selection then falls
//!   back to the always-available native reference backend, recording the
//!   reason in `RuntimeStats::fallback_reason`.
//! * All types are plain data (`Send + Sync`), so the coordinator's
//!   parallel round engine can rely on `Runtime: Sync` regardless of
//!   backend.
//!
//! To execute real artifacts, patch the `xla` dependency of `supersfl`
//! to a vendored checkout of the PJRT bindings with this same surface.

use std::fmt;

/// Backend error. The stub only ever produces [`Error::unavailable`].
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Error {
        Error(
            "PJRT backend unavailable: supersfl was built against the bundled \
             `xla` stub crate. Vendor the real PJRT bindings (patch the `xla` \
             path dependency in rust/Cargo.toml) to execute artifacts."
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the literal marshaller accepts.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// PJRT client handle. The stub cannot construct one.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Create the CPU client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module text.
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

/// A computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals. Returns one buffer list
    /// per device (the coordinator uses `[0][0]`).
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// A device-resident result buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// A host literal (tensor value + shape).
#[derive(Debug)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// 0-d f32 scalar.
    pub fn scalar(_v: f32) -> Literal {
        Literal { _priv: () }
    }

    /// 1-d literal from a flat slice.
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal { _priv: () }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _priv: () })
    }

    /// Unpack a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    /// Copy out as a flat vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_fails_with_explanatory_error() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("PJRT backend unavailable"), "{msg}");
        assert!(msg.contains("stub"), "{msg}");
    }

    #[test]
    fn literal_constructors_are_usable() {
        // Marshalling helpers must not panic: the coordinator builds
        // literals before dispatch (even though dispatch itself is
        // unreachable with the stub, unit tests exercise the builders).
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_ok());
        let s = Literal::scalar(3.5);
        assert!(s.reshape(&[]).is_ok());
        let i = Literal::vec1(&[1i32, 2, 3]);
        assert!(i.reshape(&[3]).is_ok());
    }

    #[test]
    fn stub_types_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
        assert_send_sync::<PjRtClient>();
        assert_send_sync::<PjRtLoadedExecutable>();
        assert_send_sync::<Literal>();
        assert_send_sync::<PjRtBuffer>();
    }
}
