//! Wire-codec microbench: encode/decode throughput and round-trip error
//! for every payload codec, on the two tensor shapes that dominate the
//! protocol (the per-step smashed-activation tensor and a typical
//! subnetwork upload). Always runs — pure CPU, no artifacts, no backend.
//!
//! `SUPERSFL_SMOKE=1` shrinks the iteration counts to a CI-sized run.

use supersfl::bench_util::{black_box, measure, report, throughput};
use supersfl::metrics::Table;
use supersfl::util::rng::Pcg32;
use supersfl::wire::{MsgType, Wire, WireCodecKind};

fn main() {
    let smoke = std::env::var("SUPERSFL_SMOKE").ok().as_deref() == Some("1");
    let (warmup, iters) = if smoke { (1, 5) } else { (3, 40) };

    let mut rng = Pcg32::seeded(0xBEEF);
    // Native-model smashed tensor [8, 16, 32] and a depth-4 subnetwork
    // upload (prefix + classifier) — representative, not load-bearing.
    let shapes: &[(&str, MsgType, usize)] = &[
        ("smashed[8x16x32]", MsgType::Smashed, 8 * 16 * 32),
        ("upload[d4+clf]", MsgType::PrefixUpload, 18_752 + 330),
    ];
    let kinds = [
        WireCodecKind::Fp32,
        WireCodecKind::Fp16,
        WireCodecKind::Int8,
        WireCodecKind::TopK(10),
    ];

    println!("== wire codec throughput (frame encode + decode) ==\n");
    let mut table = Table::new(&[
        "codec", "tensor", "frame B", "ratio", "enc MB/s", "dec MB/s", "max |err|",
    ]);

    for &(label, msg, elems) in shapes {
        let data: Vec<f32> = (0..elems).map(|_| rng.normal() as f32).collect();
        let raw_bytes = (4 * elems) as f64;
        for kind in kinds {
            let wire = Wire::new(kind);
            let frame = wire.encode(msg, &data, 0.0);
            let frame_bytes = frame.len() as f64;

            let enc = measure(warmup, iters, || {
                black_box(wire.encode(msg, black_box(&data), 0.0));
            });
            let dec = measure(warmup, iters, || {
                black_box(wire.decode(black_box(&frame)).unwrap());
            });
            report(&format!("encode/{}/{}", kind.label(), label), &enc);
            report(&format!("decode/{}/{}", kind.label(), label), &dec);

            let decoded = wire.decode(&frame).unwrap().data;
            let max_err = data
                .iter()
                .zip(decoded.iter())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);

            table.row(&[
                kind.label(),
                label.to_string(),
                format!("{}", frame.len()),
                format!("{:.2}x", raw_bytes / frame_bytes),
                format!("{:.0}", throughput(&enc, raw_bytes) / 1e6),
                format!("{:.0}", throughput(&dec, raw_bytes) / 1e6),
                format!("{max_err:.5}"),
            ]);
        }
    }

    println!("\n{}", table.render());
    println!(
        "ratio = analytic f32 bytes / encoded frame bytes; fp32 pays only the \
         28-byte frame envelope, topk quantizes parameter frames to int8."
    );
}
