//! Regenerates **Fig. 5**: power-per-accuracy (W/%) and carbon footprint
//! bars per method and dataset. Derived from the same runs as Table II
//! but rendered as the figure's two bar groups.

use supersfl::bench_util::scenarios::{cell_config, efficiency_grid, paper_table2, Scale};
use supersfl::config::{ExperimentConfig, Method};
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;

fn bar(x: f64, unit: f64) -> String {
    "#".repeat(((x / unit).round() as usize).clamp(1, 50))
}

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);
    let scale = Scale::from_env();
    println!("== Fig. 5: consumption-per-accuracy and carbon footprint ==\n");

    for cell in efficiency_grid().into_iter().filter(|c| c.classes == 10) {
        let paper = paper_table2(cell.classes, cell.paper_clients);
        println!("-- C{} ({} clients) --", cell.classes, cell.paper_clients);
        for (mi, method) in [Method::Sfl, Method::Dfl, Method::SuperSfl]
            .into_iter()
            .enumerate()
        {
            let mut cfg = cell_config(&scale, &cell, method, 42);
            cfg.train.target_accuracy = None;
            cfg.train.rounds = scale.rounds_cap.min(10);
            let m = run_experiment(&rt, &cfg)?.metrics;
            println!(
                "  {:<4} W/%: {:>7.2} |{:<30}| CO2 g: {:>8.1} |{:<20}| (paper W/% {:.2})",
                method.as_str().to_uppercase(),
                m.power_per_acc,
                bar(m.power_per_acc, 0.05),
                m.co2_g,
                bar(m.co2_g, 0.5),
                paper[mi].2
            );
        }
        println!();
    }
    println!("shape: SSFL best (lowest) W/% on the 10-class task; SFL worst everywhere.");
    Ok(())
}
