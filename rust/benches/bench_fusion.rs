//! Perf microbench + ablation: TPGF Phase-3 fused update, Rust SIMD loop
//! vs the Pallas `tpgf_update` artifact (DESIGN.md §7 design choice).
//!
//! The two paths are numerically interchangeable; this bench quantifies
//! the dispatch-overhead / fusion tradeoff that decides the default
//! (`ssfl.fuse_via_artifact = false`). Feeds EXPERIMENTS.md §Perf.

use supersfl::bench_util::{black_box, measure, report, throughput};
use supersfl::config::{ExperimentConfig, TpgfMode};
use supersfl::runtime::Runtime;
use supersfl::tpgf;
use supersfl::util::math;
use supersfl::util::rng::Pcg32;

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);
    let mut rng = Pcg32::seeded(2);

    println!("== bench_fusion: Rust loop vs Pallas artifact ==");
    for depth in [1usize, 4, 7] {
        let n = rt.model().enc_size(depth);
        let theta: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let gc: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let gs: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();

        // Correctness cross-check first.
        let mut rust_out = theta.clone();
        tpgf::fuse_update(&mut rust_out, &gc, &gs, 1.3, 0.7, depth, 8 - depth, 0.05, TpgfMode::Full);
        let art_out = rt.tpgf_update(depth, &theta, &gc, &gs, 1.3, 0.7, 0.05)?;
        let diff = math::max_abs_diff(&rust_out, &art_out);
        assert!(diff < 1e-5, "paths diverge: {diff}");

        let mut buf = theta.clone();
        let s_rust = measure(3, 60, || {
            buf.copy_from_slice(&theta);
            tpgf::fuse_update(
                &mut buf, &gc, &gs, 1.3, 0.7, depth, 8 - depth, 0.05, TpgfMode::Full,
            );
            black_box(&buf);
        });
        report(&format!("rust_loop_d{depth} ({n} params)"), &s_rust);

        let s_art = measure(2, 12, || {
            black_box(rt.tpgf_update(depth, &theta, &gc, &gs, 1.3, 0.7, 0.05).unwrap());
        });
        report(&format!("pallas_artifact_d{depth} ({n} params)"), &s_art);

        println!(
            "    -> rust {:.2} Gparam/s vs artifact {:.2} Gparam/s (x{:.1} dispatch overhead)",
            throughput(&s_rust, n as f64) / 1e9,
            throughput(&s_art, n as f64) / 1e9,
            s_art.mean_s / s_rust.mean_s
        );
    }
    println!("(max |Δ| between paths < 1e-5 asserted above)");
    Ok(())
}
