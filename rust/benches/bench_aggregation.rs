//! Perf microbench: layer-aligned aggregation throughput (Eq. 6–8).
//!
//! The Fed server aggregates every client prefix each round; this measures
//! the Rust hot loop at fleet sizes 10/50/100/200 over the resolved
//! backend's real model geometry (native fallback makes this run
//! anywhere). Reports the fused in-place pass that ships in
//! `fedserver::aggregate_weighted` against the scratch-buffer reference it
//! replaced — the before/after of the zero-copy aggregation work. Feeds
//! EXPERIMENTS.md §Perf.

use supersfl::bench_util::{black_box, measure, report, throughput};
use supersfl::config::ExperimentConfig;
use supersfl::fedserver::{aggregate, client_weights, ClientUpdate};
use supersfl::runtime::Runtime;
use supersfl::util::math;
use supersfl::util::rng::Pcg32;

/// The pre-optimization reference: per-layer scratch accumulate, then a
/// combine pass reading the server segment (one allocation + two passes).
fn aggregate_scratch_reference(
    global: &mut [f32],
    layer_sizes: &[usize],
    items: &[(usize, &[f32], f64)],
    lambda: f64,
) {
    let mut scratch: Vec<f32> = Vec::new();
    let mut off = 0usize;
    for (layer, &len) in layer_sizes.iter().enumerate() {
        let holders: Vec<usize> = items
            .iter()
            .enumerate()
            .filter(|(_, (depth, _, _))| *depth > layer)
            .map(|(i, _)| i)
            .collect();
        if holders.is_empty() {
            off += len;
            continue;
        }
        scratch.clear();
        scratch.resize(len, 0.0);
        let mut wsum = 0.0f64;
        for &i in &holders {
            let (_, params, w) = &items[i];
            math::axpy(&mut scratch, &params[off..off + len], *w as f32);
            wsum += *w;
        }
        let denom = (wsum + lambda) as f32;
        for (g, s) in global[off..off + len].iter_mut().zip(scratch.iter()) {
            *g = (s + lambda as f32 * *g) / denom;
        }
        off += len;
    }
}

fn main() -> supersfl::Result<()> {
    // The resolved backend's real model geometry.
    let sizes: Vec<usize> =
        Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir)
            .model()
            .enc_layer_sizes
            .clone();
    let total: usize = sizes.iter().sum();
    let depth = sizes.len();
    let mut rng = Pcg32::seeded(1);

    println!(
        "== bench_aggregation: Eq. 8 over {total} params x {depth} layers =="
    );
    for &n_clients in &[10usize, 50, 100, 200] {
        // Heterogeneous depths 1..L-1, random params/losses.
        let depths: Vec<usize> = (0..n_clients).map(|i| 1 + i % (depth - 1)).collect();
        let params: Vec<Vec<f32>> = depths
            .iter()
            .map(|&d| {
                let len: usize = sizes[..d].iter().sum();
                (0..len).map(|_| rng.normal() as f32).collect()
            })
            .collect();
        let losses: Vec<f64> = (0..n_clients).map(|_| rng.uniform_range(0.1, 3.0)).collect();
        let mut global: Vec<f32> = (0..total).map(|_| rng.normal() as f32).collect();
        let touched: f64 = params.iter().map(|p| p.len() as f64).sum();

        let updates: Vec<ClientUpdate<'_>> = (0..n_clients)
            .map(|i| ClientUpdate {
                client: i,
                depth: depths[i],
                params: &params[i],
                loss: losses[i],
            })
            .collect();
        let items: Vec<(usize, &[f32], f64)> = {
            let w = client_weights(&updates, 1e-8);
            (0..n_clients)
                .map(|i| (depths[i], params[i].as_slice(), w[i]))
                .collect()
        };

        // Before: scratch-buffer reference. Same precomputed `items` as
        // the fused measurement so the comparison is symmetric — only the
        // per-layer averaging pass differs between the two timings.
        let s_ref = measure(2, 10, || {
            aggregate_scratch_reference(&mut global, &sizes, &items, 0.01);
            black_box(global.first().copied());
        });
        report(&format!("aggregate n={n_clients} (scratch ref)"), &s_ref);

        // After: the fused in-place pass that ships.
        let s = measure(2, 10, || {
            black_box(supersfl::fedserver::aggregate_weighted(
                &mut global,
                &sizes,
                &items,
                0.01,
            ));
        });
        report(&format!("aggregate n={n_clients} (fused)"), &s);

        // End-to-end Eq. 6–8 entry point (includes Eq. 6 weight
        // computation + update assembly), reported separately.
        let s_e2e = measure(2, 10, || {
            let updates: Vec<ClientUpdate<'_>> = (0..n_clients)
                .map(|i| ClientUpdate {
                    client: i,
                    depth: depths[i],
                    params: &params[i],
                    loss: losses[i],
                })
                .collect();
            black_box(aggregate(&mut global, &sizes, &updates, 0.01, 1e-8));
        });
        report(&format!("aggregate n={n_clients} (e2e incl. Eq.6)"), &s_e2e);
        println!(
            "    -> {:.2} Gparam/s weighted-averaged | fused {:.2}x vs scratch ref",
            throughput(&s, touched) / 1e9,
            s_ref.mean_s / s.mean_s.max(1e-12)
        );
    }
    Ok(())
}
