//! Perf microbench: layer-aligned aggregation throughput (Eq. 6–8).
//!
//! The Fed server aggregates every client prefix each round; this measures
//! the Rust hot loop at fleet sizes 10/50/100/200 over the real model
//! geometry. Feeds EXPERIMENTS.md §Perf.

use supersfl::bench_util::{black_box, measure, report, throughput};
use supersfl::config::ExperimentConfig;
use supersfl::fedserver::{aggregate, ClientUpdate};
use supersfl::runtime::Runtime;
use supersfl::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(&ExperimentConfig::default().artifacts_dir)?;
    let sizes = rt.model().enc_layer_sizes.clone();
    let total: usize = sizes.iter().sum();
    let depth = sizes.len();
    let mut rng = Pcg32::seeded(1);

    println!(
        "== bench_aggregation: Eq. 8 over {total} params x {depth} layers =="
    );
    for &n_clients in &[10usize, 50, 100, 200] {
        // Heterogeneous depths 1..L-1, random params/losses.
        let depths: Vec<usize> = (0..n_clients).map(|i| 1 + i % (depth - 1)).collect();
        let params: Vec<Vec<f32>> = depths
            .iter()
            .map(|&d| {
                let len: usize = sizes[..d].iter().sum();
                (0..len).map(|_| rng.normal() as f32).collect()
            })
            .collect();
        let losses: Vec<f64> = (0..n_clients).map(|_| rng.uniform_range(0.1, 3.0)).collect();
        let mut global: Vec<f32> = (0..total).map(|_| rng.normal() as f32).collect();

        let s = measure(2, 10, || {
            let updates: Vec<ClientUpdate<'_>> = (0..n_clients)
                .map(|i| ClientUpdate {
                    client: i,
                    depth: depths[i],
                    params: &params[i],
                    loss: losses[i],
                })
                .collect();
            black_box(aggregate(&mut global, &sizes, &updates, 0.01, 1e-8));
        });
        report(&format!("aggregate n={n_clients}"), &s);
        let touched: f64 = params.iter().map(|p| p.len() as f64).sum();
        println!(
            "    -> {:.2} Gparam/s weighted-averaged",
            throughput(&s, touched) / 1e9
        );
    }
    Ok(())
}
