//! Ablation bench: aggregation consistency weight λ (paper Eq. 7–8,
//! default 0.01) — one of the design choices DESIGN.md §7 calls out.
//!
//! Runs SuperSFL with λ ∈ {0, 0.01, 0.1, 1.0} under degraded server
//! availability (where fallback-trained prefixes diverge most and the
//! consistency pull matters) and reports accuracy.

use supersfl::config::ExperimentConfig;
use supersfl::metrics::Table;
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;

fn cfg(lambda: f64, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default()
        .with_name(&format!("lam_{lambda}"))
        .with_clients(6)
        .with_rounds(10)
        .with_seed(seed);
    cfg.ssfl.lambda = lambda;
    cfg.net.server_availability = 0.5; // stress the consistency term
    cfg.data.train_per_class = 120;
    cfg.train.local_steps = 2;
    cfg.train.eval_samples = 400;
    cfg
}

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);
    println!("== λ ablation (Eq. 8 consistency term) at 50% availability ==\n");

    let mut table = Table::new(&["lambda", "best acc %", "final acc %"]);
    for lambda in [0.0, 0.01, 0.1, 1.0] {
        let mut best = 0.0;
        let mut fin = 0.0;
        for seed in [42u64] {
            let m = run_experiment(&rt, &cfg(lambda, seed))?.metrics;
            best += m.best_accuracy;
            fin += m.final_accuracy;
        }
        eprintln!("  lambda {lambda}: best {best:.3}");
        table.row(&[
            format!("{lambda}"),
            format!("{:.2}", best * 100.0),
            format!("{:.2}", fin * 100.0),
        ]);
    }
    println!("{}", table.render());
    println!("paper uses λ=0.01; expect small-λ ≈ best, large λ (1.0) pins to the server copy and hurts.");
    Ok(())
}
