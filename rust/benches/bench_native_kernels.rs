//! Perf microbench for the native backend's kernel core (the offline
//! compute path every e2e test, paper-figure bench and example runs on).
//!
//! Three sections:
//! 1. **Per-kernel GFLOP/s + naive-vs-tiled before/after** — the tiled
//!    kernels (`gemm_bias`, `block_fwd`/`block_bwd`) against the
//!    pre-kernel-core naive reference implementations they replaced,
//!    bit-identity asserted before timing. The ISSUE acceptance number
//!    is the block fwd+bwd pair at n = 64 (1024 token rows).
//! 2. **End-to-end exec-call latency** — client_local / server_step /
//!    client_bwd / eval through the real backend, plus the kernel-time
//!    fraction and scratch-arena stats from RuntimeStats.
//! 3. **Round throughput at 10/50/100 clients** — marginal host
//!    ms/round of whole simulated SSFL rounds (prepare cost excluded).
//!
//! Results are also written to `BENCH_native.json` at the repository
//! root (machine-readable, seeds the perf trajectory across PRs). Runs
//! everywhere — the native backend needs no artifacts — so the CI smoke
//! leg (`SUPERSFL_SMOKE=1`) asserts it never prints "skipping".

use std::path::PathBuf;

use supersfl::bench_util::scenarios::smoke;
use supersfl::bench_util::{black_box, measure, report, Sample};
use supersfl::config::ExperimentConfig;
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::native::kernels::{self, reference};
use supersfl::runtime::Runtime;
use supersfl::util::json::JsonValue;
use supersfl::util::rng::Pcg32;

const DIM: usize = 32;
const HIDDEN: usize = 64;
const PATCH_ELEMS: usize = 192;
const TOKENS: usize = 16;
const BLOCK_W: usize = DIM * HIDDEN + HIDDEN + HIDDEN * DIM + DIM;

fn n(v: f64) -> JsonValue {
    JsonValue::Number(v)
}

fn randv(rng: &mut Pcg32, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal() as f32).collect()
}

fn gflops(flops: f64, s: &Sample) -> f64 {
    flops / s.mean_s / 1e9
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: tiled kernels drifted from naive");
    }
}

/// Section 1: per-kernel GFLOP/s and the naive-vs-tiled speedups.
fn kernel_section(out: &mut JsonValue, warmup: usize, iters: usize) {
    let mut rng = Pcg32::seeded(42);

    // -- embed-shaped GEMM: [rows, 192] · [192, 32] + bias --
    let rows_embed = 8 * TOKENS; // one training batch of patch rows
    let a = randv(&mut rng, rows_embed * PATCH_ELEMS);
    let w = randv(&mut rng, PATCH_ELEMS * DIM);
    let bias = randv(&mut rng, DIM);
    let mut c_tiled = vec![0.0f32; rows_embed * DIM];
    let mut c_naive = vec![0.0f32; rows_embed * DIM];
    kernels::gemm_bias(&a, &w, &bias, rows_embed, PATCH_ELEMS, DIM, &mut c_tiled);
    reference::gemm_bias(&a, &w, &bias, rows_embed, PATCH_ELEMS, DIM, &mut c_naive);
    assert_bits_eq(&c_tiled, &c_naive, "gemm_bias embed shape");
    let flops = 2.0 * (rows_embed * PATCH_ELEMS * DIM) as f64;
    let s_t = measure(warmup, iters, || {
        kernels::gemm_bias(&a, &w, &bias, rows_embed, PATCH_ELEMS, DIM, &mut c_tiled);
        black_box(c_tiled[0]);
    });
    report("gemm_bias [128x192x32] tiled", &s_t);
    println!("    -> {:.2} GFLOP/s", gflops(flops, &s_t));
    let s_n = measure(warmup, iters, || {
        reference::gemm_bias(&a, &w, &bias, rows_embed, PATCH_ELEMS, DIM, &mut c_naive);
        black_box(c_naive[0]);
    });
    report("gemm_bias [128x192x32] naive", &s_n);
    out.set("gemm_bias_embed_gflops", n(gflops(flops, &s_t)));
    out.set("gemm_bias_embed_speedup", n(s_n.mean_s / s_t.mean_s));

    // -- the acceptance pair: block fwd+bwd at n = 64 (1024 rows) --
    let rows = 64 * TOKENS;
    let wb = randv(&mut rng, BLOCK_W);
    let t_in = randv(&mut rng, rows * DIM);
    let d_out = randv(&mut rng, rows * DIM);
    let mut t_out = vec![0.0f32; rows * DIM];
    let mut u = vec![0.0f32; rows * HIDDEN];
    let mut g_w = vec![0.0f32; BLOCK_W];
    let mut d_in = vec![0.0f32; rows * DIM];
    let mut du = vec![0.0f32; rows * HIDDEN];

    // Bit-identity of the pair before timing it.
    kernels::block_fwd(&wb, &t_in, rows, DIM, HIDDEN, &mut t_out, &mut u);
    kernels::block_bwd(&wb, &t_in, &u, &d_out, rows, DIM, HIDDEN, &mut g_w, &mut d_in, &mut du);
    {
        let mut t_ref = vec![0.0f32; rows * DIM];
        let mut u_ref = vec![0.0f32; rows * HIDDEN];
        let mut g_ref = vec![0.0f32; BLOCK_W];
        let mut d_ref = vec![0.0f32; rows * DIM];
        reference::block_fwd(&wb, &t_in, rows, DIM, HIDDEN, &mut t_ref, &mut u_ref);
        reference::block_bwd(&wb, &t_in, &u_ref, &d_out, rows, DIM, HIDDEN, &mut g_ref, &mut d_ref);
        assert_bits_eq(&t_out, &t_ref, "block_fwd.t");
        assert_bits_eq(&u, &u_ref, "block_fwd.u");
        assert_bits_eq(&g_w, &g_ref, "block_bwd.g_w");
        assert_bits_eq(&d_in, &d_ref, "block_bwd.d_in");
    }

    // fwd ≈ 4·R·D·H flops (two matmuls), bwd ≈ 8·R·D·H (four).
    let pair_flops = 12.0 * (rows * DIM * HIDDEN) as f64;
    let s_tiled = measure(warmup, iters, || {
        kernels::block_fwd(&wb, &t_in, rows, DIM, HIDDEN, &mut t_out, &mut u);
        g_w.fill(0.0);
        kernels::block_bwd(&wb, &t_in, &u, &d_out, rows, DIM, HIDDEN, &mut g_w, &mut d_in, &mut du);
        black_box(d_in[0]);
    });
    report("block fwd+bwd pair n=64 tiled", &s_tiled);
    println!("    -> {:.2} GFLOP/s", gflops(pair_flops, &s_tiled));
    let s_naive = measure(warmup, iters, || {
        reference::block_fwd(&wb, &t_in, rows, DIM, HIDDEN, &mut t_out, &mut u);
        g_w.fill(0.0);
        reference::block_bwd(&wb, &t_in, &u, &d_out, rows, DIM, HIDDEN, &mut g_w, &mut d_in);
        black_box(d_in[0]);
    });
    report("block fwd+bwd pair n=64 naive", &s_naive);
    let speedup = s_naive.mean_s / s_tiled.mean_s;
    println!(
        "block fwd+bwd pair n=64: naive {:.3} ms -> tiled {:.3} ms = {speedup:.2}x speedup (acceptance target >= 3x)",
        s_naive.mean_s * 1e3,
        s_tiled.mean_s * 1e3,
    );
    out.set("block_fwd_bwd_n64_naive_ms", n(s_naive.mean_s * 1e3));
    out.set("block_fwd_bwd_n64_tiled_ms", n(s_tiled.mean_s * 1e3));
    out.set("block_fwd_bwd_n64_speedup", n(speedup));
    out.set("block_fwd_bwd_n64_gflops", n(gflops(pair_flops, &s_tiled)));

    // -- im2col batched gather (vs its cost being paid twice per op) --
    let imgs = randv(&mut rng, 8 * 32 * 32 * 3);
    let mut patches = vec![0.0f32; 8 * TOKENS * PATCH_ELEMS];
    let s_i = measure(warmup, iters, || {
        kernels::im2col(&imgs, 8, 32, 8, 3, &mut patches);
        black_box(patches[0]);
    });
    report("im2col [8x32x32x3]", &s_i);
    out.set("im2col_batch8_us", n(s_i.mean_s * 1e6));
}

/// Section 2: end-to-end exec-call latency on the real backend.
fn exec_section(rt: &Runtime, out: &mut JsonValue, warmup: usize, iters: usize) -> supersfl::Result<()> {
    let m = rt.model().clone();
    let enc = rt.load_init("init_enc_c10")?;
    let clf_c = rt.load_init("init_clf_client_c10")?;
    let clf_s = rt.load_init("init_clf_s_c10")?;
    let mut rng = Pcg32::seeded(7);
    let x = randv(&mut rng, m.batch * m.image_elems());
    let xe = randv(&mut rng, m.eval_batch * m.image_elems());
    let y: Vec<i32> = (0..m.batch as i32).map(|i| i % 10).collect();
    let depth = 4;
    let ne = m.enc_size(depth);

    println!("\n== end-to-end exec-call latency (native backend) ==");
    let s = measure(warmup, iters, || {
        black_box(rt.client_local(depth, 10, &enc[..ne], &clf_c, &x, &y).unwrap());
    });
    report("client_local_d4", &s);
    out.set("client_local_d4_us", n(s.mean_s * 1e6));

    let local = rt.client_local(depth, 10, &enc[..ne], &clf_c, &x, &y)?;
    let s = measure(warmup, iters, || {
        black_box(rt.server_step(depth, 10, &enc[ne..], &clf_s, &local.z, &y).unwrap());
    });
    report("server_step_d4", &s);
    out.set("server_step_d4_us", n(s.mean_s * 1e6));

    let srv_out = rt.server_step(depth, 10, &enc[ne..], &clf_s, &local.z, &y)?;
    let s = measure(warmup, iters, || {
        black_box(rt.client_bwd(depth, &enc[..ne], &x, &srv_out.g_z).unwrap());
    });
    report("client_bwd_d4", &s);
    out.set("client_bwd_d4_us", n(s.mean_s * 1e6));

    let s = measure(warmup, iters.min(8), || {
        black_box(rt.eval_batch(10, &enc, &clf_s, &xe).unwrap());
    });
    report("eval_batch", &s);
    out.set("eval_batch_us", n(s.mean_s * 1e6));

    let st = rt.stats();
    let frac = st.kernel_time_s / st.exec_time_s.max(1e-12);
    println!(
        "runtime stats: {} executions | exec {:.3}s | kernel {:.3}s ({:.1}% of exec) | arena hwm {} bytes, {} alloc events",
        st.executions,
        st.exec_time_s,
        st.kernel_time_s,
        100.0 * frac,
        st.arena_hwm_bytes,
        st.arena_allocs
    );
    out.set("kernel_time_fraction", n(frac));
    out.set("arena_hwm_bytes", n(st.arena_hwm_bytes as f64));
    out.set("arena_allocs", n(st.arena_allocs as f64));
    Ok(())
}

fn round_cfg(clients: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default()
        .with_name("bench_native_kernels")
        .with_clients(clients)
        .with_rounds(rounds)
        .with_seed(1234)
        .with_threads(0);
    cfg.data.train_per_class = 20;
    cfg.data.test_total = 200;
    cfg.train.local_steps = 1;
    cfg.train.eval_samples = 100;
    cfg
}

/// Section 3: whole-round host throughput at fleet scale. Marginal
/// measurement (wall(R) − wall(1)) / (R−1) excludes `Harness::prepare`.
fn round_section(rt: &Runtime, out: &mut JsonValue, rounds: usize) -> supersfl::Result<()> {
    println!("\n== round throughput (native backend, threads=auto) ==");
    println!("clients  ms/round  rounds/s  branches/s");
    let mut arr = Vec::new();
    for &clients in &[10usize, 50, 100] {
        // Warm pass (compile caches, allocator, arena) outside timing.
        run_experiment(rt, &round_cfg(clients, 1))?;
        let base = run_experiment(rt, &round_cfg(clients, 1))?;
        let full = run_experiment(rt, &round_cfg(clients, rounds))?;
        let marginal_s = (full.metrics.host_wall_s - base.metrics.host_wall_s).max(1e-9)
            / (rounds - 1) as f64;
        let rps = 1.0 / marginal_s;
        println!(
            "{clients:>7}  {:>8.2}  {rps:>8.2}  {:>10.1}",
            marginal_s * 1e3,
            clients as f64 * rps
        );
        let mut cell = JsonValue::object();
        cell.set("clients", n(clients as f64));
        cell.set("ms_per_round", n(marginal_s * 1e3));
        cell.set("rounds_per_s", n(rps));
        cell.set("client_branches_per_s", n(clients as f64 * rps));
        arr.push(cell);
    }
    out.set("rounds", JsonValue::Array(arr));
    Ok(())
}

fn main() -> supersfl::Result<()> {
    let is_smoke = smoke();
    let (warmup, iters, rounds) = if is_smoke { (1, 3, 2) } else { (3, 20, 5) };
    // The kernel core is the native backend's — bench it directly, no
    // artifacts needed anywhere.
    let rt = Runtime::native();
    println!("backend: {} (smoke: {is_smoke})", rt.backend_name());
    println!("== native kernel core: naive vs tiled ==");

    let mut root = JsonValue::object();
    root.set("bench", JsonValue::String("bench_native_kernels".into()));
    root.set(
        "mode",
        JsonValue::String(if is_smoke { "smoke" } else { "full" }.into()),
    );
    let mut kern = JsonValue::object();
    kernel_section(&mut kern, warmup, iters);
    root.set("kernels", kern);
    let mut exec = JsonValue::object();
    exec_section(&rt, &mut exec, warmup, iters)?;
    root.set("exec", exec);
    round_section(&rt, &mut root, rounds)?;

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_native.json");
    std::fs::write(&path, root.to_string_pretty())?;
    println!("\nwrote {}", path.display());
    Ok(())
}
