//! Perf microbench for the native backend's kernel core (the offline
//! compute path every e2e test, paper-figure bench and example runs on).
//!
//! Four sections:
//! 1. **Per-kernel GFLOP/s + naive-vs-tiled before/after** — the tiled
//!    kernels (`gemm_bias`, `block_fwd`/`block_bwd`) against the
//!    pre-kernel-core naive reference implementations they replaced,
//!    bit-identity asserted before timing.
//! 2. **Intra-client parallel kernels, 1-vs-N** — the deterministic
//!    shard reduction at kernel-threads 2/4 against 1, per kernel and
//!    for one end-to-end client step, bit-identity asserted across
//!    thread counts before timing. The acceptance number is the n = 64
//!    block fwd+bwd pair at kernel-threads 4 (target ≥ 1.5×).
//! 3. **End-to-end exec-call latency** — client_local / server_step /
//!    client_bwd / eval through the real backend, plus the kernel-time
//!    fraction and scratch-arena stats from RuntimeStats.
//! 4. **Round throughput at 10/50/100 clients** — marginal host
//!    ms/round of whole simulated SSFL rounds (prepare cost excluded).
//!
//! Results are also written to `BENCH_native.json` at the repository
//! root (machine-readable, seeds the perf trajectory across PRs). Runs
//! everywhere — the native backend needs no artifacts — so the CI smoke
//! leg (`SUPERSFL_SMOKE=1`) asserts it never prints "skipping".

use std::path::PathBuf;

use supersfl::bench_util::scenarios::smoke;
use supersfl::bench_util::{black_box, measure, report, Sample};
use supersfl::config::ExperimentConfig;
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::native::kernels::{self, reference, ShardPlan};
use supersfl::runtime::native::pool::ShardPool;
use supersfl::runtime::Runtime;
use supersfl::util::json::JsonValue;
use supersfl::util::rng::Pcg32;

const DIM: usize = 32;
const HIDDEN: usize = 64;
const PATCH_ELEMS: usize = 192;
const TOKENS: usize = 16;
const BLOCK_W: usize = DIM * HIDDEN + HIDDEN + HIDDEN * DIM + DIM;

fn n(v: f64) -> JsonValue {
    JsonValue::Number(v)
}

fn randv(rng: &mut Pcg32, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal() as f32).collect()
}

fn gflops(flops: f64, s: &Sample) -> f64 {
    flops / s.mean_s / 1e9
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: tiled kernels drifted from naive");
    }
}

/// Section 1: per-kernel GFLOP/s and the naive-vs-tiled speedups.
fn kernel_section(out: &mut JsonValue, warmup: usize, iters: usize) {
    let mut rng = Pcg32::seeded(42);

    // -- embed-shaped GEMM: [rows, 192] · [192, 32] + bias --
    let rows_embed = 8 * TOKENS; // one training batch of patch rows
    let a = randv(&mut rng, rows_embed * PATCH_ELEMS);
    let w = randv(&mut rng, PATCH_ELEMS * DIM);
    let bias = randv(&mut rng, DIM);
    let mut c_tiled = vec![0.0f32; rows_embed * DIM];
    let mut c_naive = vec![0.0f32; rows_embed * DIM];
    kernels::gemm_bias(&a, &w, &bias, rows_embed, PATCH_ELEMS, DIM, &mut c_tiled);
    reference::gemm_bias(&a, &w, &bias, rows_embed, PATCH_ELEMS, DIM, &mut c_naive);
    assert_bits_eq(&c_tiled, &c_naive, "gemm_bias embed shape");
    let flops = 2.0 * (rows_embed * PATCH_ELEMS * DIM) as f64;
    let s_t = measure(warmup, iters, || {
        kernels::gemm_bias(&a, &w, &bias, rows_embed, PATCH_ELEMS, DIM, &mut c_tiled);
        black_box(c_tiled[0]);
    });
    report("gemm_bias [128x192x32] tiled", &s_t);
    println!("    -> {:.2} GFLOP/s", gflops(flops, &s_t));
    let s_n = measure(warmup, iters, || {
        reference::gemm_bias(&a, &w, &bias, rows_embed, PATCH_ELEMS, DIM, &mut c_naive);
        black_box(c_naive[0]);
    });
    report("gemm_bias [128x192x32] naive", &s_n);
    out.set("gemm_bias_embed_gflops", n(gflops(flops, &s_t)));
    out.set("gemm_bias_embed_speedup", n(s_n.mean_s / s_t.mean_s));

    // -- the acceptance pair: block fwd+bwd at n = 64 (1024 rows) --
    let rows = 64 * TOKENS;
    let wb = randv(&mut rng, BLOCK_W);
    let t_in = randv(&mut rng, rows * DIM);
    let d_out = randv(&mut rng, rows * DIM);
    let mut t_out = vec![0.0f32; rows * DIM];
    let mut u = vec![0.0f32; rows * HIDDEN];
    let mut g_w = vec![0.0f32; BLOCK_W];
    let mut d_in = vec![0.0f32; rows * DIM];
    let mut du = vec![0.0f32; rows * HIDDEN];

    // Bit-identity of the pair before timing it.
    kernels::block_fwd(&wb, &t_in, rows, DIM, HIDDEN, &mut t_out, &mut u);
    kernels::block_bwd(&wb, &t_in, &u, &d_out, rows, DIM, HIDDEN, &mut g_w, &mut d_in, &mut du);
    {
        let mut t_ref = vec![0.0f32; rows * DIM];
        let mut u_ref = vec![0.0f32; rows * HIDDEN];
        let mut g_ref = vec![0.0f32; BLOCK_W];
        let mut d_ref = vec![0.0f32; rows * DIM];
        reference::block_fwd(&wb, &t_in, rows, DIM, HIDDEN, &mut t_ref, &mut u_ref);
        reference::block_bwd(&wb, &t_in, &u_ref, &d_out, rows, DIM, HIDDEN, &mut g_ref, &mut d_ref);
        assert_bits_eq(&t_out, &t_ref, "block_fwd.t");
        assert_bits_eq(&u, &u_ref, "block_fwd.u");
        assert_bits_eq(&g_w, &g_ref, "block_bwd.g_w");
        assert_bits_eq(&d_in, &d_ref, "block_bwd.d_in");
    }

    // fwd ≈ 4·R·D·H flops (two matmuls), bwd ≈ 8·R·D·H (four).
    let pair_flops = 12.0 * (rows * DIM * HIDDEN) as f64;
    let s_tiled = measure(warmup, iters, || {
        kernels::block_fwd(&wb, &t_in, rows, DIM, HIDDEN, &mut t_out, &mut u);
        g_w.fill(0.0);
        kernels::block_bwd(&wb, &t_in, &u, &d_out, rows, DIM, HIDDEN, &mut g_w, &mut d_in, &mut du);
        black_box(d_in[0]);
    });
    report("block fwd+bwd pair n=64 tiled", &s_tiled);
    println!("    -> {:.2} GFLOP/s", gflops(pair_flops, &s_tiled));
    let s_naive = measure(warmup, iters, || {
        reference::block_fwd(&wb, &t_in, rows, DIM, HIDDEN, &mut t_out, &mut u);
        g_w.fill(0.0);
        reference::block_bwd(&wb, &t_in, &u, &d_out, rows, DIM, HIDDEN, &mut g_w, &mut d_in);
        black_box(d_in[0]);
    });
    report("block fwd+bwd pair n=64 naive", &s_naive);
    let speedup = s_naive.mean_s / s_tiled.mean_s;
    println!(
        "block fwd+bwd pair n=64: naive {:.3} ms -> tiled {:.3} ms = {speedup:.2}x speedup (acceptance target >= 3x)",
        s_naive.mean_s * 1e3,
        s_tiled.mean_s * 1e3,
    );
    out.set("block_fwd_bwd_n64_naive_ms", n(s_naive.mean_s * 1e3));
    out.set("block_fwd_bwd_n64_tiled_ms", n(s_tiled.mean_s * 1e3));
    out.set("block_fwd_bwd_n64_speedup", n(speedup));
    out.set("block_fwd_bwd_n64_gflops", n(gflops(pair_flops, &s_tiled)));

    // -- im2col batched gather (vs its cost being paid twice per op) --
    let imgs = randv(&mut rng, 8 * 32 * 32 * 3);
    let mut patches = vec![0.0f32; 8 * TOKENS * PATCH_ELEMS];
    let s_i = measure(warmup, iters, || {
        kernels::im2col(&imgs, 8, 32, 8, 3, &mut patches);
        black_box(patches[0]);
    });
    report("im2col [8x32x32x3]", &s_i);
    out.set("im2col_batch8_us", n(s_i.mean_s * 1e6));
}

/// Section 2: end-to-end exec-call latency on the real backend.
fn exec_section(rt: &Runtime, out: &mut JsonValue, warmup: usize, iters: usize) -> supersfl::Result<()> {
    let m = rt.model().clone();
    let enc = rt.load_init("init_enc_c10")?;
    let clf_c = rt.load_init("init_clf_client_c10")?;
    let clf_s = rt.load_init("init_clf_s_c10")?;
    let mut rng = Pcg32::seeded(7);
    let x = randv(&mut rng, m.batch * m.image_elems());
    let xe = randv(&mut rng, m.eval_batch * m.image_elems());
    let y: Vec<i32> = (0..m.batch as i32).map(|i| i % 10).collect();
    let depth = 4;
    let ne = m.enc_size(depth);

    println!("\n== end-to-end exec-call latency (native backend) ==");
    let s = measure(warmup, iters, || {
        black_box(rt.client_local(depth, 10, &enc[..ne], &clf_c, &x, &y).unwrap());
    });
    report("client_local_d4", &s);
    out.set("client_local_d4_us", n(s.mean_s * 1e6));

    let local = rt.client_local(depth, 10, &enc[..ne], &clf_c, &x, &y)?;
    let s = measure(warmup, iters, || {
        black_box(rt.server_step(depth, 10, &enc[ne..], &clf_s, &local.z, &y).unwrap());
    });
    report("server_step_d4", &s);
    out.set("server_step_d4_us", n(s.mean_s * 1e6));

    let srv_out = rt.server_step(depth, 10, &enc[ne..], &clf_s, &local.z, &y)?;
    let s = measure(warmup, iters, || {
        black_box(rt.client_bwd(depth, &enc[..ne], &x, &srv_out.g_z).unwrap());
    });
    report("client_bwd_d4", &s);
    out.set("client_bwd_d4_us", n(s.mean_s * 1e6));

    let s = measure(warmup, iters.min(8), || {
        black_box(rt.eval_batch(10, &enc, &clf_s, &xe).unwrap());
    });
    report("eval_batch", &s);
    out.set("eval_batch_us", n(s.mean_s * 1e6));

    let st = rt.stats();
    let frac = st.kernel_time_s / st.exec_time_s.max(1e-12);
    println!(
        "runtime stats: {} executions | exec {:.3}s | kernel {:.3}s ({:.1}% of exec) | arena hwm {} bytes, {} alloc events",
        st.executions,
        st.exec_time_s,
        st.kernel_time_s,
        100.0 * frac,
        st.arena_hwm_bytes,
        st.arena_allocs
    );
    out.set("kernel_time_fraction", n(frac));
    out.set("arena_hwm_bytes", n(st.arena_hwm_bytes as f64));
    out.set("arena_allocs", n(st.arena_allocs as f64));
    Ok(())
}

/// Time one kernel under the 1-thread and 4-thread pools (the caller
/// has already run a warm pass per pool and asserted bit-identity) and
/// report + record the speedup under `key`.
fn one_vs_four(
    out: &mut JsonValue,
    key: &str,
    label: &str,
    warmup: usize,
    iters: usize,
    t1: impl FnMut(),
    t4: impl FnMut(),
) {
    let s1 = measure(warmup, iters, t1);
    let s4 = measure(warmup, iters, t4);
    println!(
        "{label}: t1 {:.3} ms -> t4 {:.3} ms = {:.2}x",
        s1.mean_s * 1e3,
        s4.mean_s * 1e3,
        s1.mean_s / s4.mean_s
    );
    out.set(key, n(s1.mean_s / s4.mean_s));
}

/// Section: intra-client parallel kernels — the 1-vs-N speedups of the
/// deterministic shard reduction, per kernel and end to end. Bit-identity
/// between every thread count is asserted before anything is timed; the
/// ISSUE acceptance number is the n = 64 block fwd+bwd pair at
/// `--kernel-threads 4` (target ≥ 1.5×).
fn parallel_section(out: &mut JsonValue, warmup: usize, iters: usize) -> supersfl::Result<()> {
    println!("\n== intra-client parallel kernels: 1-vs-N (deterministic shard reduction) ==");
    let mut rng = Pcg32::seeded(99);
    let rows = 64 * TOKENS;
    let plan = ShardPlan::of(rows);
    let wb = randv(&mut rng, BLOCK_W);
    let t_in = randv(&mut rng, rows * DIM);
    let d_out = randv(&mut rng, rows * DIM);
    let pool1 = ShardPool::new(1);

    // Baseline buffers (threads = 1).
    let mut t1 = vec![0.0f32; rows * DIM];
    let mut u1 = vec![0.0f32; rows * HIDDEN];
    let mut g1 = vec![0.0f32; BLOCK_W];
    let mut d1 = vec![0.0f32; rows * DIM];
    let mut du1 = vec![0.0f32; rows * HIDDEN];
    let mut gpart = vec![0.0f32; plan.nshards() * BLOCK_W];

    let pair = |pool: &ShardPool,
                t: &mut Vec<f32>,
                u: &mut Vec<f32>,
                g: &mut Vec<f32>,
                d: &mut Vec<f32>,
                du: &mut Vec<f32>,
                gpart: &mut Vec<f32>| {
        kernels::block_fwd_sharded(pool, plan, &wb, &t_in, rows, DIM, HIDDEN, t, u);
        g.fill(0.0);
        kernels::block_bwd_sharded(
            pool, plan, &wb, &t_in, u, &d_out, rows, DIM, HIDDEN, g, d, du, gpart,
        );
    };
    pair(&pool1, &mut t1, &mut u1, &mut g1, &mut d1, &mut du1, &mut gpart);

    let s_1 = measure(warmup, iters, || {
        pair(&pool1, &mut t1, &mut u1, &mut g1, &mut d1, &mut du1, &mut gpart);
        black_box(d1[0]);
    });
    report("block pair n=64 sharded, kernel-threads 1", &s_1);

    let mut cells = Vec::new();
    let mut cell1 = JsonValue::object();
    cell1.set("threads", n(1.0));
    cell1.set("ms", n(s_1.mean_s * 1e3));
    cell1.set("speedup", n(1.0));
    cells.push(cell1);
    let mut t4_speedup = 0.0f64;
    for threads in [2usize, 4] {
        let pool_n = ShardPool::new(threads);
        let mut tn = vec![0.0f32; rows * DIM];
        let mut un = vec![0.0f32; rows * HIDDEN];
        let mut gn = vec![0.0f32; BLOCK_W];
        let mut dn = vec![0.0f32; rows * DIM];
        let mut dun = vec![0.0f32; rows * HIDDEN];
        let mut gpn = vec![0.0f32; plan.nshards() * BLOCK_W];
        // Bit-identity across thread counts before timing.
        pair(&pool_n, &mut tn, &mut un, &mut gn, &mut dn, &mut dun, &mut gpn);
        assert_bits_eq(&tn, &t1, "parallel block_fwd.t");
        assert_bits_eq(&un, &u1, "parallel block_fwd.u");
        assert_bits_eq(&gn, &g1, "parallel block_bwd.g_w");
        assert_bits_eq(&dn, &d1, "parallel block_bwd.d_in");
        let s_n = measure(warmup, iters, || {
            pair(&pool_n, &mut tn, &mut un, &mut gn, &mut dn, &mut dun, &mut gpn);
            black_box(dn[0]);
        });
        let speedup = s_1.mean_s / s_n.mean_s;
        report(&format!("block pair n=64 sharded, kernel-threads {threads}"), &s_n);
        println!("    -> {speedup:.2}x vs kernel-threads 1");
        if threads == 4 {
            t4_speedup = speedup;
        }
        let mut cell = JsonValue::object();
        cell.set("threads", n(threads as f64));
        cell.set("ms", n(s_n.mean_s * 1e3));
        cell.set("speedup", n(speedup));
        cells.push(cell);
    }
    println!(
        "block fwd+bwd pair n=64 at kernel-threads 4: {t4_speedup:.2}x (acceptance target >= 1.5x)"
    );
    out.set("block_pair_n64", JsonValue::Array(cells));
    out.set("block_pair_n64_speedup_t4", n(t4_speedup));

    // Per-kernel 1-vs-4 on the remaining sharded hot kernels. Each
    // caller runs one warm pass per pool and asserts bit-identity
    // before handing the timed closures to `one_vs_four`.
    let pool4 = ShardPool::new(4);
    {
        let a = randv(&mut rng, rows * PATCH_ELEMS);
        let w = randv(&mut rng, PATCH_ELEMS * DIM);
        let bias = randv(&mut rng, DIM);
        let mut c1 = vec![0.0f32; rows * DIM];
        let mut c4 = vec![0.0f32; rows * DIM];
        kernels::gemm_bias_sharded(&pool1, plan, &a, &w, &bias, rows, PATCH_ELEMS, DIM, &mut c1);
        kernels::gemm_bias_sharded(&pool4, plan, &a, &w, &bias, rows, PATCH_ELEMS, DIM, &mut c4);
        assert_bits_eq(&c1, &c4, "parallel gemm_bias");
        one_vs_four(
            out,
            "gemm_bias_speedup_t4",
            "gemm_bias [1024x192x32]",
            warmup,
            iters,
            || {
                kernels::gemm_bias_sharded(&pool1, plan, &a, &w, &bias, rows, PATCH_ELEMS, DIM, &mut c1);
                black_box(c1[0]);
            },
            || {
                kernels::gemm_bias_sharded(&pool4, plan, &a, &w, &bias, rows, PATCH_ELEMS, DIM, &mut c4);
                black_box(c4[0]);
            },
        );

        // gemm_bt at the block-backward du shape: [rows,32]·[64,32]ᵀ.
        let d_up = randv(&mut rng, rows * DIM);
        let w2 = randv(&mut rng, HIDDEN * DIM);
        let mut b1 = vec![0.0f32; rows * HIDDEN];
        let mut b4 = vec![0.0f32; rows * HIDDEN];
        kernels::gemm_bt_sharded(&pool1, plan, &d_up, &w2, None, rows, DIM, HIDDEN, &mut b1);
        kernels::gemm_bt_sharded(&pool4, plan, &d_up, &w2, None, rows, DIM, HIDDEN, &mut b4);
        assert_bits_eq(&b1, &b4, "parallel gemm_bt");
        one_vs_four(
            out,
            "gemm_bt_speedup_t4",
            "gemm_bt [1024x32x64]",
            warmup,
            iters,
            || {
                kernels::gemm_bt_sharded(&pool1, plan, &d_up, &w2, None, rows, DIM, HIDDEN, &mut b1);
                black_box(b1[0]);
            },
            || {
                kernels::gemm_bt_sharded(&pool4, plan, &d_up, &w2, None, rows, DIM, HIDDEN, &mut b4);
                black_box(b4[0]);
            },
        );

        let mut g1g = randv(&mut rng, PATCH_ELEMS * DIM);
        let mut g4g = g1g.clone();
        let y = randv(&mut rng, rows * DIM);
        let mut part1 = vec![0.0f32; plan.nshards() * PATCH_ELEMS * DIM];
        let mut part4 = part1.clone();
        kernels::ger_acc_rows_sharded(&pool1, plan, &mut g1g, &a, &y, rows, PATCH_ELEMS, DIM, &mut part1);
        kernels::ger_acc_rows_sharded(&pool4, plan, &mut g4g, &a, &y, rows, PATCH_ELEMS, DIM, &mut part4);
        // (accumulators drift apart after repeated timing passes, so
        // bit-identity is asserted on this single warm pass only)
        assert_bits_eq(&g1g, &g4g, "parallel ger_acc_rows");
        one_vs_four(
            out,
            "ger_acc_rows_speedup_t4",
            "ger_acc_rows [1024x192x32]",
            warmup,
            iters,
            || {
                kernels::ger_acc_rows_sharded(&pool1, plan, &mut g1g, &a, &y, rows, PATCH_ELEMS, DIM, &mut part1);
                black_box(g1g[0]);
            },
            || {
                kernels::ger_acc_rows_sharded(&pool4, plan, &mut g4g, &a, &y, rows, PATCH_ELEMS, DIM, &mut part4);
                black_box(g4g[0]);
            },
        );
    }
    {
        let imgs = randv(&mut rng, 64 * 32 * 32 * 3);
        let mut p1 = vec![0.0f32; rows * PATCH_ELEMS];
        let mut p4 = vec![0.0f32; rows * PATCH_ELEMS];
        kernels::im2col_sharded(&pool1, plan, &imgs, 64, 32, 8, 3, &mut p1);
        kernels::im2col_sharded(&pool4, plan, &imgs, 64, 32, 8, 3, &mut p4);
        assert_bits_eq(&p1, &p4, "parallel im2col");
        one_vs_four(
            out,
            "im2col_speedup_t4",
            "im2col [64x32x32x3]",
            warmup,
            iters,
            || {
                kernels::im2col_sharded(&pool1, plan, &imgs, 64, 32, 8, 3, &mut p1);
                black_box(p1[0]);
            },
            || {
                kernels::im2col_sharded(&pool4, plan, &imgs, 64, 32, 8, 3, &mut p4);
                black_box(p4[0]);
            },
        );
    }

    // End to end: one client step (client_local + server_step) through
    // backends pinned to 1 vs 4 kernel threads, outputs asserted
    // bitwise identical first.
    let rt1 = Runtime::native_with_kernel_threads(1);
    let rt4 = Runtime::native_with_kernel_threads(4);
    let m = rt1.model().clone();
    let enc = rt1.load_init("init_enc_c10")?;
    let clf_c = rt1.load_init("init_clf_client_c10")?;
    let clf_s = rt1.load_init("init_clf_s_c10")?;
    let x = randv(&mut rng, m.batch * m.image_elems());
    let y: Vec<i32> = (0..m.batch as i32).map(|i| i % 10).collect();
    let depth = 4;
    let ne = m.enc_size(depth);
    let step = |rt: &Runtime| {
        let local = rt.client_local(depth, 10, &enc[..ne], &clf_c, &x, &y).unwrap();
        let srv = rt.server_step(depth, 10, &enc[ne..], &clf_s, &local.z, &y).unwrap();
        (local, srv)
    };
    let (l1, s1o) = step(&rt1);
    let (l4, s4o) = step(&rt4);
    assert_bits_eq(&l1.g_enc, &l4.g_enc, "e2e client_local.g_enc");
    assert_bits_eq(&s1o.g_srv, &s4o.g_srv, "e2e server_step.g_srv");
    assert_bits_eq(&s1o.g_z, &s4o.g_z, "e2e server_step.g_z");
    let e1 = measure(warmup, iters, || {
        black_box(step(&rt1).1.loss);
    });
    let e4 = measure(warmup, iters, || {
        black_box(step(&rt4).1.loss);
    });
    let speedup = e1.mean_s / e4.mean_s;
    println!(
        "single-client step (local+server, d=4): kernel-threads 1 {:.3} ms -> 4 {:.3} ms = {speedup:.2}x",
        e1.mean_s * 1e3,
        e4.mean_s * 1e3
    );
    out.set("client_step_t1_us", n(e1.mean_s * 1e6));
    out.set("client_step_t4_us", n(e4.mean_s * 1e6));
    out.set("client_step_speedup_t4", n(speedup));
    Ok(())
}

fn round_cfg(clients: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default()
        .with_name("bench_native_kernels")
        .with_clients(clients)
        .with_rounds(rounds)
        .with_seed(1234)
        .with_threads(0);
    cfg.data.train_per_class = 20;
    cfg.data.test_total = 200;
    cfg.train.local_steps = 1;
    cfg.train.eval_samples = 100;
    cfg
}

/// Section 3: whole-round host throughput at fleet scale. Marginal
/// measurement (wall(R) − wall(1)) / (R−1) excludes `Harness::prepare`.
fn round_section(rt: &Runtime, out: &mut JsonValue, rounds: usize) -> supersfl::Result<()> {
    println!("\n== round throughput (native backend, threads=auto) ==");
    println!("clients  ms/round  rounds/s  branches/s");
    let mut arr = Vec::new();
    for &clients in &[10usize, 50, 100] {
        // Warm pass (compile caches, allocator, arena) outside timing.
        run_experiment(rt, &round_cfg(clients, 1))?;
        let base = run_experiment(rt, &round_cfg(clients, 1))?;
        let full = run_experiment(rt, &round_cfg(clients, rounds))?;
        let marginal_s = (full.metrics.host_wall_s - base.metrics.host_wall_s).max(1e-9)
            / (rounds - 1) as f64;
        let rps = 1.0 / marginal_s;
        println!(
            "{clients:>7}  {:>8.2}  {rps:>8.2}  {:>10.1}",
            marginal_s * 1e3,
            clients as f64 * rps
        );
        let mut cell = JsonValue::object();
        cell.set("clients", n(clients as f64));
        cell.set("ms_per_round", n(marginal_s * 1e3));
        cell.set("rounds_per_s", n(rps));
        cell.set("client_branches_per_s", n(clients as f64 * rps));
        arr.push(cell);
    }
    out.set("rounds", JsonValue::Array(arr));
    Ok(())
}

fn main() -> supersfl::Result<()> {
    let is_smoke = smoke();
    let (warmup, iters, rounds) = if is_smoke { (1, 3, 2) } else { (3, 20, 5) };
    // The kernel core is the native backend's — bench it directly, no
    // artifacts needed anywhere.
    let rt = Runtime::native();
    println!("backend: {} (smoke: {is_smoke})", rt.backend_name());
    println!("== native kernel core: naive vs tiled ==");

    let mut root = JsonValue::object();
    root.set("bench", JsonValue::String("bench_native_kernels".into()));
    root.set(
        "mode",
        JsonValue::String(if is_smoke { "smoke" } else { "full" }.into()),
    );
    let mut kern = JsonValue::object();
    kernel_section(&mut kern, warmup, iters);
    root.set("kernels", kern);
    let mut par = JsonValue::object();
    parallel_section(&mut par, warmup, iters)?;
    root.set("kernel_parallel", par);
    let mut exec = JsonValue::object();
    exec_section(&rt, &mut exec, warmup, iters)?;
    root.set("exec", exec);
    round_section(&rt, &mut root, rounds)?;

    // Shared provenance stamp: the kernel bench always runs the native
    // backend, so stamp the default config pinned to it.
    let mut prov_cfg = ExperimentConfig::default();
    prov_cfg.backend = supersfl::config::BackendKind::Native;
    root.set("provenance", supersfl::bench_util::provenance(&prov_cfg));

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_native.json");
    supersfl::util::fs::atomic_write(&path, root.to_string_pretty().as_bytes())?;
    println!("\nwrote {}", path.display());
    Ok(())
}
