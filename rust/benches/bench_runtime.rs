//! Perf microbench: PJRT dispatch cost per protocol op (L3 hot path).
//!
//! Measures each artifact call the coordinator makes per client step —
//! client_local / server_step / client_bwd / tpgf_update / eval — plus the
//! literal-marshalling overhead split reported by RuntimeStats. Feeds
//! EXPERIMENTS.md §Perf.

use supersfl::bench_util::{black_box, measure, report, throughput};
use supersfl::config::ExperimentConfig;
use supersfl::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(&ExperimentConfig::default().artifacts_dir)?;
    let m = rt.model().clone();
    let enc = rt.manifest.load_init("init_enc_c10")?;
    let clf_c = rt.manifest.load_init("init_clf_client_c10")?;
    let clf_s = rt.manifest.load_init("init_clf_s_c10")?;
    let x = vec![0.1f32; m.batch * m.image_elems()];
    let xe = vec![0.1f32; m.eval_batch * m.image_elems()];
    let y: Vec<i32> = (0..m.batch as i32).map(|i| i % 10).collect();

    println!("== bench_runtime: per-op dispatch cost (batch {}) ==", m.batch);
    for depth in [1usize, 4, 7] {
        let ne = m.enc_size(depth);
        let enc_d = &enc[..ne];
        let srv = &enc[ne..];

        let s = measure(2, 8, || {
            black_box(rt.client_local(depth, 10, enc_d, &clf_c, &x, &y).unwrap());
        });
        report(&format!("client_local_d{depth}"), &s);
        println!(
            "    -> {:.0} samples/s",
            throughput(&s, m.batch as f64)
        );

        let local = rt.client_local(depth, 10, enc_d, &clf_c, &x, &y)?;
        let s = measure(2, 8, || {
            black_box(
                rt.server_step(depth, 10, srv, &clf_s, &local.z, &y)
                    .unwrap(),
            );
        });
        report(&format!("server_step_d{depth}"), &s);

        let srv_out = rt.server_step(depth, 10, srv, &clf_s, &local.z, &y)?;
        let s = measure(2, 8, || {
            black_box(rt.client_bwd(depth, enc_d, &x, &srv_out.g_z).unwrap());
        });
        report(&format!("client_bwd_d{depth}"), &s);

        let s = measure(2, 8, || {
            black_box(
                rt.tpgf_update(depth, enc_d, &local.g_enc, &local.g_enc, 1.0, 1.0, 0.05)
                    .unwrap(),
            );
        });
        report(&format!("tpgf_update_d{depth} (artifact)"), &s);
    }

    let s = measure(2, 6, || {
        black_box(rt.eval_batch(10, &enc, &clf_s, &xe).unwrap());
    });
    report(&format!("eval_batch (B={})", m.eval_batch), &s);

    let st = rt.stats();
    println!(
        "\nruntime stats: {} executions | exec {:.3}s | marshal {:.3}s ({:.1}% of exec) | {} compiles {:.2}s",
        st.executions,
        st.exec_time_s,
        st.marshal_time_s,
        100.0 * st.marshal_time_s / st.exec_time_s.max(1e-9),
        st.compile_count,
        st.compile_time_s
    );
    Ok(())
}
