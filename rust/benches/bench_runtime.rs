//! Perf microbench: PJRT dispatch cost per protocol op (L3 hot path) plus
//! the parallel round engine's host-time throughput at fleet scale.
//!
//! Part 1 measures each artifact call the coordinator makes per client
//! step — client_local / server_step / client_bwd / tpgf_update / eval —
//! plus the literal-marshalling overhead split reported by RuntimeStats.
//!
//! Part 2 runs whole simulated rounds at 10/50/100 clients with
//! `threads = 1` (the old sequential behaviour) vs `threads = 0` (all
//! cores) and reports host ms/round, client-branches/s and the speedup —
//! the ISSUE's before/after number. Results are bit-identical across the
//! two configurations (asserted here on final accuracy).
//!
//! Feeds EXPERIMENTS.md §Perf.

use supersfl::bench_util::{black_box, measure, report, throughput};
use supersfl::config::ExperimentConfig;
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;

fn per_op_section(rt: &Runtime) -> supersfl::Result<()> {
    let m = rt.model().clone();
    let enc = rt.load_init("init_enc_c10")?;
    let clf_c = rt.load_init("init_clf_client_c10")?;
    let clf_s = rt.load_init("init_clf_s_c10")?;
    let x = vec![0.1f32; m.batch * m.image_elems()];
    let xe = vec![0.1f32; m.eval_batch * m.image_elems()];
    let y: Vec<i32> = (0..m.batch as i32).map(|i| i % 10).collect();

    println!("== bench_runtime: per-op dispatch cost (batch {}) ==", m.batch);
    for depth in [1usize, 4, 7] {
        let ne = m.enc_size(depth);
        let enc_d = &enc[..ne];
        let srv = &enc[ne..];

        let s = measure(2, 8, || {
            black_box(rt.client_local(depth, 10, enc_d, &clf_c, &x, &y).unwrap());
        });
        report(&format!("client_local_d{depth}"), &s);
        println!(
            "    -> {:.0} samples/s",
            throughput(&s, m.batch as f64)
        );

        let local = rt.client_local(depth, 10, enc_d, &clf_c, &x, &y)?;
        let s = measure(2, 8, || {
            black_box(
                rt.server_step(depth, 10, srv, &clf_s, &local.z, &y)
                    .unwrap(),
            );
        });
        report(&format!("server_step_d{depth}"), &s);

        let srv_out = rt.server_step(depth, 10, srv, &clf_s, &local.z, &y)?;
        let s = measure(2, 8, || {
            black_box(rt.client_bwd(depth, enc_d, &x, &srv_out.g_z).unwrap());
        });
        report(&format!("client_bwd_d{depth}"), &s);

        let s = measure(2, 8, || {
            black_box(
                rt.tpgf_update(depth, enc_d, &local.g_enc, &local.g_enc, 1.0, 1.0, 0.05)
                    .unwrap(),
            );
        });
        report(&format!("tpgf_update_d{depth} (artifact)"), &s);
    }

    let s = measure(2, 6, || {
        black_box(rt.eval_batch(10, &enc, &clf_s, &xe).unwrap());
    });
    report(&format!("eval_batch (B={})", m.eval_batch), &s);
    Ok(())
}

fn engine_cfg(clients: usize, threads: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default()
        .with_name("bench_engine")
        .with_clients(clients)
        .with_rounds(rounds)
        .with_seed(1234)
        .with_threads(threads);
    cfg.data.train_per_class = 20;
    cfg.data.test_total = 200;
    cfg.train.local_steps = 1;
    cfg.train.eval_samples = 100;
    cfg
}

/// Whole-round host throughput: sequential (threads=1) vs parallel
/// (threads=0, all cores) at 10/50/100 clients.
///
/// Per-round time is measured *marginally* — wall(R rounds) − wall(1
/// round), divided by R−1 — so the thread-count-independent cost of
/// `Harness::prepare` (dataset synthesis, fleet sampling) does not dilute
/// the reported speedup.
fn engine_section(rt: &Runtime) -> supersfl::Result<()> {
    const ROUNDS: usize = 5;
    println!("\n== parallel round engine: marginal host time per round ==");
    println!("clients  threads  ms/round  branches/s  speedup");
    for &clients in &[10usize, 50, 100] {
        let mut seq_ms = 0.0f64;
        let mut seq_bits = 0u64;
        for &threads in &[1usize, 0] {
            let full_cfg = engine_cfg(clients, threads, ROUNDS);
            // Warm the compile cache outside the measured runs.
            run_experiment(rt, &full_cfg)?;
            let base = run_experiment(rt, &engine_cfg(clients, threads, 1))?;
            let full = run_experiment(rt, &full_cfg)?;
            let marginal_s =
                (full.metrics.host_wall_s - base.metrics.host_wall_s).max(0.0)
                    / (ROUNDS - 1) as f64;
            let ms_per_round = marginal_s * 1e3;
            let branches_s = clients as f64 / marginal_s.max(1e-9);
            if threads == 1 {
                seq_ms = ms_per_round;
                seq_bits = full.metrics.final_accuracy.to_bits();
                println!(
                    "{clients:>7}  {:>7}  {ms_per_round:>8.1}  {branches_s:>10.1}  baseline",
                    "1"
                );
            } else {
                println!(
                    "{clients:>7}  {:>7}  {ms_per_round:>8.1}  {branches_s:>10.1}  {:.2}x",
                    "auto",
                    seq_ms / ms_per_round.max(1e-9)
                );
                // The engine's determinism contract: same bits either way.
                assert_eq!(
                    seq_bits,
                    full.metrics.final_accuracy.to_bits(),
                    "thread-count invariance violated at {clients} clients"
                );
            }
        }
    }
    Ok(())
}

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);
    println!("backend: {}", rt.backend_name());

    per_op_section(&rt)?;

    // Print the per-op marshal/exec split before the engine section so the
    // stats describe Part 1 only (they accumulate process-wide).
    let st = rt.stats();
    println!(
        "\nruntime stats (per-op section): {} executions | exec {:.3}s | marshal {:.3}s ({:.1}% of exec) | {} compiles {:.2}s",
        st.executions,
        st.exec_time_s,
        st.marshal_time_s,
        100.0 * st.marshal_time_s / st.exec_time_s.max(1e-9),
        st.compile_count,
        st.compile_time_s
    );

    engine_section(&rt)?;
    Ok(())
}
