//! Regenerates **Table I**: rounds / communication cost / training time to
//! a fixed target accuracy, for SFL vs DFL vs SSFL over the
//! {CIFAR-10-like, CIFAR-100-like} × {50, 100}-client grid (scaled fleet
//! by default; `SUPERSFL_FULL=1` for paper-scale).
//!
//! The reproduction claim is the *shape*: SSFL reaches the target in the
//! fewest rounds, with the least communication and the shortest simulated
//! training time, and the gaps widen with client count / task difficulty.

use supersfl::bench_util::scenarios::{
    cell_config, efficiency_grid, efficiency_numbers, paper_table1, run_cell, Scale,
};
use supersfl::config::{ExperimentConfig, Method};
use supersfl::metrics::Table;
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;
use supersfl::wire::WireCodecKind;

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);
    let scale = Scale::from_env();
    println!(
        "== Table I: rounds / comm / time to target (scaled fleet: {}→50, {}→100) ==\n",
        scale.clients_small, scale.clients_large
    );

    let mut table = Table::new(&[
        "dataset", "clients", "metric", "SFL", "DFL", "SSFL", "paper SFL", "paper DFL",
        "paper SSFL",
    ]);

    for cell in efficiency_grid() {
        let mut ours = Vec::new();
        for method in [Method::Sfl, Method::Dfl, Method::SuperSfl] {
            let m = run_cell(&rt, &scale, &cell, method, 42)?;
            let nums = efficiency_numbers(&m);
            eprintln!(
                "  ran c{} n{} {}: rounds {} comm {:.0} MB time {:.0} s (best acc {:.3})",
                cell.classes,
                cell.paper_clients,
                method.as_str(),
                nums.0,
                nums.1,
                nums.2,
                m.best_accuracy
            );
            ours.push(nums);
        }
        let paper = paper_table1(cell.classes, cell.paper_clients);
        let ds = format!("C{}", cell.classes);
        let cl = cell.paper_clients.to_string();
        table.row(&[
            ds.clone(),
            cl.clone(),
            format!("rounds→{:.0}%", cell.target * 100.0),
            ours[0].0.to_string(),
            ours[1].0.to_string(),
            ours[2].0.to_string(),
            paper[0].0.to_string(),
            paper[1].0.to_string(),
            paper[2].0.to_string(),
        ]);
        table.row(&[
            ds.clone(),
            cl.clone(),
            "comm (MB)".into(),
            format!("{:.0}", ours[0].1),
            format!("{:.0}", ours[1].1),
            format!("{:.0}", ours[2].1),
            format!("{:.0}", paper[0].1),
            format!("{:.0}", paper[1].1),
            format!("{:.0}", paper[2].1),
        ]);
        table.row(&[
            ds,
            cl,
            "time (s)".into(),
            format!("{:.0}", ours[0].2),
            format!("{:.0}", ours[1].2),
            format!("{:.0}", ours[2].2),
            format!("{:.0}", paper[0].2),
            format!("{:.0}", paper[1].2),
            format!("{:.0}", paper[2].2),
        ]);
    }

    println!("{}", table.render());
    println!("shape checks: SSFL rounds <= DFL <= SFL; SSFL comm lowest; SSFL time lowest.");

    // ---- Communication cost vs accuracy per wire codec ----
    // The headline 20× claim is about bytes on the link; with the wire
    // layer the encoded bytes are measured, not assumed, so each codec's
    // compression-vs-accuracy trade-off is a real end-to-end number.
    let cell = efficiency_grid()[0];
    println!(
        "\n== SSFL comm cost vs accuracy per wire codec (C{}, {} clients) ==\n",
        cell.classes,
        scale.clients(cell.paper_clients)
    );
    // A SUPERSFL_WIRE override pins every run to one codec — sweeping the
    // four kinds would just repeat the identical experiment four times.
    let env_pinned = std::env::var("SUPERSFL_WIRE").is_ok();
    if env_pinned {
        println!("note: SUPERSFL_WIRE is set — running the pinned codec once\n");
    }
    let mut wt = Table::new(&[
        "codec", "enc MB", "raw MB", "ratio", "best acc", "rounds→target",
    ]);
    for kind in [
        WireCodecKind::Fp32,
        WireCodecKind::Fp16,
        WireCodecKind::Int8,
        WireCodecKind::TopK(10),
    ] {
        let cfg = cell_config(&scale, &cell, Method::SuperSfl, 42).with_wire(kind);
        let m = run_experiment(&rt, &cfg)?.metrics;
        let (rounds, _, _) = efficiency_numbers(&m);
        eprintln!(
            "  ran wire={}: {:.1} MB encoded / {:.1} MB raw, best acc {:.3}",
            m.wire_codec, m.total_comm_mb, m.total_raw_mb, m.best_accuracy
        );
        wt.row(&[
            m.wire_codec.clone(),
            format!("{:.1}", m.total_comm_mb),
            format!("{:.1}", m.total_raw_mb),
            format!("{:.2}x", m.compression),
            format!("{:.3}", m.best_accuracy),
            rounds.to_string(),
        ]);
        if env_pinned {
            break;
        }
    }
    println!("{}", wt.render());
    if !env_pinned {
        println!(
            "shape checks: int8/topk cut encoded bytes >= 3x with accuracy close to fp32; \
             fp32's ratio is just under 1x (frame overhead)."
        );
    }
    Ok(())
}
