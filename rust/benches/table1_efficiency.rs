//! Regenerates **Table I**: rounds / communication cost / training time to
//! a fixed target accuracy, for SFL vs DFL vs SSFL over the
//! {CIFAR-10-like, CIFAR-100-like} × {50, 100}-client grid (scaled fleet
//! by default; `SUPERSFL_FULL=1` for paper-scale).
//!
//! The reproduction claim is the *shape*: SSFL reaches the target in the
//! fewest rounds, with the least communication and the shortest simulated
//! training time, and the gaps widen with client count / task difficulty.

use supersfl::bench_util::scenarios::{
    efficiency_grid, efficiency_numbers, paper_table1, run_cell, Scale,
};
use supersfl::config::{ExperimentConfig, Method};
use supersfl::metrics::Table;
use supersfl::runtime::Runtime;

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);
    let scale = Scale::from_env();
    println!(
        "== Table I: rounds / comm / time to target (scaled fleet: {}→50, {}→100) ==\n",
        scale.clients_small, scale.clients_large
    );

    let mut table = Table::new(&[
        "dataset", "clients", "metric", "SFL", "DFL", "SSFL", "paper SFL", "paper DFL",
        "paper SSFL",
    ]);

    for cell in efficiency_grid() {
        let mut ours = Vec::new();
        for method in [Method::Sfl, Method::Dfl, Method::SuperSfl] {
            let m = run_cell(&rt, &scale, &cell, method, 42)?;
            let nums = efficiency_numbers(&m);
            eprintln!(
                "  ran c{} n{} {}: rounds {} comm {:.0} MB time {:.0} s (best acc {:.3})",
                cell.classes,
                cell.paper_clients,
                method.as_str(),
                nums.0,
                nums.1,
                nums.2,
                m.best_accuracy
            );
            ours.push(nums);
        }
        let paper = paper_table1(cell.classes, cell.paper_clients);
        let ds = format!("C{}", cell.classes);
        let cl = cell.paper_clients.to_string();
        table.row(&[
            ds.clone(),
            cl.clone(),
            format!("rounds→{:.0}%", cell.target * 100.0),
            ours[0].0.to_string(),
            ours[1].0.to_string(),
            ours[2].0.to_string(),
            paper[0].0.to_string(),
            paper[1].0.to_string(),
            paper[2].0.to_string(),
        ]);
        table.row(&[
            ds.clone(),
            cl.clone(),
            "comm (MB)".into(),
            format!("{:.0}", ours[0].1),
            format!("{:.0}", ours[1].1),
            format!("{:.0}", ours[2].1),
            format!("{:.0}", paper[0].1),
            format!("{:.0}", paper[1].1),
            format!("{:.0}", paper[2].1),
        ]);
        table.row(&[
            ds,
            cl,
            "time (s)".into(),
            format!("{:.0}", ours[0].2),
            format!("{:.0}", ours[1].2),
            format!("{:.0}", ours[2].2),
            format!("{:.0}", paper[0].2),
            format!("{:.0}", paper[1].2),
            format!("{:.0}", paper[2].2),
        ]);
    }

    println!("{}", table.render());
    println!("shape checks: SSFL rounds <= DFL <= SFL; SSFL comm lowest; SSFL time lowest.");
    Ok(())
}
