//! Regenerates **Fig. 3a/3b**: accuracy-vs-round curves on the
//! CIFAR-100-like task with the 50- and 100-client fleets (scaled), for
//! SSFL / DFL / SFL. Emits the series as CSV (results/fig3_*.csv) and an
//! ASCII sparkline summary; the shape claim is SSFL above DFL above SFL
//! at every round horizon.

use supersfl::bench_util::scenarios::{cell_config, GridCell, Scale};
use supersfl::config::{ExperimentConfig, Method};
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;

fn spark(series: &[f64]) -> String {
    let glyphs = [' ', '▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    series
        .iter()
        .map(|&a| glyphs[((a * 8.0).round() as usize).min(8)])
        .collect()
}

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);
    let scale = Scale::from_env();
    std::fs::create_dir_all("results")?;

    for (fig, paper_clients) in [("fig3a", 50usize), ("fig3b", 100)] {
        println!("== {fig}: C100-like accuracy curves, paper fleet {paper_clients} ==");
        let cell = GridCell {
            classes: 100,
            paper_clients,
            target: 1.0, // never early-stop: we want full curves
            paper_target_pct: 0.0,
        };
        let mut csv = String::from("round,sfl,dfl,ssfl\n");
        let mut curves: Vec<Vec<f64>> = Vec::new();
        for method in [Method::Sfl, Method::Dfl, Method::SuperSfl] {
            let mut cfg = cell_config(&scale, &cell, method, 42);
            cfg.train.target_accuracy = None;
            cfg.train.rounds = scale.rounds_cap.min(12);
            let m = run_experiment(&rt, &cfg)?.metrics;
            let series: Vec<f64> = m.rounds.iter().map(|r| r.accuracy).collect();
            println!(
                "  {:<4} final {:.3}  |{}|",
                method.as_str(),
                series.last().copied().unwrap_or(0.0),
                spark(&series)
            );
            curves.push(series);
        }
        let rounds = curves.iter().map(|c| c.len()).max().unwrap_or(0);
        for r in 0..rounds {
            let g = |i: usize| {
                curves[i]
                    .get(r)
                    .map(|a| format!("{a:.4}"))
                    .unwrap_or_default()
            };
            csv.push_str(&format!("{},{},{},{}\n", r + 1, g(0), g(1), g(2)));
        }
        let path = format!("results/{fig}_accuracy.csv");
        std::fs::write(&path, csv)?;
        println!("  series written to {path}");

        // Shape check at mid-training: SSFL should lead.
        let mid = rounds / 2;
        if mid > 0 {
            let at = |i: usize| curves[i].get(mid).copied().unwrap_or(0.0);
            println!(
                "  at round {}: SFL {:.3}, DFL {:.3}, SSFL {:.3} (paper shape: SSFL > DFL > SFL)\n",
                mid + 1,
                at(0),
                at(1),
                at(2)
            );
        }
    }
    Ok(())
}
