//! Regenerates **Table II**: accuracy, average power, power-per-accuracy
//! (W/%) and CO₂ for SFL vs DFL vs SSFL over the evaluation grid.
//!
//! Runs each cell to the round cap (no early stop — Table II measures the
//! full training run) and reads power/energy off the simulated clock +
//! device power model (DESIGN.md §4.2–4.3).

use supersfl::bench_util::scenarios::{cell_config, efficiency_grid, paper_table2, Scale};
use supersfl::config::{ExperimentConfig, Method};
use supersfl::metrics::Table;
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);
    let scale = Scale::from_env();
    println!("== Table II: accuracy / power / W-per-%, CO2 ==\n");

    let mut table = Table::new(&[
        "dataset", "clients", "model", "acc %", "avg W", "W/%", "CO2 g", "paper acc",
        "paper W/%",
    ]);

    for cell in efficiency_grid() {
        let paper = paper_table2(cell.classes, cell.paper_clients);
        for (mi, method) in [Method::Sfl, Method::Dfl, Method::SuperSfl]
            .into_iter()
            .enumerate()
        {
            let mut cfg = cell_config(&scale, &cell, method, 42);
            cfg.train.target_accuracy = None; // full run for energy totals
            cfg.train.rounds = scale.rounds_cap.min(10);
            let m = run_experiment(&rt, &cfg)?.metrics;
            eprintln!(
                "  ran c{} n{} {}: acc {:.3} power {:.0} W",
                cell.classes,
                cell.paper_clients,
                method.as_str(),
                m.best_accuracy,
                m.avg_power_w
            );
            table.row(&[
                format!("C{}", cell.classes),
                cell.paper_clients.to_string(),
                method.as_str().to_uppercase(),
                format!("{:.2}", m.best_accuracy * 100.0),
                format!("{:.0}", m.avg_power_w),
                format!("{:.2}", m.power_per_acc),
                format!("{:.1}", m.co2_g),
                format!("{:.2}", paper[mi].0),
                format!("{:.2}", paper[mi].2),
            ]);
        }
    }

    println!("{}", table.render());
    println!(
        "shape checks: SSFL has the highest accuracy per cell and the best (lowest) \
         W/% on the 10-class task despite a power draw above DFL."
    );
    Ok(())
}
