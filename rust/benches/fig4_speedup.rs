//! Regenerates **Fig. 4**: communication- and training-time speed-up of
//! SSFL over SFL and DFL across the evaluation grid (bars in the paper;
//! ASCII bars + a table here). Speed-up = baseline metric / SSFL metric
//! at the same target accuracy.

use supersfl::bench_util::scenarios::{
    efficiency_grid, efficiency_numbers, paper_table1, run_cell, Scale,
};
use supersfl::config::{ExperimentConfig, Method};
use supersfl::metrics::Table;
use supersfl::runtime::Runtime;

fn bar(x: f64, unit: f64) -> String {
    let n = ((x / unit).round() as usize).clamp(1, 60);
    "#".repeat(n)
}

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);
    let scale = Scale::from_env();
    println!("== Fig. 4: SSFL speed-up over SFL / DFL ==\n");

    let mut table = Table::new(&[
        "setting",
        "comm ×(SFL/SSFL)",
        "comm ×(DFL/SSFL)",
        "time ×(SFL/SSFL)",
        "time ×(DFL/SSFL)",
        "paper comm ×SFL",
        "paper time ×SFL",
    ]);

    for cell in efficiency_grid().into_iter().filter(|c| c.classes == 10) {
        let sfl = efficiency_numbers(&run_cell(&rt, &scale, &cell, Method::Sfl, 42)?);
        let dfl = efficiency_numbers(&run_cell(&rt, &scale, &cell, Method::Dfl, 42)?);
        let ssfl = efficiency_numbers(&run_cell(&rt, &scale, &cell, Method::SuperSfl, 42)?);
        let paper = paper_table1(cell.classes, cell.paper_clients);
        let p_comm = paper[0].1 / paper[2].1;
        let p_time = paper[0].2 / paper[2].2;
        let label = format!("C{} n{}", cell.classes, cell.paper_clients);
        let c_sfl = sfl.1 / ssfl.1.max(1e-9);
        let c_dfl = dfl.1 / ssfl.1.max(1e-9);
        let t_sfl = sfl.2 / ssfl.2.max(1e-9);
        let t_dfl = dfl.2 / ssfl.2.max(1e-9);
        eprintln!("  {label} comm x{c_sfl:.1} |{}|", bar(c_sfl, 0.5));
        table.row(&[
            label,
            format!("{c_sfl:.1}"),
            format!("{c_dfl:.1}"),
            format!("{t_sfl:.1}"),
            format!("{t_dfl:.1}"),
            format!("{p_comm:.1}"),
            format!("{p_time:.1}"),
        ]);
    }
    println!("{}", table.render());
    println!("shape: every speed-up factor > 1; largest gains at 100 clients (paper: up to 20× comm, 13× time).");
    Ok(())
}
