//! Regenerates **Fig. 4**: communication- and training-time speed-up of
//! SSFL over SFL and DFL across the evaluation grid (bars in the paper;
//! ASCII bars + a table here). Speed-up = baseline metric / SSFL metric
//! at the same target accuracy.
//!
//! A second section runs the **fleet-size ladder**: sampled SuperSFL
//! over 1k and 10k clients with a fixed cohort, asserting that per-round
//! client state (pooled `ClientState`s + lane buffers) stays flat while
//! the fleet grows 10× — the scaling claim behind `--sample`.
//!
//! Everything is also written to `BENCH_fig4.json` at the repository
//! root so CI can accumulate the numbers across commits.

use std::path::PathBuf;

use supersfl::bench_util::provenance;
use supersfl::bench_util::scenarios::{
    cell_config, efficiency_grid, efficiency_numbers, fleet_ladder, ladder_config, paper_table1,
    run_cell, smoke, Scale,
};
use supersfl::config::{ExperimentConfig, Method};
use supersfl::metrics::Table;
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;
use supersfl::util::json::JsonValue;

fn bar(x: f64, unit: f64) -> String {
    let n = ((x / unit).round() as usize).clamp(1, 60);
    "#".repeat(n)
}

fn num(x: f64) -> JsonValue {
    JsonValue::Number(x)
}

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);
    let scale = Scale::from_env();
    let mut root = JsonValue::object();
    root.set("bench", JsonValue::String("fig4_speedup".into()));
    root.set("smoke", JsonValue::Bool(smoke()));
    println!("== Fig. 4: SSFL speed-up over SFL / DFL ==\n");

    let mut table = Table::new(&[
        "setting",
        "comm ×(SFL/SSFL)",
        "comm ×(DFL/SSFL)",
        "time ×(SFL/SSFL)",
        "time ×(DFL/SSFL)",
        "paper comm ×SFL",
        "paper time ×SFL",
    ]);

    let mut speedup_rows = Vec::new();
    for cell in efficiency_grid().into_iter().filter(|c| c.classes == 10) {
        let sfl = efficiency_numbers(&run_cell(&rt, &scale, &cell, Method::Sfl, 42)?);
        let dfl = efficiency_numbers(&run_cell(&rt, &scale, &cell, Method::Dfl, 42)?);
        let ssfl = efficiency_numbers(&run_cell(&rt, &scale, &cell, Method::SuperSfl, 42)?);
        let paper = paper_table1(cell.classes, cell.paper_clients);
        let p_comm = paper[0].1 / paper[2].1;
        let p_time = paper[0].2 / paper[2].2;
        let label = format!("C{} n{}", cell.classes, cell.paper_clients);
        let c_sfl = sfl.1 / ssfl.1.max(1e-9);
        let c_dfl = dfl.1 / ssfl.1.max(1e-9);
        let t_sfl = sfl.2 / ssfl.2.max(1e-9);
        let t_dfl = dfl.2 / ssfl.2.max(1e-9);
        eprintln!("  {label} comm x{c_sfl:.1} |{}|", bar(c_sfl, 0.5));
        let mut row = JsonValue::object();
        row.set("setting", JsonValue::String(label.clone()));
        row.set("comm_x_sfl", num(c_sfl));
        row.set("comm_x_dfl", num(c_dfl));
        row.set("time_x_sfl", num(t_sfl));
        row.set("time_x_dfl", num(t_dfl));
        speedup_rows.push(row);
        table.row(&[
            label,
            format!("{c_sfl:.1}"),
            format!("{c_dfl:.1}"),
            format!("{t_sfl:.1}"),
            format!("{t_dfl:.1}"),
            format!("{p_comm:.1}"),
            format!("{p_time:.1}"),
        ]);
    }
    root.set("speedup", JsonValue::Array(speedup_rows));
    println!("{}", table.render());
    println!("shape: every speed-up factor > 1; largest gains at 100 clients (paper: up to 20× comm, 13× time).");

    // ---- Fleet-size ladder: sampled participation keeps memory flat ----
    println!("\n== scaling: sampled participation (fixed cohort, growing fleet) ==\n");
    let mut l_table = Table::new(&[
        "fleet",
        "cohort",
        "max pooled clients",
        "max pooled lane f32",
        "final acc",
        "sim time s",
    ]);
    let mut ladder_rows = Vec::new();
    let mut high_water: Vec<usize> = Vec::new();
    for (label, fleet, cohort) in fleet_ladder() {
        let res = run_experiment(&rt, &ladder_config(&scale, fleet, cohort, 42))?;
        // The scaling claim: pooled state is bounded by the cohort, not
        // the fleet. A rung that materializes more than its cohort is a
        // regression, full stop.
        assert!(
            res.pool.max_materialized <= cohort,
            "{label}: {} clients materialized for a cohort of {cohort}",
            res.pool.max_materialized
        );
        high_water.push(res.pool.max_materialized);
        l_table.row(&[
            label.to_string(),
            format!("{cohort}"),
            format!("{}", res.pool.max_materialized),
            format!("{}", res.pool.max_lane_f32),
            format!("{:.3}", res.metrics.final_accuracy),
            format!("{:.1}", res.metrics.total_sim_time_s),
        ]);
        let mut row = JsonValue::object();
        row.set("fleet", num(fleet as f64));
        row.set("cohort", num(cohort as f64));
        row.set("max_materialized", num(res.pool.max_materialized as f64));
        row.set("max_lane_f32", num(res.pool.max_lane_f32 as f64));
        row.set("final_accuracy", num(res.metrics.final_accuracy));
        row.set("sim_time_s", num(res.metrics.total_sim_time_s));
        ladder_rows.push(row);
    }
    // Flat means flat: the 10k rung must pool exactly as many clients as
    // the 1k rung (both cohort-bounded), not merely "fewer than fleet".
    assert_eq!(
        high_water.first(),
        high_water.last(),
        "pooled client high-water must not grow with the fleet"
    );
    root.set("fleet_ladder", JsonValue::Array(ladder_rows));
    println!("{}", l_table.render());
    println!("shape: pooled state is cohort-bounded — the 10k-client rung pools no more than the 1k rung.");

    // Stamp the shared provenance block (anchored on the grid's first
    // SSFL cell — every other cell derives from the same base config).
    root.set(
        "provenance",
        provenance(&cell_config(
            &scale,
            &efficiency_grid()[0],
            Method::SuperSfl,
            42,
        )),
    );

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_fig4.json");
    supersfl::util::fs::atomic_write(&path, root.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}
