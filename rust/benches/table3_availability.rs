//! Regenerates **Table III**: SuperSFL accuracy vs server-gradient
//! availability {100, 70, 50, 20, 10, 0}% (3 seeds → mean ± std), showing
//! graceful degradation instead of collapse thanks to the fault-tolerant
//! client-side classifier (paper §II-C / §IV).
//!
//! Two chaos extensions ride on the same fleet (full availability, the
//! deterministic fault engine doing the damage instead):
//! * **Bursty-link sweep** — the Gilbert–Elliott severity ladder from
//!   `bench_util::scenarios::ge_ladder`, reporting accuracy next to the
//!   drop/retry counters the ledger recorded.
//! * **Quorum sweep** — one mid-round crash + bursty links under
//!   increasingly strict merge-quorum fractions.
//!
//! Everything is also written to `BENCH_table3.json` at the repository
//! root (machine-readable, accumulated as a CI artifact). Runs on the
//! native backend everywhere, so the CI smoke leg asserts it never
//! prints "skipping".

use std::path::PathBuf;

use supersfl::bench_util::scenarios::{
    ge_ladder, paper_table3, quorum_churn_spec, quorum_ladder, smoke, with_faults,
};
use supersfl::config::ExperimentConfig;
use supersfl::metrics::{RunMetrics, Table};
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;
use supersfl::util::json::JsonValue;

fn cfg(avail: f64, seed: u64) -> ExperimentConfig {
    let rounds = if smoke() { 3 } else { 10 };
    let mut cfg = ExperimentConfig::default()
        .with_name(&format!("t3_a{:.0}", avail * 100.0))
        .with_clients(6)
        .with_rounds(rounds)
        .with_seed(seed);
    cfg.net.server_availability = avail;
    cfg.data.train_per_class = if smoke() { 30 } else { 100 };
    cfg.train.local_steps = if smoke() { 1 } else { 2 };
    cfg.train.eval_samples = if smoke() { 100 } else { 400 };
    cfg
}

fn mode_label(avail: f64) -> &'static str {
    match (avail * 100.0) as u32 {
        100 => "Fully server-assisted",
        70 => "Mostly server-assisted",
        50 => "Partially server-assisted",
        20 => "Mostly client-driven",
        10 => "Client-driven",
        _ => "Serverless",
    }
}

/// Fraction of client steps that took the Alg. 3 local-only fallback.
fn fallback_frac(m: &RunMetrics) -> f64 {
    let fb: usize = m.rounds.iter().map(|r| r.fallback_steps).sum();
    let total: usize = m
        .rounds
        .iter()
        .map(|r| r.fallback_steps + r.server_steps)
        .sum();
    fb as f64 / total.max(1) as f64
}

fn num(x: f64) -> JsonValue {
    JsonValue::Number(x)
}

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);
    let mut root = JsonValue::object();
    root.set("bench", JsonValue::String("table3_availability".into()));
    root.set("smoke", JsonValue::Bool(smoke()));

    println!("== Table III: accuracy vs server gradient availability ==\n");

    let seeds: &[u64] = if smoke() { &[42] } else { &[42, 43] };
    let mut table = Table::new(&[
        "availability %", "training mode", "acc % (mean±std)", "fallback %", "paper acc %",
    ]);

    let mut avail_rows = Vec::new();
    let mut accs_by_avail = Vec::new();
    for &(avail_pct, paper_acc, paper_std) in paper_table3().iter() {
        let avail = avail_pct / 100.0;
        let mut accs = Vec::new();
        let mut fb_frac = 0.0;
        for &seed in seeds {
            let m = run_experiment(&rt, &cfg(avail, seed))?.metrics;
            accs.push(m.best_accuracy * 100.0);
            fb_frac += fallback_frac(&m);
            eprintln!("  avail {avail_pct}% seed {seed}: acc {:.2}%", m.best_accuracy * 100.0);
        }
        fb_frac /= seeds.len() as f64;
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        let var = accs.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>() / accs.len() as f64;
        accs_by_avail.push(mean);
        table.row(&[
            format!("{avail_pct:.0}"),
            mode_label(avail).into(),
            format!("{mean:.2} ± {:.2}", var.sqrt()),
            format!("{:.0}%", fb_frac * 100.0),
            format!("{paper_acc:.2} ± {paper_std:.2}"),
        ]);
        let mut row = JsonValue::object();
        row.set("availability_pct", num(avail_pct));
        row.set("acc_pct_mean", num(mean));
        row.set("acc_pct_std", num(var.sqrt()));
        row.set("fallback_frac", num(fb_frac));
        row.set("paper_acc_pct", num(paper_acc));
        row.set("paper_acc_std", num(paper_std));
        avail_rows.push(row);
    }
    root.set("availability", JsonValue::Array(avail_rows));

    println!("{}", table.render());
    // Shape check: monotone-ish degradation, serverless still learns.
    let first = accs_by_avail.first().copied().unwrap_or(0.0);
    let last = accs_by_avail.last().copied().unwrap_or(0.0);
    println!(
        "shape: 100% avail {:.1}% → serverless {:.1}% (graceful, not collapse; paper: 95.6 → 86.4)",
        first, last
    );

    // ---- Bursty-link (Gilbert–Elliott) sweep ---------------------------
    // Full server availability; the chaos engine supplies the loss. The
    // shape being reproduced: accuracy degrades gracefully as π_bad and
    // burst length rise, while the ledger proves the faults happened.
    println!("\n== Table III-b: accuracy under bursty (Gilbert–Elliott) links ==\n");
    let mut ge_table = Table::new(&["link", "acc %", "drops", "retries", "fallback %"]);
    let mut ge_rows = Vec::new();
    for (i, (label, spec)) in ge_ladder().iter().enumerate() {
        let c = with_faults(cfg(1.0, 42).with_name(&format!("t3_ge{i}")), spec);
        let m = run_experiment(&rt, &c)?.metrics;
        eprintln!(
            "  ge[{label}]: acc {:.2}%  drops {}  retries {}",
            m.best_accuracy * 100.0,
            m.total_drops,
            m.total_retries
        );
        ge_table.row(&[
            (*label).into(),
            format!("{:.2}", m.best_accuracy * 100.0),
            format!("{}", m.total_drops),
            format!("{}", m.total_retries),
            format!("{:.0}%", fallback_frac(&m) * 100.0),
        ]);
        let mut row = JsonValue::object();
        row.set("label", JsonValue::String((*label).into()));
        row.set("spec", JsonValue::String((*spec).into()));
        row.set("acc_pct", num(m.best_accuracy * 100.0));
        row.set("drops", num(m.total_drops as f64));
        row.set("retries", num(m.total_retries as f64));
        row.set("timeouts", num(m.total_timeouts as f64));
        row.set("fallback_frac", num(fallback_frac(&m)));
        ge_rows.push(row);
    }
    root.set("ge_sweep", JsonValue::Array(ge_rows));
    println!("{}", ge_table.render());

    // ---- Quorum-barrier sweep ------------------------------------------
    // One mid-round crash + bursty links; the quorum fraction decides how
    // many live lanes must report before the SSFL merge proceeds.
    println!("== Table III-c: accuracy vs merge-quorum under churn ==\n");
    let mut q_table = Table::new(&["quorum", "acc %", "crashes", "drops", "fallback %"]);
    let mut q_rows = Vec::new();
    for q in quorum_ladder() {
        let spec = quorum_churn_spec(q);
        let c = with_faults(cfg(1.0, 42).with_name(&format!("t3_q{:.0}", q * 100.0)), &spec);
        let m = run_experiment(&rt, &c)?.metrics;
        eprintln!(
            "  quorum {q}: acc {:.2}%  crashes {}",
            m.best_accuracy * 100.0,
            m.total_crashes
        );
        q_table.row(&[
            format!("{q:.2}"),
            format!("{:.2}", m.best_accuracy * 100.0),
            format!("{}", m.total_crashes),
            format!("{}", m.total_drops),
            format!("{:.0}%", fallback_frac(&m) * 100.0),
        ]);
        let mut row = JsonValue::object();
        row.set("quorum", num(q));
        row.set("spec", JsonValue::String(spec));
        row.set("acc_pct", num(m.best_accuracy * 100.0));
        row.set("crashes", num(m.total_crashes as f64));
        row.set("drops", num(m.total_drops as f64));
        row.set("fallback_frac", num(fallback_frac(&m)));
        q_rows.push(row);
    }
    root.set("quorum_sweep", JsonValue::Array(q_rows));
    println!("{}", q_table.render());

    // Shared provenance stamp, anchored on the bench's base config (the
    // availability/fault sweeps derive from it).
    root.set("provenance", supersfl::bench_util::provenance(&cfg(1.0, 42)));

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_table3.json");
    supersfl::util::fs::atomic_write(&path, root.to_string_pretty().as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}
