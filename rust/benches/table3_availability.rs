//! Regenerates **Table III**: SuperSFL accuracy vs server-gradient
//! availability {100, 70, 50, 20, 10, 0}% (3 seeds → mean ± std), showing
//! graceful degradation instead of collapse thanks to the fault-tolerant
//! client-side classifier (paper §II-C / §IV).

use supersfl::config::ExperimentConfig;
use supersfl::metrics::Table;
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;
use supersfl::bench_util::scenarios::{paper_table3, smoke};

fn cfg(avail: f64, seed: u64) -> ExperimentConfig {
    let rounds = if smoke() { 3 } else { 10 };
    let mut cfg = ExperimentConfig::default()
        .with_name(&format!("t3_a{:.0}", avail * 100.0))
        .with_clients(6)
        .with_rounds(rounds)
        .with_seed(seed);
    cfg.net.server_availability = avail;
    cfg.data.train_per_class = if smoke() { 30 } else { 100 };
    cfg.train.local_steps = if smoke() { 1 } else { 2 };
    cfg.train.eval_samples = if smoke() { 100 } else { 400 };
    cfg
}

fn mode_label(avail: f64) -> &'static str {
    match (avail * 100.0) as u32 {
        100 => "Fully server-assisted",
        70 => "Mostly server-assisted",
        50 => "Partially server-assisted",
        20 => "Mostly client-driven",
        10 => "Client-driven",
        _ => "Serverless",
    }
}

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);
    println!("== Table III: accuracy vs server gradient availability ==\n");

    let seeds: &[u64] = if smoke() { &[42] } else { &[42, 43] };
    let mut table = Table::new(&[
        "availability %", "training mode", "acc % (mean±std)", "fallback %", "paper acc %",
    ]);

    let mut accs_by_avail = Vec::new();
    for (ai, &(avail_pct, paper_acc, paper_std)) in paper_table3().iter().enumerate() {
        let avail = avail_pct / 100.0;
        let mut accs = Vec::new();
        let mut fb_frac = 0.0;
        for &seed in seeds {
            let m = run_experiment(&rt, &cfg(avail, seed))?.metrics;
            accs.push(m.best_accuracy * 100.0);
            let fb: usize = m.rounds.iter().map(|r| r.fallback_steps).sum();
            let total: usize = m
                .rounds
                .iter()
                .map(|r| r.fallback_steps + r.server_steps)
                .sum();
            fb_frac += fb as f64 / total.max(1) as f64;
            eprintln!("  avail {avail_pct}% seed {seed}: acc {:.2}%", m.best_accuracy * 100.0);
        }
        fb_frac /= seeds.len() as f64;
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        let var = accs.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>() / accs.len() as f64;
        accs_by_avail.push(mean);
        table.row(&[
            format!("{avail_pct:.0}"),
            mode_label(avail).into(),
            format!("{mean:.2} ± {:.2}", var.sqrt()),
            format!("{:.0}%", fb_frac * 100.0),
            format!("{paper_acc:.2} ± {paper_std:.2}"),
        ]);
        let _ = ai;
    }

    println!("{}", table.render());
    // Shape check: monotone-ish degradation, serverless still learns.
    let first = accs_by_avail.first().copied().unwrap_or(0.0);
    let last = accs_by_avail.last().copied().unwrap_or(0.0);
    println!(
        "shape: 100% avail {:.1}% → serverless {:.1}% (graceful, not collapse; paper: 95.6 → 86.4)",
        first, last
    );
    Ok(())
}
