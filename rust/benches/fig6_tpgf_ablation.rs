//! Regenerates **Fig. 6**: the TPGF fusion-rule ablation on the
//! CIFAR-10-like task — full rule vs no-loss-term vs no-depth-term vs
//! naïve equal fusion (paper §IV). Expected ordering:
//! full > no_loss > no_depth > equal.

use supersfl::bench_util::scenarios::paper_fig6;
use supersfl::config::{ExperimentConfig, TpgfMode};
use supersfl::metrics::Table;
use supersfl::orchestrator::run_experiment;
use supersfl::runtime::Runtime;

fn cfg(mode: TpgfMode) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default()
        .with_name(&format!("fig6_{}", mode.as_str()))
        .with_clients(8)
        .with_rounds(12)
        .with_seed(42);
    cfg.ssfl.tpgf_mode = mode;
    cfg.data.train_per_class = 100;
    cfg.train.local_steps = 2;
    cfg.train.eval_samples = 400;
    cfg
}

fn main() -> supersfl::Result<()> {
    let rt = Runtime::load_if_available(&ExperimentConfig::default().artifacts_dir);
    println!("== Fig. 6: TPGF fusion-rule ablation ==\n");

    let mut table = Table::new(&["fusion rule", "best acc %", "final acc %", "paper acc %"]);
    let mut results = Vec::new();
    for (mode, (paper_name, paper_acc)) in [
        TpgfMode::Full,
        TpgfMode::NoLoss,
        TpgfMode::NoDepth,
        TpgfMode::Equal,
    ]
    .into_iter()
    .zip(paper_fig6())
    {
        let m = run_experiment(&rt, &cfg(mode))?.metrics;
        eprintln!("  {}: best {:.3}", mode.as_str(), m.best_accuracy);
        assert_eq!(mode.as_str(), paper_name);
        results.push((mode, m.best_accuracy));
        table.row(&[
            mode.as_str().into(),
            format!("{:.2}", m.best_accuracy * 100.0),
            format!("{:.2}", m.final_accuracy * 100.0),
            format!("{paper_acc:.2}"),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper ordering: full > no_loss > no_depth > equal; ours: {}",
        results
            .iter()
            .map(|(m, a)| format!("{} {:.3}", m.as_str(), a))
            .collect::<Vec<_>>()
            .join(" | ")
    );
    Ok(())
}
