//! Determinism-contract audit for the SuperSFL reproduction.
//!
//! `cargo run -p xtask -- audit` walks `rust/src` with a hand-rolled,
//! comment/string/attribute-aware Rust lexer (no `syn`, no external
//! dependencies) and enforces the named lints in [`rules::RULES`]:
//! hash-order leaks, wall-clock reads, ambient entropy, undocumented
//! `unsafe`, raw artifact writes, stray env reads, and implicit f32
//! iterator folds. Diagnostics are `file:line`; the machine-readable
//! report lands in `AUDIT.json` (atomic write, provenance-stamped).
//!
//! Escape hatch: `// audit:allow(<rule>) -- <justification>` on or
//! directly above the flagged line. Bare allows are rejected.

#![deny(unreachable_pub)]

pub mod lexer;
pub mod report;
pub mod rules;

use rules::{Allow, Violation};
use std::path::Path;

/// Aggregate result of auditing a tree.
pub struct AuditOutcome {
    pub files_scanned: usize,
    pub violations: Vec<Violation>,
    pub allows: Vec<Allow>,
    pub malformed: Vec<Violation>,
}

impl AuditOutcome {
    pub fn clean(&self) -> bool {
        self.violations.is_empty() && self.malformed.is_empty()
    }
}

/// Audit every `.rs` file under `src_root`. Findings come back sorted
/// by (file, line) for deterministic diagnostics and reports.
pub fn audit_tree(src_root: &Path) -> std::io::Result<AuditOutcome> {
    let files = rules::collect_rs_files(src_root)?;
    let mut out = AuditOutcome {
        files_scanned: files.len(),
        violations: Vec::new(),
        allows: Vec::new(),
        malformed: Vec::new(),
    };
    for path in &files {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let text = std::fs::read_to_string(path)?;
        let rep = rules::audit_file(&rel, &text);
        out.violations.extend(rep.violations);
        out.allows.extend(rep.allows);
        out.malformed.extend(rep.malformed);
    }
    // collect_rs_files sorts paths; per-file findings are already in
    // line order, so a stable sort on file keeps everything canonical.
    out.violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    out.allows.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    out.malformed.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(out)
}
