//! The determinism-contract rules and the `audit:allow` escape hatch.
//!
//! Each rule is a named lint with file:line diagnostics. A violation is
//! suppressed only by an inline annotation of the form
//!
//! ```text
//! // audit:allow(rule-name) -- justification text
//! ```
//!
//! either trailing on the flagged line or as an own-line comment
//! immediately above it (attribute/comment lines in between are fine).
//! A bare `audit:allow(rule)` without a ` -- justification`, or one
//! naming an unknown rule, is itself a failure (`malformed-allow`).

use crate::lexer::{lex, Lexed};
use std::path::Path;

/// Identity of one lint. `allow_files` are path prefixes (relative to
/// the src root, `/`-separated) where the pattern is part of the
/// documented contract and never flagged.
pub struct Rule {
    pub name: &'static str,
    pub description: &'static str,
    /// Identifier-boundary patterns matched on the comment+string-free
    /// code view.
    pub code_patterns: &'static [&'static str],
    /// Substring patterns matched on the comment-free view that keeps
    /// string literals (for contraband like `"/dev/urandom"`).
    pub string_patterns: &'static [&'static str],
    /// Path prefixes exempt from this rule.
    pub allow_files: &'static [&'static str],
    /// When set, the rule only applies under these path prefixes.
    pub only_files: &'static [&'static str],
    /// Whether `#[cfg(test)]` regions are scanned.
    pub include_tests: bool,
}

/// The determinism contract, one row per rule. Order is report order.
pub const RULES: &[Rule] = &[
    Rule {
        name: "unordered-iter",
        description: "no HashMap/HashSet construction or iteration: hash \
                      iteration order is unspecified and would leak into \
                      merge order, wire bytes, or trace streams; use \
                      BTreeMap/BTreeSet or sorted vectors",
        code_patterns: &["HashMap", "HashSet"],
        string_patterns: &[],
        allow_files: &[],
        only_files: &[],
        include_tests: true,
    },
    Rule {
        name: "wall-clock",
        description: "no Instant::now/SystemTime outside the allowlisted \
                      host-timing sites (runtime kernel/compile timers, \
                      transport socket deadlines, bench_util, main): wall \
                      clock on the round path would diverge trajectories \
                      across hosts and thread counts",
        code_patterns: &["Instant::now", "SystemTime"],
        string_patterns: &[],
        allow_files: &[
            "runtime/pjrt.rs",
            "runtime/native/mod.rs",
            "runtime/native/kernels.rs",
            "transport/tcp.rs",
            "bench_util/",
            "main.rs",
        ],
        only_files: &[],
        include_tests: false,
    },
    Rule {
        name: "os-entropy",
        description: "no OS or ambient randomness anywhere (rand, \
                      thread_rng, RandomState, OsRng, getrandom, \
                      /dev/urandom): all randomness flows through seeded \
                      Pcg32 lane streams",
        code_patterns: &["thread_rng", "RandomState", "OsRng", "getrandom", "from_entropy"],
        string_patterns: &["/dev/urandom", "/dev/random"],
        allow_files: &[],
        only_files: &[],
        include_tests: true,
    },
    Rule {
        name: "unsafe-undocumented",
        description: "every unsafe block/impl must carry a `// SAFETY:` \
                      comment within two lines above (or trailing)",
        code_patterns: &[], // custom logic
        string_patterns: &[],
        allow_files: &[],
        only_files: &[],
        include_tests: true,
    },
    Rule {
        name: "raw-artifact-write",
        description: "no direct File::create/fs::write outside util/fs.rs: \
                      run artifacts must go through the atomic \
                      temp+rename funnel so interrupted runs never leave \
                      truncated files",
        code_patterns: &["File::create", "fs::write"],
        string_patterns: &[],
        allow_files: &["util/fs.rs"],
        only_files: &[],
        include_tests: false,
    },
    Rule {
        name: "env-read",
        description: "std::env::var only in config/, main.rs and \
                      bench_util/: every other env-wins override site \
                      must be annotated so the documented precedence \
                      stays auditable",
        code_patterns: &["env::var", "env::var_os", "env::vars"],
        string_patterns: &[],
        allow_files: &["config/", "main.rs", "bench_util/"],
        only_files: &[],
        include_tests: false,
    },
    Rule {
        name: "float-fold",
        description: "no .sum::<f32>()/.product::<f32>() iterator folds in \
                      runtime/native: fold order must be spelled out per \
                      the kernels.rs bitwise contract",
        code_patterns: &["sum::<f32>", "product::<f32>"],
        string_patterns: &[],
        allow_files: &[],
        only_files: &["runtime/native/"],
        include_tests: true,
    },
];

pub fn rule_by_name(name: &str) -> Option<&'static Rule> {
    RULES.iter().find(|r| r.name == name)
}

/// One diagnostic. `rule` may also be the pseudo-rule `malformed-allow`.
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: String,
    pub file: String,
    pub line: usize,
    pub message: String,
    pub snippet: String,
}

/// One accepted escape hatch.
#[derive(Debug, Clone)]
pub struct Allow {
    pub rule: String,
    pub file: String,
    pub line: usize,
    /// The code line this allow governs.
    pub target_line: usize,
    pub justification: String,
}

/// Everything the audit learned about one file.
pub struct FileReport {
    pub violations: Vec<Violation>,
    pub allows: Vec<Allow>,
    pub malformed: Vec<Violation>,
}

fn path_matches(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p))
}

fn ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// All identifier-boundary occurrences of `pat` in `view`: the bytes
/// just before and after the match must not extend an identifier.
fn find_pattern(view: &str, pat: &str) -> Vec<usize> {
    let v = view.as_bytes();
    let p = pat.as_bytes();
    let mut out = Vec::new();
    if p.is_empty() || v.len() < p.len() {
        return out;
    }
    let first_ident = ident_byte(p[0]);
    let last_ident = ident_byte(p[p.len() - 1]);
    let mut i = 0usize;
    while i + p.len() <= v.len() {
        if &v[i..i + p.len()] == p {
            let before_ok = !first_ident || i == 0 || !ident_byte(v[i - 1]);
            let after = i + p.len();
            let after_ok = !last_ident || after >= v.len() || !ident_byte(v[after]);
            if before_ok && after_ok {
                out.push(i);
            }
        }
        i += 1;
    }
    out
}

/// Parse the `audit:allow(...)` annotations in a file's comments.
fn collect_allows(
    rel: &str,
    lx: &Lexed,
    allows: &mut Vec<Allow>,
    malformed: &mut Vec<Violation>,
) {
    for c in &lx.comments {
        let Some(pos) = c.text.find("audit:allow") else {
            continue;
        };
        let rest = &c.text[pos + "audit:allow".len()..];
        let parsed = (|| {
            let rest = rest.strip_prefix('(')?;
            let close = rest.find(')')?;
            let rule = rest[..close].trim().to_string();
            let after = &rest[close + 1..];
            let just = after.trim_start().strip_prefix("--")?.trim().to_string();
            Some((rule, just))
        })();
        let (rule, just) = match parsed {
            Some(p) => p,
            None => {
                malformed.push(Violation {
                    rule: "malformed-allow".into(),
                    file: rel.into(),
                    line: c.line,
                    message: "audit:allow must be written \
                              `audit:allow(<rule>) -- <justification>`"
                        .into(),
                    snippet: c.text.trim().to_string(),
                });
                continue;
            }
        };
        if rule_by_name(&rule).is_none() {
            malformed.push(Violation {
                rule: "malformed-allow".into(),
                file: rel.into(),
                line: c.line,
                message: format!("audit:allow names unknown rule '{rule}'"),
                snippet: c.text.trim().to_string(),
            });
            continue;
        }
        if just.is_empty() {
            malformed.push(Violation {
                rule: "malformed-allow".into(),
                file: rel.into(),
                line: c.line,
                message: format!(
                    "bare audit:allow({rule}) — a non-empty justification \
                     after ` -- ` is required"
                ),
                snippet: c.text.trim().to_string(),
            });
            continue;
        }
        // An own-line allow governs the next line holding code; a
        // trailing allow governs its own line.
        let target_line = if c.own_line {
            let mut l = c.line + 1;
            while l <= lx.line_count() && lx.line_is_codeless(l) {
                l += 1;
            }
            l
        } else {
            c.line
        };
        allows.push(Allow {
            rule,
            file: rel.into(),
            line: c.line,
            target_line,
            justification: just,
        });
    }
}

/// True when `line` has a SAFETY comment either trailing or in the
/// contiguous comment block ending within two lines above (attribute
/// lines may intervene).
fn has_safety_comment(lx: &Lexed, line: usize) -> bool {
    if lx.comments_on(line).any(|c| c.text.contains("SAFETY:")) {
        return true;
    }
    // Find the nearest comment line within the two lines above, skipping
    // attribute-only lines.
    let mut probe = line;
    let mut hops = 0;
    while probe > 1 && hops < 2 {
        probe -= 1;
        hops += 1;
        let code_line = lx.line_text(&lx.code, probe).trim().to_string();
        let is_attr = code_line.starts_with("#[") || code_line.starts_with("#![");
        if lx.comments_on(probe).next().is_some() {
            // Walk the contiguous comment block upward.
            let mut l = probe;
            loop {
                if lx.comments_on(l).any(|c| c.text.contains("SAFETY:")) {
                    return true;
                }
                if l == 1 || lx.comments_on(l - 1).next().is_none() {
                    break;
                }
                l -= 1;
            }
            return false;
        }
        if !code_line.is_empty() && !is_attr {
            return false; // real code intervenes
        }
        if is_attr {
            hops -= 1; // attributes don't consume the two-line budget
        }
    }
    false
}

/// Scan for `unsafe` blocks / impls / traits missing a SAFETY comment.
/// `unsafe fn` declarations are exempt here: their contract lives in the
/// `# Safety` doc section, and their bodies' inner `unsafe {}` blocks
/// are still scanned (and forced to exist by `unsafe_op_in_unsafe_fn`).
fn check_unsafe(rel: &str, lx: &Lexed, out: &mut Vec<Violation>) {
    for off in find_pattern(&lx.code, "unsafe") {
        let after = lx.code[off + "unsafe".len()..].trim_start();
        let kind = if after.starts_with("fn") {
            continue;
        } else if after.starts_with("impl") || after.starts_with("trait") {
            "impl"
        } else if after.starts_with('{') {
            "block"
        } else {
            continue; // e.g. `unsafe` in a macro path or attr argument
        };
        let line = lx.line_of(off);
        if !has_safety_comment(lx, line) {
            out.push(Violation {
                rule: "unsafe-undocumented".into(),
                file: rel.into(),
                line,
                message: format!(
                    "unsafe {kind} without a `// SAFETY:` comment within \
                     two lines"
                ),
                snippet: lx.line_text(&lx.code, line).trim().to_string(),
            });
        }
    }
}

/// Run every rule over one file. `rel` is the `/`-separated path
/// relative to the src root.
pub fn audit_file(rel: &str, text: &str) -> FileReport {
    let lx = lex(text);
    let mut allows = Vec::new();
    let mut malformed = Vec::new();
    collect_allows(rel, &lx, &mut allows, &mut malformed);

    let mut raw: Vec<Violation> = Vec::new();
    for rule in RULES {
        if path_matches(rel, rule.allow_files) {
            continue;
        }
        if !rule.only_files.is_empty() && !path_matches(rel, rule.only_files) {
            continue;
        }
        if rule.name == "unsafe-undocumented" {
            check_unsafe(rel, &lx, &mut raw);
            continue;
        }
        for (view, pats) in [
            (&lx.code, rule.code_patterns),
            (&lx.code_strings, rule.string_patterns),
        ] {
            for pat in pats {
                for off in find_pattern(view, pat) {
                    if !rule.include_tests && lx.in_test(off) {
                        continue;
                    }
                    let line = lx.line_of(off);
                    raw.push(Violation {
                        rule: rule.name.into(),
                        file: rel.into(),
                        line,
                        message: format!("{pat} — {}", rule.description),
                        snippet: lx.line_text(&lx.code_strings, line).trim().to_string(),
                    });
                }
            }
        }
    }

    // Apply the escape hatch: an allow suppresses violations of its rule
    // on its target line.
    let violations: Vec<Violation> = raw
        .into_iter()
        .filter(|v| {
            !allows
                .iter()
                .any(|a| a.rule == v.rule && a.target_line == v.line)
        })
        .collect();

    FileReport {
        violations,
        allows,
        malformed,
    }
}

/// Recursively collect `.rs` files under `root`, sorted for
/// deterministic report order.
pub fn collect_rs_files(root: &Path) -> std::io::Result<Vec<std::path::PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let entry = entry?;
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn violations_of(rel: &str, src: &str, rule: &str) -> Vec<Violation> {
        let rep = audit_file(rel, src);
        rep.violations
            .into_iter()
            .filter(|v| v.rule == rule)
            .collect()
    }

    #[test]
    fn hashmap_fires_and_btreemap_does_not() {
        let fire = violations_of(
            "orchestrator/mod.rs",
            "use std::collections::HashMap;\n",
            "unordered-iter",
        );
        assert_eq!(fire.len(), 1);
        assert_eq!(fire[0].line, 1);
        let clean = audit_file("orchestrator/mod.rs", "use std::collections::BTreeMap;\n");
        assert!(clean.violations.is_empty());
    }

    #[test]
    fn comments_and_strings_never_fire() {
        let src = "// HashMap is banned\nlet s = \"HashMap\";\n";
        assert!(audit_file("wire/mod.rs", src).violations.is_empty());
    }

    #[test]
    fn wall_clock_respects_the_allowlist() {
        let src = "let t0 = std::time::Instant::now();\n";
        assert_eq!(violations_of("orchestrator/mod.rs", src, "wall-clock").len(), 1);
        assert!(violations_of("runtime/native/mod.rs", src, "wall-clock").is_empty());
        assert!(violations_of("bench_util/mod.rs", src, "wall-clock").is_empty());
    }

    #[test]
    fn wall_clock_skips_cfg_test_code() {
        let src = "#[cfg(test)]\nmod tests {\n  fn t() { let _ = std::time::Instant::now(); }\n}\n";
        assert!(violations_of("tpgf/mod.rs", src, "wall-clock").is_empty());
    }

    #[test]
    fn os_entropy_sees_through_string_literals() {
        let src = "let p = \"/dev/urandom\";\n";
        assert_eq!(violations_of("util/rng.rs", src, "os-entropy").len(), 1);
        let ident = "let r = thread_rng();\n";
        assert_eq!(violations_of("client/mod.rs", ident, "os-entropy").len(), 1);
    }

    #[test]
    fn undocumented_unsafe_fires_documented_passes() {
        let bad = "fn f() { unsafe { g() } }\n";
        assert_eq!(
            violations_of("transport/tcp.rs", bad, "unsafe-undocumented").len(),
            1
        );
        let good = "fn f() {\n    // SAFETY: g has no preconditions here.\n    unsafe { g() }\n}\n";
        assert!(violations_of("transport/tcp.rs", good, "unsafe-undocumented").is_empty());
        let trailing = "unsafe impl Send for X {} // SAFETY: X owns its data.\n";
        assert!(violations_of("a.rs", trailing, "unsafe-undocumented").is_empty());
    }

    #[test]
    fn safety_comment_blocks_extend_upward() {
        let src = "// SAFETY: the borrow is pinned by the pool mutex\n// and outlives every worker dereference.\nunsafe impl Send for Job {}\n";
        assert!(violations_of("pool.rs", src, "unsafe-undocumented").is_empty());
    }

    #[test]
    fn unsafe_fn_decl_is_not_flagged_but_its_block_is() {
        let src = "unsafe fn sub(p: *mut f32) -> &'static mut [f32] {\n    unsafe { std::slice::from_raw_parts_mut(p, 1) }\n}\n";
        let v = violations_of("k.rs", src, "unsafe-undocumented");
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn raw_artifact_write_funnel_exemption() {
        let src = "let f = File::create(&tmp)?;\n";
        assert_eq!(
            violations_of("metrics/mod.rs", src, "raw-artifact-write").len(),
            1
        );
        assert!(violations_of("util/fs.rs", src, "raw-artifact-write").is_empty());
    }

    #[test]
    fn env_read_only_in_config_main_bench_util() {
        let src = "let v = std::env::var(\"SUPERSFL_X\");\n";
        assert_eq!(violations_of("wire/mod.rs", src, "env-read").len(), 1);
        assert!(violations_of("config/mod.rs", src, "env-read").is_empty());
        assert!(violations_of("main.rs", src, "env-read").is_empty());
    }

    #[test]
    fn float_fold_only_under_runtime_native() {
        let src = "let s = xs.iter().sum::<f32>();\n";
        assert_eq!(
            violations_of("runtime/native/kernels.rs", src, "float-fold").len(),
            1
        );
        assert!(violations_of("metrics/mod.rs", src, "float-fold").is_empty());
        // f64 folds are fine even in the kernel core.
        let f64_fold = "let s = xs.iter().sum::<f64>();\n";
        assert!(violations_of("runtime/native/mod.rs", f64_fold, "float-fold").is_empty());
    }

    #[test]
    fn justified_allow_suppresses_own_line_and_trailing() {
        let own = "// audit:allow(unordered-iter) -- compile cache; iteration order never observed.\nlet c: HashMap<String, u32> = HashMap::new();\n";
        let rep = audit_file("runtime/pjrt.rs", own);
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
        assert_eq!(rep.allows.len(), 1);
        assert_eq!(rep.allows[0].target_line, 2);

        let trailing = "use std::collections::HashMap; // audit:allow(unordered-iter) -- cache key set, order-free.\n";
        let rep = audit_file("runtime/pjrt.rs", trailing);
        assert!(rep.violations.is_empty());
    }

    #[test]
    fn bare_or_unknown_allow_is_malformed() {
        let bare = "// audit:allow(unordered-iter)\nlet m = HashMap::new();\n";
        let rep = audit_file("a.rs", bare);
        assert_eq!(rep.malformed.len(), 1);
        assert_eq!(rep.violations.len(), 1, "bare allow must not suppress");

        let unknown = "// audit:allow(no-such-rule) -- because.\nlet m = HashMap::new();\n";
        let rep = audit_file("a.rs", unknown);
        assert_eq!(rep.malformed.len(), 1);
        assert_eq!(rep.violations.len(), 1);

        let empty_just = "// audit:allow(unordered-iter) -- \nlet m = HashMap::new();\n";
        let rep = audit_file("a.rs", empty_just);
        assert_eq!(rep.malformed.len(), 1);
    }

    #[test]
    fn allow_for_the_wrong_rule_does_not_suppress() {
        let src = "// audit:allow(wall-clock) -- wrong rule on purpose.\nlet m = HashMap::new();\n";
        let rep = audit_file("server/mod.rs", src);
        assert_eq!(rep.violations.len(), 1);
    }

    #[test]
    fn var_os_is_caught_but_other_idents_are_not() {
        let src = "let v = std::env::var_os(\"X\");\n";
        assert_eq!(violations_of("wire/mod.rs", src, "env-read").len(), 1);
        let not_env = "let v = my_env::variable();\n";
        assert!(violations_of("wire/mod.rs", not_env, "env-read").is_empty());
    }
}
