//! CLI entry point: `cargo run -p xtask -- audit [--src DIR] [--json PATH]`.

use std::path::PathBuf;
use std::process::ExitCode;

fn usage() -> ExitCode {
    eprintln!("usage: cargo run -p xtask -- audit [--src DIR] [--json PATH]");
    eprintln!();
    eprintln!("  audit   run the determinism-contract lints over rust/src");
    eprintln!("  --src   scan DIR instead of rust/src (no AUDIT.json unless --json)");
    eprintln!("  --json  write the report to PATH (default: <repo>/AUDIT.json)");
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("audit") => {}
        _ => return usage(),
    }
    let mut src_override: Option<PathBuf> = None;
    let mut json_override: Option<PathBuf> = None;
    while let Some(flag) = args.next() {
        match flag.as_str() {
            "--src" => match args.next() {
                Some(v) => src_override = Some(PathBuf::from(v)),
                None => return usage(),
            },
            "--json" => match args.next() {
                Some(v) => json_override = Some(PathBuf::from(v)),
                None => return usage(),
            },
            _ => return usage(),
        }
    }

    // xtask lives at <repo>/rust/xtask; pop twice for the repo root.
    let repo_root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .and_then(|p| p.parent())
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| PathBuf::from("."));
    let default_src = repo_root.join("rust").join("src");
    let src = src_override.clone().unwrap_or_else(|| default_src.clone());

    let outcome = match xtask::audit_tree(&src) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("audit: cannot scan {}: {e}", src.display());
            return ExitCode::from(2);
        }
    };

    for v in &outcome.violations {
        eprintln!("audit: {}: {}:{}: {}", v.rule, v.file, v.line, v.message);
        eprintln!("       | {}", v.snippet);
    }
    for m in &outcome.malformed {
        eprintln!("audit: malformed-allow: {}:{}: {}", m.file, m.line, m.message);
        eprintln!("       | {}", m.snippet);
    }

    // Only the default full-tree run writes AUDIT.json, unless an
    // explicit --json path asks for one (fixture runs stay write-free).
    let json_path = match (&json_override, &src_override) {
        (Some(p), _) => Some(p.clone()),
        (None, None) => Some(repo_root.join("AUDIT.json")),
        (None, Some(_)) => None,
    };
    if let Some(path) = json_path {
        let src_label = if src == default_src {
            "rust/src".to_string()
        } else {
            src.display().to_string()
        };
        let doc = xtask::report::render(
            &src_label,
            outcome.files_scanned,
            &outcome.violations,
            &outcome.allows,
            &outcome.malformed,
        );
        if let Err(e) = xtask::report::write_atomic(&path, &doc) {
            eprintln!("audit: cannot write {}: {e}", path.display());
            return ExitCode::from(2);
        }
        eprintln!("audit: report written to {}", path.display());
    }

    if outcome.clean() {
        eprintln!(
            "audit: clean — {} files, {} allows, 0 violations",
            outcome.files_scanned,
            outcome.allows.len()
        );
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "audit: FAILED — {} violation(s), {} malformed allow(s) across {} files",
            outcome.violations.len(),
            outcome.malformed.len(),
            outcome.files_scanned
        );
        ExitCode::FAILURE
    }
}
