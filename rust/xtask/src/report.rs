//! `AUDIT.json` emission: hand-rolled JSON (the workspace has zero
//! external dependencies and the audit keeps that), provenance-stamped
//! with an FNV-1a hash of the rule table like the BENCH artifacts, and
//! written atomically (temp + rename) so an interrupted run never
//! leaves a truncated report — the same contract `raw-artifact-write`
//! enforces on the library.

use crate::rules::{Allow, Violation, RULES};
use std::fmt::Write as _;
use std::path::Path;

pub const TOOL: &str = "supersfl-xtask-audit";
pub const VERSION: &str = "1";

/// FNV-1a 64-bit, matching `bench_util::fnv1a64`.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Hash of the rule table: names, patterns, scopes. Changing any rule
/// changes the stamp, so a stale AUDIT.json is detectable.
pub fn rules_fingerprint() -> u64 {
    let mut buf = String::new();
    for r in RULES {
        let _ = write!(
            buf,
            "{}|{:?}|{:?}|{:?}|{:?}|{};",
            r.name, r.code_patterns, r.string_patterns, r.allow_files, r.only_files, r.include_tests
        );
    }
    fnv1a64(buf.as_bytes())
}

fn esc(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Render the full report. Deterministic: no timestamps, inputs arrive
/// pre-sorted (files in path order, findings in line order).
pub fn render(
    src_root: &str,
    files_scanned: usize,
    violations: &[Violation],
    allows: &[Allow],
    malformed: &[Violation],
) -> String {
    let mut o = String::with_capacity(4096);
    o.push_str("{\n");
    let _ = write!(o, "  \"tool\": ");
    esc(&mut o, TOOL);
    let _ = write!(o, ",\n  \"version\": ");
    esc(&mut o, VERSION);
    let _ = write!(o, ",\n  \"rules_fnv1a64\": \"{:016x}\"", rules_fingerprint());
    let _ = write!(o, ",\n  \"src_root\": ");
    esc(&mut o, src_root);
    let _ = write!(o, ",\n  \"files_scanned\": {files_scanned}");
    let _ = write!(
        o,
        ",\n  \"clean\": {}",
        violations.is_empty() && malformed.is_empty()
    );

    o.push_str(",\n  \"rules\": [");
    for (i, r) in RULES.iter().enumerate() {
        if i > 0 {
            o.push(',');
        }
        o.push_str("\n    {\"name\": ");
        esc(&mut o, r.name);
        o.push_str(", \"description\": ");
        esc(&mut o, r.description);
        let v = violations.iter().filter(|v| v.rule == r.name).count();
        let a = allows.iter().filter(|a| a.rule == r.name).count();
        let _ = write!(o, ", \"violations\": {v}, \"allowed\": {a}}}");
    }
    o.push_str("\n  ]");

    o.push_str(",\n  \"violations\": [");
    for (i, v) in violations.iter().enumerate() {
        if i > 0 {
            o.push(',');
        }
        o.push_str("\n    {\"rule\": ");
        esc(&mut o, &v.rule);
        o.push_str(", \"file\": ");
        esc(&mut o, &v.file);
        let _ = write!(o, ", \"line\": {}, \"snippet\": ", v.line);
        esc(&mut o, &v.snippet);
        o.push('}');
    }
    o.push_str("\n  ]");

    o.push_str(",\n  \"allows\": [");
    for (i, a) in allows.iter().enumerate() {
        if i > 0 {
            o.push(',');
        }
        o.push_str("\n    {\"rule\": ");
        esc(&mut o, &a.rule);
        o.push_str(", \"file\": ");
        esc(&mut o, &a.file);
        let _ = write!(o, ", \"line\": {}, \"justification\": ", a.line);
        esc(&mut o, &a.justification);
        o.push('}');
    }
    o.push_str("\n  ]");

    o.push_str(",\n  \"malformed_allows\": [");
    for (i, m) in malformed.iter().enumerate() {
        if i > 0 {
            o.push(',');
        }
        o.push_str("\n    {\"file\": ");
        esc(&mut o, &m.file);
        let _ = write!(o, ", \"line\": {}, \"reason\": ", m.line);
        esc(&mut o, &m.message);
        o.push('}');
    }
    o.push_str("\n  ]\n}\n");
    o
}

/// Atomic write: temp file in the destination directory, then rename.
pub fn write_atomic(path: &Path, contents: &str) -> std::io::Result<()> {
    let dir = path.parent().unwrap_or_else(|| Path::new("."));
    let tmp = dir.join(".AUDIT.json.tmp");
    std::fs::write(&tmp, contents)?;
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_matches_reference_vectors() {
        // Same vectors bench_util asserts.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn report_is_valid_shape_and_escapes() {
        let v = vec![Violation {
            rule: "env-read".into(),
            file: "wire/mod.rs".into(),
            line: 9,
            message: "m".into(),
            snippet: "let v = env::var(\"X\\n\");".into(),
        }];
        let r = render("rust/src", 3, &v, &[], &[]);
        assert!(r.contains("\"clean\": false"));
        assert!(r.contains("\\\"X\\\\n\\\""));
        assert!(r.contains("\"files_scanned\": 3"));
        // Every rule appears in the summary table.
        for rule in RULES {
            assert!(r.contains(rule.name));
        }
    }

    #[test]
    fn fingerprint_is_stable_within_a_build() {
        assert_eq!(rules_fingerprint(), rules_fingerprint());
    }
}
