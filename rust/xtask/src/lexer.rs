//! A hand-rolled Rust source lexer for the determinism audit.
//!
//! The audit does not need a parse tree — every rule is a token- or
//! line-level check — but it must never fire on text inside comments or
//! string literals, and it must know which regions are `#[cfg(test)]`
//! code. So the lexer produces three aligned *views* of each file, all
//! byte-for-byte the same length as the original (newlines preserved, so
//! byte offsets and line numbers agree across views):
//!
//! * `code` — comments and string/char-literal contents masked to spaces.
//!   Rules that match identifiers and paths (`HashMap`, `Instant::now`,
//!   `env::var`, …) scan this view.
//! * `code_strings` — comments masked, string literals kept. Rules that
//!   must see literal contents (`"/dev/urandom"`) scan this one.
//! * `comments` — every comment segment with its line number, for the
//!   `// SAFETY:` and `// audit:allow(...)` conventions.
//!
//! Handled syntax: line comments, nested block comments, doc comments,
//! regular/byte strings with escapes, raw strings `r#"…"#` (any hash
//! count, `br` included), char literals vs. lifetimes, and
//! `#[cfg(test)]`-gated items (the whole braced item body is recorded as
//! a test region).

/// One comment segment. Block comments spanning N lines produce N
/// entries, one per line, so line-based lookups stay trivial.
#[derive(Debug, Clone)]
pub struct Comment {
    /// 1-based line number.
    pub line: usize,
    /// The comment text on that line (delimiters included).
    pub text: String,
    /// True when the line holds nothing but whitespace + this comment
    /// (an "own-line" comment, as opposed to a trailing one).
    pub own_line: bool,
}

/// The lexed views of one source file.
pub struct Lexed {
    pub code: String,
    pub code_strings: String,
    pub comments: Vec<Comment>,
    /// Byte offset of the start of each line (index 0 = line 1).
    pub line_starts: Vec<usize>,
    /// Byte ranges of `#[cfg(test)]`-gated items.
    pub test_regions: Vec<(usize, usize)>,
}

impl Lexed {
    /// 1-based line number containing byte `offset`.
    pub fn line_of(&self, offset: usize) -> usize {
        match self.line_starts.binary_search(&offset) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// Whether byte `offset` falls inside `#[cfg(test)]`-gated code.
    pub fn in_test(&self, offset: usize) -> bool {
        self.test_regions.iter().any(|&(a, b)| offset >= a && offset < b)
    }

    /// The comment entries on `line`, if any.
    pub fn comments_on(&self, line: usize) -> impl Iterator<Item = &Comment> {
        self.comments.iter().filter(move |c| c.line == line)
    }

    /// True when `line` holds only whitespace/comments in the code view.
    pub fn line_is_codeless(&self, line: usize) -> bool {
        self.line_text(&self.code, line).trim().is_empty()
    }

    /// The text of `line` (1-based) in the given view.
    pub fn line_text<'a>(&self, view: &'a str, line: usize) -> &'a str {
        let start = self.line_starts[line - 1];
        let end = self
            .line_starts
            .get(line)
            .copied()
            .unwrap_or(view.len());
        view[start..end].trim_end_matches('\n')
    }

    pub fn line_count(&self) -> usize {
        self.line_starts.len()
    }
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Normal,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    Char,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte length of the UTF-8 sequence starting with `lead`.
fn utf8_len(lead: u8) -> usize {
    match lead {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Lex `src` into the aligned views. Never panics on malformed input —
/// an unterminated literal or comment simply masks to end of file.
pub fn lex(src: &str) -> Lexed {
    let bytes = src.as_bytes();
    let n = bytes.len();
    let mut code = bytes.to_vec();
    let mut code_strings = bytes.to_vec();
    let mut comments: Vec<Comment> = Vec::new();
    let mut line_starts = vec![0usize];
    for (i, &b) in bytes.iter().enumerate() {
        if b == b'\n' && i + 1 < n {
            line_starts.push(i + 1);
        }
    }

    // Collect raw comment spans first; they are split per line below.
    let mut comment_spans: Vec<(usize, usize)> = Vec::new();

    let mut state = State::Normal;
    let mut i = 0usize;
    let mut seg_start = 0usize; // start of the current comment/string
    while i < n {
        let b = bytes[i];
        match state {
            State::Normal => {
                if b == b'/' && i + 1 < n && bytes[i + 1] == b'/' {
                    state = State::LineComment;
                    seg_start = i;
                    i += 2;
                } else if b == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
                    state = State::BlockComment(1);
                    seg_start = i;
                    i += 2;
                } else if b == b'"' {
                    state = State::Str;
                    seg_start = i;
                    i += 1;
                } else if (b == b'r' || b == b'b')
                    && (i == 0 || !is_ident(bytes[i - 1]))
                {
                    // Possible raw/byte string start: r" r#" b" br" br#".
                    let mut j = i + 1;
                    if b == b'b' && j < n && bytes[j] == b'r' {
                        j += 1;
                    }
                    let raw = j > i + 1 || b == b'r';
                    let mut hashes = 0u32;
                    while raw && j < n && bytes[j] == b'#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && bytes[j] == b'"' && (raw || b == b'b') {
                        seg_start = i;
                        state = if raw { State::RawStr(hashes) } else { State::Str };
                        i = j + 1;
                    } else {
                        i += 1;
                    }
                } else if b == b'\'' {
                    // Char literal or lifetime. A char literal closes
                    // with a quote right after one (escaped or plain,
                    // possibly multibyte) character; a lifetime
                    // (`'static`, `'a`) never does.
                    let j = i + 1;
                    if j < n && bytes[j] == b'\\' {
                        state = State::Char;
                        seg_start = i;
                        i += 2; // skip the backslash + escaped byte
                        continue;
                    }
                    if j < n && bytes[j] != b'\'' {
                        let k = j + utf8_len(bytes[j]);
                        if k < n && bytes[k] == b'\'' {
                            // Plain char literal 'x' — covers '"' too,
                            // which must not open a string state.
                            mask(&mut code, i, k + 1);
                            mask(&mut code_strings, i, k + 1);
                            i = k + 1;
                            continue;
                        }
                    }
                    // Lifetime or stray quote: leave as-is.
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::LineComment => {
                if b == b'\n' {
                    comment_spans.push((seg_start, i));
                    mask(&mut code, seg_start, i);
                    mask(&mut code_strings, seg_start, i);
                    state = State::Normal;
                }
                i += 1;
            }
            State::BlockComment(depth) => {
                if b == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else if b == b'*' && i + 1 < n && bytes[i + 1] == b'/' {
                    if depth == 1 {
                        comment_spans.push((seg_start, i + 2));
                        mask(&mut code, seg_start, i + 2);
                        mask(&mut code_strings, seg_start, i + 2);
                        state = State::Normal;
                    } else {
                        state = State::BlockComment(depth - 1);
                    }
                    i += 2;
                } else {
                    i += 1;
                }
            }
            State::Str => {
                if b == b'\\' {
                    i += 2;
                } else if b == b'"' {
                    mask(&mut code, seg_start, i + 1);
                    state = State::Normal;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if b == b'"' {
                    let mut j = i + 1;
                    let mut h = 0u32;
                    while h < hashes && j < n && bytes[j] == b'#' {
                        h += 1;
                        j += 1;
                    }
                    if h == hashes {
                        mask(&mut code, seg_start, j);
                        state = State::Normal;
                        i = j;
                        continue;
                    }
                }
                i += 1;
            }
            State::Char => {
                if b == b'\'' {
                    mask(&mut code, seg_start, i + 1);
                    mask(&mut code_strings, seg_start, i + 1);
                    state = State::Normal;
                }
                i += 1;
            }
        }
    }
    // Unterminated segments mask (and record) to EOF.
    match state {
        State::LineComment | State::BlockComment(_) => {
            comment_spans.push((seg_start, n));
            mask(&mut code, seg_start, n);
            mask(&mut code_strings, seg_start, n);
        }
        State::Str | State::RawStr(_) | State::Char => {
            mask(&mut code, seg_start, n);
            if state == State::Char {
                mask(&mut code_strings, seg_start, n);
            }
        }
        State::Normal => {}
    }

    // Masked views are pure-ASCII replacements of byte ranges; both stay
    // valid UTF-8 because masking always covers whole literals/comments.
    let code = String::from_utf8(code).expect("masked view stays UTF-8");
    let code_strings =
        String::from_utf8(code_strings).expect("masked view stays UTF-8");

    let mut lexed = Lexed {
        code,
        code_strings,
        comments: Vec::new(),
        line_starts,
        test_regions: Vec::new(),
    };

    // Split comment spans per line, and compute own-line-ness against
    // the code view (which has the comments already blanked).
    for (a, b) in comment_spans {
        let first = lexed.line_of(a);
        let last = lexed.line_of(b.saturating_sub(1).max(a));
        for line in first..=last {
            let ls = lexed.line_starts[line - 1];
            let le = lexed
                .line_starts
                .get(line)
                .copied()
                .unwrap_or(src.len());
            let s = a.max(ls);
            let e = b.min(le);
            if s >= e {
                continue;
            }
            let text = src[s..e].trim_end_matches('\n').to_string();
            let own_line = lexed.code[ls..e.min(lexed.code.len())]
                .trim()
                .is_empty()
                && lexed.code[e.min(lexed.code.len())..le]
                    .trim()
                    .is_empty();
            lexed.comments.push(Comment {
                line,
                text,
                own_line,
            });
        }
    }

    lexed.test_regions = find_test_regions(&lexed.code);
    lexed
}

fn mask(buf: &mut [u8], from: usize, to: usize) {
    for b in buf[from..to.min(buf.len())].iter_mut() {
        if *b != b'\n' {
            *b = b' ';
        }
    }
}

/// Find `#[cfg(test)]`-gated item ranges in the comment-free code view.
/// Any `#[cfg(...)]` attribute whose argument list contains the word
/// `test` gates the next item: the byte range runs from the attribute to
/// the item's closing brace (or terminating semicolon for brace-less
/// items such as `use` declarations).
fn find_test_regions(code: &str) -> Vec<(usize, usize)> {
    let bytes = code.as_bytes();
    let n = bytes.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 1 < n {
        if bytes[i] == b'#' && bytes[i + 1] == b'[' {
            let attr_start = i;
            // Balanced-bracket scan of the attribute body.
            let mut depth = 0usize;
            let mut j = i + 1;
            while j < n {
                match bytes[j] {
                    b'[' => depth += 1,
                    b']' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            if j >= n {
                break;
            }
            let body = &code[i + 2..j];
            if attr_gates_test(body) {
                if let Some(end) = item_end(bytes, j + 1) {
                    out.push((attr_start, end));
                    i = end;
                    continue;
                }
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    out
}

/// `cfg(test)`, `cfg(all(test, …))`, `cfg(any(…, test))` — a `cfg`
/// attribute mentioning the bare predicate `test`.
fn attr_gates_test(body: &str) -> bool {
    let t = body.trim();
    if !t.starts_with("cfg") {
        return false;
    }
    // Word-boundary search for `test` inside the predicate.
    let b = t.as_bytes();
    let pat = b"test";
    let mut k = 0usize;
    while k + pat.len() <= b.len() {
        if &b[k..k + pat.len()] == pat {
            let before_ok = k == 0 || !is_ident(b[k - 1]);
            let after = k + pat.len();
            let after_ok = after >= b.len() || !is_ident(b[after]);
            if before_ok && after_ok {
                return true;
            }
        }
        k += 1;
    }
    false
}

/// End offset (exclusive) of the item starting after an attribute: skip
/// further attributes, then run to the matching close of the first `{`,
/// or to the first `;` if that comes before any brace.
fn item_end(bytes: &[u8], mut i: usize) -> Option<usize> {
    let n = bytes.len();
    loop {
        // Skip whitespace.
        while i < n && bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        // Skip stacked attributes.
        if i + 1 < n && bytes[i] == b'#' && bytes[i + 1] == b'[' {
            let mut depth = 0usize;
            while i < n {
                match bytes[i] {
                    b'[' => depth += 1,
                    b']' => {
                        depth -= 1;
                        if depth == 0 {
                            i += 1;
                            break;
                        }
                    }
                    _ => {}
                }
                i += 1;
            }
            continue;
        }
        break;
    }
    // Scan to first `{` or `;`.
    while i < n {
        match bytes[i] {
            b'{' => {
                let mut depth = 0usize;
                while i < n {
                    match bytes[i] {
                        b'{' => depth += 1,
                        b'}' => {
                            depth -= 1;
                            if depth == 0 {
                                return Some(i + 1);
                            }
                        }
                        _ => {}
                    }
                    i += 1;
                }
                return Some(n);
            }
            b';' => return Some(i + 1),
            _ => i += 1,
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_masked_in_code_view() {
        let src = "let a = \"HashMap\"; // HashMap here\nlet b = HashMap::new();\n";
        let l = lex(src);
        assert!(!l.code.contains("HashMap here"));
        assert_eq!(l.code.matches("HashMap").count(), 1);
        assert_eq!(l.line_of(l.code.find("HashMap").unwrap()), 2);
        // The string view keeps the literal but drops the comment.
        assert!(l.code_strings.contains("\"HashMap\""));
        assert!(!l.code_strings.contains("HashMap here"));
    }

    #[test]
    fn raw_strings_and_char_literals_mask() {
        let src = "let r = r#\"Instant::now()\"#;\nlet c = '\\n';\nlet lt: &'static str = x;\n";
        let l = lex(src);
        assert!(!l.code.contains("Instant::now"));
        assert!(l.code_strings.contains("Instant::now")); // strings kept
        assert!(l.code.contains("'static")); // lifetime untouched
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let src = "/* outer /* inner */ still */ let x = SystemTime;\n";
        let l = lex(src);
        assert!(l.code.contains("SystemTime"));
        assert!(!l.code.contains("outer"));
    }

    #[test]
    fn views_keep_byte_alignment() {
        let src = "let s = \"π multi”byte\"; // trailing π\nlet t = 1;\n";
        let l = lex(src);
        assert_eq!(l.code.len(), src.len());
        assert_eq!(l.code_strings.len(), src.len());
        assert_eq!(l.line_of(l.code.find("let t").unwrap()), 2);
    }

    #[test]
    fn cfg_test_module_is_a_test_region() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { HashMap::new(); }\n}\nfn after() {}\n";
        let l = lex(src);
        let off = l.code.find("HashMap").unwrap();
        assert!(l.in_test(off));
        assert!(!l.in_test(l.code.find("live").unwrap()));
        assert!(!l.in_test(l.code.find("after").unwrap()));
    }

    #[test]
    fn cfg_all_test_counts_and_attributes_stack() {
        let src = "#[cfg(all(test, unix))]\n#[allow(dead_code)]\nfn helper() { x() }\nfn live() {}\n";
        let l = lex(src);
        assert!(l.in_test(l.code.find("x()").unwrap()));
        assert!(!l.in_test(l.code.find("live").unwrap()));
    }

    #[test]
    fn cfg_not_test_does_not_gate() {
        let src = "#[cfg(unix)]\nfn a() { y() }\n";
        let l = lex(src);
        assert!(!l.in_test(l.code.find("y()").unwrap()));
    }

    #[test]
    fn own_line_vs_trailing_comments() {
        let src = "// own line\nlet x = 1; // trailing\n";
        let l = lex(src);
        let own: Vec<_> = l.comments_on(1).collect();
        assert!(own[0].own_line);
        let tr: Vec<_> = l.comments_on(2).collect();
        assert!(!tr[0].own_line);
    }

    #[test]
    fn multi_line_block_comment_yields_per_line_entries() {
        let src = "/* SAFETY: part one\n   part two */\nunsafe impl Send for X {}\n";
        let l = lex(src);
        assert!(l.comments_on(1).any(|c| c.text.contains("SAFETY:")));
        assert!(l.comments_on(2).next().is_some());
    }
}
