//! Self-test: every rule has a firing fixture and a non-firing fixture
//! under `fixtures/<rule>/{fire,clean}`. The fire trees must produce at
//! least one violation of exactly that rule; the clean trees must audit
//! clean. The CLI is exercised too, so the exit-code contract the CI
//! job relies on is itself under test.

use std::path::PathBuf;
use std::process::Command;

fn fixture(rule_dir: &str, kind: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("fixtures")
        .join(rule_dir)
        .join(kind)
}

fn assert_fires(rule_dir: &str, rule: &str) {
    let out = xtask::audit_tree(&fixture(rule_dir, "fire")).expect("scan fire fixture");
    assert!(
        !out.clean(),
        "{rule_dir}/fire must not audit clean"
    );
    let total = out.violations.len() + out.malformed.len();
    let hits = out
        .violations
        .iter()
        .chain(out.malformed.iter())
        .filter(|v| v.rule == rule)
        .count();
    assert!(hits >= 1, "{rule_dir}/fire must fire `{rule}`: {:?}", out.violations);
    assert_eq!(
        hits, total,
        "{rule_dir}/fire must fire ONLY `{rule}`: {:?} {:?}",
        out.violations, out.malformed
    );
}

fn assert_clean(rule_dir: &str) {
    let out = xtask::audit_tree(&fixture(rule_dir, "clean")).expect("scan clean fixture");
    assert!(
        out.clean(),
        "{rule_dir}/clean must audit clean: {:?} {:?}",
        out.violations, out.malformed
    );
}

#[test]
fn unordered_iter_fixture_pair() {
    assert_fires("unordered_iter", "unordered-iter");
    assert_clean("unordered_iter");
    // The clean tree exercises the escape hatch; make sure the allow
    // was actually recorded rather than the pattern being missed.
    let out = xtask::audit_tree(&fixture("unordered_iter", "clean")).unwrap();
    assert_eq!(out.allows.len(), 1);
    assert_eq!(out.allows[0].rule, "unordered-iter");
    assert!(!out.allows[0].justification.is_empty());
}

#[test]
fn wall_clock_fixture_pair() {
    assert_fires("wall_clock", "wall-clock");
    assert_clean("wall_clock");
}

#[test]
fn os_entropy_fixture_pair() {
    assert_fires("os_entropy", "os-entropy");
    assert_clean("os_entropy");
}

#[test]
fn unsafe_undocumented_fixture_pair() {
    assert_fires("unsafe_undocumented", "unsafe-undocumented");
    assert_clean("unsafe_undocumented");
}

#[test]
fn raw_artifact_write_fixture_pair() {
    assert_fires("raw_artifact_write", "raw-artifact-write");
    assert_clean("raw_artifact_write");
}

#[test]
fn env_read_fixture_pair() {
    assert_fires("env_read", "env-read");
    assert_clean("env_read");
}

#[test]
fn float_fold_fixture_pair() {
    assert_fires("float_fold", "float-fold");
    assert_clean("float_fold");
}

#[test]
fn malformed_allow_fires_and_does_not_suppress() {
    let out = xtask::audit_tree(&fixture("malformed_allow", "fire")).unwrap();
    assert!(!out.clean());
    assert_eq!(out.malformed.len(), 1, "{:?}", out.malformed);
    assert_eq!(
        out.violations.len(),
        1,
        "bare allow must leave the violation standing: {:?}",
        out.violations
    );
    assert_eq!(out.violations[0].rule, "unordered-iter");
}

#[test]
fn cli_exit_codes_match_the_audit_verdict() {
    let bin = env!("CARGO_BIN_EXE_xtask");
    let fire = Command::new(bin)
        .args(["audit", "--src"])
        .arg(fixture("unordered_iter", "fire"))
        .output()
        .expect("run xtask on fire fixture");
    assert!(
        !fire.status.success(),
        "fire fixture must exit nonzero: {}",
        String::from_utf8_lossy(&fire.stderr)
    );

    let clean = Command::new(bin)
        .args(["audit", "--src"])
        .arg(fixture("float_fold", "clean"))
        .output()
        .expect("run xtask on clean fixture");
    assert!(
        clean.status.success(),
        "clean fixture must exit zero: {}",
        String::from_utf8_lossy(&clean.stderr)
    );
}

#[test]
fn the_real_tree_audits_clean() {
    // The acceptance criterion itself: rust/src carries zero
    // unannotated violations and every allow is justified.
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/")
        .join("src");
    let out = xtask::audit_tree(&src).expect("scan rust/src");
    assert!(
        out.clean(),
        "rust/src must audit clean — violations: {:#?} malformed: {:#?}",
        out.violations,
        out.malformed
    );
    assert!(out.files_scanned > 10, "walker saw the real tree");
    for a in &out.allows {
        assert!(
            !a.justification.is_empty(),
            "bare allow at {}:{}",
            a.file,
            a.line
        );
    }
}
