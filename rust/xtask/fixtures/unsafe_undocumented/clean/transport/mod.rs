// Fixture: documented unsafe passes in all three shapes — comment
// directly above, multi-line comment block, and trailing comment.
pub fn read_first(p: *const u8) -> u8 {
    // SAFETY: callers pass a pointer to at least one readable byte.
    unsafe { *p }
}

pub struct Shard(*mut f32);

// SAFETY: each Shard addresses a disjoint half-open range of the
// backing buffer, so moving one across threads cannot alias another.
unsafe impl Send for Shard {}

pub fn zero(s: &Shard) {
    unsafe { s.0.write(0.0) } // SAFETY: Shard pointers are valid for writes by construction.
}
