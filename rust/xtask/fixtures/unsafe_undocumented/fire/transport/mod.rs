// Fixture: an unsafe block with no SAFETY comment fires.
pub fn read_first(p: *const u8) -> u8 {
    unsafe { *p }
}
