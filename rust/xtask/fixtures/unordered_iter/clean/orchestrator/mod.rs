// Fixture: BTreeMap is deterministic; HashMap in comments and strings
// is inert; a justified allow suppresses a real use.
use std::collections::BTreeMap;

pub fn merge(updates: &[(u64, f32)]) -> BTreeMap<u64, f32> {
    // A HashMap would leak hash order here.
    let banner = "HashMap is banned on the round path";
    let _ = banner;
    let mut acc = BTreeMap::new();
    for &(k, v) in updates {
        *acc.entry(k).or_insert(0.0) += v;
    }
    acc
}

// audit:allow(unordered-iter) -- cache keyed by opaque id; iteration order never observed.
pub type Cache = std::collections::HashMap<u64, f32>;
