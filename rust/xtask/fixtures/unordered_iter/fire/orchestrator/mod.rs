// Fixture: HashMap on the round path must fire `unordered-iter`.
use std::collections::HashMap;

pub fn merge(updates: &[(u64, f32)]) -> HashMap<u64, f32> {
    let mut acc = HashMap::new();
    for &(k, v) in updates {
        *acc.entry(k).or_insert(0.0) += v;
    }
    acc
}
