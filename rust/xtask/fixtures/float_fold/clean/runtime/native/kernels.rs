// Fixture: an explicit sequential fold pins the reduction order; f64
// accumulation is likewise fine.
pub fn l2(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for x in xs {
        acc += x * x;
    }
    acc.sqrt()
}

pub fn mean(xs: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for x in xs {
        acc += *x as f64;
    }
    acc / xs.len().max(1) as f64
}
