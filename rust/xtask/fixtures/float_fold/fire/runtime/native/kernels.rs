// Fixture: implicit f32 iterator fold in the kernel core fires —
// fold order must be spelled out.
pub fn l2(xs: &[f32]) -> f32 {
    xs.iter().map(|x| x * x).sum::<f32>().sqrt()
}
