// Fixture: ambient entropy fires even inside a string literal.
pub fn entropy_path() -> &'static str {
    "/dev/urandom"
}
