// Fixture: seeded Pcg32 lane streams are the sanctioned randomness.
// Mentioning thread_rng in a comment is inert.
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, lane: u64) -> Self {
        Self {
            state: seed.wrapping_mul(6364136223846793005).wrapping_add(lane | 1),
            inc: lane | 1,
        }
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }
}
