// Fixture: an env read outside config/, main.rs, bench_util/ fires.
pub fn wire_kind() -> String {
    std::env::var("SUPERSFL_WIRE").unwrap_or_default()
}
