// Fixture: config/ is where env-wins precedence is implemented.
pub fn backend() -> String {
    std::env::var("SUPERSFL_BACKEND").unwrap_or_else(|_| "native".into())
}
