// Fixture: wall-clock read outside the host-timing allowlist fires.
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
