// Fixture: sim-time ticks are fine anywhere; wall clock is fine in
// cfg(test) code (host-only assertions never touch the trajectory).
pub fn advance(sim_ms: &mut u64, dt: u64) {
    *sim_ms += dt;
}

#[cfg(test)]
mod tests {
    #[test]
    fn host_timing_in_tests_is_exempt() {
        let t0 = std::time::Instant::now();
        assert!(t0.elapsed().as_secs() < 60);
    }
}
