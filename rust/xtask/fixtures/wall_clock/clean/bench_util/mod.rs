// Fixture: bench_util/ is on the wall-clock allowlist.
pub fn measure<F: FnOnce()>(f: F) -> u128 {
    let t0 = std::time::Instant::now();
    f();
    t0.elapsed().as_nanos()
}
