// Fixture: a direct artifact write outside util/fs.rs fires.
pub fn dump(path: &str, body: &str) -> std::io::Result<()> {
    std::fs::write(path, body)
}
