// Fixture: util/fs.rs is the one sanctioned write site (the atomic
// temp + rename funnel).
use std::fs::File;
use std::io::Write;
use std::path::Path;

pub fn atomic_write(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let tmp = path.with_extension("tmp");
    let mut f = File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    std::fs::rename(&tmp, path)
}
