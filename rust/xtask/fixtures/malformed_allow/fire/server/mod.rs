// Fixture: a bare allow (no ` -- justification`) is itself a failure
// and does not suppress the underlying violation.
// audit:allow(unordered-iter)
pub type Registry = std::collections::HashMap<u64, u32>;
