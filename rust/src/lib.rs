//! # SuperSFL — resource-heterogeneous federated split learning
//!
//! Rust implementation of the coordination layer of *"SuperSFL:
//! Resource-Heterogeneous Federated Split Learning with Weight-Sharing
//! Super-Networks"* (CS.DC 2026), on top of AOT-compiled JAX/Pallas compute
//! artifacts executed through the PJRT C API (`xla` crate).
//!
//! The crate is organised bottom-up (see `DESIGN.md` for the full system
//! inventory):
//!
//! * [`util`] — JSON, PRNG, vector math, property-testing helpers
//!   (hand-rolled: the offline build has no serde/proptest/criterion).
//! * [`config`] — typed experiment configuration with JSON overrides.
//! * [`data`] — synthetic CIFAR-like dataset + Dirichlet non-IID partitioner.
//! * [`network`] — simulated edge network: latency, bandwidth, failures,
//!   timeouts, byte accounting, and the simulated cluster clock.
//! * [`energy`] — device power states, energy integration, CO₂ accounting.
//! * [`metrics`] — round records, run summaries, CSV/JSON export.
//! * [`runtime`] — PJRT artifact registry and executor (loads
//!   `artifacts/*.hlo.txt` per the manifest; Python never runs here).
//! * [`allocation`] — resource-aware subnetwork allocation (paper Eq. 1).
//! * [`tpgf`] — Three-Phase Gradient Fusion weighting + fused update
//!   (paper Eq. 3–4), Rust SIMD-friendly loop and Pallas-artifact paths.
//! * [`client`] — the fault-tolerant split-learning client (paper Alg. 3).
//! * [`server`] — the main server: deep-suffix execution over the shared
//!   super-network.
//! * [`fedserver`] — collaborative layer-aligned aggregation (paper Eq. 6–8).
//! * [`orchestrator`] — the round loop tying everything together.
//! * [`baselines`] — SFL (SplitFed) and DFL comparators.
//! * [`bench_util`] — the bench harness used by `cargo bench` targets.

pub mod allocation;
pub mod baselines;
pub mod bench_util;
pub mod client;
pub mod config;
pub mod data;
pub mod energy;
pub mod fedserver;
pub mod metrics;
pub mod network;
pub mod orchestrator;
pub mod runtime;
pub mod server;
pub mod tpgf;
pub mod util;

pub use config::ExperimentConfig;
pub use orchestrator::{run_experiment, RunResult};

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("xla: {0}")]
    Xla(String),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("json: {0}")]
    Json(String),
    #[error("config: {0}")]
    Config(String),
    #[error("manifest: {0}")]
    Manifest(String),
    #[error("shape mismatch: {0}")]
    Shape(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
