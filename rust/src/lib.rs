//! # SuperSFL — resource-heterogeneous federated split learning
//!
//! Rust implementation of the coordination layer of *"SuperSFL:
//! Resource-Heterogeneous Federated Split Learning with Weight-Sharing
//! Super-Networks"* (CS.DC 2026), on top of AOT-compiled JAX/Pallas compute
//! artifacts executed through the PJRT C API (`xla` crate).
//!
//! The crate is organised bottom-up (see `DESIGN.md` for the full system
//! inventory):
//!
//! * [`util`] — JSON, PRNG, vector math, property-testing helpers
//!   (hand-rolled: the offline build has no serde/proptest/criterion).
//! * [`config`] — typed experiment configuration with JSON overrides.
//! * [`data`] — synthetic CIFAR-like dataset + Dirichlet non-IID partitioner.
//! * [`network`] — simulated edge network: latency, bandwidth, failures,
//!   timeouts, byte accounting, and the simulated cluster clock.
//! * [`wire`] — the framed binary codec layer: every client↔server
//!   tensor exchange is serialized through a checksummed frame with a
//!   selectable payload codec (`fp32|fp16|int8|topk:<k>`), and the
//!   network is charged with the actual encoded bytes.
//! * [`energy`] — device power states, energy integration, CO₂ accounting.
//! * [`metrics`] — round records, run summaries, CSV/JSON export.
//! * [`runtime`] — the execution backends behind one `Backend` trait:
//!   the PJRT artifact executor (loads `artifacts/*.hlo.txt` per the
//!   manifest; Python never runs here) and the always-available native
//!   pure-Rust reference MLP that makes every end-to-end test, bench and
//!   example run offline (`--backend auto|native|pjrt`).
//! * [`allocation`] — resource-aware subnetwork allocation (paper Eq. 1).
//! * [`tpgf`] — Three-Phase Gradient Fusion weighting + fused update
//!   (paper Eq. 3–4), Rust SIMD-friendly loop and Pallas-artifact paths.
//! * [`client`] — the fault-tolerant split-learning client (paper Alg. 3).
//! * [`server`] — the main server: deep-suffix execution over the shared
//!   super-network.
//! * [`fedserver`] — collaborative layer-aligned aggregation (paper Eq. 6–8).
//! * [`trace`] — deterministic span tracing + per-client straggler
//!   telemetry (Chrome-trace export, fixed-log-bucket histograms).
//! * [`transport`] — real TCP transport speaking the [`wire`] frame
//!   envelope over sockets (server + client processes), plus the
//!   incremental frame reader, control-message protocol, and graceful
//!   shutdown latch (`--transport sim|serve:<addr>|connect:<addr>`).
//! * [`orchestrator`] — the round loop tying everything together.
//! * [`baselines`] — SFL (SplitFed) and DFL comparators.
//! * [`bench_util`] — the bench harness used by `cargo bench` targets.

// Crate-level (not workspace) so bins/benches/examples — where `pub` at
// crate root is meaningless but harmless — stay out of scope.
#![deny(unreachable_pub)]

#[cfg(not(feature = "xla"))]
compile_error!(
    "supersfl requires the `xla` feature (enabled by default). It resolves to \
     the bundled PJRT stub crate at rust/xla unless patched with real bindings."
);

pub mod allocation;
pub mod baselines;
pub mod bench_util;
pub mod client;
pub mod config;
pub mod data;
pub mod energy;
pub mod fedserver;
pub mod metrics;
pub mod network;
pub mod orchestrator;
pub mod runtime;
pub mod server;
pub mod tpgf;
pub mod trace;
pub mod transport;
pub mod util;
pub mod wire;

pub use config::ExperimentConfig;
pub use orchestrator::{run_experiment, RunResult};

/// Crate-wide error type (hand-rolled: the offline build has no
/// `thiserror` either).
#[derive(Debug)]
pub enum Error {
    Xla(String),
    Io(std::io::Error),
    Json(String),
    Config(String),
    Manifest(String),
    Shape(String),
    /// Wire-frame errors: truncated/corrupted frames, version or codec
    /// mismatches, malformed payloads (`crate::wire`).
    Wire(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json(e) => write!(f, "json: {e}"),
            Error::Config(e) => write!(f, "config: {e}"),
            Error::Manifest(e) => write!(f, "manifest: {e}"),
            Error::Shape(e) => write!(f, "shape mismatch: {e}"),
            Error::Wire(e) => write!(f, "wire: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

// CLI/config plumbing parses numbers from text; fold those into Config
// errors so `--set`/flag handling can use `?` without a helper crate.
impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::Config(format!("invalid integer: {e}"))
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::Config(format!("invalid number: {e}"))
    }
}

pub type Result<T> = std::result::Result<T, Error>;
