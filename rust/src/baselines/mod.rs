//! Baseline comparators reimplemented from their papers (DESIGN.md §4.6):
//!
//! * [`sfl`] — SplitFed (Thapa et al., AAAI 2022): a fixed global split
//!   point, per-client server-side model copies FedAvg'd every round,
//!   server-only gradients, strict synchronization (stalls on failures).
//! * [`dfl`] — Dynamic Federated Split Learning (Samikwa et al., IEEE
//!   IoT-J 2024): resource-aware per-client split points over a shared
//!   server model, full-backbone provisioning each round so the split can
//!   move, no auxiliary classifier, no fault tolerance.
//!
//! Both run on the same [`crate::orchestrator::Harness`] as SuperSFL, so
//! bytes / simulated time / energy are accounted identically.

pub mod dfl;
pub mod sfl;
