//! Dynamic Federated Split Learning (DFL) baseline — Samikwa et al.,
//! IEEE IoT-J 2024, as characterized by the SuperSFL paper (§I/§III:
//! "requires frequent coordination across decentralized replicas").
//!
//! * Split points are **resource-aware per client** and **dynamic**:
//!   client resources fluctuate round to round (`fleet.resource_jitter`),
//!   DFL re-profiles every round and moves each client's split point —
//!   re-provisioning the full backbone to each client. SuperSFL profiles
//!   once (§II-A: "eliminates the need for client profiling during
//!   training").
//! * The server side is **decentralized**: `dfl_replicas` server replicas
//!   each hold a full backbone copy and serve a subset of clients. Between
//!   syncs each replica's deep layers train only on its own clients'
//!   non-IID shards, so replicas drift and the per-round averaging loses
//!   progress — the fragmentation cost SuperSFL's single centrally-hosted
//!   super-network avoids (SFL is the extreme: one copy per client).
//!   Replica coordination ships every replica's backbone both ways each
//!   round (the "frequent coordination" communication term).
//! * No auxiliary classifier and no fault tolerance: clients learn from
//!   server gradients only and **stall** when the server is unreachable.
//!
//! Parallel execution: the natural unit of independence in DFL is the
//! **replica** — clients of one replica serialize on its backbone copy,
//! but replicas never touch each other between coordination barriers. So
//! the engine fans out one worker per replica; each worker walks its
//! replica's clients in ascending id order, which keeps the per-replica
//! update sequence identical to the sequential loop (clients of a replica
//! were already visited in id order there).
//!
//! Under sampled participation (`--sample`) the per-round re-profiling
//! sweep is skipped: jittering a 100k-device fleet every round to move
//! splits for a 100-client cohort is exactly the O(fleet) scan sampling
//! exists to avoid, and a freshly materialized cohort member gets a
//! current resource-aware split at materialization anyway. Splits are
//! static per client within a sampled run; the replica topology (`ci %
//! replicas`) is unchanged.

use crate::allocation;
use crate::client::ClientState;
use crate::network::{DeviceProfile, Framed, NetLane};
use crate::orchestrator::engine::{self, RoundLedger};
use crate::orchestrator::Harness;
use crate::runtime::Runtime;
use crate::trace::{InstantKind, SpanKind, TRACK_SERVER};
use crate::util::math;
use crate::util::rng::Pcg32;
use crate::wire::{MsgType, WireScratch};
use crate::Result;

/// One round of observed (jittered) resources, per client.
fn jittered_profiles(
    base: &[DeviceProfile],
    jitter: f64,
    rng: &mut Pcg32,
) -> Vec<DeviceProfile> {
    base.iter()
        .map(|p| {
            let mut q = *p;
            q.mem_gb = (p.mem_gb * (1.0 + jitter * (rng.uniform() * 2.0 - 1.0))).max(0.5);
            q.latency_s =
                (p.latency_s * (1.0 + jitter * (rng.uniform() * 2.0 - 1.0))).max(1e-3);
            q
        })
        .collect()
}

/// One client's context inside a replica worker.
struct DflClientLane<'a> {
    client: &'a mut ClientState,
    profile: DeviceProfile,
    /// Prefix length of this client's current split (into the backbone).
    cut: usize,
    srv_time: f64,
    /// Local steps this round (truncated by a mid-round crash).
    steps: usize,
    net: NetLane,
    ledger: RoundLedger,
}

/// One decentralized server replica + the clients it serves this round.
struct DflReplicaLane<'a> {
    enc: &'a mut [f32],
    clf: &'a mut [f32],
    members: Vec<DflClientLane<'a>>,
}

/// One entry of the round's lane roster (profile/split resolved up
/// front so the fan-out borrow of the harness stays disjoint).
#[derive(Clone, Copy)]
struct DflSlot {
    ci: usize,
    profile: DeviceProfile,
    cut: usize,
    srv_time: f64,
    steps: usize,
}

pub fn run(rt: &Runtime, h: &mut Harness) -> Result<()> {
    let classes = h.cfg.data.classes;
    let dim = rt.model().dim;
    let batch_n = rt.model().batch;
    let local_steps = h.cfg.train.local_steps;
    let n = h.cfg.fleet.clients;
    let full_bytes = (h.server.enc.len() * 4) as u64;
    let total_layers = rt.model().depth;
    let lr_server = h.cfg.train.lr_server as f32;
    let threads = h.cfg.threads;
    let smashed = h.cost.smashed_bytes(dim);
    let smashed_elems = rt.model().smashed_elems();
    let gz_frame_len = h.wire.frame_len(MsgType::ActGrad, smashed_elems);
    let sampled = h.cohort_k.is_some();
    let mut profile_rng = Pcg32::new(h.cfg.train.seed, 0xDF1);

    // Decentralized server replicas: full backbone + classifier each.
    let r = h.cfg.dfl_replicas.clamp(1, n.max(1));
    let mut rep_enc: Vec<Vec<f32>> = vec![h.server.enc.clone(); r];
    let mut rep_clf: Vec<Vec<f32>> = vec![h.server.clf_s.clone(); r];

    // Reused coordination buffers (no per-round allocations).
    let clf_len = h.server.clf_s.len();
    let mut enc_avg = vec![0.0f32; h.server.enc.len()];
    let mut clf_avg = vec![0.0f32; clf_len];
    // Reusable encode/decode buffers for the barrier frames (the
    // per-step frames inside the fan-out use each member's own lane
    // scratch).
    let mut bar_scratch = WireScratch::default();
    // Identical fault schedule to SuperSFL (shared lane streams + churn
    // windows); DFL has no quorum concept or local fallback.
    let fc = h.cfg.net.faults.clone();
    let lane_trace = h.tracer.as_ref().is_some_and(|t| t.lane_events_enabled());

    for round in 1..=h.cfg.train.rounds {
        if crate::transport::shutdown::requested() {
            h.interrupted = Some(round);
            break;
        }
        let round_u = round as u64;
        let roster = h.roster(round);
        h.materialize_cohort(rt, &roster)?;
        h.net.begin_round();

        // ---- Churn: dead clients sit out; rejoiners resync first ----
        // Shared with the SSFL loop: the resync download rides the
        // faulted exchange path, and a failed attempt keeps the client
        // down for the round instead of aborting the run.
        let (sitting_out, resync_faults) = h.resync_roster(round_u, &roster, &fc);

        // ---- Dynamic re-profiling: resources moved, so do the splits ----
        // (round 1 keeps the initial allocation; re-profiling starts once
        // training is underway, as in the DFL protocol. Sampled runs skip
        // the sweep entirely — see module docs.)
        if !sampled && round > 1 && h.cfg.fleet.resource_jitter > 0.0 {
            let observed =
                jittered_profiles(&h.profiles, h.cfg.fleet.resource_jitter, &mut profile_rng);
            let new_assign = allocation::allocate(&observed, &h.cfg.alloc, total_layers);
            for ci in 0..n {
                // Down clients can't be re-profiled (moving their split
                // would hand them fresh global weights for free — the
                // rejoin path pays for that via the charged resync).
                if fc.is_down(round_u, ci) {
                    continue;
                }
                let new_depth = new_assign[ci].depth;
                if new_depth != h.clients[ci].depth {
                    // Split moved: the client takes over a different
                    // prefix of the (just-provisioned) global backbone.
                    let len: usize = h.server.layer_sizes()[..new_depth].iter().sum();
                    let c = &mut h.clients[ci];
                    c.depth = new_depth;
                    c.enc.resize(len, 0.0);
                    c.enc.copy_from_slice(&h.server.enc[..len]);
                }
            }
        }

        // ---- Lane roster: who actually runs a branch this round ----
        // Depths may have moved above, so split cuts and server step
        // times are resolved per slot through the shared helpers.
        let mut slots: Vec<DflSlot> = Vec::with_capacity(roster.len());
        for &ci in &roster {
            if fc.is_down(round_u, ci) || sitting_out.binary_search(&ci).is_ok() {
                continue;
            }
            let depth = {
                let c = h.client(ci);
                if c.shard.is_empty() {
                    continue; // sampled past the dataset: no data, no lane
                }
                c.depth
            };
            let steps = fc
                .crash_at(round_u, ci)
                .map(|c| c.step.min(local_steps))
                .unwrap_or(local_steps);
            slots.push(DflSlot {
                ci,
                profile: h.profile(ci),
                cut: h.server.prefix_len(depth),
                srv_time: h.server_step_time(depth),
                steps,
            });
        }

        // ---- Fan out: one worker per replica; clients of a replica run
        // in id order on its private backbone copy ----
        let mut ledgers: Vec<RoundLedger> = {
            let Harness {
                clients,
                pool,
                net,
                cost,
                train,
                wire,
                ..
            } = h;
            let cost = &*cost;
            let train = &*train;
            let wire = &*wire;

            let states: Box<dyn Iterator<Item = (usize, &mut ClientState)>> = if sampled {
                Box::new(pool.iter_mut().map(|(id, c)| (*id, c)))
            } else {
                Box::new(clients.iter_mut().enumerate())
            };

            let mut groups: Vec<DflReplicaLane<'_>> = rep_enc
                .iter_mut()
                .zip(rep_clf.iter_mut())
                .map(|(enc, clf)| DflReplicaLane {
                    enc,
                    clf,
                    members: Vec::new(),
                })
                .collect();
            let mut slot_it = slots.iter().peekable();
            for (ci, client) in states {
                let Some(s) = slot_it.peek() else { break };
                if s.ci != ci {
                    continue;
                }
                let s = *slot_it.next().expect("peeked");
                let mut lane_net = net.lane(ci, round_u);
                if lane_trace {
                    lane_net.enable_attempt_log();
                }
                groups[ci % r].members.push(DflClientLane {
                    profile: s.profile,
                    cut: s.cut,
                    srv_time: s.srv_time,
                    steps: s.steps,
                    net: lane_net,
                    ledger: RoundLedger::traced(ci, lane_trace),
                    client,
                });
            }
            debug_assert!(slot_it.peek().is_none(), "every slot must get a lane");

            engine::run_lanes(threads, &mut groups, |rep| {
                for m in rep.members.iter_mut() {
                    m.client.begin_round();
                    let depth = m.client.depth;
                    for _ in 0..m.steps {
                        let batch = m.client.shard.next_batch(train, batch_n);

                        let z = rt.client_fwd(depth, &m.client.enc, &batch.x)?;
                        let t_fwd =
                            cost.time_s(cost.client_fwd_flops(depth), m.profile.flops);
                        let p1_t0 = m.ledger.branch_s;
                        m.ledger.work(&m.profile, t_fwd);
                        m.ledger.trace.span(SpanKind::LocalUpdate, p1_t0, t_fwd, 0, 0);

                        // Wire-framed exchange (see orchestrator docs).
                        // Frames stage in the member's reusable lane
                        // scratch — identical bytes, no per-frame Vec.
                        let up_len = wire
                            .encode_to(MsgType::Smashed, &z, 0.0, &mut m.net.scratch)
                            .len() as u64;
                        m.ledger
                            .trace
                            .span(SpanKind::Encode, m.ledger.branch_s, 0.0, up_len, 0);
                        let ex_t0 = m.ledger.branch_s;
                        let ex = m.net.exchange_framed(
                            Framed {
                                wire: up_len,
                                raw: smashed,
                            },
                            Framed {
                                wire: gz_frame_len,
                                raw: smashed,
                            },
                            m.srv_time,
                        );
                        m.ledger.exchange(&m.profile, ex.time_s(), m.srv_time);
                        m.ledger
                            .trace
                            .exchange_spans(ex_t0, &m.net.attempts, up_len);

                        if ex.is_ok() {
                            // CRC/decode failure = exchange fault: count
                            // and stall the step, don't abort the run.
                            if wire
                                .decode_into(&m.net.scratch.frame, &mut m.net.scratch.decoded)
                                .is_err()
                            {
                                m.net.faults.corruptions += 1;
                                m.ledger
                                    .trace
                                    .instant(InstantKind::Corruption, m.ledger.branch_s);
                                m.ledger.fallback_steps += 1;
                                continue;
                            }
                            let out = rt.server_step(
                                depth,
                                classes,
                                &rep.enc[m.cut..],
                                &*rep.clf,
                                &m.net.scratch.decoded,
                                &batch.y,
                            )?;
                            math::sgd_step(&mut rep.enc[m.cut..], &out.g_srv, lr_server);
                            math::sgd_step(rep.clf, &out.g_clf_s, lr_server);
                            m.client.round_server_loss.push(out.loss as f64);
                            m.ledger.server_step(m.srv_time);

                            wire.encode_to(MsgType::ActGrad, &out.g_z, 0.0, &mut m.net.scratch);
                            if wire
                                .decode_into(&m.net.scratch.frame, &mut m.net.scratch.decoded)
                                .is_err()
                            {
                                m.net.faults.corruptions += 1;
                                m.ledger
                                    .trace
                                    .instant(InstantKind::Corruption, m.ledger.branch_s);
                                m.ledger.fallback_steps += 1;
                                continue;
                            }
                            m.ledger.trace.span(
                                SpanKind::Decode,
                                m.ledger.branch_s,
                                0.0,
                                gz_frame_len,
                                0,
                            );
                            let g_enc =
                                rt.client_bwd(depth, &m.client.enc, &batch.x, &m.net.scratch.decoded)?;
                            let lr = m.client.lr;
                            math::sgd_step(&mut m.client.enc, &g_enc, lr);
                            let t_bwd =
                                cost.time_s(cost.client_bwd_flops(depth), m.profile.flops);
                            let bwd_t0 = m.ledger.branch_s;
                            m.ledger.work(&m.profile, t_bwd);
                            m.ledger.trace.span(SpanKind::Fusion, bwd_t0, t_bwd, 0, 0);
                        } else {
                            // Server-dependent: no local supervision, step lost.
                            m.ledger.fallback_steps += 1;
                            m.ledger
                                .trace
                                .span(SpanKind::Fallback, m.ledger.branch_s, 0.0, 0, 0);
                        }
                    }
                }
                Ok(())
            })?;

            // Collect per-client results out of the replica groups and
            // restore ascending client-id order for the merge.
            let mut collected: Vec<(NetLane, RoundLedger)> = groups
                .into_iter()
                .flat_map(|g| g.members.into_iter().map(|m| (m.net, m.ledger)))
                .collect();
            collected.sort_by_key(|(_, l)| l.client);
            collected
                .into_iter()
                .map(|(lane, ledger)| {
                    net.absorb_lane(&lane);
                    let mut ledger = ledger;
                    ledger.faults.add(&lane.faults);
                    ledger.wire_bytes = lane.traffic.total_bytes();
                    if fc.crash_at(round_u, ledger.client).is_some() {
                        ledger.faults.crashes += 1;
                        ledger
                            .trace
                            .instant(InstantKind::Crash, ledger.branch_s);
                    }
                    ledger
                })
                .collect()
        };

        let (round_dt, busy, stalled, server_steps, mut faults) = h.absorb_ledgers(&mut ledgers);
        faults.add(&resync_faults);

        // ---- Replica coordination: ship every replica both ways and
        // average (the "frequent coordination" term), then layer-align
        // with the client prefixes. ----
        // One logical transfer per replica per direction, each paying
        // the fed-link half-RTT.
        let agg_t0 = h.clock.now();
        let mut agg_bytes = (full_bytes + (clf_len * 4) as u64) * r as u64 * 2;
        let fed_t = h
            .net
            .fed_link((full_bytes + (clf_len * 4) as u64) * r as u64 * 2, r as u64 * 2);
        h.clock.advance(fed_t);
        enc_avg.fill(0.0);
        clf_avg.fill(0.0);
        for rep in 0..r {
            math::axpy(&mut enc_avg, &rep_enc[rep], 1.0 / r as f32);
            math::axpy(&mut clf_avg, &rep_clf[rep], 1.0 / r as f32);
        }

        // ---- Layer-aligned FedAvg of client prefixes (sample weights)
        // on top of the replica average. Uploads travel as PrefixUpload
        // frames (DFL clients train no auxiliary classifier) and the
        // server averages the *decoded* prefixes. ----
        // Dead and mid-round-crashed clients skip the barrier; FedAvg
        // weights renormalize over the actual participants.
        let mut agg_entries: Vec<(usize, f64)> = roster.iter().map(|&id| (id, 0.0)).collect();
        let mut uploads: Vec<(usize, Vec<f32>)> = Vec::with_capacity(slots.len());
        for s in &slots {
            if fc.crash_at(round_u, s.ci).is_some() {
                continue;
            }
            let payload = h.client(s.ci).upload_payload();
            let frame_len = h
                .wire
                .encode_to(MsgType::PrefixUpload, &payload, 0.0, &mut bar_scratch)
                .len() as u64;
            let t = h.net.bulk_up_framed(
                s.ci,
                Framed {
                    wire: frame_len,
                    raw: (payload.len() * 4) as u64,
                },
            );
            let pos = roster
                .binary_search(&s.ci)
                .expect("slot drawn from roster");
            agg_entries[pos].1 = t;
            agg_bytes += frame_len;
            uploads.push((s.ci, h.wire.decode(&bar_scratch.frame)?.data));
        }
        h.charge_barrier_phase(&agg_entries);
        let total_samples: f64 = uploads
            .iter()
            .map(|(ci, _)| h.client(*ci).shard.len() as f64)
            .sum();
        {
            let items: Vec<(usize, &[f32], f64)> = uploads
                .iter()
                .map(|(ci, data)| {
                    let c = h.client(*ci);
                    (
                        c.depth,
                        data.as_slice(),
                        c.shard.len() as f64 / total_samples.max(1.0),
                    )
                })
                .collect();
            // λ = 1 against the replica average: layers trained by both
            // clients and replicas blend 50/50 (Σw_i = 1 for FedAvg
            // weights); client-only layers follow the clients, server-only
            // layers keep the replica average.
            h.server.enc.copy_from_slice(&enc_avg);
            h.server.fedavg_prefixes(&items, 1.0);
        }
        h.server.clf_s.copy_from_slice(&clf_avg);
        for rep in 0..r {
            rep_enc[rep].copy_from_slice(&h.server.enc);
            rep_clf[rep].copy_from_slice(&h.server.clf_s);
        }
        // The aggregate span covers replica coordination plus the
        // layer-aligned FedAvg of client prefixes.
        let agg_dur = h.clock.now() - agg_t0;
        if let Some(tr) = h.tracer.as_mut() {
            tr.track_span(
                TRACK_SERVER,
                SpanKind::Aggregate,
                agg_t0,
                agg_dur,
                agg_bytes,
                uploads.len() as u64,
            );
        }

        // ---- Full-backbone provisioning for the dynamic split ----
        // Every client receives the same full backbone, so the Broadcast
        // frame is encoded (and decoded) once and charged per client;
        // clients sync from the decoded tensor.
        let bc_t0 = h.clock.now();
        let mut bc_bytes = 0u64;
        let mut bc_count = 0u64;
        let frame_len = h
            .wire
            .encode_to(MsgType::Broadcast, &h.server.enc, 0.0, &mut bar_scratch)
            .len() as u64;
        let bc_payload = h.wire.decode(&bar_scratch.frame)?.data;
        let bc_framed = Framed {
            wire: frame_len,
            raw: full_bytes,
        };
        let mut bc_entries: Vec<(usize, f64)> = roster.iter().map(|&id| (id, 0.0)).collect();
        for s in &slots {
            if fc.crash_at(round_u, s.ci).is_some() {
                continue; // absentees catch up via the charged resync
            }
            let pos = roster
                .binary_search(&s.ci)
                .expect("slot drawn from roster");
            bc_entries[pos].1 = h.net.bulk_down_framed(s.ci, bc_framed);
            bc_bytes += frame_len;
            bc_count += 1;
            h.client_mut(s.ci).sync_from_global(&bc_payload);
        }
        h.charge_barrier_phase(&bc_entries);
        let bc_dur = h.clock.now() - bc_t0;
        if let Some(tr) = h.tracer.as_mut() {
            tr.track_span(TRACK_SERVER, SpanKind::Broadcast, bc_t0, bc_dur, bc_bytes, bc_count);
        }

        let acc = h.eval_global(rt)?;
        if h.finish_round(
            round,
            round_dt,
            &roster,
            &busy,
            acc,
            stalled,
            server_steps,
            faults,
        ) {
            break;
        }
    }
    Ok(())
}
