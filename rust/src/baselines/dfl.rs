//! Dynamic Federated Split Learning (DFL) baseline — Samikwa et al.,
//! IEEE IoT-J 2024, as characterized by the SuperSFL paper (§I/§III:
//! "requires frequent coordination across decentralized replicas").
//!
//! * Split points are **resource-aware per client** and **dynamic**:
//!   client resources fluctuate round to round (`fleet.resource_jitter`),
//!   DFL re-profiles every round and moves each client's split point —
//!   re-provisioning the full backbone to each client. SuperSFL profiles
//!   once (§II-A: "eliminates the need for client profiling during
//!   training").
//! * The server side is **decentralized**: `dfl_replicas` server replicas
//!   each hold a full backbone copy and serve a subset of clients. Between
//!   syncs each replica's deep layers train only on its own clients'
//!   non-IID shards, so replicas drift and the per-round averaging loses
//!   progress — the fragmentation cost SuperSFL's single centrally-hosted
//!   super-network avoids (SFL is the extreme: one copy per client).
//!   Replica coordination ships every replica's backbone both ways each
//!   round (the "frequent coordination" communication term).
//! * No auxiliary classifier and no fault tolerance: clients learn from
//!   server gradients only and **stall** when the server is unreachable.

use crate::allocation;
use crate::energy::PowerState;
use crate::fedserver;
use crate::network::DeviceProfile;
use crate::orchestrator::Harness;
use crate::runtime::Runtime;
use crate::util::math;
use crate::util::rng::Pcg32;
use crate::Result;

/// One round of observed (jittered) resources, per client.
fn jittered_profiles(
    base: &[DeviceProfile],
    jitter: f64,
    rng: &mut Pcg32,
) -> Vec<DeviceProfile> {
    base.iter()
        .map(|p| {
            let mut q = p.clone();
            q.mem_gb = (p.mem_gb * (1.0 + jitter * (rng.uniform() * 2.0 - 1.0))).max(0.5);
            q.latency_s =
                (p.latency_s * (1.0 + jitter * (rng.uniform() * 2.0 - 1.0))).max(1e-3);
            q
        })
        .collect()
}

pub fn run(rt: &Runtime, h: &mut Harness) -> Result<()> {
    let classes = h.cfg.data.classes;
    let dim = rt.model().dim;
    let local_steps = h.cfg.train.local_steps;
    let n = h.clients.len();
    let full_bytes = (h.server.enc.len() * 4) as u64;
    let total_layers = rt.model().depth;
    let lr_server = h.cfg.train.lr_server as f32;
    let mut profile_rng = Pcg32::new(h.cfg.train.seed, 0xDF1);

    // Decentralized server replicas: full backbone + classifier each.
    let r = h.cfg.dfl_replicas.clamp(1, n.max(1));
    let mut rep_enc: Vec<Vec<f32>> = vec![h.server.enc.clone(); r];
    let mut rep_clf: Vec<Vec<f32>> = vec![h.server.clf_s.clone(); r];
    let replica_of = |client: usize| client % r;

    for round in 1..=h.cfg.train.rounds {
        h.net.begin_round();

        // ---- Dynamic re-profiling: resources moved, so do the splits ----
        // (round 1 keeps the initial allocation; re-profiling starts once
        // training is underway, as in the DFL protocol.)
        if round > 1 && h.cfg.fleet.resource_jitter > 0.0 {
            let observed =
                jittered_profiles(&h.profiles, h.cfg.fleet.resource_jitter, &mut profile_rng);
            let new_assign = allocation::allocate(&observed, &h.cfg.alloc, total_layers);
            for ci in 0..n {
                let new_depth = new_assign[ci].depth;
                if new_depth != h.clients[ci].depth {
                    // Split moved: the client takes over a different
                    // prefix of the (just-provisioned) global backbone.
                    let len: usize = h.server.layer_sizes()[..new_depth].iter().sum();
                    h.clients[ci].depth = new_depth;
                    h.clients[ci].enc = h.server.enc[..len].to_vec();
                }
            }
        }

        let mut busy = vec![0.0f64; n];
        let mut branch = vec![0.0f64; n];
        let mut stalled = 0usize;
        let mut server_steps = 0usize;

        for ci in 0..n {
            h.clients[ci].begin_round();
            let depth = h.clients[ci].depth;
            let profile = h.profiles[ci].clone();
            let smashed = h.cost.smashed_bytes(dim);
            let srv_time = h.server_step_time(depth);
            let rep = replica_of(ci);
            let cut = h.server.prefix_len(depth);

            for _ in 0..local_steps {
                let batch = h.clients[ci].shard.next_batch(&h.train, rt.model().batch);

                let z = rt.client_fwd(depth, &h.clients[ci].enc, &batch.x)?;
                let t_fwd = h.cost.time_s(h.cost.client_fwd_flops(depth), profile.flops);
                h.meter.client(&profile, PowerState::Compute, t_fwd);
                branch[ci] += t_fwd;
                busy[ci] += t_fwd;

                let ex = h.net.exchange(ci, smashed, smashed, srv_time);
                branch[ci] += ex.time_s();
                let tx = (ex.time_s() - srv_time).max(0.0);
                h.meter.client(&profile, PowerState::Transmit, tx);
                busy[ci] += tx;

                if ex.is_ok() {
                    h.meter.server_busy(srv_time);
                    let out = rt.server_step(
                        depth,
                        classes,
                        &rep_enc[rep][cut..],
                        &rep_clf[rep],
                        &z,
                        &batch.y,
                    )?;
                    math::sgd_step(&mut rep_enc[rep][cut..], &out.g_srv, lr_server);
                    math::sgd_step(&mut rep_clf[rep], &out.g_clf_s, lr_server);
                    h.clients[ci].round_server_loss.push(out.loss as f64);

                    let g_enc = rt.client_bwd(depth, &h.clients[ci].enc, &batch.x, &out.g_z)?;
                    let lr = h.clients[ci].lr;
                    math::sgd_step(&mut h.clients[ci].enc, &g_enc, lr);
                    let t_bwd = h.cost.time_s(h.cost.client_bwd_flops(depth), profile.flops);
                    h.meter.client(&profile, PowerState::Compute, t_bwd);
                    branch[ci] += t_bwd;
                    busy[ci] += t_bwd;
                    server_steps += 1;
                } else {
                    // Server-dependent: no local supervision, step lost.
                    stalled += 1;
                }
            }
        }

        let round_dt = h.clock.advance_parallel(&branch);

        // ---- Replica coordination: ship every replica both ways and
        // average (the "frequent coordination" term), then layer-align
        // with the client prefixes. ----
        let clf_len = h.server.clf_s.len();
        let fed_t = h
            .net
            .fed_link((full_bytes + (clf_len * 4) as u64) * r as u64 * 2);
        h.clock.advance(fed_t);
        let mut enc_avg = vec![0.0f32; h.server.enc.len()];
        let mut clf_avg = vec![0.0f32; clf_len];
        for rep in 0..r {
            math::axpy(&mut enc_avg, &rep_enc[rep], 1.0 / r as f32);
            math::axpy(&mut clf_avg, &rep_clf[rep], 1.0 / r as f32);
        }

        // ---- Layer-aligned FedAvg of client prefixes (sample weights)
        // on top of the replica average. ----
        let mut agg_branch = vec![0.0f64; n];
        for ci in 0..n {
            agg_branch[ci] = h.net.bulk_up(ci, (h.clients[ci].enc.len() * 4) as u64);
        }
        let agg_dt = h.clock.advance_parallel(&agg_branch);
        for (i, &t) in agg_branch.iter().enumerate() {
            let p = h.profiles[i].clone();
            h.meter.client(&p, PowerState::Transmit, t);
            h.meter.client(&p, PowerState::Idle, (agg_dt - t).max(0.0));
        }
        let total_samples: f64 = h.clients.iter().map(|c| c.shard.len() as f64).sum();
        {
            let items: Vec<(usize, &[f32], f64)> = h
                .clients
                .iter()
                .map(|c| {
                    (
                        c.depth,
                        c.enc.as_slice(),
                        c.shard.len() as f64 / total_samples.max(1.0),
                    )
                })
                .collect();
            let sizes = h.server.layer_sizes().to_vec();
            // λ = 1 against the replica average: layers trained by both
            // clients and replicas blend 50/50 (Σw_i = 1 for FedAvg
            // weights); client-only layers follow the clients, server-only
            // layers keep the replica average.
            h.server.enc.copy_from_slice(&enc_avg);
            fedserver::aggregate_weighted(&mut h.server.enc, &sizes, &items, 1.0);
        }
        h.server.clf_s.copy_from_slice(&clf_avg);
        for rep in 0..r {
            rep_enc[rep].copy_from_slice(&h.server.enc);
            rep_clf[rep].copy_from_slice(&h.server.clf_s);
        }

        // ---- Full-backbone provisioning for the dynamic split ----
        let mut bc = vec![0.0f64; n];
        for ci in 0..n {
            bc[ci] = h.net.bulk_down(ci, full_bytes);
            let g = h.server.enc.clone();
            h.clients[ci].sync_from_global(&g);
        }
        let bc_dt = h.clock.advance_parallel(&bc);
        for (i, &t) in bc.iter().enumerate() {
            let p = h.profiles[i].clone();
            h.meter.client(&p, PowerState::Transmit, t);
            h.meter.client(&p, PowerState::Idle, (bc_dt - t).max(0.0));
        }

        let acc = h.eval_global(rt)?;
        if h.finish_round(round, round_dt, &busy, acc, stalled, server_steps) {
            break;
        }
    }
    Ok(())
}
