//! SplitFed (SFL) baseline — Thapa et al., AAAI 2022.
//!
//! Faithful to SplitFed v1's architecture:
//! * one **fixed** split depth for every client (no resource awareness);
//! * the main server keeps a **per-client copy** of the server-side
//!   network (suffix + classifier); each round the Fed server FedAvgs
//!   both the client-side and the server-side models, which is why SFL's
//!   communication bill scales with `clients × server-side size`;
//! * clients depend entirely on server gradients: when the server is
//!   unreachable the step **stalls** (the behaviour SuperSFL's fallback
//!   removes — recorded in `fallback_steps` as stalled steps).
//!
//! The per-client server-side copies make SplitFed naturally lane
//! friendly: each client branch (forward → exchange → server step on its
//! own copy → backward) runs on a worker thread of the
//! [`crate::orchestrator::engine`], with no cross-client state until the
//! FedAvg barrier.
//!
//! Under sampled participation (`--sample`) the copies pool to the
//! cohort instead of the fleet: each lane slot holds one copy, refreshed
//! from the current server state at round start. That is semantically
//! the reset SplitFed performs at every round end anyway (all copies —
//! absent clients' included — snap back to the fresh average), so the
//! pooled path trains the same values while keeping memory flat in the
//! fleet size.

use crate::client::ClientState;
use crate::network::{DeviceProfile, Framed, NetLane};
use crate::orchestrator::engine::{self, RoundLedger};
use crate::orchestrator::Harness;
use crate::runtime::Runtime;
use crate::trace::{InstantKind, SpanKind, TRACK_SERVER};
use crate::util::math;
use crate::wire::{MsgType, WireScratch};
use crate::Result;

/// One SplitFed client's worker-thread context for a round.
struct SflLane<'a> {
    client: &'a mut ClientState,
    profile: DeviceProfile,
    /// This client's private server-side suffix copy (SplitFed semantics).
    srv: &'a mut [f32],
    /// This client's private server-side classifier copy.
    clf: &'a mut [f32],
    /// Local steps this round (truncated by a mid-round crash).
    steps: usize,
    net: NetLane,
    ledger: RoundLedger,
}

/// One entry of the round's lane roster: who runs a branch, with which
/// profile, for how many steps, training which server-side copy.
#[derive(Clone, Copy)]
struct SflSlot {
    ci: usize,
    profile: DeviceProfile,
    steps: usize,
    /// Index into `srv_copies`/`clf_copies`: the client id when every
    /// copy is eagerly allocated (full participation), the slot position
    /// when copies pool to the cohort (sampled participation). Strictly
    /// ascending across the slot list in both modes.
    buf: usize,
}

pub fn run(rt: &Runtime, h: &mut Harness) -> Result<()> {
    let classes = h.cfg.data.classes;
    let depth = h.cfg.sfl_fixed_depth.clamp(1, rt.model().depth - 1);
    let dim = rt.model().dim;
    let batch_n = rt.model().batch;
    let local_steps = h.cfg.train.local_steps;
    let lr_server = h.cfg.train.lr_server as f32;
    let threads = h.cfg.threads;
    let suffix_len = h.server.suffix(depth).len();
    let clf_len = h.server.clf_s.len();
    let smashed = h.cost.smashed_bytes(dim);
    let smashed_elems = rt.model().smashed_elems();
    let gz_frame_len = h.wire.frame_len(MsgType::ActGrad, smashed_elems);
    let srv_time = h.server_step_time(depth);
    let sampled = h.cohort_k.is_some();
    let n = h.cfg.fleet.clients;

    // Per-client server-side copies (suffix + classifier), SplitFed-style.
    // Full participation allocates all of them up front — that O(fleet ×
    // server-side) footprint *is* SplitFed's defining cost. Sampled runs
    // start empty and pool to the cohort inside the round loop.
    let mut srv_copies: Vec<Vec<f32>> = if sampled {
        Vec::new()
    } else {
        vec![h.server.suffix(depth).to_vec(); n]
    };
    let mut clf_copies: Vec<Vec<f32>> = if sampled {
        Vec::new()
    } else {
        vec![h.server.clf_s.clone(); n]
    };
    // Reusable encode/decode buffers for the barrier frames (the
    // per-step frames inside the fan-out use each lane's own scratch).
    let mut bar_scratch = WireScratch::default();
    // The baselines face the *identical* fault schedule SuperSFL does
    // (same lane streams, same churn windows) so robustness comparisons
    // are apples to apples. SplitFed has no quorum concept — the fault
    // surface here is churn, bursty links, outages and corruption.
    let fc = h.cfg.net.faults.clone();
    let lane_trace = h.tracer.as_ref().is_some_and(|t| t.lane_events_enabled());

    for round in 1..=h.cfg.train.rounds {
        if crate::transport::shutdown::requested() {
            h.interrupted = Some(round);
            break;
        }
        let round_u = round as u64;
        let roster = h.roster(round);
        h.materialize_cohort(rt, &roster)?;
        h.net.begin_round();

        // ---- Churn: dead clients sit out; rejoiners resync first ----
        // Shared with the SSFL loop: the resync download rides the
        // faulted exchange path, and a failed attempt keeps the client
        // down for the round instead of aborting the run.
        let (sitting_out, resync_faults) = h.resync_roster(round_u, &roster, &fc);

        // ---- Lane roster: who actually runs a branch this round ----
        let mut slots: Vec<SflSlot> = Vec::with_capacity(roster.len());
        for &ci in &roster {
            if fc.is_down(round_u, ci) || sitting_out.binary_search(&ci).is_ok() {
                continue;
            }
            if h.client(ci).shard.is_empty() {
                continue; // sampled past the dataset: no data, no lane
            }
            let steps = fc
                .crash_at(round_u, ci)
                .map(|c| c.step.min(local_steps))
                .unwrap_or(local_steps);
            let buf = if sampled { slots.len() } else { ci };
            slots.push(SflSlot {
                ci,
                profile: h.profile(ci),
                steps,
                buf,
            });
        }

        // Pool the copies to the cohort: every slot trains a fresh image
        // of the current server-side state (see module docs for why that
        // matches the eager path's round-end reset).
        if sampled {
            if srv_copies.len() < slots.len() {
                srv_copies.resize_with(slots.len(), Vec::new);
                clf_copies.resize_with(slots.len(), Vec::new);
            }
            for s in &slots {
                srv_copies[s.buf].resize(suffix_len, 0.0);
                srv_copies[s.buf].copy_from_slice(h.server.suffix(depth));
                clf_copies[s.buf].resize(clf_len, 0.0);
                clf_copies[s.buf].copy_from_slice(&h.server.clf_s);
            }
            let pooled = srv_copies.len() * (suffix_len + clf_len);
            if pooled > h.pool_stats.max_lane_f32 {
                h.pool_stats.max_lane_f32 = pooled;
            }
        }

        // ---- Fan out: every client branch on a worker thread ----
        let mut ledgers: Vec<RoundLedger> = {
            let Harness {
                clients,
                pool,
                net,
                cost,
                train,
                wire,
                ..
            } = h;
            let cost = &*cost;
            let train = &*train;
            let wire = &*wire;

            let states: Box<dyn Iterator<Item = (usize, &mut ClientState)>> = if sampled {
                Box::new(pool.iter_mut().map(|(id, c)| (*id, c)))
            } else {
                Box::new(clients.iter_mut().enumerate())
            };

            let mut lanes: Vec<SflLane<'_>> = Vec::with_capacity(slots.len());
            let mut slot_it = slots.iter().peekable();
            let mut srv_it = srv_copies.iter_mut();
            let mut clf_it = clf_copies.iter_mut();
            // `buf` indices are strictly ascending across the slot list,
            // so the copy iterators advance monotonically — `next_buf`
            // tracks the index they currently point at.
            let mut next_buf = 0usize;
            for (ci, client) in states {
                let Some(s) = slot_it.peek() else { break };
                if s.ci != ci {
                    continue;
                }
                let s = *slot_it.next().expect("peeked");
                let skip = s.buf - next_buf;
                let srv = srv_it.nth(skip).expect("copies sized to roster");
                let clf = clf_it.nth(skip).expect("copies sized to roster");
                next_buf = s.buf + 1;
                let mut lane_net = net.lane(ci, round_u);
                if lane_trace {
                    lane_net.enable_attempt_log();
                }
                lanes.push(SflLane {
                    client,
                    profile: s.profile,
                    srv,
                    clf,
                    steps: s.steps,
                    net: lane_net,
                    ledger: RoundLedger::traced(ci, lane_trace),
                });
            }
            debug_assert!(slot_it.peek().is_none(), "every slot must get a lane");

            engine::run_lanes(threads, &mut lanes, |lane| {
                lane.client.begin_round();
                for _ in 0..lane.steps {
                    let batch = lane.client.shard.next_batch(train, batch_n);

                    let z = rt.client_fwd(depth, &lane.client.enc, &batch.x)?;
                    let t_fwd = cost.time_s(cost.client_fwd_flops(depth), lane.profile.flops);
                    let p1_t0 = lane.ledger.branch_s;
                    lane.ledger.work(&lane.profile, t_fwd);
                    lane.ledger.trace.span(SpanKind::LocalUpdate, p1_t0, t_fwd, 0, 0);

                    // Wire-framed exchange: encoded bytes on the link,
                    // analytic f32 count as raw (see orchestrator docs).
                    // Frames stage in the lane's reusable scratch —
                    // identical bytes, zero per-frame allocations.
                    let up_len = wire
                        .encode_to(MsgType::Smashed, &z, 0.0, &mut lane.net.scratch)
                        .len() as u64;
                    lane.ledger
                        .trace
                        .span(SpanKind::Encode, lane.ledger.branch_s, 0.0, up_len, 0);
                    let ex_t0 = lane.ledger.branch_s;
                    let ex = lane.net.exchange_framed(
                        Framed {
                            wire: up_len,
                            raw: smashed,
                        },
                        Framed {
                            wire: gz_frame_len,
                            raw: smashed,
                        },
                        srv_time,
                    );
                    lane.ledger.exchange(&lane.profile, ex.time_s(), srv_time);
                    lane.ledger
                        .trace
                        .exchange_spans(ex_t0, &lane.net.attempts, up_len);

                    if ex.is_ok() {
                        // CRC/decode failure is an exchange fault: count
                        // it and stall the step (SplitFed has no local
                        // fallback), don't abort the run.
                        if wire
                            .decode_into(&lane.net.scratch.frame, &mut lane.net.scratch.decoded)
                            .is_err()
                        {
                            lane.net.faults.corruptions += 1;
                            lane.ledger
                                .trace
                                .instant(InstantKind::Corruption, lane.ledger.branch_s);
                            lane.ledger.fallback_steps += 1;
                            continue;
                        }
                        let out = rt.server_step(
                            depth,
                            classes,
                            &*lane.srv,
                            &*lane.clf,
                            &lane.net.scratch.decoded,
                            &batch.y,
                        )?;
                        math::sgd_step(lane.srv, &out.g_srv, lr_server);
                        math::sgd_step(lane.clf, &out.g_clf_s, lr_server);
                        lane.client.round_server_loss.push(out.loss as f64);
                        lane.ledger.server_step(srv_time);

                        wire.encode_to(MsgType::ActGrad, &out.g_z, 0.0, &mut lane.net.scratch);
                        if wire
                            .decode_into(&lane.net.scratch.frame, &mut lane.net.scratch.decoded)
                            .is_err()
                        {
                            lane.net.faults.corruptions += 1;
                            lane.ledger
                                .trace
                                .instant(InstantKind::Corruption, lane.ledger.branch_s);
                            lane.ledger.fallback_steps += 1;
                            continue;
                        }
                        lane.ledger.trace.span(
                            SpanKind::Decode,
                            lane.ledger.branch_s,
                            0.0,
                            gz_frame_len,
                            0,
                        );
                        let g_enc =
                            rt.client_bwd(depth, &lane.client.enc, &batch.x, &lane.net.scratch.decoded)?;
                        let lr = lane.client.lr;
                        math::sgd_step(&mut lane.client.enc, &g_enc, lr);
                        let t_bwd =
                            cost.time_s(cost.client_bwd_flops(depth), lane.profile.flops);
                        let bwd_t0 = lane.ledger.branch_s;
                        lane.ledger.work(&lane.profile, t_bwd);
                        lane.ledger.trace.span(SpanKind::Fusion, bwd_t0, t_bwd, 0, 0);
                    } else {
                        // No fallback path in SplitFed: the step is lost.
                        lane.ledger.fallback_steps += 1;
                        lane.ledger
                            .trace
                            .span(SpanKind::Fallback, lane.ledger.branch_s, 0.0, 0, 0);
                    }
                }
                Ok(())
            })?;

            lanes
                .into_iter()
                .map(|lane| {
                    net.absorb_lane(&lane.net);
                    let mut ledger = lane.ledger;
                    ledger.faults.add(&lane.net.faults);
                    ledger.wire_bytes = lane.net.traffic.total_bytes();
                    if fc.crash_at(round_u, ledger.client).is_some() {
                        ledger.faults.crashes += 1;
                        ledger
                            .trace
                            .instant(InstantKind::Crash, ledger.branch_s);
                    }
                    ledger
                })
                .collect()
        };

        let (round_dt, busy, stalled, server_steps, mut faults) = h.absorb_ledgers(&mut ledgers);
        faults.add(&resync_faults);

        // ---- FedAvg of client-side models (sample-count weights) ----
        // Uploads travel as PrefixUpload frames (SplitFed clients train
        // no auxiliary classifier, so the payload is the prefix alone)
        // and the server averages the *decoded* prefixes.
        // Dead and mid-round-crashed clients skip the barrier; FedAvg
        // weights renormalize over the actual participants.
        let agg_t0 = h.clock.now();
        let mut agg_bytes = 0u64;
        let mut agg_entries: Vec<(usize, f64)> = roster.iter().map(|&id| (id, 0.0)).collect();
        let mut uploads: Vec<(usize, usize, Vec<f32>)> = Vec::with_capacity(slots.len());
        for s in &slots {
            if fc.crash_at(round_u, s.ci).is_some() {
                continue;
            }
            let payload = h.client(s.ci).upload_payload();
            let frame_len = h
                .wire
                .encode_to(MsgType::PrefixUpload, &payload, 0.0, &mut bar_scratch)
                .len() as u64;
            let t = h.net.bulk_up_framed(
                s.ci,
                Framed {
                    wire: frame_len,
                    raw: (payload.len() * 4) as u64,
                },
            );
            let pos = roster
                .binary_search(&s.ci)
                .expect("slot drawn from roster");
            agg_entries[pos].1 = t;
            agg_bytes += frame_len;
            uploads.push((s.ci, s.buf, h.wire.decode(&bar_scratch.frame)?.data));
        }
        h.charge_barrier_phase(&agg_entries);
        let total_samples: f64 = uploads
            .iter()
            .map(|(ci, _, _)| h.client(*ci).shard.len() as f64)
            .sum();
        if !uploads.is_empty() {
            let items: Vec<(usize, &[f32], f64)> = uploads
                .iter()
                .map(|(ci, _, data)| {
                    (
                        depth,
                        data.as_slice(),
                        h.client(*ci).shard.len() as f64 / total_samples.max(1.0),
                    )
                })
                .collect();
            h.server.fedavg_prefixes(&items, 0.0);
        }

        // ---- FedAvg of the per-client server-side copies (SplitFed) ----
        // Only participating clients' copies cross the main↔Fed server
        // link (and enter the average); afterwards every copy — absent
        // clients' included — is reset to the fresh average server-side
        // (a server-internal memcpy, no wire charge). Pooled copies skip
        // the reset: next round's refresh reads the averaged server
        // state and lands on the same values.
        let n_par = uploads.len() as u64;
        let copy_bytes = ((suffix_len + clf_len) * 4) as u64;
        // One logical transfer per participating copy per direction,
        // each paying the fed-link half-RTT.
        let fed_t = h.net.fed_link(copy_bytes * n_par * 2, n_par * 2);
        h.clock.advance(fed_t);
        let mut srv_avg = vec![0.0f32; suffix_len];
        let mut clf_avg = vec![0.0f32; clf_len];
        for (ci, buf, _) in &uploads {
            let w = (h.client(*ci).shard.len() as f64 / total_samples.max(1.0)) as f32;
            math::axpy(&mut srv_avg, &srv_copies[*buf], w);
            math::axpy(&mut clf_avg, &clf_copies[*buf], w);
        }
        let cut = h.server.prefix_len(depth);
        if !uploads.is_empty() {
            h.server.enc[cut..].copy_from_slice(&srv_avg);
            h.server.clf_s.copy_from_slice(&clf_avg);
            if !sampled {
                for ci in 0..n {
                    srv_copies[ci].copy_from_slice(&srv_avg);
                    clf_copies[ci].copy_from_slice(&clf_avg);
                }
            }
        }
        // The aggregate span covers both FedAvg legs: prefix uploads and
        // the fed-link round trip of the server-side copies.
        agg_bytes += copy_bytes * n_par * 2;
        let agg_dur = h.clock.now() - agg_t0;
        if let Some(tr) = h.tracer.as_mut() {
            tr.track_span(TRACK_SERVER, SpanKind::Aggregate, agg_t0, agg_dur, agg_bytes, n_par);
        }

        // ---- Broadcast the aggregated client-side model ----
        // One fixed split → every client receives the same prefix, so the
        // Broadcast frame is encoded (and decoded) once and charged per
        // client; clients sync from the decoded tensor.
        let bc_t0 = h.clock.now();
        let mut bc_bytes = 0u64;
        let mut bc_count = 0u64;
        let frame_len = h
            .wire
            .encode_to(MsgType::Broadcast, &h.server.enc[..cut], 0.0, &mut bar_scratch)
            .len() as u64;
        let bc_payload = h.wire.decode(&bar_scratch.frame)?.data;
        let bc_framed = Framed {
            wire: frame_len,
            raw: (cut * 4) as u64,
        };
        let mut bc_entries: Vec<(usize, f64)> = roster.iter().map(|&id| (id, 0.0)).collect();
        for s in &slots {
            if fc.crash_at(round_u, s.ci).is_some() {
                continue; // absentees catch up via the charged resync
            }
            let pos = roster
                .binary_search(&s.ci)
                .expect("slot drawn from roster");
            bc_entries[pos].1 = h.net.bulk_down_framed(s.ci, bc_framed);
            bc_bytes += frame_len;
            bc_count += 1;
            h.client_mut(s.ci).sync_from_global(&bc_payload);
        }
        h.charge_barrier_phase(&bc_entries);
        let bc_dur = h.clock.now() - bc_t0;
        if let Some(tr) = h.tracer.as_mut() {
            tr.track_span(TRACK_SERVER, SpanKind::Broadcast, bc_t0, bc_dur, bc_bytes, bc_count);
        }

        let acc = h.eval_global(rt)?;
        if h.finish_round(
            round,
            round_dt,
            &roster,
            &busy,
            acc,
            stalled,
            server_steps,
            faults,
        ) {
            break;
        }
    }
    Ok(())
}
