//! SplitFed (SFL) baseline — Thapa et al., AAAI 2022.
//!
//! Faithful to SplitFed v1's architecture:
//! * one **fixed** split depth for every client (no resource awareness);
//! * the main server keeps a **per-client copy** of the server-side
//!   network (suffix + classifier); each round the Fed server FedAvgs
//!   both the client-side and the server-side models, which is why SFL's
//!   communication bill scales with `clients × server-side size`;
//! * clients depend entirely on server gradients: when the server is
//!   unreachable the step **stalls** (the behaviour SuperSFL's fallback
//!   removes — recorded in `fallback_steps` as stalled steps).

use crate::energy::PowerState;
use crate::fedserver;
use crate::orchestrator::Harness;
use crate::runtime::Runtime;
use crate::util::math;
use crate::Result;

pub fn run(rt: &Runtime, h: &mut Harness) -> Result<()> {
    let classes = h.cfg.data.classes;
    let depth = h.cfg.sfl_fixed_depth.clamp(1, rt.model().depth - 1);
    let dim = rt.model().dim;
    let local_steps = h.cfg.train.local_steps;
    let lr_server = h.cfg.train.lr_server as f32;
    let suffix_len = h.server.suffix(depth).len();

    // Per-client server-side copies (suffix + classifier), SplitFed-style.
    let n = h.clients.len();
    let mut srv_copies: Vec<Vec<f32>> = vec![h.server.suffix(depth).to_vec(); n];
    let mut clf_copies: Vec<Vec<f32>> = vec![h.server.clf_s.clone(); n];

    for round in 1..=h.cfg.train.rounds {
        h.net.begin_round();
        let mut busy = vec![0.0f64; n];
        let mut branch = vec![0.0f64; n];
        let mut stalled = 0usize;
        let mut server_steps = 0usize;

        for ci in 0..n {
            h.clients[ci].begin_round();
            let profile = h.profiles[ci].clone();
            let smashed = h.cost.smashed_bytes(dim);
            let srv_time = h.server_step_time(depth);

            for _ in 0..local_steps {
                let batch = h.clients[ci].shard.next_batch(&h.train, rt.model().batch);

                let z = rt.client_fwd(depth, &h.clients[ci].enc, &batch.x)?;
                let t_fwd = h.cost.time_s(h.cost.client_fwd_flops(depth), profile.flops);
                h.meter.client(&profile, PowerState::Compute, t_fwd);
                branch[ci] += t_fwd;
                busy[ci] += t_fwd;

                let ex = h.net.exchange(ci, smashed, smashed, srv_time);
                branch[ci] += ex.time_s();
                let tx = (ex.time_s() - srv_time).max(0.0);
                h.meter.client(&profile, PowerState::Transmit, tx);
                busy[ci] += tx;

                if ex.is_ok() {
                    h.meter.server_busy(srv_time);
                    let out = rt.server_step(
                        depth,
                        classes,
                        &srv_copies[ci],
                        &clf_copies[ci],
                        &z,
                        &batch.y,
                    )?;
                    math::sgd_step(&mut srv_copies[ci], &out.g_srv, lr_server);
                    math::sgd_step(&mut clf_copies[ci], &out.g_clf_s, lr_server);
                    h.clients[ci].round_server_loss.push(out.loss as f64);

                    let g_enc = rt.client_bwd(depth, &h.clients[ci].enc, &batch.x, &out.g_z)?;
                    let lr = h.clients[ci].lr;
                    math::sgd_step(&mut h.clients[ci].enc, &g_enc, lr);
                    let t_bwd = h.cost.time_s(h.cost.client_bwd_flops(depth), profile.flops);
                    h.meter.client(&profile, PowerState::Compute, t_bwd);
                    branch[ci] += t_bwd;
                    busy[ci] += t_bwd;
                    server_steps += 1;
                } else {
                    // No fallback path in SplitFed: the step is lost.
                    stalled += 1;
                }
            }
        }

        let round_dt = h.clock.advance_parallel(&branch);

        // ---- FedAvg of client-side models (sample-count weights) ----
        let mut agg_branch = vec![0.0f64; n];
        for ci in 0..n {
            agg_branch[ci] = h.net.bulk_up(ci, (h.clients[ci].enc.len() * 4) as u64);
        }
        let agg_dt = h.clock.advance_parallel(&agg_branch);
        for (i, &t) in agg_branch.iter().enumerate() {
            let p = h.profiles[i].clone();
            h.meter.client(&p, PowerState::Transmit, t);
            h.meter.client(&p, PowerState::Idle, (agg_dt - t).max(0.0));
        }
        let total_samples: f64 = h.clients.iter().map(|c| c.shard.len() as f64).sum();
        {
            let items: Vec<(usize, &[f32], f64)> = h
                .clients
                .iter()
                .map(|c| {
                    (
                        depth,
                        c.enc.as_slice(),
                        c.shard.len() as f64 / total_samples.max(1.0),
                    )
                })
                .collect();
            let sizes = h.server.layer_sizes().to_vec();
            fedserver::aggregate_weighted(&mut h.server.enc, &sizes, &items, 0.0);
        }

        // ---- FedAvg of the per-client server-side copies (SplitFed) ----
        // Every copy crosses the main↔Fed server link, both directions.
        let copy_bytes = ((suffix_len + h.server.clf_s.len()) * 4) as u64;
        let fed_t = h.net.fed_link(copy_bytes * n as u64 * 2);
        h.clock.advance(fed_t);
        let mut srv_avg = vec![0.0f32; suffix_len];
        let mut clf_avg = vec![0.0f32; h.server.clf_s.len()];
        for ci in 0..n {
            let w = (h.clients[ci].shard.len() as f64 / total_samples.max(1.0)) as f32;
            math::axpy(&mut srv_avg, &srv_copies[ci], w);
            math::axpy(&mut clf_avg, &clf_copies[ci], w);
        }
        let cut = h.server.prefix_len(depth);
        h.server.enc[cut..].copy_from_slice(&srv_avg);
        h.server.clf_s.copy_from_slice(&clf_avg);
        for ci in 0..n {
            srv_copies[ci].copy_from_slice(&srv_avg);
            clf_copies[ci].copy_from_slice(&clf_avg);
        }

        // ---- Broadcast the aggregated client-side model ----
        let mut bc = vec![0.0f64; n];
        for ci in 0..n {
            bc[ci] = h.net.bulk_down(ci, (h.clients[ci].enc.len() * 4) as u64);
            let g = h.server.enc.clone();
            h.clients[ci].sync_from_global(&g);
        }
        let bc_dt = h.clock.advance_parallel(&bc);
        for (i, &t) in bc.iter().enumerate() {
            let p = h.profiles[i].clone();
            h.meter.client(&p, PowerState::Transmit, t);
            h.meter.client(&p, PowerState::Idle, (bc_dt - t).max(0.0));
        }

        let acc = h.eval_global(rt)?;
        if h.finish_round(round, round_dt, &busy, acc, stalled, server_steps) {
            break;
        }
    }
    Ok(())
}
