//! Heterogeneous device fleet sampling (paper §III-A).
//!
//! Each simulated client gets a resource profile drawn once at experiment
//! start: memory U[2,16] GB and latency U[20,200] ms exactly as the paper
//! samples them, plus compute speed, link bandwidths and a power draw used
//! by the cost/energy models.

use crate::config::{EnergyConfig, FleetConfig};
use crate::util::rng::Pcg32;

/// One client device's static resource profile — the `C_i = (m_i, lat_i)`
/// of paper Eq. 1 plus simulator-side attributes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceProfile {
    pub id: usize,
    /// Memory capacity, GB (paper: reported via psutil//proc/meminfo).
    pub mem_gb: f64,
    /// Round-trip latency to the server, seconds (paper: measured with a
    /// dummy 2-layer CNN probe during initialization).
    pub latency_s: f64,
    /// Device compute speed, FLOP/s.
    pub flops: f64,
    /// Uplink bandwidth, bytes/s.
    pub uplink_bps: f64,
    /// Downlink bandwidth, bytes/s.
    pub downlink_bps: f64,
    /// Power while computing, W.
    pub active_w: f64,
    /// Power while idle, W.
    pub idle_w: f64,
    /// Radio power while transmitting, W.
    pub tx_w: f64,
}

/// Uniform draws one profile consumes from the fleet stream, in fixed
/// order: memory, latency, compute, uplink, downlink. Client `i`'s
/// profile therefore depends only on stream positions `[5i, 5i+5)` —
/// the invariant [`Fleet::profile`] jumps on.
pub const PROFILE_DRAWS: u64 = 5;

/// Draw one client profile from the fleet stream positioned at its
/// 5-draw window.
fn sample_one(
    cfg: &FleetConfig,
    energy: &EnergyConfig,
    id: usize,
    rng: &mut Pcg32,
) -> DeviceProfile {
    let mem_gb = rng.uniform_range(cfg.mem_gb.0, cfg.mem_gb.1);
    let latency_s = rng.uniform_range(cfg.latency_ms.0, cfg.latency_ms.1) / 1e3;
    let flops = rng.uniform_range(cfg.compute_gflops.0, cfg.compute_gflops.1) * 1e9;
    // Power correlates with compute capability: faster devices are
    // bigger SoCs. Map the compute draw linearly into the range.
    let frac = (flops / 1e9 - cfg.compute_gflops.0)
        / (cfg.compute_gflops.1 - cfg.compute_gflops.0).max(1e-9);
    let active_w = energy.client_active_w.0
        + frac * (energy.client_active_w.1 - energy.client_active_w.0);
    DeviceProfile {
        id,
        mem_gb,
        latency_s,
        flops,
        uplink_bps: rng.uniform_range(cfg.uplink_mbps.0, cfg.uplink_mbps.1) * 1e6 / 8.0,
        downlink_bps: rng.uniform_range(cfg.downlink_mbps.0, cfg.downlink_mbps.1) * 1e6 / 8.0,
        active_w,
        idle_w: energy.client_idle_w,
        tx_w: energy.client_tx_w,
    }
}

/// Sample a fleet of `cfg.clients` profiles.
pub fn sample_fleet(
    cfg: &FleetConfig,
    energy: &EnergyConfig,
    rng: &mut Pcg32,
) -> Vec<DeviceProfile> {
    (0..cfg.clients)
        .map(|id| sample_one(cfg, energy, id, rng))
        .collect()
}

/// A lazily-sampled device fleet: O(1) memory for any fleet size.
///
/// [`Fleet::profile`] reproduces exactly what [`sample_fleet`] would
/// have drawn for the same stream, without materializing the other
/// clients: each profile consumes [`PROFILE_DRAWS`] sequential uniforms,
/// so client `i`'s profile is a pure function of `(fleet stream, i)` —
/// the generator jumps to position `5·i` in O(log i) via
/// [`Pcg32::advance`] and draws the 5-uniform window. Profiles are
/// therefore **prefix-stable**: client `i` gets the identical profile
/// whether the fleet holds 10 clients or a million, and regardless of
/// which cohort a sampled round draws.
#[derive(Clone, Debug)]
pub struct Fleet {
    cfg: FleetConfig,
    energy: EnergyConfig,
    base: Pcg32,
}

impl Fleet {
    /// Wrap the fleet stream (`rng` at position 0, e.g. the harness's
    /// `root.fork(3)`) for on-demand sampling.
    pub fn new(cfg: FleetConfig, energy: EnergyConfig, rng: Pcg32) -> Fleet {
        Fleet {
            cfg,
            energy,
            base: rng,
        }
    }

    /// Number of clients in the (virtual) fleet.
    pub fn len(&self) -> usize {
        self.cfg.clients
    }

    pub fn is_empty(&self) -> bool {
        self.cfg.clients == 0
    }

    /// Client `id`'s profile, generated on demand (id may exceed
    /// `len()` — the window is position-defined for any index).
    pub fn profile(&self, id: usize) -> DeviceProfile {
        let mut rng = self.base.clone();
        rng.advance(PROFILE_DRAWS * id as u64);
        sample_one(&self.cfg, &self.energy, id, &mut rng)
    }
}

/// Stream-selector salt for the per-round cohort draw. The cohort uses
/// its own `(seed ^ salt, round)` PCG stream so drawing it perturbs no
/// other stream in the run — `sample=off` trajectories stay bitwise
/// identical to builds that never had sampling.
const COHORT_SALT: u64 = 0xC0_0B17_5EED;

/// Draw the round's participant cohort: `k` distinct client ids out of
/// `fleet`, returned sorted ascending.
///
/// Determinism contract: the cohort is a pure function of
/// `(seed, round, fleet, k)` — never of thread count, engine state, or
/// which profiles were previously materialized — so sampled runs are
/// bitwise identical for any `--threads`/`--kernel-threads`.
///
/// Memory: O(k) when `k` is a small fraction of the fleet (distinct-id
/// rejection sampling; acceptance ≥ ½ while `2k ≤ fleet`), O(fleet)
/// transiently otherwise (partial Fisher–Yates).
pub fn sample_cohort(seed: u64, round: usize, fleet: usize, k: usize) -> Vec<usize> {
    let k = k.min(fleet);
    if k == fleet {
        return (0..fleet).collect();
    }
    let mut rng = Pcg32::new(seed ^ COHORT_SALT, round as u64);
    let mut picked: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
    if 2 * k <= fleet {
        while picked.len() < k {
            picked.insert(rng.uniform_usize(fleet));
        }
    } else {
        // Dense cohort: partial Fisher–Yates over the full index range.
        let mut ids: Vec<usize> = (0..fleet).collect();
        for i in 0..k {
            let j = i + rng.uniform_usize(fleet - i);
            ids.swap(i, j);
        }
        picked.extend(ids[..k].iter().copied());
    }
    picked.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn profiles_within_configured_ranges() {
        forall(1, 20, |rng| {
            let cfg = FleetConfig {
                clients: 25,
                ..FleetConfig::default()
            };
            let fleet = sample_fleet(&cfg, &EnergyConfig::default(), rng);
            assert_eq!(fleet.len(), 25);
            for p in &fleet {
                assert!((2.0..=16.0).contains(&p.mem_gb));
                assert!((0.020..=0.200).contains(&p.latency_s));
                assert!(p.flops > 0.0 && p.uplink_bps > 0.0 && p.downlink_bps > 0.0);
                assert!(p.active_w >= EnergyConfig::default().client_active_w.0 - 1e-9);
                assert!(p.active_w <= EnergyConfig::default().client_active_w.1 + 1e-9);
            }
        });
    }

    #[test]
    fn fleet_is_actually_heterogeneous() {
        let cfg = FleetConfig {
            clients: 30,
            ..FleetConfig::default()
        };
        let fleet = sample_fleet(&cfg, &EnergyConfig::default(), &mut Pcg32::seeded(3));
        let min_mem = fleet.iter().map(|p| p.mem_gb).fold(f64::MAX, f64::min);
        let max_mem = fleet.iter().map(|p| p.mem_gb).fold(f64::MIN, f64::max);
        assert!(max_mem - min_mem > 4.0, "spread {}", max_mem - min_mem);
    }

    #[test]
    fn ids_are_sequential() {
        let cfg = FleetConfig {
            clients: 5,
            ..FleetConfig::default()
        };
        let fleet = sample_fleet(&cfg, &EnergyConfig::default(), &mut Pcg32::seeded(4));
        for (i, p) in fleet.iter().enumerate() {
            assert_eq!(p.id, i);
        }
    }

    #[test]
    fn lazy_fleet_reproduces_eager_sampling_exactly() {
        let cfg = FleetConfig {
            clients: 17,
            ..FleetConfig::default()
        };
        let energy = EnergyConfig::default();
        let eager = sample_fleet(&cfg, &energy, &mut Pcg32::seeded(9));
        let lazy = Fleet::new(cfg, energy, Pcg32::seeded(9));
        assert_eq!(lazy.len(), 17);
        // Any access order, including repeated and reverse.
        for &i in &[16usize, 0, 7, 7, 3, 16] {
            assert_eq!(lazy.profile(i), eager[i], "client {i}");
        }
    }

    #[test]
    fn lazy_profiles_are_prefix_stable_across_fleet_sizes() {
        // Client i's profile must not depend on how many clients exist:
        // a 10-client fleet and a 10_000-client fleet drawn from the
        // same stream agree on every shared prefix index.
        let energy = EnergyConfig::default();
        let small = Fleet::new(
            FleetConfig { clients: 10, ..FleetConfig::default() },
            energy.clone(),
            Pcg32::seeded(21),
        );
        let big = Fleet::new(
            FleetConfig { clients: 10_000, ..FleetConfig::default() },
            energy,
            Pcg32::seeded(21),
        );
        for i in 0..10 {
            assert_eq!(small.profile(i), big.profile(i), "client {i}");
        }
        // And a deep index is reachable without drawing the prefix.
        let p = big.profile(9_999);
        assert_eq!(p.id, 9_999);
        assert!((2.0..=16.0).contains(&p.mem_gb));
    }

    #[test]
    fn cohort_is_a_pure_function_of_seed_and_round() {
        let a = sample_cohort(42, 3, 10_000, 64);
        let b = sample_cohort(42, 3, 10_000, 64);
        assert_eq!(a, b);
        assert_eq!(a.len(), 64);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted + distinct");
        assert!(a.iter().all(|&i| i < 10_000));
        // Different rounds (and seeds) draw different cohorts.
        assert_ne!(a, sample_cohort(42, 4, 10_000, 64));
        assert_ne!(a, sample_cohort(43, 3, 10_000, 64));
    }

    #[test]
    fn cohort_dense_and_full_paths() {
        // Dense path (2k > fleet): still k distinct sorted ids.
        let c = sample_cohort(7, 0, 10, 8);
        assert_eq!(c.len(), 8);
        assert!(c.windows(2).all(|w| w[0] < w[1]));
        // k == fleet (and k > fleet) degenerate to full participation.
        assert_eq!(sample_cohort(7, 5, 6, 6), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(sample_cohort(7, 5, 6, 99), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn cohorts_cover_the_fleet_over_rounds() {
        // 20 rounds × 16-of-64 should touch most of the fleet; a biased
        // sampler (e.g. always low ids) would fail this.
        let mut seen = std::collections::BTreeSet::new();
        for round in 0..20 {
            seen.extend(sample_cohort(11, round, 64, 16));
        }
        assert!(seen.len() > 48, "only {} of 64 ids ever sampled", seen.len());
    }

    #[test]
    fn power_tracks_compute() {
        let cfg = FleetConfig {
            clients: 40,
            ..FleetConfig::default()
        };
        let fleet = sample_fleet(&cfg, &EnergyConfig::default(), &mut Pcg32::seeded(5));
        let fastest = fleet
            .iter()
            .max_by(|a, b| a.flops.partial_cmp(&b.flops).unwrap())
            .unwrap();
        let slowest = fleet
            .iter()
            .min_by(|a, b| a.flops.partial_cmp(&b.flops).unwrap())
            .unwrap();
        assert!(fastest.active_w > slowest.active_w);
    }
}
