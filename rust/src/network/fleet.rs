//! Heterogeneous device fleet sampling (paper §III-A).
//!
//! Each simulated client gets a resource profile drawn once at experiment
//! start: memory U[2,16] GB and latency U[20,200] ms exactly as the paper
//! samples them, plus compute speed, link bandwidths and a power draw used
//! by the cost/energy models.

use crate::config::{EnergyConfig, FleetConfig};
use crate::util::rng::Pcg32;

/// One client device's static resource profile — the `C_i = (m_i, lat_i)`
/// of paper Eq. 1 plus simulator-side attributes.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub id: usize,
    /// Memory capacity, GB (paper: reported via psutil//proc/meminfo).
    pub mem_gb: f64,
    /// Round-trip latency to the server, seconds (paper: measured with a
    /// dummy 2-layer CNN probe during initialization).
    pub latency_s: f64,
    /// Device compute speed, FLOP/s.
    pub flops: f64,
    /// Uplink bandwidth, bytes/s.
    pub uplink_bps: f64,
    /// Downlink bandwidth, bytes/s.
    pub downlink_bps: f64,
    /// Power while computing, W.
    pub active_w: f64,
    /// Power while idle, W.
    pub idle_w: f64,
    /// Radio power while transmitting, W.
    pub tx_w: f64,
}

/// Sample a fleet of `cfg.clients` profiles.
pub fn sample_fleet(
    cfg: &FleetConfig,
    energy: &EnergyConfig,
    rng: &mut Pcg32,
) -> Vec<DeviceProfile> {
    (0..cfg.clients)
        .map(|id| {
            let mem_gb = rng.uniform_range(cfg.mem_gb.0, cfg.mem_gb.1);
            let latency_s = rng.uniform_range(cfg.latency_ms.0, cfg.latency_ms.1) / 1e3;
            let flops = rng.uniform_range(cfg.compute_gflops.0, cfg.compute_gflops.1) * 1e9;
            // Power correlates with compute capability: faster devices are
            // bigger SoCs. Map the compute draw linearly into the range.
            let frac = (flops / 1e9 - cfg.compute_gflops.0)
                / (cfg.compute_gflops.1 - cfg.compute_gflops.0).max(1e-9);
            let active_w = energy.client_active_w.0
                + frac * (energy.client_active_w.1 - energy.client_active_w.0);
            DeviceProfile {
                id,
                mem_gb,
                latency_s,
                flops,
                uplink_bps: rng.uniform_range(cfg.uplink_mbps.0, cfg.uplink_mbps.1) * 1e6
                    / 8.0,
                downlink_bps: rng.uniform_range(cfg.downlink_mbps.0, cfg.downlink_mbps.1)
                    * 1e6
                    / 8.0,
                active_w,
                idle_w: energy.client_idle_w,
                tx_w: energy.client_tx_w,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn profiles_within_configured_ranges() {
        forall(1, 20, |rng| {
            let cfg = FleetConfig {
                clients: 25,
                ..FleetConfig::default()
            };
            let fleet = sample_fleet(&cfg, &EnergyConfig::default(), rng);
            assert_eq!(fleet.len(), 25);
            for p in &fleet {
                assert!((2.0..=16.0).contains(&p.mem_gb));
                assert!((0.020..=0.200).contains(&p.latency_s));
                assert!(p.flops > 0.0 && p.uplink_bps > 0.0 && p.downlink_bps > 0.0);
                assert!(p.active_w >= EnergyConfig::default().client_active_w.0 - 1e-9);
                assert!(p.active_w <= EnergyConfig::default().client_active_w.1 + 1e-9);
            }
        });
    }

    #[test]
    fn fleet_is_actually_heterogeneous() {
        let cfg = FleetConfig {
            clients: 30,
            ..FleetConfig::default()
        };
        let fleet = sample_fleet(&cfg, &EnergyConfig::default(), &mut Pcg32::seeded(3));
        let min_mem = fleet.iter().map(|p| p.mem_gb).fold(f64::MAX, f64::min);
        let max_mem = fleet.iter().map(|p| p.mem_gb).fold(f64::MIN, f64::max);
        assert!(max_mem - min_mem > 4.0, "spread {}", max_mem - min_mem);
    }

    #[test]
    fn ids_are_sequential() {
        let cfg = FleetConfig {
            clients: 5,
            ..FleetConfig::default()
        };
        let fleet = sample_fleet(&cfg, &EnergyConfig::default(), &mut Pcg32::seeded(4));
        for (i, p) in fleet.iter().enumerate() {
            assert_eq!(p.id, i);
        }
    }

    #[test]
    fn power_tracks_compute() {
        let cfg = FleetConfig {
            clients: 40,
            ..FleetConfig::default()
        };
        let fleet = sample_fleet(&cfg, &EnergyConfig::default(), &mut Pcg32::seeded(5));
        let fastest = fleet
            .iter()
            .max_by(|a, b| a.flops.partial_cmp(&b.flops).unwrap())
            .unwrap();
        let slowest = fleet
            .iter()
            .min_by(|a, b| a.flops.partial_cmp(&b.flops).unwrap())
            .unwrap();
        assert!(fastest.active_w > slowest.active_w);
    }
}
