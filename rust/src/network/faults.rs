//! Deterministic fault-injection engine (paper §II-C stress surface).
//!
//! The seed repo modelled failure as a memoryless per-exchange Bernoulli
//! plus an iid per-round server coin — the friendliest possible failure
//! model. Real edge failures are bursty and correlated ("Optimizing Split
//! Federated Learning with Unstable Client Participation", arXiv
//! 2509.17398), so this module layers composable fault *processes* under
//! the [`crate::network::NetLane`] exchange surface:
//!
//! * **Gilbert–Elliott bursty links** — a per-client two-state Markov
//!   channel (good/bad) with configurable transition probabilities. All
//!   draws come from the lane's existing `(seed, round, client)` PCG
//!   stream, so `--threads N` bit-identity holds by construction.
//! * **Server outage windows** — multi-round (optionally periodic)
//!   outages layered on top of the iid availability coin.
//! * **Mid-round crash / churn** — a client dies partway through its
//!   local steps, misses ≥ 1 rounds, then rejoins and resyncs via a
//!   charged full Broadcast (the reconnect-with-resume semantics the
//!   future `TcpTransport` inherits).
//! * **Frame corruption** — flips payload bytes of an otherwise
//!   successful exchange so the wire layer's CRC path is exercised end
//!   to end.
//! * **Bounded retry with exponential backoff** — retries recharge real
//!   frame bytes and backoff time; budget exhaustion surfaces as the
//!   timeout that triggers the paper's Alg. 3 fallback.
//!
//! Every process is a pure function of the run seed and the schedule in
//! [`FaultConfig`]; nothing here reads wall-clock time or OS entropy.

use crate::util::rng::Pcg32;
use crate::{Error, Result};

/// One scheduled mid-round crash: `client` completes `step` local steps
/// of round `round`, contributes nothing to that round's merge, stays
/// dark for `down_rounds` full rounds, then rejoins (and is resynced via
/// a charged Broadcast) at round `round + down_rounds + 1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CrashSpec {
    pub round: u64,
    pub client: usize,
    pub step: usize,
    pub down_rounds: u64,
}

/// The composable fault schedule. `FaultConfig::default()` is inert:
/// every process disabled, zero retries, quorum 0 — byte- and
/// draw-identical to the pre-fault simulator.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// Gilbert–Elliott good→bad transition probability (per exchange).
    /// `0.0` disables the bursty-link process entirely.
    pub ge_p_gb: f64,
    /// Gilbert–Elliott bad→good transition probability (per exchange).
    /// Mean burst length is `1 / ge_p_bg` exchanges.
    pub ge_p_bg: f64,
    /// Drop probability while the link is in the bad state.
    pub ge_drop_bad: f64,
    /// Drop probability while the link is in the good state.
    pub ge_drop_good: f64,
    /// First round (1-based) of the server outage window. `outage_len == 0`
    /// disables outages.
    pub outage_start: u64,
    /// Number of consecutive rounds the server is dark per window.
    pub outage_len: u64,
    /// Window repeat period in rounds; `0` means a single window.
    pub outage_period: u64,
    /// Scheduled mid-round crashes (kept sorted by `(round, client)`).
    pub crashes: Vec<CrashSpec>,
    /// Probability that a *successful* exchange's uplink frame arrives
    /// with a flipped payload byte (CRC failure at decode).
    pub corrupt_prob: f64,
    /// Retry budget per exchange (0 = no retries, seed behaviour).
    pub retries: u32,
    /// Backoff before retry k is `base · mult^(k-1)`, jittered.
    pub backoff_base_s: f64,
    pub backoff_mult: f64,
    /// Relative jitter half-width: the backoff is scaled by a factor
    /// uniform in `[1 - j/2, 1 + j/2)`, drawn from the lane stream.
    pub backoff_jitter: f64,
    /// Quorum fraction of live lanes that must report before the SSFL
    /// merge proceeds. `0.0` means any number (seed behaviour).
    pub quorum: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            ge_p_gb: 0.0,
            ge_p_bg: 1.0,
            ge_drop_bad: 1.0,
            ge_drop_good: 0.0,
            outage_start: 0,
            outage_len: 0,
            outage_period: 0,
            crashes: Vec::new(),
            corrupt_prob: 0.0,
            retries: 0,
            backoff_base_s: 0.05,
            backoff_mult: 2.0,
            backoff_jitter: 0.0,
            quorum: 0.0,
        }
    }
}

impl FaultConfig {
    /// True when any fault process differs from the inert default.
    pub fn enabled(&self) -> bool {
        *self != FaultConfig::default()
    }

    /// The Gilbert–Elliott process is active (lanes carry channel state
    /// and burn two draws per exchange attempt instead of one).
    pub fn ge_enabled(&self) -> bool {
        self.ge_p_gb > 0.0
    }

    /// Stationary probability of the bad state, `p_gb / (p_gb + p_bg)`.
    pub fn ge_stationary_bad(&self) -> f64 {
        if self.ge_p_gb + self.ge_p_bg <= 0.0 {
            return 0.0;
        }
        self.ge_p_gb / (self.ge_p_gb + self.ge_p_bg)
    }

    /// Is the server inside an outage window at `round` (1-based)?
    pub fn in_outage(&self, round: u64) -> bool {
        if self.outage_len == 0 || round < self.outage_start {
            return false;
        }
        if self.outage_period == 0 {
            round < self.outage_start + self.outage_len
        } else {
            (round - self.outage_start) % self.outage_period < self.outage_len
        }
    }

    /// The crash scheduled to hit `client` *during* `round`, if any.
    pub fn crash_at(&self, round: u64, client: usize) -> Option<&CrashSpec> {
        self.crashes
            .iter()
            .find(|c| c.round == round && c.client == client)
    }

    /// Is `client` dark (crashed in an earlier round, not yet rejoined)
    /// for the whole of `round`?
    pub fn is_down(&self, round: u64, client: usize) -> bool {
        self.crashes
            .iter()
            .any(|c| c.client == client && c.round < round && round <= c.round + c.down_rounds)
    }

    /// Number of clients participating at the start of `round` (the
    /// participant-normalization denominator `n_live` of the quorum
    /// merge; a client crashing *during* the round still counts — it was
    /// live when the round began).
    pub fn live_count(&self, round: u64, n: usize) -> usize {
        (0..n).filter(|&c| !self.is_down(round, c)).count()
    }

    /// Quorum barrier: may the merge proceed with `reporting` of
    /// `n_live` live lanes delivering server-coupled updates?
    pub fn quorum_met(&self, reporting: usize, n_live: usize) -> bool {
        reporting as f64 + 1e-9 >= self.quorum * n_live as f64
    }

    /// Whether any *stochastic* injector is configured (bursty links,
    /// outage windows, scheduled crashes, corruption rolls). The TCP
    /// transport rejects these — on a real wire the faults come from the
    /// sockets — while the deterministic recovery knobs (retry budget,
    /// quorum fraction) stay honored.
    pub fn has_stochastic_injectors(&self) -> bool {
        self.ge_p_gb > 0.0
            || self.outage_len > 0
            || !self.crashes.is_empty()
            || self.corrupt_prob > 0.0
    }

    /// Backoff before retry `attempt` (1-based), optionally jittered
    /// from the lane stream. Only draws from `rng` when jitter is
    /// configured, so jitter-free schedules burn no extra randomness.
    pub fn backoff_s(&self, attempt: u32, rng: &mut Pcg32) -> f64 {
        let base = self.backoff_base_s * self.backoff_mult.powi(attempt as i32 - 1);
        if self.backoff_jitter > 0.0 {
            base * (1.0 + self.backoff_jitter * (rng.uniform() - 0.5))
        } else {
            base
        }
    }

    /// Parse the comma-separated fault spec grammar:
    ///
    /// ```text
    /// off                                   inert schedule (default)
    /// ge=p_gb:p_bg[:drop_bad[:drop_good]]   Gilbert–Elliott bursty link
    /// outage=start:len[:period]             server outage window(s)
    /// crash=round:client:step:down          mid-round crash (repeatable)
    /// corrupt=p                             frame-corruption probability
    /// retry=n[:base[:mult[:jitter]]]        bounded retry + backoff
    /// quorum=f                              merge quorum fraction
    /// ```
    pub fn parse(spec: &str) -> Result<FaultConfig> {
        let mut fc = FaultConfig::default();
        let spec = spec.trim();
        if spec.is_empty() || spec == "off" {
            return Ok(fc);
        }
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| bad(part, "expected key=value"))?;
            let fields: Vec<&str> = val.split(':').collect();
            match key {
                "ge" => {
                    if fields.len() < 2 || fields.len() > 4 {
                        return Err(bad(part, "ge=p_gb:p_bg[:drop_bad[:drop_good]]"));
                    }
                    fc.ge_p_gb = num(fields[0], part)?;
                    fc.ge_p_bg = num(fields[1], part)?;
                    if let Some(f) = fields.get(2) {
                        fc.ge_drop_bad = num(f, part)?;
                    }
                    if let Some(f) = fields.get(3) {
                        fc.ge_drop_good = num(f, part)?;
                    }
                }
                "outage" => {
                    if fields.len() < 2 || fields.len() > 3 {
                        return Err(bad(part, "outage=start:len[:period]"));
                    }
                    fc.outage_start = int(fields[0], part)?;
                    fc.outage_len = int(fields[1], part)?;
                    if let Some(f) = fields.get(2) {
                        fc.outage_period = int(f, part)?;
                    }
                }
                "crash" => {
                    if fields.len() != 4 {
                        return Err(bad(part, "crash=round:client:step:down"));
                    }
                    fc.crashes.push(CrashSpec {
                        round: int(fields[0], part)?,
                        client: int(fields[1], part)? as usize,
                        step: int(fields[2], part)? as usize,
                        down_rounds: int(fields[3], part)?,
                    });
                }
                "corrupt" => {
                    if fields.len() != 1 {
                        return Err(bad(part, "corrupt=p"));
                    }
                    fc.corrupt_prob = num(fields[0], part)?;
                }
                "retry" => {
                    if fields.is_empty() || fields.len() > 4 {
                        return Err(bad(part, "retry=n[:base[:mult[:jitter]]]"));
                    }
                    fc.retries = int(fields[0], part)? as u32;
                    if let Some(f) = fields.get(1) {
                        fc.backoff_base_s = num(f, part)?;
                    }
                    if let Some(f) = fields.get(2) {
                        fc.backoff_mult = num(f, part)?;
                    }
                    if let Some(f) = fields.get(3) {
                        fc.backoff_jitter = num(f, part)?;
                    }
                }
                "quorum" => {
                    if fields.len() != 1 {
                        return Err(bad(part, "quorum=f"));
                    }
                    fc.quorum = num(fields[0], part)?;
                }
                other => {
                    return Err(Error::Config(format!(
                        "unknown fault component '{other}' in '{part}' \
                         (want ge|outage|crash|corrupt|retry|quorum|off)"
                    )))
                }
            }
        }
        fc.crashes.sort_by_key(|c| (c.round, c.client));
        fc.validate().map_err(Error::Config)?;
        Ok(fc)
    }

    /// Resolve the schedule with the `SUPERSFL_FAULTS` env override
    /// (mirrors `WireCodecKind::from_env_or`): the env var wins over the
    /// config value; an invalid env spec is a hard panic because
    /// silently training under the wrong fault schedule is worse than
    /// crashing at startup.
    pub fn from_env_or(fallback: FaultConfig) -> FaultConfig {
        // audit:allow(env-read) -- documented env-wins override mirroring the other from_env_or sites; invalid specs fail fast.
        match std::env::var("SUPERSFL_FAULTS") {
            Ok(s) => match FaultConfig::parse(&s) {
                Ok(fc) => fc,
                Err(e) => panic!("SUPERSFL_FAULTS={s}: {e}"),
            },
            Err(_) => fallback,
        }
    }

    /// Canonical spec string: `FaultConfig::parse(c.to_spec()) == c`.
    pub fn to_spec(&self) -> String {
        if !self.enabled() {
            return "off".to_string();
        }
        let d = FaultConfig::default();
        let mut parts = Vec::new();
        if self.ge_p_gb != d.ge_p_gb
            || self.ge_p_bg != d.ge_p_bg
            || self.ge_drop_bad != d.ge_drop_bad
            || self.ge_drop_good != d.ge_drop_good
        {
            parts.push(format!(
                "ge={}:{}:{}:{}",
                self.ge_p_gb, self.ge_p_bg, self.ge_drop_bad, self.ge_drop_good
            ));
        }
        if self.outage_len != 0 || self.outage_start != 0 || self.outage_period != 0 {
            parts.push(format!(
                "outage={}:{}:{}",
                self.outage_start, self.outage_len, self.outage_period
            ));
        }
        for c in &self.crashes {
            parts.push(format!(
                "crash={}:{}:{}:{}",
                c.round, c.client, c.step, c.down_rounds
            ));
        }
        if self.corrupt_prob != d.corrupt_prob {
            parts.push(format!("corrupt={}", self.corrupt_prob));
        }
        if self.retries != d.retries
            || self.backoff_base_s != d.backoff_base_s
            || self.backoff_mult != d.backoff_mult
            || self.backoff_jitter != d.backoff_jitter
        {
            parts.push(format!(
                "retry={}:{}:{}:{}",
                self.retries, self.backoff_base_s, self.backoff_mult, self.backoff_jitter
            ));
        }
        if self.quorum != d.quorum {
            parts.push(format!("quorum={}", self.quorum));
        }
        parts.join(",")
    }

    /// Structural validation (probabilities in range, schedules sane).
    pub fn validate(&self) -> std::result::Result<(), String> {
        for (name, p) in [
            ("ge p_gb", self.ge_p_gb),
            ("ge p_bg", self.ge_p_bg),
            ("ge drop_bad", self.ge_drop_bad),
            ("ge drop_good", self.ge_drop_good),
            ("corrupt", self.corrupt_prob),
            ("quorum", self.quorum),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("faults: {name} must be in [0,1], got {p}"));
            }
        }
        if self.ge_enabled() && self.ge_p_bg <= 0.0 {
            return Err("faults: ge p_bg must be > 0 when p_gb > 0 (bursts must end)".into());
        }
        if self.outage_len > 0 && self.outage_start == 0 {
            return Err("faults: outage start round is 1-based, got 0".into());
        }
        if self.outage_period > 0 && self.outage_period < self.outage_len {
            return Err(format!(
                "faults: outage period {} shorter than window length {}",
                self.outage_period, self.outage_len
            ));
        }
        for c in &self.crashes {
            if c.round == 0 {
                return Err("faults: crash round is 1-based, got 0".into());
            }
            if c.down_rounds == 0 {
                return Err("faults: crash down_rounds must be ≥ 1 (churn means missing a round)".into());
            }
        }
        for i in 1..self.crashes.len() {
            let (a, b) = (&self.crashes[i - 1], &self.crashes[i]);
            if a.client == b.client && b.round <= a.round + a.down_rounds + 1 {
                return Err(format!(
                    "faults: client {} crashes at round {} before recovering from round {}",
                    b.client, b.round, a.round
                ));
            }
        }
        if !(self.backoff_base_s > 0.0) || !(self.backoff_mult >= 1.0) {
            return Err(format!(
                "faults: backoff base must be > 0 and mult ≥ 1, got {}:{}",
                self.backoff_base_s, self.backoff_mult
            ));
        }
        if !(0.0..=1.0).contains(&self.backoff_jitter) {
            return Err(format!(
                "faults: backoff jitter must be in [0,1], got {}",
                self.backoff_jitter
            ));
        }
        Ok(())
    }
}

fn bad(part: &str, want: &str) -> Error {
    Error::Config(format!("bad fault component '{part}' (want {want})"))
}

fn num(s: &str, part: &str) -> Result<f64> {
    s.parse::<f64>()
        .map_err(|_| Error::Config(format!("bad number '{s}' in fault component '{part}'")))
}

fn int(s: &str, part: &str) -> Result<u64> {
    s.parse::<u64>()
        .map_err(|_| Error::Config(format!("bad integer '{s}' in fault component '{part}'")))
}

/// Per-lane Gilbert–Elliott channel state. Initialized from the lane's
/// own `(seed, round, client)` stream by a stationary-distribution draw,
/// so lanes stay pure functions of their triple: the chain effectively
/// runs *within* a round and re-equilibrates each round, which keeps
/// bursts spanning several consecutive exchanges (the paper-relevant
/// regime: one round is `local_steps` exchanges) without threading
/// mutable channel state across the parallel barrier.
#[derive(Clone, Copy, Debug)]
pub struct GeState {
    bad: bool,
}

impl GeState {
    /// Draw the initial state from the stationary distribution.
    pub fn init(fc: &FaultConfig, rng: &mut Pcg32) -> GeState {
        GeState {
            bad: rng.bernoulli(fc.ge_stationary_bad()),
        }
    }

    pub fn is_bad(&self) -> bool {
        self.bad
    }

    /// One exchange attempt: roll the drop for the current state, then
    /// advance the chain. Exactly two draws per call, always — the draw
    /// count must not depend on the state or the outcome, or replaying a
    /// lane would desynchronize.
    pub fn roll(&mut self, fc: &FaultConfig, rng: &mut Pcg32) -> bool {
        let p_drop = if self.bad {
            fc.ge_drop_bad
        } else {
            fc.ge_drop_good
        };
        let dropped = rng.bernoulli(p_drop);
        let p_flip = if self.bad { fc.ge_p_bg } else { fc.ge_p_gb };
        if rng.bernoulli(p_flip) {
            self.bad = !self.bad;
        }
        dropped
    }
}

/// Cause-classified fault counters (satellite: a timed-out exchange used
/// to record no distinguishable cause). Folded lane → ledger → round
/// record, so availability tables report *why* fallbacks happened.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Server dark (outage / availability coin) or link slower than the
    /// timeout window.
    pub timeouts: u64,
    /// Transmission lost while the server was up (Bernoulli or
    /// Gilbert–Elliott drop).
    pub drops: u64,
    /// Frames whose CRC check failed at decode.
    pub corruptions: u64,
    /// Retry attempts spent (each recharged uplink bytes and backoff).
    pub retries: u64,
    /// Mid-round client crashes.
    pub crashes: u64,
}

impl FaultCounters {
    pub fn add(&mut self, other: &FaultCounters) {
        self.timeouts += other.timeouts;
        self.drops += other.drops;
        self.corruptions += other.corruptions;
        self.retries += other.retries;
        self.crashes += other.crashes;
    }

    pub fn total(&self) -> u64 {
        self.timeouts + self.drops + self.corruptions + self.retries + self.crashes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn default_is_inert_and_spec_roundtrips_off() {
        let fc = FaultConfig::default();
        assert!(!fc.enabled());
        assert!(!fc.ge_enabled());
        assert_eq!(fc.to_spec(), "off");
        assert_eq!(FaultConfig::parse("off").unwrap(), fc);
        assert_eq!(FaultConfig::parse("").unwrap(), fc);
        assert!(!fc.in_outage(1));
        assert!(fc.crash_at(1, 0).is_none());
        assert!(!fc.is_down(3, 0));
        assert_eq!(fc.live_count(2, 8), 8);
        assert!(fc.quorum_met(0, 8));
    }

    #[test]
    fn parse_full_grammar_and_roundtrip() {
        let spec = "ge=0.05:0.3,outage=4:2:10,crash=3:1:4:2,crash=5:0:0:1,\
                    corrupt=0.01,retry=2:0.02:2:0.5,quorum=0.5";
        let fc = FaultConfig::parse(spec).unwrap();
        assert!(fc.enabled());
        assert_eq!(fc.ge_p_gb, 0.05);
        assert_eq!(fc.ge_p_bg, 0.3);
        assert_eq!(fc.ge_drop_bad, 1.0);
        assert_eq!(fc.ge_drop_good, 0.0);
        assert_eq!((fc.outage_start, fc.outage_len, fc.outage_period), (4, 2, 10));
        assert_eq!(fc.crashes.len(), 2);
        // Sorted by (round, client) regardless of spec order.
        assert_eq!(fc.crashes[0], CrashSpec { round: 3, client: 1, step: 4, down_rounds: 2 });
        assert_eq!(fc.corrupt_prob, 0.01);
        assert_eq!((fc.retries, fc.backoff_base_s, fc.backoff_mult, fc.backoff_jitter),
                   (2, 0.02, 2.0, 0.5));
        assert_eq!(fc.quorum, 0.5);
        let rt = FaultConfig::parse(&fc.to_spec()).unwrap();
        assert_eq!(rt, fc);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "ge=0.5",             // missing p_bg
            "ge=2:0.5",           // probability out of range
            "ge=0.5:0",           // bursts never end
            "outage=0:3",         // 1-based rounds
            "outage=5:4:2",       // period shorter than window
            "crash=1:0:2",        // missing down_rounds
            "crash=0:0:0:1",      // 1-based rounds
            "crash=1:0:0:0",      // must miss ≥ 1 round
            "crash=1:2:0:2,crash=3:2:0:1", // overlaps the recovery window
            "retry=1:0",          // backoff base must be positive
            "retry=1:0.1:0.5",    // mult < 1 shrinks
            "quorum=1.5",         // fraction
            "nonsense=1",         // unknown key
            "ge",                 // not key=value
        ] {
            assert!(FaultConfig::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn outage_windows_single_and_periodic() {
        let one = FaultConfig::parse("outage=4:2").unwrap();
        let down: Vec<u64> = (1..=10).filter(|&r| one.in_outage(r)).collect();
        assert_eq!(down, vec![4, 5]);

        let periodic = FaultConfig::parse("outage=2:1:3").unwrap();
        let down: Vec<u64> = (1..=10).filter(|&r| periodic.in_outage(r)).collect();
        assert_eq!(down, vec![2, 5, 8]);
    }

    #[test]
    fn crash_schedule_down_and_rejoin_windows() {
        let fc = FaultConfig::parse("crash=3:1:4:2").unwrap();
        // Crash round: the client runs (truncated) but is not "down".
        assert!(fc.crash_at(3, 1).is_some());
        assert!(!fc.is_down(3, 1));
        // Dark for the next two rounds, back at round 6.
        assert!(fc.is_down(4, 1));
        assert!(fc.is_down(5, 1));
        assert!(!fc.is_down(6, 1));
        // Other clients unaffected.
        assert!(fc.crash_at(3, 0).is_none());
        assert!(!fc.is_down(4, 0));
        assert_eq!(fc.live_count(4, 4), 3);
        assert_eq!(fc.live_count(3, 4), 4); // crash round still counts as live
    }

    #[test]
    fn quorum_edges() {
        let fc = FaultConfig::parse("quorum=0.5").unwrap();
        assert!(fc.quorum_met(4, 8));
        assert!(fc.quorum_met(5, 8));
        assert!(!fc.quorum_met(3, 8));
        assert!(fc.quorum_met(0, 0));
        // quorum=1.0 needs everyone, exactly.
        let all = FaultConfig::parse("quorum=1").unwrap();
        assert!(all.quorum_met(8, 8));
        assert!(!all.quorum_met(7, 8));
    }

    #[test]
    fn backoff_is_exponential_and_jitter_free_without_config() {
        let fc = FaultConfig::parse("retry=3:0.1:2").unwrap();
        let mut rng = Pcg32::seeded(1);
        let before = rng.clone().next_u32();
        assert_eq!(fc.backoff_s(1, &mut rng), 0.1);
        assert_eq!(fc.backoff_s(2, &mut rng), 0.2);
        assert_eq!(fc.backoff_s(3, &mut rng), 0.4);
        // No jitter configured → no draws burned.
        assert_eq!(rng.next_u32(), before);
    }

    #[test]
    fn backoff_jitter_is_bounded_and_draws_once() {
        let fc = FaultConfig::parse("retry=2:0.1:2:0.5").unwrap();
        forall(0xBAC0FF, 50, |rng| {
            let b = fc.backoff_s(1, rng);
            assert!((0.075..0.125).contains(&b), "jittered backoff {b}");
        });
    }

    #[test]
    fn ge_state_stationary_drop_rate() {
        // π_bad = 0.05 / (0.05 + 0.20) = 0.2; drop_bad=1, drop_good=0
        // → long-run drop rate 0.2.
        let fc = FaultConfig::parse("ge=0.05:0.2").unwrap();
        let mut rng = Pcg32::seeded(42);
        let mut st = GeState::init(&fc, &mut rng);
        let n = 200_000;
        let drops = (0..n).filter(|_| st.roll(&fc, &mut rng)).count();
        let rate = drops as f64 / n as f64;
        let want = fc.ge_stationary_bad();
        assert!((rate - want).abs() < 0.01, "drop rate {rate}, want {want}");
    }

    #[test]
    fn ge_burst_lengths_are_geometric() {
        // Mean burst length = 1/p_bg = 5; bursts are runs of consecutive
        // drops with drop_bad = 1.
        let fc = FaultConfig::parse("ge=0.02:0.2").unwrap();
        let mut rng = Pcg32::seeded(7);
        let mut st = GeState::init(&fc, &mut rng);
        let mut bursts = Vec::new();
        let mut run = 0u64;
        for _ in 0..400_000 {
            if st.roll(&fc, &mut rng) {
                run += 1;
            } else if run > 0 {
                bursts.push(run);
                run = 0;
            }
        }
        assert!(bursts.len() > 1000, "only {} bursts", bursts.len());
        let mean = bursts.iter().sum::<u64>() as f64 / bursts.len() as f64;
        assert!((mean - 5.0).abs() < 0.4, "mean burst {mean}, want 5");
        // Geometric shape: P(len > 2·mean) ≈ e^-2 ≈ 0.135 for the
        // exponential tail; a fixed-length process would have none.
        let long = bursts.iter().filter(|&&b| b as f64 > 2.0 * mean).count();
        let frac = long as f64 / bursts.len() as f64;
        assert!((0.08..0.20).contains(&frac), "tail fraction {frac}");
    }

    #[test]
    fn ge_roll_burns_exactly_two_draws() {
        let fc = FaultConfig::parse("ge=0.3:0.3").unwrap();
        let mut a = Pcg32::seeded(9);
        let mut b = Pcg32::seeded(9);
        let mut st = GeState::init(&fc, &mut a);
        let _ = b.next_u32(); // init draw
        for _ in 0..100 {
            st.roll(&fc, &mut a);
            let _ = b.next_u32();
            let _ = b.next_u32();
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn counters_add_and_total() {
        let mut a = FaultCounters { timeouts: 1, drops: 2, corruptions: 3, retries: 4, crashes: 5 };
        let b = FaultCounters { timeouts: 10, drops: 20, corruptions: 30, retries: 40, crashes: 50 };
        a.add(&b);
        assert_eq!(a.timeouts, 11);
        assert_eq!(a.crashes, 55);
        assert_eq!(a.total(), 11 + 22 + 33 + 44 + 55);
    }

    #[test]
    fn env_override_wins() {
        // from_env_or falls through to the fallback when unset (the env
        // panic path is intentionally untested in-process).
        if std::env::var("SUPERSFL_FAULTS").is_err() {
            let fb = FaultConfig::parse("corrupt=0.5").unwrap();
            assert_eq!(FaultConfig::from_env_or(fb.clone()), fb);
        }
    }
}
