//! Simulated edge network: device fleet, link model, failures, accounting.
//!
//! The paper evaluates on homogeneous GPUs with *simulated* device
//! heterogeneity (§III-A); we do the same. The network simulator owns:
//!
//! * per-client link parameters (RTT, up/downlink bandwidth),
//! * the server-availability schedule (Table III sweeps it) and transient
//!   drops, producing the timeout behaviour of paper §II-C,
//! * byte-level communication accounting (Table I's "Communication Cost"),
//! * the simulated clock (training time is simulated time — this box's
//!   wall-clock is not comparable to the paper's A100 testbed).

pub mod clock;
pub mod faults;
pub mod fleet;

pub use clock::{Event, EventQueue, SimClock};
pub use faults::{CrashSpec, FaultConfig, FaultCounters, GeState};
pub use fleet::{sample_cohort, sample_fleet, DeviceProfile, Fleet};

use crate::config::NetConfig;
use crate::trace::{AttemptOutcome, AttemptRec};
use crate::util::rng::Pcg32;
use crate::wire::frame::{HEADER_LEN, TRAILER_LEN};
use crate::wire::WireScratch;

/// Outcome of one client↔server exchange attempt.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Exchange {
    /// Server responded: total simulated round-trip seconds.
    Ok { time_s: f64 },
    /// No response within the timeout window → client enters fallback
    /// (paper Alg. 3). Elapsed simulated time equals the timeout.
    TimedOut { time_s: f64 },
}

impl Exchange {
    pub fn time_s(&self) -> f64 {
        match self {
            Exchange::Ok { time_s } | Exchange::TimedOut { time_s } => *time_s,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, Exchange::Ok { .. })
    }
}

/// Byte counters, split by direction (activations vs weights accounted by
/// the caller through distinct channels).
#[derive(Clone, Copy, Debug, Default)]
pub struct Traffic {
    pub up_bytes: u64,
    pub down_bytes: u64,
}

impl Traffic {
    pub fn total_bytes(&self) -> u64 {
        self.up_bytes + self.down_bytes
    }

    pub fn total_mb(&self) -> f64 {
        self.total_bytes() as f64 / 1e6
    }

    fn add(&mut self, other: &Traffic) {
        self.up_bytes += other.up_bytes;
        self.down_bytes += other.down_bytes;
    }
}

/// One transfer's size under the wire layer: the encoded frame bytes
/// that actually cross the link (and drive transfer times / timeouts)
/// next to the analytic `4·n` f32 count they replaced. Their per-round
/// quotient is the compression ratio reported in
/// [`crate::metrics::RoundRecord`].
#[derive(Clone, Copy, Debug)]
pub struct Framed {
    /// Encoded frame bytes on the link (header + payload + checksum).
    pub wire: u64,
    /// Analytic uncompressed size of the tensor (4 bytes per f32).
    pub raw: u64,
}

impl Framed {
    /// An uncoded transfer: wire bytes == raw bytes (pre-wire-layer
    /// paths such as the main↔Fed server link).
    pub fn uncoded(bytes: u64) -> Framed {
        Framed {
            wire: bytes,
            raw: bytes,
        }
    }
}

/// A client's effective link parameters (bandwidths already capped by the
/// server NIC). Shared between [`NetworkSim`] and the per-client
/// [`NetLane`] forks so both compute identical transfer times.
#[derive(Clone, Copy, Debug)]
pub struct LinkParams {
    pub latency_s: f64,
    pub up_bps: f64,
    pub down_bps: f64,
}

impl LinkParams {
    fn of(profile: &DeviceProfile, cfg: &NetConfig) -> LinkParams {
        let cap = cfg.server_bandwidth_mbps * 1e6 / 8.0;
        LinkParams {
            latency_s: profile.latency_s,
            up_bps: profile.uplink_bps.min(cap),
            down_bps: profile.downlink_bps.min(cap),
        }
    }

    /// Pure transfer-time model (no failure roll): one-way up.
    pub fn up_time(&self, bytes: u64) -> f64 {
        self.latency_s / 2.0 + bytes as f64 / self.up_bps
    }

    /// Pure transfer-time model: one-way down.
    pub fn down_time(&self, bytes: u64) -> f64 {
        self.latency_s / 2.0 + bytes as f64 / self.down_bps
    }
}

/// Exchange logic shared by [`NetworkSim`] and [`NetLane`]. Uplink bytes
/// are always charged (the client transmitted them before it could observe
/// the failure); downlink bytes only on success. Each charged counter is
/// an `(encoded, raw)` pair; transfer times — and therefore the timeout
/// behaviour — follow the **encoded** frame bytes, which is how a lossy
/// wire codec widens the effective timeout window on slow links.
///
/// With a [`FaultConfig`] retry budget, failed attempts recharge real
/// uplink frame bytes plus exponential backoff time; the returned time is
/// the sum over all attempts, and only exhausting the budget surfaces as
/// `TimedOut` (the paper's Alg. 3 fallback trigger). The drop roll comes
/// from the Gilbert–Elliott channel when one is attached, else from the
/// legacy memoryless `drop_prob` Bernoulli. With the inert default
/// schedule this reduces to exactly one Bernoulli per call and
/// `0.0 + t` arithmetic, so times and draw streams are bit-identical to
/// the pre-fault simulator.
#[allow(clippy::too_many_arguments)]
fn exchange_impl(
    cfg: &NetConfig,
    link: &LinkParams,
    rng: &mut Pcg32,
    mut ge: Option<&mut GeState>,
    counters: &mut FaultCounters,
    traffic: &mut [(&mut Traffic, &mut Traffic)],
    mut log: Option<&mut Vec<AttemptRec>>,
    server_up: bool,
    up: Framed,
    down: Framed,
    server_time_s: f64,
) -> Exchange {
    let fc = &cfg.faults;
    let mut total_s = 0.0f64;
    for attempt in 0..=fc.retries {
        let mut backoff = 0.0f64;
        if attempt > 0 {
            counters.retries += 1;
            backoff = fc.backoff_s(attempt, rng);
            total_s += backoff;
        }
        for (t, raw) in traffic.iter_mut() {
            t.up_bytes += up.wire;
            raw.up_bytes += up.raw;
        }
        let dropped = match ge {
            Some(ref mut st) => st.roll(fc, rng),
            None => rng.bernoulli(cfg.drop_prob),
        };
        if !server_up || dropped {
            let outcome = if server_up {
                counters.drops += 1;
                AttemptOutcome::Drop
            } else {
                counters.timeouts += 1;
                AttemptOutcome::Timeout
            };
            total_s += cfg.timeout_s;
            if let Some(l) = log.as_deref_mut() {
                l.push(AttemptRec {
                    backoff_s: backoff,
                    cost_s: cfg.timeout_s,
                    up_s: 0.0,
                    server_s: 0.0,
                    outcome,
                });
            }
            continue;
        }
        let up_s = link.up_time(up.wire);
        let t = up_s + server_time_s + link.down_time(down.wire);
        if t > cfg.timeout_s {
            // Link too slow for the timeout window: same observable
            // behaviour as an outage (paper §II-C fallback trigger).
            counters.timeouts += 1;
            total_s += cfg.timeout_s;
            if let Some(l) = log.as_deref_mut() {
                l.push(AttemptRec {
                    backoff_s: backoff,
                    cost_s: cfg.timeout_s,
                    up_s: 0.0,
                    server_s: 0.0,
                    outcome: AttemptOutcome::Timeout,
                });
            }
            continue;
        }
        for (tr, raw) in traffic.iter_mut() {
            tr.down_bytes += down.wire;
            raw.down_bytes += down.raw;
        }
        total_s += t;
        if let Some(l) = log.as_deref_mut() {
            l.push(AttemptRec {
                backoff_s: backoff,
                cost_s: t,
                up_s,
                server_s: server_time_s,
                outcome: AttemptOutcome::Ok,
            });
        }
        return Exchange::Ok { time_s: total_s };
    }
    Exchange::TimedOut { time_s: total_s }
}

/// A single client's private view of the network for one round — the
/// parallel round engine's fork of [`NetworkSim`].
///
/// Lanes own an independent PCG stream derived from `(run seed, round,
/// client id)`, so the drop/timeout draws a client observes do not depend
/// on how many worker threads the engine uses or on the order in which
/// other clients execute. Byte accounting happens on the lane-local
/// [`Traffic`] counter and is folded back into the simulator at the
/// aggregation barrier via [`NetworkSim::absorb_lane`] in client-id order.
#[derive(Clone, Debug)]
pub struct NetLane {
    cfg: NetConfig,
    link: LinkParams,
    server_up: bool,
    rng: Pcg32,
    /// Gilbert–Elliott channel state when the bursty-link process is
    /// configured; `None` keeps the legacy memoryless drop roll.
    ge: Option<GeState>,
    /// Cause-classified fault counters, folded into the client's
    /// [`crate::orchestrator::RoundLedger`] at the barrier.
    pub faults: FaultCounters,
    /// Encoded (on-the-link) frame bytes this lane moved.
    pub traffic: Traffic,
    /// Analytic uncompressed bytes of the same transfers.
    pub raw_traffic: Traffic,
    /// Reusable wire encode/decode buffers for this lane's per-step
    /// frames: the round loops encode into (and decode out of) these
    /// instead of building a fresh `Vec` per frame. Purely a perf
    /// vehicle — the bytes on the wire are identical (see
    /// [`crate::wire::WireScratch`]).
    pub scratch: WireScratch,
    /// Per-attempt replay log of the most recent faulted transfer,
    /// consumed by the tracing layer to reconstruct the retry/backoff
    /// timeline. Empty (and never written) unless
    /// [`NetLane::enable_attempt_log`] was called — the untraced hot
    /// path pays one branch per attempt and allocates nothing.
    pub attempts: Vec<AttemptRec>,
    log_attempts: bool,
}

impl NetLane {
    pub fn server_available(&self) -> bool {
        self.server_up
    }

    /// Turn on per-attempt logging for this lane (tracing only; has no
    /// effect on times, bytes, or the lane's draw stream).
    pub fn enable_attempt_log(&mut self) {
        self.log_attempts = true;
    }

    pub fn up_time(&self, bytes: u64) -> f64 {
        self.link.up_time(bytes)
    }

    pub fn down_time(&self, bytes: u64) -> f64 {
        self.link.down_time(bytes)
    }

    /// One request/response exchange with the server (paper Alg. 2
    /// Phase 2), drawn from this lane's private stream.
    ///
    /// This is the only traffic source on a lane: the barrier-phase bulk
    /// weight syncs (aggregation upload / broadcast download) happen after
    /// the fan-out, on the simulator itself via [`NetworkSim::bulk_up`] /
    /// [`NetworkSim::bulk_down`] — keeping exactly one accounting path for
    /// each phase.
    ///
    /// Uncoded convenience form: wire bytes == raw bytes. The round loops
    /// go through [`NetLane::exchange_framed`] with real frame sizes.
    pub fn exchange(&mut self, up_bytes: u64, down_bytes: u64, server_time_s: f64) -> Exchange {
        self.exchange_framed(
            Framed::uncoded(up_bytes),
            Framed::uncoded(down_bytes),
            server_time_s,
        )
    }

    /// The wire-layer exchange: encoded frame bytes drive transfer times
    /// and the timeout roll; the analytic raw sizes ride along for the
    /// compression accounting. Draw sequence is identical to
    /// [`NetLane::exchange`] (one Bernoulli per call), so switching codecs
    /// never desynchronizes the lane's PCG stream.
    ///
    /// When frame-corruption injection is configured, a successful
    /// exchange may additionally flip one payload byte of the uplink
    /// frame sitting in [`NetLane::scratch`] — the subsequent
    /// `decode_into` then fails its CRC check, exercising the wire
    /// layer's integrity path end to end. The corruption rolls draw from
    /// this lane's private stream only when `corrupt_prob > 0`, so the
    /// inert schedule burns no extra randomness.
    pub fn exchange_framed(&mut self, up: Framed, down: Framed, server_time_s: f64) -> Exchange {
        self.attempts.clear();
        let log = self.log_attempts.then_some(&mut self.attempts);
        let ex = exchange_impl(
            &self.cfg,
            &self.link,
            &mut self.rng,
            self.ge.as_mut(),
            &mut self.faults,
            &mut [(&mut self.traffic, &mut self.raw_traffic)],
            log,
            self.server_up,
            up,
            down,
            server_time_s,
        );
        let p = self.cfg.faults.corrupt_prob;
        if ex.is_ok() && p > 0.0 && self.rng.bernoulli(p) {
            let frame = &mut self.scratch.frame;
            if frame.len() > HEADER_LEN + TRAILER_LEN {
                let payload = frame.len() - HEADER_LEN - TRAILER_LEN;
                let idx = HEADER_LEN + self.rng.uniform_usize(payload);
                frame[idx] ^= 0xFF;
            }
        }
        ex
    }

    /// TCP-mode replay of the exchange arithmetic from a
    /// socket-**observed** outcome — no RNG draws, reality already
    /// rolled the dice. `delivered = true` follows
    /// [`NetLane::exchange_framed`]'s success branch bit for bit (uplink
    /// + server + downlink transfer model, timeout window honored), so a
    /// fault-free served run charges exactly what the in-process
    /// simulator charges. `delivered = false` (the socket died or the
    /// response never came) charges the uplink frame plus the timeout
    /// window and counts a drop — identical to the sim's single-attempt
    /// failure under the inert retry budget. Retries are not replayed:
    /// on a real wire a dead connection has nothing to retry against;
    /// the reconnect path owns recovery.
    pub fn exchange_observed(
        &mut self,
        up: Framed,
        down: Framed,
        server_time_s: f64,
        delivered: bool,
    ) -> Exchange {
        self.attempts.clear();
        // The client transmitted before it could observe any failure:
        // uplink bytes are always charged (same invariant as the sim).
        self.traffic.up_bytes += up.wire;
        self.raw_traffic.up_bytes += up.raw;
        if !delivered {
            self.faults.drops += 1;
            if self.log_attempts {
                self.attempts.push(AttemptRec {
                    backoff_s: 0.0,
                    cost_s: self.cfg.timeout_s,
                    up_s: 0.0,
                    server_s: 0.0,
                    outcome: AttemptOutcome::Drop,
                });
            }
            return Exchange::TimedOut {
                time_s: self.cfg.timeout_s,
            };
        }
        let up_s = self.link.up_time(up.wire);
        let t = up_s + server_time_s + self.link.down_time(down.wire);
        if t > self.cfg.timeout_s {
            self.faults.timeouts += 1;
            if self.log_attempts {
                self.attempts.push(AttemptRec {
                    backoff_s: 0.0,
                    cost_s: self.cfg.timeout_s,
                    up_s: 0.0,
                    server_s: 0.0,
                    outcome: AttemptOutcome::Timeout,
                });
            }
            return Exchange::TimedOut {
                time_s: self.cfg.timeout_s,
            };
        }
        self.traffic.down_bytes += down.wire;
        self.raw_traffic.down_bytes += down.raw;
        if self.log_attempts {
            self.attempts.push(AttemptRec {
                backoff_s: 0.0,
                cost_s: t,
                up_s,
                server_s: server_time_s,
                outcome: AttemptOutcome::Ok,
            });
        }
        Exchange::Ok { time_s: t }
    }

    /// Download-only sibling of [`NetLane::exchange_observed`] — the
    /// served resync/broadcast accounting (zero-byte request up, one
    /// frame down).
    pub fn download_observed(&mut self, down: Framed, server_time_s: f64, delivered: bool) -> Exchange {
        self.exchange_observed(Framed { wire: 0, raw: 0 }, down, server_time_s, delivered)
    }

    /// A download-only faulted transfer: the rejoin-resync path (a
    /// recovering client pulling the current global weights). Runs
    /// through the same GE/drop/timeout/retry/backoff machinery as
    /// [`NetLane::exchange_framed`] — the uplink is a zero-byte request,
    /// so only the request half-RTT and the downlink frame are charged —
    /// and rolls the same corruption flip against the frame sitting in
    /// [`NetLane::scratch`] (the caller decodes from there; a flipped
    /// byte then fails the CRC check exactly like a round-path frame).
    pub fn faulted_download(&mut self, down: Framed, server_time_s: f64) -> Exchange {
        self.attempts.clear();
        let log = self.log_attempts.then_some(&mut self.attempts);
        let ex = exchange_impl(
            &self.cfg,
            &self.link,
            &mut self.rng,
            self.ge.as_mut(),
            &mut self.faults,
            &mut [(&mut self.traffic, &mut self.raw_traffic)],
            log,
            self.server_up,
            Framed { wire: 0, raw: 0 },
            down,
            server_time_s,
        );
        let p = self.cfg.faults.corrupt_prob;
        if ex.is_ok() && p > 0.0 && self.rng.bernoulli(p) {
            let frame = &mut self.scratch.frame;
            if frame.len() > HEADER_LEN + TRAILER_LEN {
                let payload = frame.len() - HEADER_LEN - TRAILER_LEN;
                let idx = HEADER_LEN + self.rng.uniform_usize(payload);
                frame[idx] ^= 0xFF;
            }
        }
        ex
    }
}

/// Stream-selector salt for [`NetworkSim::resync_lane`] forks.
const RESYNC_SALT: u64 = 0x5EC0_4DC4_A81E_57A3;

/// Where the per-client [`LinkParams`] come from. Small fleets keep the
/// seed's eager vectors; scaled runs regenerate links on demand from the
/// lazy [`Fleet`] stream so the simulator holds O(1) state in fleet size.
/// Both sources produce bit-identical parameters for the same client.
enum LinkSource {
    Eager(Vec<DeviceProfile>, Vec<LinkParams>),
    Lazy(Fleet),
}

/// The network simulator. One instance per experiment run.
pub struct NetworkSim {
    cfg: NetConfig,
    links: LinkSource,
    rng: Pcg32,
    /// Base seed for the per-round per-client lane streams.
    lane_seed: u64,
    /// 1-based round counter (advanced by [`NetworkSim::begin_round`]);
    /// drives the outage-window schedule.
    round: u64,
    /// Gilbert–Elliott state for the serial exchange path (the round
    /// loops use per-lane states instead).
    ge: Option<GeState>,
    /// Whether the server answers during the current round (Table III's
    /// "server gradient availability" is a per-round schedule).
    server_up_this_round: bool,
    /// Fault counters for the serial path plus everything folded back
    /// from lanes via [`NetworkSim::absorb_lane`].
    pub faults: FaultCounters,
    /// Encoded (on-the-link) frame bytes, whole run.
    pub traffic: Traffic,
    /// Traffic accumulated during the current round only.
    pub round_traffic: Traffic,
    /// Analytic uncompressed bytes of the same transfers, whole run.
    pub raw_traffic: Traffic,
    /// Raw counterpart of [`NetworkSim::round_traffic`].
    pub round_raw_traffic: Traffic,
}

impl NetworkSim {
    pub fn new(cfg: NetConfig, profiles: Vec<DeviceProfile>, rng: Pcg32) -> Self {
        let links = profiles.iter().map(|p| LinkParams::of(p, &cfg)).collect();
        Self::with_links(cfg, LinkSource::Eager(profiles, links), rng)
    }

    /// Lazy-fleet constructor for scaled runs: link parameters are
    /// regenerated on demand from the fleet stream (O(1) simulator state
    /// in fleet size), bit-identical to the eager form for every client.
    /// Consumes the same draws from `rng` as [`NetworkSim::new`], so the
    /// two forms are interchangeable without perturbing any stream.
    pub fn new_lazy(cfg: NetConfig, fleet: Fleet, rng: Pcg32) -> Self {
        Self::with_links(cfg, LinkSource::Lazy(fleet), rng)
    }

    fn with_links(cfg: NetConfig, links: LinkSource, mut rng: Pcg32) -> Self {
        let lane_seed = rng.next_u64();
        let ge = if cfg.faults.ge_enabled() {
            Some(GeState::init(&cfg.faults, &mut rng))
        } else {
            None
        };
        NetworkSim {
            cfg,
            links,
            rng,
            lane_seed,
            round: 0,
            ge,
            server_up_this_round: true,
            faults: FaultCounters::default(),
            traffic: Traffic::default(),
            round_traffic: Traffic::default(),
            raw_traffic: Traffic::default(),
            round_raw_traffic: Traffic::default(),
        }
    }

    /// Client `id`'s link parameters (indexed or regenerated on demand
    /// depending on the link source).
    fn link(&self, client: usize) -> LinkParams {
        match &self.links {
            LinkSource::Eager(_, links) => links[client],
            LinkSource::Lazy(fleet) => LinkParams::of(&fleet.profile(client), &self.cfg),
        }
    }

    /// The eager profile table (tests/diagnostics; panics on a lazy
    /// simulator — scaled runs query [`Fleet::profile`] instead).
    pub fn profiles(&self) -> &[DeviceProfile] {
        match &self.links {
            LinkSource::Eager(profiles, _) => profiles,
            LinkSource::Lazy(_) => panic!("profiles(): lazy NetworkSim has no eager table"),
        }
    }

    /// Draw the server-availability schedule for a new round and reset the
    /// per-round byte counters. The availability coin is drawn every round
    /// regardless of the outage schedule so that configuring an outage
    /// window never shifts the simulator's draw stream for other rounds.
    pub fn begin_round(&mut self) {
        self.round += 1;
        let coin = self.rng.bernoulli(self.cfg.server_availability);
        self.server_up_this_round = coin && !self.cfg.faults.in_outage(self.round);
        self.round_traffic = Traffic::default();
        self.round_raw_traffic = Traffic::default();
    }

    pub fn server_available(&self) -> bool {
        self.server_up_this_round
    }

    /// Fork a per-client lane for the current round. The lane's stream is
    /// a pure function of `(run seed, round, client)` — independent of the
    /// order lanes are created or executed in, which is what makes the
    /// parallel round engine bit-identical across thread counts.
    pub fn lane(&self, client: usize, round: u64) -> NetLane {
        self.lane_salted(client, round, 0)
    }

    /// A rejoin-resync lane for `(client, round)`: same purity contract
    /// as [`NetworkSim::lane`], but on a salted stream so the resync
    /// download's fault draws never correlate with (or perturb) the
    /// client's regular round lane. Fault-free configs never resync, so
    /// existing golden trajectories are untouched.
    pub fn resync_lane(&self, client: usize, round: u64) -> NetLane {
        self.lane_salted(client, round, RESYNC_SALT)
    }

    fn lane_salted(&self, client: usize, round: u64, salt: u64) -> NetLane {
        let round_salt = round.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg32::new(self.lane_seed ^ round_salt ^ salt, client as u64 + 1);
        let ge = if self.cfg.faults.ge_enabled() {
            // Channel state seeded from the lane's own stream by a
            // stationary-distribution draw: the burst process lives
            // within a round's `local_steps` exchanges, and the lane
            // stays a pure function of (seed, round, client).
            Some(GeState::init(&self.cfg.faults, &mut rng))
        } else {
            None
        };
        NetLane {
            cfg: self.cfg.clone(),
            link: self.link(client),
            server_up: self.server_up_this_round,
            rng,
            ge,
            faults: FaultCounters::default(),
            traffic: Traffic::default(),
            raw_traffic: Traffic::default(),
            scratch: WireScratch::default(),
            attempts: Vec::new(),
            log_attempts: false,
        }
    }

    /// Fold a finished lane's byte and fault counters back into the
    /// global and per-round accounting (called at the barrier, in
    /// client-id order).
    pub fn absorb_lane(&mut self, lane: &NetLane) {
        self.faults.add(&lane.faults);
        self.traffic.add(&lane.traffic);
        self.round_traffic.add(&lane.traffic);
        self.raw_traffic.add(&lane.raw_traffic);
        self.round_raw_traffic.add(&lane.raw_traffic);
    }

    /// Pure transfer-time model (no failure roll): one-way up.
    pub fn up_time(&self, client: usize, bytes: u64) -> f64 {
        self.link(client).up_time(bytes)
    }

    /// Pure transfer-time model: one-way down.
    pub fn down_time(&self, client: usize, bytes: u64) -> f64 {
        self.link(client).down_time(bytes)
    }

    /// One request/response exchange with the server (smashed data up,
    /// gradients down; paper Alg. 2 Phase 2). `server_time_s` is the
    /// simulated server-side compute time between receive and reply.
    ///
    /// Serial-path variant drawing from the simulator's own stream; the
    /// round loops use [`NetworkSim::lane`] forks instead.
    pub fn exchange(
        &mut self,
        client: usize,
        up_bytes: u64,
        down_bytes: u64,
        server_time_s: f64,
    ) -> Exchange {
        exchange_impl(
            &self.cfg,
            &self.link(client),
            &mut self.rng,
            self.ge.as_mut(),
            &mut self.faults,
            &mut [
                (&mut self.traffic, &mut self.raw_traffic),
                (&mut self.round_traffic, &mut self.round_raw_traffic),
            ],
            None,
            self.server_up_this_round,
            Framed::uncoded(up_bytes),
            Framed::uncoded(down_bytes),
            server_time_s,
        )
    }

    /// A bulk weight sync (aggregation upload / broadcast download).
    /// Returns the transfer time; bytes are always charged. Uncoded
    /// convenience form — the round loops charge real frame sizes via
    /// [`NetworkSim::bulk_up_framed`].
    pub fn bulk_up(&mut self, client: usize, bytes: u64) -> f64 {
        self.bulk_up_framed(client, Framed::uncoded(bytes))
    }

    pub fn bulk_down(&mut self, client: usize, bytes: u64) -> f64 {
        self.bulk_down_framed(client, Framed::uncoded(bytes))
    }

    /// Bulk weight sync charged with actual encoded frame bytes; the
    /// transfer time follows the wire size.
    pub fn bulk_up_framed(&mut self, client: usize, f: Framed) -> f64 {
        self.traffic.up_bytes += f.wire;
        self.round_traffic.up_bytes += f.wire;
        self.raw_traffic.up_bytes += f.raw;
        self.round_raw_traffic.up_bytes += f.raw;
        self.up_time(client, f.wire)
    }

    pub fn bulk_down_framed(&mut self, client: usize, f: Framed) -> f64 {
        self.traffic.down_bytes += f.wire;
        self.round_traffic.down_bytes += f.wire;
        self.raw_traffic.down_bytes += f.raw;
        self.round_raw_traffic.down_bytes += f.raw;
        self.down_time(client, f.wire)
    }

    /// Main-server ↔ Fed-server bulk transfer (Fig. 2 of the paper; used
    /// heavily by the SplitFed baseline, which ships every per-client
    /// server-side model copy to the Fed server each round). Charged as
    /// uplink traffic over the server NIC. This is a datacenter-internal
    /// link, not a client↔server exchange, so it bypasses the wire codec
    /// (wire == raw in the compression accounting).
    ///
    /// `bytes` is the round's total payload over the link and
    /// `transfers` the number of logical transfers it comprises (e.g.
    /// one per model copy per direction). Time = `transfers` half-RTTs
    /// + bytes/bandwidth — the same one-way model every other transfer
    /// pays ([`LinkParams::up_time`]), applied per transfer; the seed
    /// charged bandwidth only, silently giving the Fed link a free
    /// latency pass (a tiny SFL/DFL-only simulated-time undercount —
    /// SSFL never touches this link).
    pub fn fed_link(&mut self, bytes: u64, transfers: u64) -> f64 {
        self.traffic.up_bytes += bytes;
        self.round_traffic.up_bytes += bytes;
        self.raw_traffic.up_bytes += bytes;
        self.round_raw_traffic.up_bytes += bytes;
        transfers as f64 * self.cfg.fed_latency_ms * 1e-3 / 2.0
            + bytes as f64 / (self.cfg.server_bandwidth_mbps * 1e6 / 8.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnergyConfig, FleetConfig};

    fn sim(avail: f64, drop: f64) -> NetworkSim {
        let fleet = FleetConfig {
            clients: 4,
            ..FleetConfig::default()
        };
        let profiles = sample_fleet(&fleet, &EnergyConfig::default(), &mut Pcg32::seeded(1));
        let cfg = NetConfig {
            server_availability: avail,
            drop_prob: drop,
            ..NetConfig::default()
        };
        NetworkSim::new(cfg, profiles, Pcg32::seeded(2))
    }

    #[test]
    fn exchange_ok_accounts_both_directions() {
        let mut s = sim(1.0, 0.0);
        s.begin_round();
        let e = s.exchange(0, 1000, 2000, 0.001);
        assert!(e.is_ok());
        assert!(e.time_s() > 0.0);
        assert_eq!(s.traffic.up_bytes, 1000);
        assert_eq!(s.traffic.down_bytes, 2000);
    }

    #[test]
    fn unavailable_round_times_out_and_charges_uplink_only() {
        let mut s = sim(0.0, 0.0);
        s.begin_round();
        assert!(!s.server_available());
        let e = s.exchange(1, 500, 700, 0.001);
        assert_eq!(
            e,
            Exchange::TimedOut {
                time_s: s.cfg.timeout_s
            }
        );
        assert_eq!(s.traffic.up_bytes, 500);
        assert_eq!(s.traffic.down_bytes, 0);
    }

    #[test]
    fn availability_is_per_round_schedule() {
        let mut s = sim(0.5, 0.0);
        let mut ups = 0;
        for _ in 0..200 {
            s.begin_round();
            if s.server_available() {
                ups += 1;
            }
        }
        assert!((60..140).contains(&ups), "ups {ups}");
    }

    #[test]
    fn fed_link_pays_half_rtt_per_transfer_plus_bandwidth_and_charges_all_ledgers() {
        let mut s = sim(1.0, 0.0);
        s.begin_round();
        let bytes = 4_000_000u64;
        let t = s.fed_link(bytes, 1);
        let cfg = NetConfig::default();
        let half_rtt = cfg.fed_latency_ms * 1e-3 / 2.0;
        let want = half_rtt + bytes as f64 / (cfg.server_bandwidth_mbps * 1e6 / 8.0);
        assert!((t - want).abs() < 1e-15, "fed_link time {t} != {want}");
        // The latency term must actually be there: even a zero-byte
        // transfer takes the half-RTT (the seed returned 0.0 here).
        assert!(s.fed_link(0, 1) >= half_rtt - 1e-15);
        // A bulk of k logical transfers pays k half-RTTs (the SFL round
        // ships one copy per client per direction in one call).
        let t16 = s.fed_link(bytes, 16);
        assert!(
            (t16 - (16.0 * half_rtt + bytes as f64 / (cfg.server_bandwidth_mbps * 1e6 / 8.0)))
                .abs()
                < 1e-15,
            "per-transfer latency collapsed: {t16}"
        );
        // Bytes land on all four ledgers (uplink, wire == raw).
        assert_eq!(s.traffic.up_bytes, 2 * bytes);
        assert_eq!(s.round_traffic.up_bytes, 2 * bytes);
        assert_eq!(s.raw_traffic.up_bytes, 2 * bytes);
        assert_eq!(s.round_raw_traffic.up_bytes, 2 * bytes);
    }

    #[test]
    fn transfer_time_scales_with_bytes_and_latency() {
        let s = sim(1.0, 0.0);
        let small = s.up_time(0, 1_000);
        let big = s.up_time(0, 10_000_000);
        assert!(big > small);
        assert!(small >= s.profiles()[0].latency_s / 2.0);
    }

    #[test]
    fn slow_link_exceeding_timeout_behaves_as_outage() {
        let mut s = sim(1.0, 0.0);
        s.begin_round();
        // Enormous payload cannot fit in the 5 s window on any edge link.
        let e = s.exchange(2, 100_000_000_000, 0, 0.0);
        assert!(!e.is_ok());
        assert_eq!(e.time_s(), s.cfg.timeout_s);
    }

    #[test]
    fn drops_cause_sporadic_timeouts() {
        let mut s = sim(1.0, 0.3);
        s.begin_round();
        let fails = (0..300)
            .filter(|_| !s.exchange(0, 10, 10, 0.0).is_ok())
            .count();
        assert!((40..160).contains(&fails), "fails {fails}");
    }

    #[test]
    fn lanes_are_pure_functions_of_round_and_client() {
        let mut s = sim(1.0, 0.3);
        s.begin_round();
        // Same (round, client) → identical draw sequence, regardless of
        // how many other lanes were created in between.
        let mut a = s.lane(2, 7);
        let _unrelated = (s.lane(0, 7), s.lane(1, 7), s.lane(3, 9));
        let mut b = s.lane(2, 7);
        for _ in 0..50 {
            assert_eq!(
                a.exchange(10, 10, 0.0).is_ok(),
                b.exchange(10, 10, 0.0).is_ok()
            );
        }
        // Different round or client → independent streams.
        let mut c = s.lane(2, 8);
        let flips = (0..64)
            .filter(|_| a.exchange(1, 1, 0.0).is_ok() != c.exchange(1, 1, 0.0).is_ok())
            .count();
        assert!(flips > 0, "round salt must decorrelate lanes");
    }

    /// Property: a lane is a pure function of `(run seed, round, client)`
    /// — re-forking the same triple replays the exact same delay/drop
    /// sequence, including the timed-out/ok pattern AND the simulated
    /// round-trip times, for any draw count and payload size.
    #[test]
    fn prop_lane_fork_is_deterministic_per_triple() {
        use crate::util::prop::forall;
        forall(0xA11CE, 40, |rng| {
            let mut sim = sim(0.8, 0.2);
            sim.begin_round();
            let client = rng.uniform_usize(4);
            let round = rng.next_u64() % 1000;
            let draws = 1 + rng.uniform_usize(30);
            let bytes = 1 + rng.uniform_usize(100_000) as u64;
            let mut a = sim.lane(client, round);
            // Interleave unrelated forks + draws: they must not perturb
            // the (client, round) stream.
            let mut noise = sim.lane((client + 1) % 4, round);
            noise.exchange(1, 1, 0.0);
            let mut b = sim.lane(client, round);
            for _ in 0..draws {
                let ea = a.exchange(bytes, bytes, 1e-3);
                let eb = b.exchange(bytes, bytes, 1e-3);
                assert_eq!(ea.is_ok(), eb.is_ok());
                assert_eq!(ea.time_s().to_bits(), eb.time_s().to_bits());
            }
            assert_eq!(a.traffic.up_bytes, b.traffic.up_bytes);
            assert_eq!(a.traffic.down_bytes, b.traffic.down_bytes);
        });
    }

    /// Property: disjoint clients (and disjoint rounds) get independent
    /// streams — over enough draws their drop patterns must diverge.
    #[test]
    fn prop_disjoint_clients_have_independent_streams() {
        use crate::util::prop::forall;
        forall(0xB0B, 20, |rng| {
            let mut sim = sim(1.0, 0.5);
            sim.begin_round();
            let round = 1 + rng.next_u64() % 500;
            let c1 = rng.uniform_usize(4);
            let c2 = (c1 + 1 + rng.uniform_usize(3)) % 4;
            assert_ne!(c1, c2);
            let mut a = sim.lane(c1, round);
            let mut b = sim.lane(c2, round);
            let diverged = (0..128)
                .filter(|_| a.exchange(8, 8, 0.0).is_ok() != b.exchange(8, 8, 0.0).is_ok())
                .count();
            assert!(diverged > 0, "clients {c1}/{c2} round {round} correlated");

            // Same client, different round: also independent.
            let mut r1 = sim.lane(c1, round);
            let mut r2 = sim.lane(c1, round + 1);
            let diverged = (0..128)
                .filter(|_| r1.exchange(8, 8, 0.0).is_ok() != r2.exchange(8, 8, 0.0).is_ok())
                .count();
            assert!(diverged > 0, "rounds {round}/{} correlated", round + 1);
        });
    }

    #[test]
    fn lane_respects_round_availability_and_accounts_bytes() {
        let mut s = sim(0.0, 0.0);
        s.begin_round();
        let mut lane = s.lane(1, 1);
        assert!(!lane.server_available());
        let e = lane.exchange(500, 700, 0.001);
        assert!(!e.is_ok());
        // Timeout charges uplink only (client transmitted before it could
        // observe the failure).
        assert_eq!(lane.traffic.up_bytes, 500);
        assert_eq!(lane.traffic.down_bytes, 0);

        // Absorbing the lane folds its bytes into both counters.
        s.absorb_lane(&lane);
        assert_eq!(s.traffic.up_bytes, 500);
        assert_eq!(s.round_traffic.up_bytes, 500);
        assert_eq!(s.round_traffic.down_bytes, 0);
    }

    #[test]
    fn lane_times_match_simulator_times() {
        let mut s = sim(1.0, 0.0);
        s.begin_round();
        let lane = s.lane(0, 1);
        assert_eq!(lane.up_time(4096), s.up_time(0, 4096));
        assert_eq!(lane.down_time(4096), s.down_time(0, 4096));
    }

    #[test]
    fn framed_transfers_split_wire_and_raw_accounting() {
        let mut s = sim(1.0, 0.0);
        s.begin_round();
        // Bulk: 1000 wire bytes standing in for 4000 raw.
        let t = s.bulk_up_framed(0, Framed { wire: 1000, raw: 4000 });
        assert!(t > 0.0);
        assert_eq!(s.traffic.up_bytes, 1000);
        assert_eq!(s.raw_traffic.up_bytes, 4000);
        assert_eq!(s.round_raw_traffic.up_bytes, 4000);
        // Transfer time follows the wire bytes, not the raw size.
        assert!(s.up_time(0, 1000) < s.up_time(0, 4000));

        // Lane exchange: uplink raw charged even on success; downlink on
        // success only.
        let mut lane = s.lane(0, 1);
        let e = lane.exchange_framed(
            Framed { wire: 500, raw: 2000 },
            Framed { wire: 250, raw: 1000 },
            0.001,
        );
        assert!(e.is_ok());
        assert_eq!(lane.traffic.up_bytes, 500);
        assert_eq!(lane.traffic.down_bytes, 250);
        assert_eq!(lane.raw_traffic.up_bytes, 2000);
        assert_eq!(lane.raw_traffic.down_bytes, 1000);
        s.absorb_lane(&lane);
        assert_eq!(s.round_traffic.up_bytes, 1500);
        assert_eq!(s.round_raw_traffic.down_bytes, 1000);

        // Round reset clears the raw counter too; the totals persist.
        s.begin_round();
        assert_eq!(s.round_raw_traffic.up_bytes, 0);
        assert_eq!(s.raw_traffic.up_bytes, 6000);
    }

    #[test]
    fn framed_timeout_charges_raw_uplink_only() {
        let mut s = sim(0.0, 0.0);
        s.begin_round();
        let mut lane = s.lane(2, 3);
        let e = lane.exchange_framed(
            Framed { wire: 100, raw: 400 },
            Framed { wire: 100, raw: 400 },
            0.0,
        );
        assert!(!e.is_ok());
        assert_eq!(lane.raw_traffic.up_bytes, 400);
        assert_eq!(lane.raw_traffic.down_bytes, 0);
    }

    #[test]
    fn framed_and_uncoded_exchanges_share_one_draw_sequence() {
        // Switching codecs must not desynchronize a lane's PCG stream:
        // both forms burn exactly one Bernoulli per call.
        let mut s = sim(1.0, 0.4);
        s.begin_round();
        let mut a = s.lane(1, 5);
        let mut b = s.lane(1, 5);
        for i in 0..100 {
            let ea = a.exchange(64, 64, 0.0);
            let eb = b.exchange_framed(Framed::uncoded(64), Framed::uncoded(64), 0.0);
            assert_eq!(ea.is_ok(), eb.is_ok(), "draw {i}");
        }
    }

    fn sim_faults(spec: &str, avail: f64, drop: f64) -> NetworkSim {
        let fleet = FleetConfig {
            clients: 4,
            ..FleetConfig::default()
        };
        let profiles = sample_fleet(&fleet, &EnergyConfig::default(), &mut Pcg32::seeded(1));
        let cfg = NetConfig {
            server_availability: avail,
            drop_prob: drop,
            faults: FaultConfig::parse(spec).unwrap(),
            ..NetConfig::default()
        };
        NetworkSim::new(cfg, profiles, Pcg32::seeded(2))
    }

    #[test]
    fn retry_recharges_uplink_bytes_and_backoff_time() {
        // Every attempt drops (p = 1): the budget is exhausted, each
        // attempt recharges the uplink frame, and the elapsed time is
        // three timeouts plus the 0.1 s and 0.2 s backoffs.
        let mut s = sim_faults("retry=2:0.1:2", 1.0, 1.0);
        s.begin_round();
        let mut lane = s.lane(0, 1);
        let e = lane.exchange(100, 100, 0.0);
        assert!(!e.is_ok());
        let want = 3.0 * s.cfg.timeout_s + 0.1 + 0.2;
        assert!((e.time_s() - want).abs() < 1e-12, "time {}", e.time_s());
        assert_eq!(lane.traffic.up_bytes, 300);
        assert_eq!(lane.traffic.down_bytes, 0);
        assert_eq!(lane.faults.retries, 2);
        assert_eq!(lane.faults.drops, 3);
        assert_eq!(lane.faults.timeouts, 0);

        // Absorbing the lane folds the fault counters too.
        s.absorb_lane(&lane);
        assert_eq!(s.faults.drops, 3);
        assert_eq!(s.faults.retries, 2);
    }

    #[test]
    fn retry_recovers_from_transient_drops() {
        // p = 0.5 with a generous budget: nearly every exchange should
        // eventually succeed, and successes after a failed attempt carry
        // the failed attempts' time.
        let mut s = sim_faults("retry=6:0.01:2", 1.0, 0.5);
        s.begin_round();
        let mut lane = s.lane(1, 1);
        let mut oks = 0;
        let mut recovered = 0;
        for _ in 0..200 {
            let e = lane.exchange(10, 10, 0.0);
            if e.is_ok() {
                oks += 1;
                if e.time_s() > s.cfg.timeout_s {
                    recovered += 1;
                }
            }
        }
        assert!(oks > 190, "oks {oks}");
        assert!(recovered > 30, "recovered {recovered}");
        assert!(lane.faults.retries > 0);
    }

    #[test]
    fn server_down_classifies_as_timeout_not_drop() {
        let mut s = sim_faults("", 0.0, 0.0);
        s.begin_round();
        let mut lane = s.lane(0, 1);
        assert!(!lane.exchange(10, 10, 0.0).is_ok());
        assert_eq!(lane.faults.timeouts, 1);
        assert_eq!(lane.faults.drops, 0);
    }

    #[test]
    fn outage_windows_darken_scheduled_rounds() {
        let mut s = sim_faults("outage=2:2", 1.0, 0.0);
        let mut ups = Vec::new();
        for _ in 1..=5 {
            s.begin_round();
            ups.push(s.server_available());
        }
        assert_eq!(ups, vec![true, false, false, true, true]);

        // The availability coin is still drawn during outage rounds, so
        // the outage window does not shift later rounds' draws: two sims
        // differing only in the outage schedule agree on every round
        // outside the windows.
        let mut a = sim_faults("outage=2:2", 0.5, 0.0);
        let mut b = sim_faults("", 0.5, 0.0);
        for round in 1..=50u64 {
            a.begin_round();
            b.begin_round();
            if !(2..=3).contains(&round) {
                assert_eq!(a.server_available(), b.server_available(), "round {round}");
            }
        }
    }

    #[test]
    fn ge_lanes_drop_in_bursts_at_the_stationary_rate() {
        // π_bad = 0.05 / (0.05 + 0.25) = 1/6.
        let mut s = sim_faults("ge=0.05:0.25", 1.0, 0.0);
        s.begin_round();
        let mut drops = 0usize;
        let mut total = 0usize;
        let mut longest_burst = 0usize;
        for round in 1..=50u64 {
            for client in 0..4 {
                let mut lane = s.lane(client, round);
                let mut run = 0usize;
                for _ in 0..40 {
                    total += 1;
                    if !lane.exchange(10, 10, 0.0).is_ok() {
                        drops += 1;
                        run += 1;
                        longest_burst = longest_burst.max(run);
                    } else {
                        run = 0;
                    }
                }
            }
        }
        let rate = drops as f64 / total as f64;
        assert!((rate - 1.0 / 6.0).abs() < 0.04, "drop rate {rate}");
        // Mean burst length is 1/p_bg = 4 — long runs must exist, which
        // a memoryless Bernoulli at the same rate would make vanishingly
        // rare within 40-draw windows.
        assert!(longest_burst >= 4, "longest burst {longest_burst}");

        // GE lanes stay pure functions of (seed, round, client).
        let mut a = s.lane(2, 7);
        let mut b = s.lane(2, 7);
        for _ in 0..50 {
            let (ea, eb) = (a.exchange(10, 10, 0.0), b.exchange(10, 10, 0.0));
            assert_eq!(ea.is_ok(), eb.is_ok());
            assert_eq!(ea.time_s().to_bits(), eb.time_s().to_bits());
        }
    }

    #[test]
    fn corruption_flips_the_uplink_frame_so_decode_fails() {
        use crate::wire::{MsgType, Wire, WireCodecKind};
        let mut s = sim_faults("corrupt=1", 1.0, 0.0);
        s.begin_round();
        let w = Wire::new(WireCodecKind::Fp32);
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mut lane = s.lane(0, 1);
        let len = w.encode_to(MsgType::Smashed, &data, 0.0, &mut lane.scratch).len() as u64;
        let e = lane.exchange_framed(
            Framed { wire: len, raw: 256 },
            Framed { wire: len, raw: 256 },
            0.001,
        );
        assert!(e.is_ok());
        // corrupt=1 guarantees the hit; the CRC check must now fail.
        let mut out = Vec::new();
        assert!(w.decode_into(&lane.scratch.frame, &mut out).is_err());

        // With corruption off, the same frame decodes fine and the lane
        // burns no extra draws (pinned against the corrupt lane's drift).
        let mut clean = sim_faults("", 1.0, 0.0).lane(0, 1);
        let len = w.encode_to(MsgType::Smashed, &data, 0.0, &mut clean.scratch).len() as u64;
        clean.exchange_framed(
            Framed { wire: len, raw: 256 },
            Framed { wire: len, raw: 256 },
            0.001,
        );
        assert!(w.decode_into(&clean.scratch.frame, &mut out).is_ok());
    }

    #[test]
    fn lazy_sim_is_bit_identical_to_eager() {
        let fleet_cfg = FleetConfig {
            clients: 4,
            ..FleetConfig::default()
        };
        let energy = EnergyConfig::default();
        let profiles = sample_fleet(&fleet_cfg, &energy, &mut Pcg32::seeded(1));
        let cfg = NetConfig {
            drop_prob: 0.3,
            ..NetConfig::default()
        };
        let mut eager = NetworkSim::new(cfg.clone(), profiles, Pcg32::seeded(2));
        let mut lazy = NetworkSim::new_lazy(
            cfg,
            Fleet::new(fleet_cfg, energy, Pcg32::seeded(1)),
            Pcg32::seeded(2),
        );
        for round in 1..=5u64 {
            eager.begin_round();
            lazy.begin_round();
            assert_eq!(eager.server_available(), lazy.server_available());
            for client in 0..4 {
                assert_eq!(
                    eager.up_time(client, 4096).to_bits(),
                    lazy.up_time(client, 4096).to_bits()
                );
                let mut a = eager.lane(client, round);
                let mut b = lazy.lane(client, round);
                for _ in 0..10 {
                    let (ea, eb) = (a.exchange(64, 64, 1e-3), b.exchange(64, 64, 1e-3));
                    assert_eq!(ea.is_ok(), eb.is_ok());
                    assert_eq!(ea.time_s().to_bits(), eb.time_s().to_bits());
                }
            }
        }
    }

    #[test]
    fn resync_lane_is_deterministic_but_decorrelated_from_the_round_lane() {
        let mut s = sim(1.0, 0.5);
        s.begin_round();
        // Pure function of (seed, round, client).
        let mut a = s.resync_lane(2, 7);
        let mut b = s.resync_lane(2, 7);
        for _ in 0..32 {
            assert_eq!(
                a.exchange(8, 8, 0.0).is_ok(),
                b.exchange(8, 8, 0.0).is_ok()
            );
        }
        // ...but on a different stream than the regular round lane.
        let mut r = s.lane(2, 7);
        let mut q = s.resync_lane(2, 7);
        let flips = (0..128)
            .filter(|_| r.exchange(8, 8, 0.0).is_ok() != q.exchange(8, 8, 0.0).is_ok())
            .count();
        assert!(flips > 0, "resync salt must decorrelate the streams");
    }

    #[test]
    fn faulted_download_charges_downlink_on_success_and_retries_on_drops() {
        // Clean link: the download succeeds, charging downlink wire/raw
        // and no uplink payload (the request is zero-byte).
        let mut s = sim(1.0, 0.0);
        s.begin_round();
        let mut lane = s.resync_lane(0, 1);
        let e = lane.faulted_download(Framed { wire: 900, raw: 3600 }, 1e-3);
        assert!(e.is_ok());
        assert_eq!(lane.traffic.up_bytes, 0);
        assert_eq!(lane.traffic.down_bytes, 900);
        assert_eq!(lane.raw_traffic.down_bytes, 3600);

        // All-drop link with a retry budget: exhausts, counts, charges
        // no downlink, and accumulates timeout + backoff time.
        let mut s = sim_faults("retry=2:0.1:2", 1.0, 1.0);
        s.begin_round();
        let mut lane = s.resync_lane(0, 1);
        let e = lane.faulted_download(Framed { wire: 900, raw: 3600 }, 1e-3);
        assert!(!e.is_ok());
        assert_eq!(lane.traffic.down_bytes, 0);
        assert_eq!(lane.faults.drops, 3);
        assert_eq!(lane.faults.retries, 2);
        let want = 3.0 * s.cfg.timeout_s + 0.1 + 0.2;
        assert!((e.time_s() - want).abs() < 1e-12, "time {}", e.time_s());
    }

    #[test]
    fn faulted_download_corruption_flips_the_scratch_frame() {
        use crate::wire::{MsgType, Wire, WireCodecKind};
        let mut s = sim_faults("corrupt=1", 1.0, 0.0);
        s.begin_round();
        let w = Wire::new(WireCodecKind::Fp32);
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mut lane = s.resync_lane(0, 1);
        let len = w.encode_to(MsgType::Broadcast, &data, 0.0, &mut lane.scratch).len() as u64;
        let e = lane.faulted_download(Framed { wire: len, raw: 256 }, 1e-3);
        assert!(e.is_ok());
        let mut out = Vec::new();
        assert!(w.decode_into(&lane.scratch.frame, &mut out).is_err());
    }

    #[test]
    fn bulk_transfers_account_bytes() {
        let mut s = sim(1.0, 0.0);
        s.begin_round();
        let t1 = s.bulk_up(0, 4_000_000);
        let t2 = s.bulk_down(0, 4_000_000);
        assert!(t1 > 0.0 && t2 > 0.0);
        assert_eq!(s.round_traffic.up_bytes, 4_000_000);
        assert_eq!(s.round_traffic.down_bytes, 4_000_000);
        s.begin_round();
        assert_eq!(s.round_traffic.up_bytes, 0); // per-round counter resets
        assert_eq!(s.traffic.up_bytes, 4_000_000); // totals persist
    }
}
