//! Simulated edge network: device fleet, link model, failures, accounting.
//!
//! The paper evaluates on homogeneous GPUs with *simulated* device
//! heterogeneity (§III-A); we do the same. The network simulator owns:
//!
//! * per-client link parameters (RTT, up/downlink bandwidth),
//! * the server-availability schedule (Table III sweeps it) and transient
//!   drops, producing the timeout behaviour of paper §II-C,
//! * byte-level communication accounting (Table I's "Communication Cost"),
//! * the simulated clock (training time is simulated time — this box's
//!   wall-clock is not comparable to the paper's A100 testbed).

pub mod clock;
pub mod fleet;

pub use clock::SimClock;
pub use fleet::{sample_fleet, DeviceProfile};

use crate::config::NetConfig;
use crate::util::rng::Pcg32;

/// Outcome of one client↔server exchange attempt.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Exchange {
    /// Server responded: total simulated round-trip seconds.
    Ok { time_s: f64 },
    /// No response within the timeout window → client enters fallback
    /// (paper Alg. 3). Elapsed simulated time equals the timeout.
    TimedOut { time_s: f64 },
}

impl Exchange {
    pub fn time_s(&self) -> f64 {
        match self {
            Exchange::Ok { time_s } | Exchange::TimedOut { time_s } => *time_s,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, Exchange::Ok { .. })
    }
}

/// Byte counters, split by direction (activations vs weights accounted by
/// the caller through distinct channels).
#[derive(Clone, Copy, Debug, Default)]
pub struct Traffic {
    pub up_bytes: u64,
    pub down_bytes: u64,
}

impl Traffic {
    pub fn total_mb(&self) -> f64 {
        (self.up_bytes + self.down_bytes) as f64 / 1e6
    }
}

/// The network simulator. One instance per experiment run.
pub struct NetworkSim {
    cfg: NetConfig,
    profiles: Vec<DeviceProfile>,
    rng: Pcg32,
    /// Whether the server answers during the current round (Table III's
    /// "server gradient availability" is a per-round schedule).
    server_up_this_round: bool,
    pub traffic: Traffic,
    /// Traffic accumulated during the current round only.
    pub round_traffic: Traffic,
}

impl NetworkSim {
    pub fn new(cfg: NetConfig, profiles: Vec<DeviceProfile>, rng: Pcg32) -> Self {
        NetworkSim {
            cfg,
            profiles,
            rng,
            server_up_this_round: true,
            traffic: Traffic::default(),
            round_traffic: Traffic::default(),
        }
    }

    pub fn profiles(&self) -> &[DeviceProfile] {
        &self.profiles
    }

    /// Draw the server-availability schedule for a new round and reset the
    /// per-round byte counters.
    pub fn begin_round(&mut self) {
        self.server_up_this_round = self.rng.bernoulli(self.cfg.server_availability);
        self.round_traffic = Traffic::default();
    }

    pub fn server_available(&self) -> bool {
        self.server_up_this_round
    }

    fn up_bw(&self, client: usize) -> f64 {
        self.profiles[client]
            .uplink_bps
            .min(self.cfg.server_bandwidth_mbps * 1e6 / 8.0)
    }

    fn down_bw(&self, client: usize) -> f64 {
        self.profiles[client]
            .downlink_bps
            .min(self.cfg.server_bandwidth_mbps * 1e6 / 8.0)
    }

    /// Pure transfer-time model (no failure roll): one-way up.
    pub fn up_time(&self, client: usize, bytes: u64) -> f64 {
        self.profiles[client].latency_s / 2.0 + bytes as f64 / self.up_bw(client)
    }

    /// Pure transfer-time model: one-way down.
    pub fn down_time(&self, client: usize, bytes: u64) -> f64 {
        self.profiles[client].latency_s / 2.0 + bytes as f64 / self.down_bw(client)
    }

    /// One request/response exchange with the server (smashed data up,
    /// gradients down; paper Alg. 2 Phase 2). `server_time_s` is the
    /// simulated server-side compute time between receive and reply.
    ///
    /// Accounting: uplink bytes are always charged (the client transmitted
    /// them before it could observe the failure); downlink bytes only on
    /// success.
    pub fn exchange(
        &mut self,
        client: usize,
        up_bytes: u64,
        down_bytes: u64,
        server_time_s: f64,
    ) -> Exchange {
        self.traffic.up_bytes += up_bytes;
        self.round_traffic.up_bytes += up_bytes;

        let dropped = self.rng.bernoulli(self.cfg.drop_prob);
        if !self.server_up_this_round || dropped {
            return Exchange::TimedOut {
                time_s: self.cfg.timeout_s,
            };
        }

        let t = self.up_time(client, up_bytes) + server_time_s + self.down_time(client, down_bytes);
        if t > self.cfg.timeout_s {
            // Link too slow for the timeout window: same observable
            // behaviour as an outage (paper §II-C fallback trigger).
            return Exchange::TimedOut {
                time_s: self.cfg.timeout_s,
            };
        }
        self.traffic.down_bytes += down_bytes;
        self.round_traffic.down_bytes += down_bytes;
        Exchange::Ok { time_s: t }
    }

    /// A bulk weight sync (aggregation upload / broadcast download).
    /// Returns the transfer time; bytes are always charged.
    pub fn bulk_up(&mut self, client: usize, bytes: u64) -> f64 {
        self.traffic.up_bytes += bytes;
        self.round_traffic.up_bytes += bytes;
        self.up_time(client, bytes)
    }

    pub fn bulk_down(&mut self, client: usize, bytes: u64) -> f64 {
        self.traffic.down_bytes += bytes;
        self.round_traffic.down_bytes += bytes;
        self.down_time(client, bytes)
    }

    /// Main-server ↔ Fed-server bulk transfer (Fig. 2 of the paper; used
    /// heavily by the SplitFed baseline, which ships every per-client
    /// server-side model copy to the Fed server each round). Charged as
    /// uplink traffic over the server NIC.
    pub fn fed_link(&mut self, bytes: u64) -> f64 {
        self.traffic.up_bytes += bytes;
        self.round_traffic.up_bytes += bytes;
        bytes as f64 / (self.cfg.server_bandwidth_mbps * 1e6 / 8.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnergyConfig, FleetConfig};

    fn sim(avail: f64, drop: f64) -> NetworkSim {
        let fleet = FleetConfig {
            clients: 4,
            ..FleetConfig::default()
        };
        let profiles = sample_fleet(&fleet, &EnergyConfig::default(), &mut Pcg32::seeded(1));
        let cfg = NetConfig {
            server_availability: avail,
            drop_prob: drop,
            ..NetConfig::default()
        };
        NetworkSim::new(cfg, profiles, Pcg32::seeded(2))
    }

    #[test]
    fn exchange_ok_accounts_both_directions() {
        let mut s = sim(1.0, 0.0);
        s.begin_round();
        let e = s.exchange(0, 1000, 2000, 0.001);
        assert!(e.is_ok());
        assert!(e.time_s() > 0.0);
        assert_eq!(s.traffic.up_bytes, 1000);
        assert_eq!(s.traffic.down_bytes, 2000);
    }

    #[test]
    fn unavailable_round_times_out_and_charges_uplink_only() {
        let mut s = sim(0.0, 0.0);
        s.begin_round();
        assert!(!s.server_available());
        let e = s.exchange(1, 500, 700, 0.001);
        assert_eq!(
            e,
            Exchange::TimedOut {
                time_s: s.cfg.timeout_s
            }
        );
        assert_eq!(s.traffic.up_bytes, 500);
        assert_eq!(s.traffic.down_bytes, 0);
    }

    #[test]
    fn availability_is_per_round_schedule() {
        let mut s = sim(0.5, 0.0);
        let mut ups = 0;
        for _ in 0..200 {
            s.begin_round();
            if s.server_available() {
                ups += 1;
            }
        }
        assert!((60..140).contains(&ups), "ups {ups}");
    }

    #[test]
    fn transfer_time_scales_with_bytes_and_latency() {
        let s = sim(1.0, 0.0);
        let small = s.up_time(0, 1_000);
        let big = s.up_time(0, 10_000_000);
        assert!(big > small);
        assert!(small >= s.profiles()[0].latency_s / 2.0);
    }

    #[test]
    fn slow_link_exceeding_timeout_behaves_as_outage() {
        let mut s = sim(1.0, 0.0);
        s.begin_round();
        // Enormous payload cannot fit in the 5 s window on any edge link.
        let e = s.exchange(2, 100_000_000_000, 0, 0.0);
        assert!(!e.is_ok());
        assert_eq!(e.time_s(), s.cfg.timeout_s);
    }

    #[test]
    fn drops_cause_sporadic_timeouts() {
        let mut s = sim(1.0, 0.3);
        s.begin_round();
        let fails = (0..300)
            .filter(|_| !s.exchange(0, 10, 10, 0.0).is_ok())
            .count();
        assert!((40..160).contains(&fails), "fails {fails}");
    }

    #[test]
    fn bulk_transfers_account_bytes() {
        let mut s = sim(1.0, 0.0);
        s.begin_round();
        let t1 = s.bulk_up(0, 4_000_000);
        let t2 = s.bulk_down(0, 4_000_000);
        assert!(t1 > 0.0 && t2 > 0.0);
        assert_eq!(s.round_traffic.up_bytes, 4_000_000);
        assert_eq!(s.round_traffic.down_bytes, 4_000_000);
        s.begin_round();
        assert_eq!(s.round_traffic.up_bytes, 0); // per-round counter resets
        assert_eq!(s.traffic.up_bytes, 4_000_000); // totals persist
    }
}
