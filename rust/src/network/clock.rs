//! Simulated cluster clock.
//!
//! Clients run in parallel in the modeled system, so a round's duration is
//! the *maximum* over per-client branch times (stragglers dominate, as in
//! the paper's synchronized rounds), plus serial phases (aggregation,
//! evaluation). The clock only ever moves forward.

/// Forward-only simulated time.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    t: f64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { t: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.t
    }

    /// Advance by a serial phase.
    pub fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative dt {dt}");
        self.t += dt.max(0.0);
    }

    /// Advance by a set of parallel branches: the slowest one gates the
    /// round (synchronized aggregation barrier).
    pub fn advance_parallel(&mut self, branch_times: &[f64]) -> f64 {
        let dt = branch_times.iter().cloned().fold(0.0, f64::max);
        self.advance(dt);
        dt
    }

    /// Jump forward to an absolute event time (no-op if `t` is in the
    /// past — the clock only moves forward). Pure comparison, no
    /// arithmetic: draining an [`EventQueue`] of `now + bᵢ` completions
    /// lands on exactly the same bits as `advance_parallel(&[b...])`,
    /// because f64 addition is monotone and the final jump is the same
    /// `now + b_max` sum the barrier fold computed.
    pub fn advance_to(&mut self, t: f64) {
        debug_assert!(t.is_finite(), "non-finite event time {t}");
        if t > self.t {
            self.t = t;
        }
    }
}

/// What happened at a scheduled instant of simulated time.
///
/// The scheduler replaces O(fleet) per-client loops: a round only does
/// work at *events* — a branch finishing, a fault schedule edge, a
/// rejoin deadline — so idle non-cohort clients cost nothing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// Client's round branch (compute + transfers) hit the barrier.
    BranchDone { client: usize },
    /// Fault-schedule edge: the client goes down (`down`) or back up.
    OutageEdge { client: usize, down: bool },
    /// A rejoining client's resync download deadline.
    RejoinDeadline { client: usize },
}

#[derive(Clone, Debug)]
struct Scheduled {
    t: f64,
    seq: u64,
    ev: Event,
}

// Min-heap order: earliest time first, insertion order on exact ties.
// `total_cmp` keeps the ordering total (and deterministic) even if a
// NaN ever slips in, rather than silently reordering the heap.
impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .total_cmp(&other.t)
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

/// Deterministic event-driven scheduler over simulated time.
///
/// Pop order is a pure function of the push sequence: a strict
/// `(time, insertion-seq)` min-order with no hash state, so every
/// thread count replays the identical event history. Shared by the
/// SSFL orchestrator and the SFL/DFL baselines so scaled comparisons
/// stay apples-to-apples.
#[derive(Clone, Debug, Default)]
pub struct EventQueue {
    heap: std::collections::BinaryHeap<std::cmp::Reverse<Scheduled>>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue::default()
    }

    /// Schedule `ev` at absolute simulated time `t`.
    pub fn schedule(&mut self, t: f64, ev: Event) {
        debug_assert!(t.is_finite(), "non-finite event time {t}");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(std::cmp::Reverse(Scheduled { t, seq, ev }));
    }

    /// Earliest pending event, removing it from the queue.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        self.heap.pop().map(|std::cmp::Reverse(s)| (s.t, s.ev))
    }

    /// Time of the earliest pending event, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|std::cmp::Reverse(s)| s.t)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drain every pending event in deterministic order, advancing
    /// `clock` to each event's time before invoking `f`.
    pub fn drain_into(&mut self, clock: &mut SimClock, mut f: impl FnMut(f64, Event)) {
        while let Some((t, ev)) = self.pop() {
            clock.advance_to(t);
            f(t, ev);
        }
    }
}

/// Accumulator for one client's branch within a round.
#[derive(Clone, Copy, Debug, Default)]
pub struct Branch {
    pub t: f64,
}

impl Branch {
    pub fn add(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.t += dt.max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_zero_and_accumulates() {
        let mut c = SimClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        c.advance(0.5);
        assert!((c.now() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn parallel_takes_straggler_max() {
        let mut c = SimClock::new();
        let dt = c.advance_parallel(&[0.1, 3.0, 0.2]);
        assert_eq!(dt, 3.0);
        assert_eq!(c.now(), 3.0);
    }

    #[test]
    fn empty_parallel_is_noop() {
        let mut c = SimClock::new();
        c.advance_parallel(&[]);
        assert_eq!(c.now(), 0.0);
    }

    #[test]
    fn branch_accumulates() {
        let mut b = Branch::default();
        b.add(0.25);
        b.add(0.75);
        assert!((b.t - 1.0).abs() < 1e-12);
    }

    #[test]
    fn events_pop_in_time_order_with_insertion_tiebreak() {
        let mut q = EventQueue::new();
        q.schedule(2.0, Event::BranchDone { client: 2 });
        q.schedule(1.0, Event::BranchDone { client: 1 });
        q.schedule(1.0, Event::OutageEdge { client: 9, down: true });
        q.schedule(0.5, Event::RejoinDeadline { client: 4 });
        assert_eq!(q.peek_time(), Some(0.5));
        let order: Vec<(f64, Event)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(
            order,
            vec![
                (0.5, Event::RejoinDeadline { client: 4 }),
                (1.0, Event::BranchDone { client: 1 }),
                (1.0, Event::OutageEdge { client: 9, down: true }),
                (2.0, Event::BranchDone { client: 2 }),
            ]
        );
        assert!(q.is_empty());
    }

    #[test]
    fn draining_branch_completions_matches_the_barrier_fold_bitwise() {
        // The event-driven barrier must land on the same bits as the
        // straggler-max fold for any completion set.
        let branches = [0.371, 2.25e-3, 1.75, 0.0, 1.7499999];
        let mut a = SimClock::new();
        a.advance(5.5);
        let mut b = a.clone();
        a.advance_parallel(&branches);

        let mut q = EventQueue::new();
        let now = b.now();
        for (i, dt) in branches.iter().enumerate() {
            q.schedule(now + dt, Event::BranchDone { client: i });
        }
        let mut seen = 0;
        q.drain_into(&mut b, |_, _| seen += 1);
        assert_eq!(seen, branches.len());
        assert_eq!(a.now().to_bits(), b.now().to_bits());
    }

    #[test]
    fn advance_to_never_rewinds() {
        let mut c = SimClock::new();
        c.advance_to(3.0);
        c.advance_to(1.0);
        assert_eq!(c.now(), 3.0);
    }
}
