//! Simulated cluster clock.
//!
//! Clients run in parallel in the modeled system, so a round's duration is
//! the *maximum* over per-client branch times (stragglers dominate, as in
//! the paper's synchronized rounds), plus serial phases (aggregation,
//! evaluation). The clock only ever moves forward.

/// Forward-only simulated time.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    t: f64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { t: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.t
    }

    /// Advance by a serial phase.
    pub fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative dt {dt}");
        self.t += dt.max(0.0);
    }

    /// Advance by a set of parallel branches: the slowest one gates the
    /// round (synchronized aggregation barrier).
    pub fn advance_parallel(&mut self, branch_times: &[f64]) -> f64 {
        let dt = branch_times.iter().cloned().fold(0.0, f64::max);
        self.advance(dt);
        dt
    }
}

/// Accumulator for one client's branch within a round.
#[derive(Clone, Copy, Debug, Default)]
pub struct Branch {
    pub t: f64,
}

impl Branch {
    pub fn add(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.t += dt.max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_zero_and_accumulates() {
        let mut c = SimClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        c.advance(0.5);
        assert!((c.now() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn parallel_takes_straggler_max() {
        let mut c = SimClock::new();
        let dt = c.advance_parallel(&[0.1, 3.0, 0.2]);
        assert_eq!(dt, 3.0);
        assert_eq!(c.now(), 3.0);
    }

    #[test]
    fn empty_parallel_is_noop() {
        let mut c = SimClock::new();
        c.advance_parallel(&[]);
        assert_eq!(c.now(), 0.0);
    }

    #[test]
    fn branch_accumulates() {
        let mut b = Branch::default();
        b.add(0.25);
        b.add(0.75);
        assert!((b.t - 1.0).abs() < 1e-12);
    }
}
