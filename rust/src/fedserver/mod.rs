//! Collaborative client–server model aggregation (paper §II-D, Eq. 6–8).
//!
//! At round end the Fed server merges heterogeneous client encoder
//! prefixes into the global super-network:
//!
//! * **Client weighting (Eq. 6)** — depth share × inverse-loss share:
//!   `w_i = d_i/Σd_j · (L_i+ε)⁻¹ / Σ(L_j+ε)⁻¹`, where `L_i` is the fused
//!   loss when the client had server supervision (§II-B rule) and the
//!   plain local loss for fallback-only clients.
//! * **Layer-aligned averaging (Eq. 7–8)** — per layer ℓ, only clients
//!   whose prefix includes ℓ contribute; the consistency term λ pulls the
//!   average toward the server's current copy of the layer, with the
//!   closed-form solution `θ̄ℓ = (Σ wᵢ θᵢℓ + λ θsℓ) / (Σ wᵢ + λ)`.
//!
//! Classifiers are never aggregated (they have no consistent global
//! structure — §II-D).

use crate::util::math;

/// Per-client aggregation input: the trained prefix + metadata.
pub struct ClientUpdate<'a> {
    pub client: usize,
    /// Encoder depth d_i (prefix layer count).
    pub depth: usize,
    /// Flat encoder prefix parameters (length = Σ layer_sizes[0..depth]).
    pub params: &'a [f32],
    /// Loss used for Eq. 6 (fused when server-supervised, local otherwise).
    pub loss: f64,
}

/// Eq. 6 weights for a set of updates. Returns one weight per update, in
/// order; weights sum to ≤ 1 (they are products of two normalized shares).
pub fn client_weights(updates: &[ClientUpdate<'_>], eps: f64) -> Vec<f64> {
    let depth_sum: f64 = updates.iter().map(|u| u.depth as f64).sum();
    let inv_sum: f64 = updates.iter().map(|u| 1.0 / (u.loss + eps)).sum();
    updates
        .iter()
        .map(|u| {
            let depth_share = u.depth as f64 / depth_sum.max(1e-300);
            let loss_share = (1.0 / (u.loss + eps)) / inv_sum.max(1e-300);
            depth_share * loss_share
        })
        .collect()
}

/// Layer-aligned aggregation (Eq. 8) over the global encoder.
///
/// * `global` — the full flat encoder θ (server's copy; layer ℓ's segment
///   doubles as θ_s^ℓ in the consistency term). Updated in place.
/// * `layer_sizes` — per-layer segment lengths (manifest
///   `enc_layer_sizes`).
/// * `lambda` — consistency weight (paper default 0.01).
///
/// Returns per-layer contributor counts (diagnostics).
pub fn aggregate(
    global: &mut [f32],
    layer_sizes: &[usize],
    updates: &[ClientUpdate<'_>],
    lambda: f64,
    eps: f64,
) -> Vec<usize> {
    let weights = client_weights(updates, eps);
    let items: Vec<(usize, &[f32], f64)> = updates
        .iter()
        .zip(weights.iter())
        .map(|(u, &w)| (u.depth, u.params, w))
        .collect();
    aggregate_weighted(global, layer_sizes, &items, lambda)
}

/// Layer-aligned weighted average with explicit per-client weights — the
/// computational core of Eq. 8, also reused by the FedAvg-style baselines
/// (sample-count weights, λ = 0).
///
/// `items` = `(depth, prefix_params, weight)`.
///
/// The pass is fused and fully in place: per layer the server segment is
/// rescaled to carry the λ·θs term, each contributing prefix is
/// accumulated with `axpy`, and one final rescale applies the 1/(Σw+λ)
/// normalization. No per-layer scratch buffer and no holder index list —
/// the only allocation per call is the returned contributor-count
/// diagnostics Vec (one `usize` per layer, independent of fleet size).
pub fn aggregate_weighted(
    global: &mut [f32],
    layer_sizes: &[usize],
    items: &[(usize, &[f32], f64)],
    lambda: f64,
) -> Vec<usize> {
    assert_eq!(
        layer_sizes.iter().sum::<usize>(),
        global.len(),
        "layer table does not partition the global encoder"
    );
    for (i, (depth, params, _)) in items.iter().enumerate() {
        let expect: usize = layer_sizes[..*depth].iter().sum();
        assert_eq!(
            params.len(),
            expect,
            "item {i} params length {} != prefix size {expect}",
            params.len()
        );
    }

    let mut contributors = vec![0usize; layer_sizes.len()];

    let mut off = 0usize;
    for (layer, &len) in layer_sizes.iter().enumerate() {
        let mut wsum = 0.0f64;
        let mut holders = 0usize;
        for (depth, _, w) in items {
            if *depth > layer {
                wsum += *w;
                holders += 1;
            }
        }
        contributors[layer] = holders;
        if holders == 0 {
            // No client trained this layer: server copy stands (§II-D
            // "if only one source provides layer ℓ, used directly").
            off += len;
            continue;
        }

        // θ̄ℓ = (Σ wᵢ θᵢℓ + λ θsℓ) / (Σ wᵢ + λ)   — closed form of Eq. 7,
        // computed in place on the server segment.
        let g_seg = &mut global[off..off + len];
        math::scale(g_seg, lambda as f32);
        for (depth, params, w) in items {
            if *depth > layer {
                math::axpy(g_seg, &params[off..off + len], *w as f32);
            }
        }
        math::scale(g_seg, 1.0 / (wsum + lambda) as f32);
        off += len;
    }
    contributors
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    const EPS: f64 = 1e-8;

    fn sizes() -> Vec<usize> {
        vec![4, 3, 3, 2] // 4-layer toy encoder, 12 params total
    }

    fn prefix(v: f32, depth: usize) -> Vec<f32> {
        vec![v; sizes()[..depth].iter().sum::<usize>()]
    }

    #[test]
    fn weights_match_eq6_by_hand() {
        let p1 = prefix(0.0, 2);
        let p2 = prefix(0.0, 6.min(4)); // depth 4
        let updates = vec![
            ClientUpdate { client: 0, depth: 2, params: &p1, loss: 1.0 },
            ClientUpdate { client: 1, depth: 4, params: &p2, loss: 0.5 },
        ];
        let w = client_weights(&updates, 0.0);
        // depth shares: 2/6, 4/6; inv-loss shares: 1/(1+2)=1/3, 2/3.
        assert!((w[0] - (2.0 / 6.0) * (1.0 / 3.0)).abs() < 1e-9);
        assert!((w[1] - (4.0 / 6.0) * (2.0 / 3.0)).abs() < 1e-9);
    }

    #[test]
    fn deeper_and_lower_loss_weigh_more() {
        let p = prefix(0.0, 2);
        let deep = prefix(0.0, 3);
        let updates = vec![
            ClientUpdate { client: 0, depth: 2, params: &p, loss: 1.0 },
            ClientUpdate { client: 1, depth: 3, params: &deep, loss: 1.0 },
        ];
        let w = client_weights(&updates, EPS);
        assert!(w[1] > w[0]);

        let updates = vec![
            ClientUpdate { client: 0, depth: 2, params: &p, loss: 2.0 },
            ClientUpdate { client: 1, depth: 2, params: &p, loss: 0.5 },
        ];
        let w = client_weights(&updates, EPS);
        assert!(w[1] > w[0]);
    }

    #[test]
    fn aggregate_closed_form_single_client() {
        // One client, one layer held: θ̄ = (w θ_c + λ θ_s)/(w + λ).
        let mut global = vec![1.0f32; 12];
        let p = prefix(3.0, 1);
        let updates = vec![ClientUpdate { client: 0, depth: 1, params: &p, loss: 1.0 }];
        let w = client_weights(&updates, EPS)[0];
        let lambda = 0.01;
        aggregate(&mut global, &sizes(), &updates, lambda, EPS);
        let expect = ((w * 3.0 + lambda * 1.0) / (w + lambda)) as f32;
        for &g in &global[..4] {
            assert!((g - expect).abs() < 1e-5);
        }
        // Untouched deeper layers keep the server copy.
        for &g in &global[4..] {
            assert_eq!(g, 1.0);
        }
    }

    #[test]
    fn deeper_layers_only_from_deep_clients() {
        let mut global = vec![0.0f32; 12];
        let shallow = prefix(1.0, 1);
        let deep = prefix(2.0, 4);
        let updates = vec![
            ClientUpdate { client: 0, depth: 1, params: &shallow, loss: 1.0 },
            ClientUpdate { client: 1, depth: 4, params: &deep, loss: 1.0 },
        ];
        let contributors = aggregate(&mut global, &sizes(), &updates, 0.0, EPS);
        assert_eq!(contributors, vec![2, 1, 1, 1]);
        // Layer 0: mix of 1.0 and 2.0 → strictly between.
        assert!(global[0] > 1.0 && global[0] < 2.0);
        // Layers 1..: only the deep client → exactly 2.0 (λ=0).
        for &g in &global[4..] {
            assert!((g - 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn lambda_zero_ignores_server_lambda_large_keeps_server() {
        let mut g0 = vec![10.0f32; 12];
        let mut g1 = vec![10.0f32; 12];
        let p = prefix(0.0, 4);
        let updates = vec![ClientUpdate { client: 0, depth: 4, params: &p, loss: 1.0 }];
        aggregate(&mut g0, &sizes(), &updates, 0.0, EPS);
        assert!(g0.iter().all(|&v| v.abs() < 1e-6)); // pure client value
        aggregate(&mut g1, &sizes(), &updates, 1e9, EPS);
        assert!(g1.iter().all(|&v| (v - 10.0).abs() < 1e-3)); // pinned to server
    }

    #[test]
    fn aggregate_is_convex_combination_per_layer() {
        forall(5, 30, |rng: &mut Pcg32| {
            let layer_sizes = sizes();
            let total: usize = layer_sizes.iter().sum();
            let mut global: Vec<f32> = (0..total).map(|_| rng.normal() as f32).collect();
            let g0 = global.clone();

            let n = 1 + rng.uniform_usize(6);
            let depths: Vec<usize> = (0..n).map(|_| 1 + rng.uniform_usize(4)).collect();
            let params: Vec<Vec<f32>> = depths
                .iter()
                .map(|&d| {
                    let len: usize = layer_sizes[..d].iter().sum();
                    (0..len).map(|_| rng.normal() as f32).collect()
                })
                .collect();
            let losses: Vec<f64> = (0..n).map(|_| rng.uniform_range(0.05, 5.0)).collect();
            let updates: Vec<ClientUpdate<'_>> = (0..n)
                .map(|i| ClientUpdate {
                    client: i,
                    depth: depths[i],
                    params: &params[i],
                    loss: losses[i],
                })
                .collect();

            aggregate(&mut global, &layer_sizes, &updates, 0.01, EPS);

            // Every aggregated parameter lies within [min, max] of its
            // sources (client values + server prior) — convexity of Eq. 8.
            let mut off = 0;
            for (layer, &len) in layer_sizes.iter().enumerate() {
                for k in 0..len {
                    let mut lo = g0[off + k];
                    let mut hi = g0[off + k];
                    for (i, u) in updates.iter().enumerate() {
                        if u.depth > layer {
                            let v = params[i][off + k];
                            lo = lo.min(v);
                            hi = hi.max(v);
                        }
                    }
                    let v = global[off + k];
                    assert!(
                        v >= lo - 1e-4 && v <= hi + 1e-4,
                        "layer {layer} param {k}: {v} outside [{lo}, {hi}]"
                    );
                }
                off += len;
            }
        });
    }

    #[test]
    fn equal_everything_preserves_value() {
        // All clients and the server agree ⇒ aggregation is a no-op.
        let mut global = vec![2.5f32; 12];
        let p1 = prefix(2.5, 2);
        let p2 = prefix(2.5, 3);
        let updates = vec![
            ClientUpdate { client: 0, depth: 2, params: &p1, loss: 0.8 },
            ClientUpdate { client: 1, depth: 3, params: &p2, loss: 1.3 },
        ];
        aggregate(&mut global, &sizes(), &updates, 0.01, EPS);
        assert!(global.iter().all(|&v| (v - 2.5).abs() < 1e-5));
    }

    #[test]
    #[should_panic]
    fn wrong_prefix_length_rejected() {
        let mut global = vec![0.0f32; 12];
        let bad = vec![0.0f32; 5]; // depth-2 prefix should be 7 params
        let updates = vec![ClientUpdate { client: 0, depth: 2, params: &bad, loss: 1.0 }];
        aggregate(&mut global, &sizes(), &updates, 0.01, EPS);
    }

    #[test]
    fn empty_update_set_keeps_global() {
        let mut global = vec![1.25f32; 12];
        let contributors = aggregate(&mut global, &sizes(), &[], 0.01, EPS);
        assert!(global.iter().all(|&v| v == 1.25));
        assert_eq!(contributors, vec![0; 4]);
    }

    #[test]
    fn client_weights_empty_update_set_is_empty() {
        let w = client_weights(&[], EPS);
        assert!(w.is_empty());
    }

    #[test]
    fn client_weights_zero_total_depth_is_all_zero_and_finite() {
        // Degenerate fleet where every client holds an empty prefix
        // (depth 0): depth shares must collapse to zero, not NaN/inf,
        // and aggregation must leave the global model untouched.
        let empty: Vec<f32> = Vec::new();
        let updates = vec![
            ClientUpdate { client: 0, depth: 0, params: &empty, loss: 1.0 },
            ClientUpdate { client: 1, depth: 0, params: &empty, loss: 0.2 },
        ];
        let w = client_weights(&updates, EPS);
        assert!(w.iter().all(|&x| x == 0.0 && x.is_finite()), "{w:?}");

        let mut global = vec![3.0f32; 12];
        let contributors = aggregate(&mut global, &sizes(), &updates, 0.01, EPS);
        assert_eq!(contributors, vec![0; 4]);
        assert!(global.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn client_weights_equal_loss_fleet_sums_to_at_most_one() {
        // All-equal-loss fleet: loss shares are exactly 1/n, so
        // Σ wᵢ = Σ (dᵢ/Σd)·(1/n) = 1/n ≤ 1.
        let n = 6usize;
        let params: Vec<Vec<f32>> = (0..n).map(|i| prefix(0.0, 1 + i % 4)).collect();
        let updates: Vec<ClientUpdate<'_>> = (0..n)
            .map(|i| ClientUpdate {
                client: i,
                depth: 1 + i % 4,
                params: &params[i],
                loss: 0.7,
            })
            .collect();
        let w = client_weights(&updates, EPS);
        let sum: f64 = w.iter().sum();
        assert!(sum <= 1.0 + 1e-12, "sum {sum}");
        assert!((sum - 1.0 / n as f64).abs() < 1e-9, "sum {sum}");
        assert!(w.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn client_weights_sum_at_most_one_always() {
        // Σᵢ aᵢbᵢ ≤ max(b) ≤ 1 for normalized shares — property-check it.
        forall(11, 50, |rng: &mut Pcg32| {
            let n = 1 + rng.uniform_usize(12);
            let params: Vec<Vec<f32>> = (0..n).map(|i| prefix(0.0, 1 + i % 4)).collect();
            let updates: Vec<ClientUpdate<'_>> = (0..n)
                .map(|i| ClientUpdate {
                    client: i,
                    depth: 1 + i % 4,
                    params: &params[i],
                    loss: rng.uniform_range(1e-3, 10.0),
                })
                .collect();
            let w = client_weights(&updates, EPS);
            let sum: f64 = w.iter().sum();
            assert!(sum <= 1.0 + 1e-9, "sum {sum}");
            assert!(w.iter().all(|&x| x.is_finite() && x >= 0.0));
        });
    }
}
