//! Resource-aware subnetwork allocation (paper §II-A, Eq. 1, Alg. 1).
//!
//! Given each client's one-shot resource report `C_i = (m_i, lat_i)`, the
//! allocator assigns a contiguous-prefix depth
//!
//! ```text
//! d_i = min( ⌊α·m_i⌋ + ⌊β·(lat_max − lat_i)/(lat_max − lat_min + ε)⌋, L−1 ),
//! d_i ≥ 1
//! ```
//!
//! with α = 0.5 layers/GB and β = 4 by default (the paper treats these as
//! interpretable resource-scaling heuristics, not tuned hyperparameters).
//! `lat_min`/`lat_max` are the extremes *observed during initialization*,
//! exactly as in Alg. 1.

use crate::config::AllocConfig;
use crate::network::DeviceProfile;

/// The allocation decision for one client.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Assignment {
    pub client: usize,
    /// Encoder depth d_i ∈ [1, L-1] (number of prefix layers).
    pub depth: usize,
}

/// Allocate depths for the whole fleet (Eq. 1 applied per client).
pub fn allocate(
    profiles: &[DeviceProfile],
    cfg: &AllocConfig,
    total_layers: usize,
) -> Vec<Assignment> {
    assert!(total_layers >= 2, "need at least one client + one server layer");
    let lat_min = profiles
        .iter()
        .map(|p| p.latency_s)
        .fold(f64::INFINITY, f64::min);
    let lat_max = profiles
        .iter()
        .map(|p| p.latency_s)
        .fold(f64::NEG_INFINITY, f64::max);

    profiles
        .iter()
        .map(|p| Assignment {
            client: p.id,
            depth: depth_for(p.mem_gb, p.latency_s, lat_min, lat_max, cfg, total_layers),
        })
        .collect()
}

/// Eq. 1 for a single client given the observed latency extremes.
pub fn depth_for(
    mem_gb: f64,
    latency_s: f64,
    lat_min: f64,
    lat_max: f64,
    cfg: &AllocConfig,
    total_layers: usize,
) -> usize {
    let mem_term = (cfg.alpha * mem_gb).floor();
    let norm = (lat_max - latency_s) / (lat_max - lat_min + cfg.eps);
    let lat_term = (cfg.beta * norm).floor();
    let d = (mem_term + lat_term).min((total_layers - 1) as f64);
    (d.max(1.0)) as usize
}

/// Histogram of assigned depths (diagnostics / tests).
pub fn depth_histogram(assignments: &[Assignment], total_layers: usize) -> Vec<usize> {
    let mut h = vec![0usize; total_layers];
    for a in assignments {
        h[a.depth] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnergyConfig, FleetConfig};
    use crate::network::sample_fleet;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    fn profile(id: usize, mem: f64, lat_ms: f64) -> DeviceProfile {
        DeviceProfile {
            id,
            mem_gb: mem,
            latency_s: lat_ms / 1e3,
            flops: 1e10,
            uplink_bps: 1e6,
            downlink_bps: 1e6,
            active_w: 10.0,
            idle_w: 1.0,
            tx_w: 2.0,
        }
    }

    #[test]
    fn paper_equation_worked_example() {
        // α=0.5, β=4. Client A: 16 GB, lat = lat_min → d = ⌊8⌋+⌊4⌋ = 12 → cap L-1.
        // Client B: 2 GB, lat = lat_max → d = ⌊1⌋+⌊0⌋ = 1.
        let profiles = vec![profile(0, 16.0, 20.0), profile(1, 2.0, 200.0)];
        let a = allocate(&profiles, &AllocConfig::default(), 8);
        assert_eq!(a[0].depth, 7); // capped at L-1
        assert_eq!(a[1].depth, 1);
    }

    #[test]
    fn bounds_one_to_l_minus_one() {
        forall(1, 30, |rng| {
            let fleet_cfg = FleetConfig {
                clients: 20,
                ..FleetConfig::default()
            };
            let profiles = sample_fleet(&fleet_cfg, &EnergyConfig::default(), rng);
            let a = allocate(&profiles, &AllocConfig::default(), 8);
            for x in &a {
                assert!((1..=7).contains(&x.depth), "depth {}", x.depth);
            }
        });
    }

    #[test]
    fn monotone_in_memory() {
        // More memory (same latency) never yields a shallower model.
        let cfg = AllocConfig::default();
        let mut prev = 0;
        for mem in [2.0, 4.0, 8.0, 12.0, 16.0] {
            let d = depth_for(mem, 0.1, 0.02, 0.2, &cfg, 16);
            assert!(d >= prev);
            prev = d;
        }
    }

    #[test]
    fn monotone_in_latency() {
        // Lower latency (same memory) never yields a shallower model.
        let cfg = AllocConfig::default();
        let mut prev = usize::MAX;
        for lat in [0.02, 0.05, 0.1, 0.15, 0.2] {
            let d = depth_for(8.0, lat, 0.02, 0.2, &cfg, 16);
            assert!(d <= prev, "lat {lat} depth {d} prev {prev}");
            prev = d;
        }
    }

    #[test]
    fn lowest_latency_client_gets_full_latency_score() {
        let cfg = AllocConfig::default();
        // lat == lat_min → normalized score = (Δ)/(Δ+ε) ≈ 1⁻, so the floor
        // yields ⌊β·(1−ε′)⌋ = β−1 extra layers over the slowest client —
        // an artifact of Eq. 1's ε guard interacting with the floor.
        let fast = depth_for(2.0, 0.02, 0.02, 0.2, &cfg, 16);
        let slow = depth_for(2.0, 0.2, 0.02, 0.2, &cfg, 16);
        assert_eq!(fast - slow, cfg.beta as usize - 1);
    }

    #[test]
    fn homogeneous_latency_does_not_blow_up() {
        // lat_max == lat_min: ε guards the division; score term ≈ 0 ⇒
        // allocation driven by memory alone.
        let profiles = vec![profile(0, 8.0, 100.0), profile(1, 8.0, 100.0)];
        let a = allocate(&profiles, &AllocConfig::default(), 8);
        assert_eq!(a[0].depth, a[1].depth);
        assert!(a[0].depth >= 1);
    }

    #[test]
    fn histogram_counts_all() {
        let profiles: Vec<_> = (0..10).map(|i| profile(i, 4.0, 50.0)).collect();
        let a = allocate(&profiles, &AllocConfig::default(), 8);
        let h = depth_histogram(&a, 8);
        assert_eq!(h.iter().sum::<usize>(), 10);
    }

    #[test]
    fn heterogeneous_fleet_spreads_depths() {
        // With the paper's U[2,16] GB × U[20,200] ms fleet, the allocator
        // must produce at least 3 distinct depths (the whole point of the
        // super-network).
        let fleet_cfg = FleetConfig {
            clients: 50,
            ..FleetConfig::default()
        };
        let profiles = sample_fleet(
            &fleet_cfg,
            &EnergyConfig::default(),
            &mut Pcg32::seeded(7),
        );
        let a = allocate(&profiles, &AllocConfig::default(), 8);
        let distinct = depth_histogram(&a, 8).iter().filter(|&&c| c > 0).count();
        assert!(distinct >= 3, "only {distinct} distinct depths");
    }
}
