//! Tiny CLI argument parser (no `clap` in the offline crate set).
//!
//! Grammar: positionals, `--key value`, `--key=value`, bare `--flag`.
//! Repeated keys accumulate (used by `--set k=v --set k2=v2`).

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pairs: Vec<(String, String)>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse<I: Iterator<Item = String>>(it: I) -> Args {
        let toks: Vec<String> = it.collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < toks.len() {
            let tok = &toks[i];
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.pairs.push((k.to_string(), v.to_string()));
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    // `--key value` form: consume the value.
                    args.pairs.push((body.to_string(), toks[i + 1].clone()));
                    i += 1;
                } else {
                    // Bare `--flag` (next token is another flag or EOF).
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        args
    }

    /// Last value for a key (later overrides earlier).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// All values for a repeatable key, in order.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.pairs
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positionals_and_pairs() {
        let a = parse(&["train", "--method", "ssfl", "--clients=50"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("method"), Some("ssfl"));
        assert_eq!(a.get("clients"), Some("50"));
    }

    #[test]
    fn repeated_set_accumulates() {
        let a = parse(&["x", "--set", "a=1", "--set", "b=2"]);
        assert_eq!(a.get_all("set"), vec!["a=1", "b=2"]);
    }

    #[test]
    fn last_value_wins() {
        let a = parse(&["--rounds", "5", "--rounds", "9"]);
        assert_eq!(a.get("rounds"), Some("9"));
    }

    #[test]
    fn bare_flags() {
        let a = parse(&["run", "--verbose", "--out", "dir"]);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get("out"), Some("dir"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--quiet"]);
        assert!(a.has_flag("quiet"));
    }
}
