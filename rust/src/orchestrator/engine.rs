//! The parallel round-execution engine.
//!
//! # Why
//!
//! Simulated clients are independent between the round start and the
//! aggregation barrier, yet the seed implementation walked them strictly
//! sequentially, so host time grew superlinearly with fleet size. This
//! module fans each client's per-round branch (Phase 1 → exchange →
//! Phase 2/3 or fallback) out over OS worker threads (`std::thread::scope`
//! — the offline crate set has no rayon) while keeping results
//! **bit-identical regardless of thread count**.
//!
//! # Determinism contract
//!
//! Every source of nondeterminism is removed by construction, not by
//! locking:
//!
//! 1. **Exclusive mutable state per lane.** A lane owns `&mut ClientState`
//!    (its shard RNG and loss accumulators live there), a [`NetLane`]
//!    fork of the network simulator, lane-local copies of the server-side
//!    state it trains (suffix + classifier snapshots taken at round
//!    start), and a [`RoundLedger`] for everything it would previously
//!    have written into shared accounting (`EnergyMeter`, `NetworkSim`
//!    byte counters, busy/branch arrays, step counts).
//! 2. **Per-client PCG streams.** The only RNG a lane touches is either
//!    already per-client (the shard loader) or derived as a pure function
//!    of `(run seed, round, client id)` ([`NetworkSim::lane`]); no draw
//!    order depends on scheduling. The wire layer keeps this intact: every
//!    payload codec ([`crate::wire`]) is a deterministic pure function, and
//!    lanes encode/decode their own frames locally, so lossy codecs
//!    perturb training identically for every thread count.
//! 3. **Deterministic merge order.** At the barrier, ledgers are absorbed
//!    in ascending client-id order: energy into per-device slots, server
//!    busy-seconds and step counts by id-ordered summation, traffic into
//!    the byte counters, and lane server deltas onto the shared
//!    super-network (`θ[ℓ] += (θ_lane[ℓ] − θ_snapshot[ℓ]) / n`, clients
//!    in id order — participant-normalized so the shared suffix trains
//!    at the configured lr_server instead of n× it; see the merge
//!    comment in `run_ssfl`). Floating-point reduction order is
//!    therefore a constant of the run configuration.
//! 4. **Static partitioning.** [`run_lanes`] splits the lane array into
//!    contiguous chunks, one per worker. Because lanes never communicate,
//!    the partition shape cannot affect any lane's result — only the merge
//!    (step 3) touches shared state, and it runs on the caller's thread.
//!
//! The fault engine ([`crate::network::faults`]) preserves the contract:
//! every fault process (Gilbert–Elliott channel state, crash/churn
//! schedule, outage windows, corruption and backoff-jitter rolls) is a
//! pure function of `(run seed, round, client)` and the static
//! `FaultConfig`, and the quorum decision at the barrier depends only on
//! the id-ordered ledger set — so a hostile schedule is exactly as
//! thread-invariant as a fault-free run.
//!
//! Consequently `threads = 1` and `threads = N` produce identical metrics
//! bit for bit (`orchestrator::tests` asserts this end to end against the
//! artifacts; the unit tests below assert it for the engine itself).
//!
//! # Server-state semantics under parallelism
//!
//! The sequential loop let client *i+1* observe the server-suffix updates
//! made while serving client *i* within the same round. That implicit
//! serialization is exactly what prevents parallelism, so the engine
//! adopts the synchronous-parallel-server semantic instead: every client
//! trains against the round-start snapshot of the shared suffix, and the
//! per-lane deltas are averaged into the super-network at the barrier
//! (before Eq. 6–8 aggregation; participant-normalized so the suffix
//! trains at the configured lr_server — raw summation applied n× it and
//! diverged at the default lr). This matches the paper's synchronized
//! aggregation barrier; `deterministic_across_runs` still holds because
//! the semantic is a function of the config alone. The SFL baseline keeps
//! true per-client server copies (SplitFed semantics — already lane
//! friendly); DFL parallelizes across server replicas, each worker
//! walking its replica's clients in id order so the per-replica update
//! sequence is unchanged.
//!
//! # Sampled participation
//!
//! Per-round client sampling (`--sample`) composes with the contract
//! rather than amending it: the cohort is a pure function of
//! `(run seed, round)` drawn on its own salted stream
//! ([`crate::network::sample_cohort`]), resolved on the caller's thread
//! *before* the fan-out, so the lane set handed to [`run_lanes`] — and
//! therefore every per-lane stream and the id-ordered merge — is
//! identical for every thread count. Lazily materialized cohort state
//! (profiles re-derived by stream jumps, shard RNGs re-derived by
//! `advance`+`fork`) reproduces the eager construction draw for draw,
//! which is what keeps `sample=off` bit-identical to the pre-sampling
//! engine and sampled runs thread- and kernel-thread-invariant. The
//! round barrier itself is the event-driven scheduler
//! ([`crate::network::EventQueue`]): branch completions drain in strict
//! `(time, insertion-seq)` order and the straggler max is a pure
//! comparison fold, bitwise equal to the old `advance_parallel` array
//! fold.

use crate::energy::{EnergyMeter, PowerState};
use crate::network::{DeviceProfile, FaultCounters};
use crate::trace::TraceBuf;
use crate::Result;

/// Per-client accounting for one round, merged deterministically at the
/// aggregation barrier. One ledger per lane; no shared state is touched
/// while workers run.
#[derive(Clone, Debug, Default)]
pub struct RoundLedger {
    pub client: usize,
    /// Critical-path time of this client's branch (gates the round via the
    /// straggler max).
    pub branch_s: f64,
    /// Device-active time (compute + transmit) — the complement of idle.
    pub busy_s: f64,
    /// Pre-integrated device energy for the round, J.
    pub energy_j: f64,
    /// Server compute performed on behalf of this client, s.
    pub server_busy_s: f64,
    pub fallback_steps: usize,
    pub server_steps: usize,
    /// Cause-classified fault counts observed by this client's lane
    /// (timeouts, drops, corruptions, retries, crashes) — folded into the
    /// round record at the barrier so availability tables can report
    /// *why* fallbacks happened.
    pub faults: FaultCounters,
    /// Wire bytes this lane put on the link this round (telemetry only —
    /// the authoritative byte accounting stays on `NetLane`/`Traffic`).
    pub wire_bytes: u64,
    /// Lane-local trace buffer ([`crate::trace`]): events at
    /// branch-relative sim time, drained in client-id order at the
    /// barrier. Disabled (a branch-and-return no-op) unless the run is
    /// traced.
    pub trace: TraceBuf,
}

impl RoundLedger {
    pub fn new(client: usize) -> RoundLedger {
        RoundLedger {
            client,
            ..RoundLedger::default()
        }
    }

    /// A ledger whose trace buffer records events (traced runs only; the
    /// plain [`RoundLedger::new`] keeps tracing off the hot path).
    pub fn traced(client: usize, record_events: bool) -> RoundLedger {
        RoundLedger {
            client,
            trace: TraceBuf::new(record_events),
            ..RoundLedger::default()
        }
    }

    /// Charge device energy without touching time accounting.
    pub fn charge(&mut self, profile: &DeviceProfile, state: PowerState, dt: f64) {
        self.energy_j += EnergyMeter::device_power_w(profile, state) * dt.max(0.0);
    }

    /// On-critical-path compute: charges Compute energy and advances both
    /// busy and branch time.
    pub fn work(&mut self, profile: &DeviceProfile, dt: f64) {
        self.charge(profile, PowerState::Compute, dt);
        self.busy_s += dt;
        self.branch_s += dt;
    }

    /// Account one client↔server exchange attempt: the whole round trip
    /// sits on the branch; the client radio is active for the round trip
    /// minus the server-compute window.
    pub fn exchange(&mut self, profile: &DeviceProfile, total_s: f64, server_s: f64) {
        self.branch_s += total_s;
        let tx = (total_s - server_s).max(0.0);
        self.charge(profile, PowerState::Transmit, tx);
        self.busy_s += tx;
    }

    /// Record a successful server-supervised step.
    pub fn server_step(&mut self, server_s: f64) {
        self.server_busy_s += server_s;
        self.server_steps += 1;
    }
}

/// Resolve a configured thread count: `0` means "all available cores".
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

/// Run `body` over every lane, fanned out across `threads` workers.
///
/// Lanes are split into balanced contiguous chunks — `n % threads`
/// workers take `⌈n/threads⌉` lanes, the rest `⌊n/threads⌋` — so every
/// requested worker is used (plain `chunks_mut(⌈n/threads⌉)` would leave
/// workers idle at e.g. 17 lanes / 16 threads). Each worker walks its
/// chunk in order. Because lanes are fully independent (see module docs),
/// the partition shape cannot influence results — `threads = 1` executes
/// the exact same per-lane instruction streams inline. The first error
/// from any worker is propagated; worker panics resume on the caller.
pub fn run_lanes<L, F>(threads: usize, lanes: &mut [L], body: F) -> Result<()>
where
    L: Send,
    F: Fn(&mut L) -> Result<()> + Sync,
{
    let n = lanes.len();
    if n == 0 {
        return Ok(());
    }
    let threads = resolve_threads(threads).min(n);
    if threads <= 1 {
        for lane in lanes.iter_mut() {
            body(lane)?;
        }
        return Ok(());
    }

    let (quot, rem) = (n / threads, n % threads);
    std::thread::scope(|scope| {
        let body = &body;
        let mut rest: &mut [L] = lanes;
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let take = quot + usize::from(w < rem);
            let (slice, tail) = rest.split_at_mut(take);
            rest = tail;
            handles.push(scope.spawn(move || -> Result<()> {
                for lane in slice.iter_mut() {
                    body(lane)?;
                }
                Ok(())
            }));
        }
        let mut first_err = None;
        for handle in handles {
            match handle.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::Error;

    /// A lane that exercises the same ingredients as the real ones:
    /// a private RNG stream and float accumulation.
    #[derive(Clone)]
    struct TestLane {
        id: usize,
        rng: Pcg32,
        sum: f64,
        ledger: RoundLedger,
    }

    fn lanes(n: usize) -> Vec<TestLane> {
        (0..n)
            .map(|id| TestLane {
                id,
                rng: Pcg32::new(99, id as u64 + 1),
                sum: 0.0,
                ledger: RoundLedger::new(id),
            })
            .collect()
    }

    fn body(l: &mut TestLane) -> Result<()> {
        for _ in 0..500 {
            l.sum += l.rng.uniform();
            l.ledger.branch_s += l.rng.uniform() * 1e-3;
        }
        l.ledger.server_steps = l.id;
        Ok(())
    }

    #[test]
    fn thread_count_invariance_is_bit_exact() {
        let baseline = {
            let mut ls = lanes(13);
            run_lanes(1, &mut ls, body).unwrap();
            ls
        };
        for threads in [2usize, 3, 4, 8, 32] {
            let mut ls = lanes(13);
            run_lanes(threads, &mut ls, body).unwrap();
            for (a, b) in baseline.iter().zip(ls.iter()) {
                assert_eq!(a.sum.to_bits(), b.sum.to_bits(), "threads={threads}");
                assert_eq!(
                    a.ledger.branch_s.to_bits(),
                    b.ledger.branch_s.to_bits(),
                    "threads={threads}"
                );
                assert_eq!(a.ledger.server_steps, b.ledger.server_steps);
            }
        }
    }

    #[test]
    fn every_lane_runs_exactly_once() {
        let mut ls = lanes(7);
        run_lanes(3, &mut ls, |l| {
            l.ledger.fallback_steps += 1;
            Ok(())
        })
        .unwrap();
        assert!(ls.iter().all(|l| l.ledger.fallback_steps == 1));
    }

    #[test]
    fn errors_propagate_from_workers() {
        let mut ls = lanes(6);
        let err = run_lanes(4, &mut ls, |l| {
            if l.id == 4 {
                Err(Error::Config("lane 4 boom".into()))
            } else {
                Ok(())
            }
        })
        .unwrap_err();
        assert!(err.to_string().contains("lane 4 boom"));
    }

    #[test]
    fn empty_and_oversubscribed_inputs_are_fine() {
        let mut none: Vec<TestLane> = Vec::new();
        run_lanes(8, &mut none, body).unwrap();
        let mut two = lanes(2);
        run_lanes(64, &mut two, body).unwrap(); // threads clamp to lane count
    }

    #[test]
    fn resolve_threads_zero_means_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(5), 5);
    }

    #[test]
    fn ledger_accounting_matches_meter_model() {
        use crate::config::{EnergyConfig, FleetConfig};
        use crate::network::sample_fleet;
        let fleet = sample_fleet(
            &FleetConfig {
                clients: 1,
                ..FleetConfig::default()
            },
            &EnergyConfig::default(),
            &mut Pcg32::seeded(1),
        );
        let p = &fleet[0];
        let mut l = RoundLedger::new(0);
        l.work(p, 2.0);
        l.exchange(p, 1.0, 0.25);
        l.server_step(0.25);
        assert!((l.branch_s - 3.0).abs() < 1e-12);
        assert!((l.busy_s - 2.75).abs() < 1e-12);
        let expect = EnergyMeter::device_power_w(p, PowerState::Compute) * 2.0
            + EnergyMeter::device_power_w(p, PowerState::Transmit) * 0.75;
        assert!((l.energy_j - expect).abs() < 1e-9);
        assert_eq!(l.server_steps, 1);
        assert!((l.server_busy_s - 0.25).abs() < 1e-12);
    }
}
