//! The round orchestrator: experiment setup + the SuperSFL training loop.
//!
//! `run_experiment` is the single entry point used by the CLI, examples
//! and benches. It prepares the simulated world (task, non-IID shards,
//! fleet, allocation, network, energy meter, simulated clock) and then
//! dispatches to the method-specific round loop — SuperSFL here, SFL/DFL
//! in [`crate::baselines`]. All three share the same [`Harness`] so their
//! accounting (bytes, simulated time, energy) is identical by
//! construction.
//!
//! Within a round, clients conceptually run in parallel: each client's
//! simulated branch time is accumulated separately and the round advances
//! the clock by the straggler maximum (synchronized aggregation barrier),
//! exactly as in the paper's synchronized-round setting.

use crate::allocation::{self, Assignment};
use crate::baselines;
use crate::client::ClientState;
use crate::config::{ExperimentConfig, Method};
use crate::data::{dirichlet_partition, ClientShard, Dataset, SyntheticSpec, SyntheticTask};
use crate::energy::{cost::ModelGeometry, CostModel, EnergyMeter, PowerState};
use crate::fedserver::{self, ClientUpdate};
use crate::metrics::{RoundRecord, RunMetrics};
use crate::network::{sample_fleet, DeviceProfile, NetworkSim, SimClock};
use crate::runtime::Runtime;
use crate::server::ServerState;
use crate::util::rng::Pcg32;
use crate::Result;

/// Everything a method loop needs, pre-built by [`Harness::prepare`].
pub struct Harness {
    pub cfg: ExperimentConfig,
    pub clients: Vec<ClientState>,
    pub server: ServerState,
    pub profiles: Vec<DeviceProfile>,
    pub assignments: Vec<Assignment>,
    pub net: NetworkSim,
    pub meter: EnergyMeter,
    pub clock: SimClock,
    pub cost: CostModel,
    pub train: Dataset,
    pub test: Dataset,
    /// Fixed test subset evaluated every round.
    pub eval_indices: Vec<usize>,
    pub records: Vec<RoundRecord>,
}

/// The result of one experiment run.
pub struct RunResult {
    pub metrics: RunMetrics,
    /// Depth assigned to each client (Eq. 1).
    pub depths: Vec<usize>,
}

impl Harness {
    /// Build the simulated world for a config.
    pub fn prepare(rt: &Runtime, cfg: &ExperimentConfig) -> Result<Harness> {
        cfg.validate()?;
        let m = rt.model().clone();
        let mut root = Pcg32::new(cfg.train.seed, 0xD15EA5E);

        // Task + datasets (shared prototypes across train/test).
        let spec = SyntheticSpec {
            classes: cfg.data.classes,
            image_size: m.image_size,
            channels: m.channels,
            noise: cfg.data.noise,
            max_shift: cfg.data.max_shift,
        };
        let mut data_rng = root.fork(1);
        let task = SyntheticTask::new(spec, &mut data_rng);
        let train = task.generate(cfg.data.train_per_class, &mut data_rng);
        let per_class_test = (cfg.data.test_total / cfg.data.classes).max(1);
        let test = task.generate(per_class_test, &mut data_rng);

        // Non-IID shards.
        let mut part_rng = root.fork(2);
        let shards = dirichlet_partition(
            &train.labels,
            cfg.data.classes,
            cfg.fleet.clients,
            cfg.data.dirichlet_alpha,
            &mut part_rng,
        );

        // Fleet + allocation (Eq. 1). Baselines override depths themselves.
        let mut fleet_rng = root.fork(3);
        let profiles = sample_fleet(&cfg.fleet, &cfg.energy, &mut fleet_rng);
        let assignments = allocation::allocate(&profiles, &cfg.alloc, m.depth);

        let server = ServerState::new(rt, cfg.data.classes, cfg.train.lr_server as f32)?;

        // Clients.
        let mut shard_rng = root.fork(4);
        let mut clients = Vec::with_capacity(cfg.fleet.clients);
        for (i, shard_idx) in shards.into_iter().enumerate() {
            let depth = match cfg.method {
                Method::Sfl => cfg.sfl_fixed_depth.clamp(1, m.depth - 1),
                _ => assignments[i].depth,
            };
            let shard = ClientShard::new(shard_idx, shard_rng.fork(i as u64));
            let c = match cfg.method {
                Method::SuperSfl => ClientState::new_ssfl(
                    rt,
                    i,
                    depth,
                    cfg.data.classes,
                    &server.enc,
                    shard,
                    cfg.train.lr_client as f32,
                )?,
                _ => ClientState::new_baseline(
                    rt,
                    i,
                    depth,
                    &server.enc,
                    shard,
                    cfg.train.lr_client as f32,
                )?,
            };
            clients.push(c);
        }

        let net = NetworkSim::new(cfg.net.clone(), profiles.clone(), root.fork(5));
        let meter = EnergyMeter::new(cfg.fleet.clients, &cfg.energy);
        let cost = CostModel::new(ModelGeometry {
            tokens: m.tokens,
            batch: m.batch,
            embed_size: m.embed_size,
            block_size: m.block_size,
            depth: m.depth,
            clf_client_size: rt.manifest.clf_client_size(cfg.data.classes)?,
            clf_server_size: rt.manifest.clf_server_size(cfg.data.classes)?,
        });

        let eval_n = cfg.train.eval_samples.min(test.len());
        let eval_indices: Vec<usize> = (0..eval_n).collect();

        Ok(Harness {
            cfg: cfg.clone(),
            clients,
            server,
            profiles,
            assignments,
            net,
            meter,
            clock: SimClock::new(),
            cost,
            train,
            test,
            eval_indices,
            records: Vec::new(),
        })
    }

    /// Simulated server compute time for one suffix step of depth `d`.
    pub fn server_step_time(&self, depth: usize) -> f64 {
        self.cost
            .time_s(self.cost.server_step_flops(depth), self.cfg.fleet.server_gflops * 1e9)
    }

    /// Evaluate the current global model on the fixed test subset.
    pub fn eval_global(&mut self, rt: &Runtime) -> Result<f64> {
        let acc = self
            .server
            .evaluate(rt, &self.test, &self.eval_indices)?;
        let t = self
            .cost
            .time_s(self.cost.eval_flops(self.eval_indices.len()), self.cfg.fleet.server_gflops * 1e9);
        self.meter.server_busy(t);
        self.clock.advance(t);
        Ok(acc)
    }

    /// Close out a round: charge client idle, build + store the record,
    /// and return whether the accuracy target was reached.
    #[allow(clippy::too_many_arguments)]
    pub fn finish_round(
        &mut self,
        round: usize,
        round_dt: f64,
        busy: &[f64],
        accuracy: f64,
        fallback_steps: usize,
        server_steps: usize,
    ) -> bool {
        for (i, &b) in busy.iter().enumerate() {
            let idle = (round_dt - b).max(0.0);
            self.meter
                .client(&self.profiles[i].clone(), PowerState::Idle, idle);
        }
        let mean = |xs: Vec<f64>| {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        let local_losses: Vec<f64> = self
            .clients
            .iter()
            .filter_map(|c| c.round_local_loss.mean())
            .collect();
        let server_losses: Vec<f64> = self
            .clients
            .iter()
            .filter_map(|c| c.round_server_loss.mean())
            .collect();
        let cum_comm = self.net.traffic.total_mb();
        let rec = RoundRecord {
            round,
            sim_time_s: self.clock.now(),
            accuracy,
            mean_client_loss: mean(local_losses),
            mean_server_loss: mean(server_losses),
            comm_mb: self.net.round_traffic.total_mb(),
            cum_comm_mb: cum_comm,
            energy_j: self.meter.total_energy_j(),
            fallback_steps,
            server_steps,
        };
        self.records.push(rec);
        match self.cfg.train.target_accuracy {
            Some(t) => accuracy >= t,
            None => false,
        }
    }

    /// Assemble the final run metrics.
    pub fn finalize(&mut self) -> RunResult {
        self.meter.finalize(self.clock.now());
        let total = self.clock.now();
        let metrics = RunMetrics::from_rounds(
            &self.cfg.name,
            self.cfg.method.as_str(),
            self.records.clone(),
            self.cfg.train.target_accuracy,
            self.meter.total_energy_j(),
            self.meter.avg_power_w(total),
            self.meter.co2_g(),
        );
        RunResult {
            metrics,
            depths: self.clients.iter().map(|c| c.depth).collect(),
        }
    }
}

/// Run one experiment end to end (the public API).
pub fn run_experiment(rt: &Runtime, cfg: &ExperimentConfig) -> Result<RunResult> {
    let mut h = Harness::prepare(rt, cfg)?;
    match cfg.method {
        Method::SuperSfl => run_ssfl(rt, &mut h)?,
        Method::Sfl => baselines::sfl::run(rt, &mut h)?,
        Method::Dfl => baselines::dfl::run(rt, &mut h)?,
    }
    Ok(h.finalize())
}

/// The SuperSFL round loop (paper Alg. 1–3 + §II-D aggregation).
fn run_ssfl(rt: &Runtime, h: &mut Harness) -> Result<()> {
    let classes = h.cfg.data.classes;
    let total_layers = rt.model().depth;
    let batch_elems_dim = rt.model().dim;
    let local_steps = h.cfg.train.local_steps;
    let tpgf_mode = h.cfg.ssfl.tpgf_mode;
    let fuse_via_artifact = h.cfg.ssfl.fuse_via_artifact;

    for round in 1..=h.cfg.train.rounds {
        h.net.begin_round();
        let mut busy = vec![0.0f64; h.clients.len()];
        let mut branch = vec![0.0f64; h.clients.len()];
        let mut fallback_steps = 0usize;
        let mut server_steps = 0usize;

        for ci in 0..h.clients.len() {
            h.clients[ci].begin_round();
            let depth = h.clients[ci].depth;
            let profile = h.profiles[ci].clone();
            let smashed = h.cost.smashed_bytes(batch_elems_dim);
            let srv_time = h.server_step_time(depth);

            for _ in 0..local_steps {
                let batch = {
                    let c = &mut h.clients[ci];
                    c.shard.next_batch(&h.train, rt.model().batch)
                };

                // Phase 1 (always; also the entire fallback step).
                let local = h.clients[ci].phase1(rt, classes, &batch)?;
                let t1 = h
                    .cost
                    .time_s(h.cost.client_local_flops(depth), profile.flops);
                h.meter.client(&profile, PowerState::Compute, t1);
                branch[ci] += t1;
                busy[ci] += t1;

                // Phase 2 attempt: smashed data up, g_z down.
                let ex = h.net.exchange(ci, smashed, smashed, srv_time);
                branch[ci] += ex.time_s();
                let tx_time = (ex.time_s() - srv_time).max(0.0);
                h.meter.client(&profile, PowerState::Transmit, tx_time);
                busy[ci] += tx_time;

                if ex.is_ok() {
                    h.meter.server_busy(srv_time);
                    let out = h.server.process(rt, depth, &local.z, &batch.y)?;
                    // Phase 2 client backprop + Phase 3 fusion.
                    h.clients[ci].phase2_phase3(
                        rt,
                        &batch,
                        &local,
                        &out.g_z,
                        out.loss,
                        tpgf_mode,
                        fuse_via_artifact,
                        total_layers,
                    )?;
                    let t23 = h.cost.time_s(
                        h.cost.client_bwd_flops(depth) + h.cost.tpgf_fuse_flops(depth),
                        profile.flops,
                    );
                    h.meter.client(&profile, PowerState::Compute, t23);
                    branch[ci] += t23;
                    busy[ci] += t23;
                    server_steps += 1;
                } else {
                    // Fault-tolerant fallback (Alg. 3): local-only update.
                    h.clients[ci].fallback_update(&local);
                    fallback_steps += 1;
                }
            }
        }

        let round_dt = h.clock.advance_parallel(&branch);

        // ---- Collaborative aggregation (Eq. 6–8) ----
        let mut agg_branch = vec![0.0f64; h.clients.len()];
        for ci in 0..h.clients.len() {
            let bytes = (h.clients[ci].enc.len() * 4) as u64;
            agg_branch[ci] = h.net.bulk_up(ci, bytes);
        }
        let agg_dt = h.clock.advance_parallel(&agg_branch);
        for (i, &t) in agg_branch.iter().enumerate() {
            let p = h.profiles[i].clone();
            h.meter.client(&p, PowerState::Transmit, t);
            h.meter
                .client(&p, PowerState::Idle, (agg_dt - t).max(0.0));
        }

        {
            let updates: Vec<ClientUpdate<'_>> = h
                .clients
                .iter()
                .map(|c| ClientUpdate {
                    client: c.id,
                    depth: c.depth,
                    params: &c.enc,
                    loss: c
                        .aggregation_loss(tpgf_mode, total_layers)
                        .unwrap_or(1.0),
                })
                .collect();
            let sizes = h.server.layer_sizes().to_vec();
            fedserver::aggregate(
                &mut h.server.enc,
                &sizes,
                &updates,
                h.cfg.ssfl.lambda,
                h.cfg.ssfl.eps,
            );
        }
        // Aggregation itself: one pass over the encoder on the server.
        let agg_compute = h
            .cost
            .time_s(2.0 * h.server.enc.len() as f64, h.cfg.fleet.server_gflops * 1e9);
        h.meter.server_busy(agg_compute);
        h.clock.advance(agg_compute);

        // ---- Broadcast the refreshed prefixes ----
        let mut bc_branch = vec![0.0f64; h.clients.len()];
        for ci in 0..h.clients.len() {
            let bytes = (h.clients[ci].enc.len() * 4) as u64;
            bc_branch[ci] = h.net.bulk_down(ci, bytes);
            let global = h.server.enc.clone();
            h.clients[ci].sync_from_global(&global);
        }
        let bc_dt = h.clock.advance_parallel(&bc_branch);
        for (i, &t) in bc_branch.iter().enumerate() {
            let p = h.profiles[i].clone();
            h.meter.client(&p, PowerState::Transmit, t);
            h.meter.client(&p, PowerState::Idle, (bc_dt - t).max(0.0));
        }

        // ---- Evaluate + record ----
        let acc = h.eval_global(rt)?;
        let hit = h.finish_round(round, round_dt, &busy, acc, fallback_steps, server_steps);
        if hit {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::load(&dir).unwrap())
    }

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default()
            .with_clients(4)
            .with_rounds(2)
            .with_seed(7);
        cfg.data.train_per_class = 20;
        cfg.data.test_total = 100;
        cfg.train.local_steps = 1;
        cfg.train.eval_samples = 100;
        cfg
    }

    #[test]
    fn prepare_builds_consistent_world() {
        let Some(rt) = runtime() else { return };
        let h = Harness::prepare(&rt, &tiny_cfg()).unwrap();
        assert_eq!(h.clients.len(), 4);
        assert_eq!(h.profiles.len(), 4);
        // Every client's prefix length matches its depth.
        for c in &h.clients {
            let expect: usize = rt.model().enc_layer_sizes[..c.depth].iter().sum();
            assert_eq!(c.enc.len(), expect);
            assert!(c.clf.is_some());
        }
        // Shards cover the training set.
        let total: usize = h.clients.iter().map(|c| c.shard.len()).sum();
        assert_eq!(total, h.train.len());
    }

    #[test]
    fn ssfl_two_rounds_produce_records() {
        let Some(rt) = runtime() else { return };
        let res = run_experiment(&rt, &tiny_cfg()).unwrap();
        assert_eq!(res.metrics.rounds.len(), 2);
        assert!(res.metrics.total_comm_mb > 0.0);
        assert!(res.metrics.total_sim_time_s > 0.0);
        assert!(res.metrics.total_energy_j > 0.0);
        assert!(res.metrics.rounds[0].server_steps > 0);
        assert_eq!(res.depths.len(), 4);
    }

    #[test]
    fn deterministic_across_runs() {
        let Some(rt) = runtime() else { return };
        let a = run_experiment(&rt, &tiny_cfg()).unwrap();
        let b = run_experiment(&rt, &tiny_cfg()).unwrap();
        assert_eq!(a.metrics.final_accuracy, b.metrics.final_accuracy);
        assert_eq!(a.metrics.total_comm_mb, b.metrics.total_comm_mb);
        assert_eq!(a.depths, b.depths);
    }

    #[test]
    fn serverless_round_uses_fallback_everywhere() {
        let Some(rt) = runtime() else { return };
        let mut cfg = tiny_cfg();
        cfg.net.server_availability = 0.0;
        let res = run_experiment(&rt, &cfg).unwrap();
        for r in &res.metrics.rounds {
            assert_eq!(r.server_steps, 0);
            assert!(r.fallback_steps > 0);
        }
    }

    #[test]
    fn target_accuracy_stops_early() {
        let Some(rt) = runtime() else { return };
        let mut cfg = tiny_cfg();
        cfg.train.rounds = 50;
        cfg.train.target_accuracy = Some(0.0); // trivially hit at round 1
        let res = run_experiment(&rt, &cfg).unwrap();
        assert_eq!(res.metrics.rounds.len(), 1);
        assert_eq!(res.metrics.rounds_to_target, Some(1));
    }
}
