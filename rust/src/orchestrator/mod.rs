//! The round orchestrator: experiment setup + the SuperSFL training loop.
//!
//! `run_experiment` is the single entry point used by the CLI, examples
//! and benches. It prepares the simulated world (task, non-IID shards,
//! fleet, allocation, network, energy meter, simulated clock) and then
//! dispatches to the method-specific round loop — SuperSFL here, SFL/DFL
//! in [`crate::baselines`]. All three share the same [`Harness`] so their
//! accounting (bytes, simulated time, energy) is identical by
//! construction.
//!
//! Within a round, clients run in parallel both in the modeled system and
//! on the host: each client's branch executes on a worker thread of the
//! [`engine`] (see its module docs for the ledger/lane design, the merge
//! order, and the determinism contract), accumulating its simulated branch
//! time in a private [`engine::RoundLedger`]. At the synchronized
//! aggregation barrier the ledgers are merged in client-id order and the
//! clock advances by the straggler maximum, exactly as in the paper's
//! synchronized-round setting. Results are bit-identical for any
//! `cfg.threads` value.
//!
//! The hot path is allocation-free where it matters: aggregation runs as
//! a fused in-place per-layer pass (no scratch buffer) and lane snapshots
//! reuse their buffers across rounds.
//!
//! Every client↔server tensor exchange is serialized through the
//! [`crate::wire`] layer: smashed activations and activation gradients as
//! per-step frames inside each lane, the subnetwork upload (prefix θ_i +
//! auxiliary classifier φ_i, with the Eq. 6 loss in the frame header) and
//! the refreshed-prefix broadcast as barrier frames. The network is
//! charged with the **actual encoded frame bytes** (the analytic `4·n`
//! counts ride along as "raw" for the compression ratio), and the
//! receiving side always trains on the *decoded* tensors — so lossy
//! codecs (`--wire-codec fp16|int8|topk:<k>`) genuinely perturb training,
//! while `fp32` remains bit-identical to never serializing at all.
//!
//! # Sampled participation (`--sample n|frac`)
//!
//! With `cfg.sample` off (the default) every client participates every
//! round and the world is built eagerly, exactly as the seed did. With a
//! sample spec, each round draws a cohort that is a pure function of
//! `(seed, round)` ([`crate::network::sample_cohort`]) and the per-round
//! cost — client state, lane buffers, barrier events — scales with the
//! *cohort*, not the fleet:
//!
//! - device profiles come on demand from the lazy [`Fleet`] stream
//!   (prefix-stable across fleet sizes, draw-identical to the eager
//!   table);
//! - cohort members are materialized into a pooled map at round start
//!   (fresh φ_i, current global prefix, flagged stale so their first
//!   participation pays the charged resync download any rejoiner pays)
//!   and evicted when they rotate out, so memory stays flat per round;
//! - barrier waits drain an [`EventQueue`] of per-participant completion
//!   events instead of folding O(fleet) vectors — bit-identical to the
//!   straggler-max fold, shared with the SFL/DFL baselines.
//!
//! Cohort draws live on their own salted stream and the event drain is
//! comparison-only, so `sample=off` trajectories are bit-identical to
//! the seed's (no golden re-bless) and sampled runs stay invariant
//! across `--threads` / `--kernel-threads`.

pub mod engine;

use std::collections::BTreeMap;

use crate::allocation::{self, Assignment};
use crate::baselines;
use crate::client::ClientState;
use crate::config::{ExperimentConfig, Method, SampleSpec};
use crate::data::{dirichlet_partition, ClientShard, Dataset, SyntheticSpec, SyntheticTask};
use crate::energy::{cost::ModelGeometry, CostModel, EnergyMeter, PowerState};
use crate::fedserver::ClientUpdate;
use crate::metrics::{RoundRecord, RunMetrics};
use crate::network::{
    sample_cohort, sample_fleet, DeviceProfile, Event, EventQueue, FaultConfig, FaultCounters,
    Fleet, Framed, NetLane, NetworkSim, SimClock,
};
use crate::runtime::Runtime;
use crate::server::ServerState;
use crate::trace::{InstantKind, SpanKind, TraceBuf, TraceReport, Tracer, TRACK_BARRIER, TRACK_SERVER};
use crate::util::math;
use crate::util::rng::Pcg32;
use crate::wire::{MsgType, Wire, WireCodecKind, WireScratch};
use crate::Result;

use engine::RoundLedger;

/// Everything a method loop needs, pre-built by [`Harness::prepare`].
pub struct Harness {
    pub cfg: ExperimentConfig,
    /// Eager per-client training state (`sample=off`). Empty under
    /// sampled participation — cohort members live in `pool` instead.
    pub clients: Vec<ClientState>,
    pub server: ServerState,
    /// Eager profile table (`sample=off`). Empty under sampled
    /// participation — use [`Harness::profile`], which serves both.
    pub profiles: Vec<DeviceProfile>,
    /// Eager Eq. 1 assignment table (`sample=off` only).
    pub assignments: Vec<Assignment>,
    /// Lazily sampled fleet (always present; the eager tables above are
    /// drawn from the same stream, so either view yields the same
    /// devices).
    pub fleet: Fleet,
    pub net: NetworkSim,
    pub meter: EnergyMeter,
    pub clock: SimClock,
    pub cost: CostModel,
    /// Wire codec policy for every client↔server tensor exchange
    /// (`cfg.wire`, overridden by `SUPERSFL_WIRE`).
    pub wire: Wire,
    pub train: Dataset,
    pub test: Dataset,
    /// Fixed test subset evaluated every round.
    pub eval_indices: Vec<usize>,
    pub records: Vec<RoundRecord>,
    /// Per-round cohort size under sampled participation; `None` = full
    /// participation (the seed behaviour).
    pub cohort_k: Option<usize>,
    /// Materialized cohort state under sampled participation, keyed by
    /// client id and evicted down to each round's roster — the fleet
    /// never exists in memory at once.
    pub pool: BTreeMap<usize, ClientState>,
    /// High-water marks of the pooled state (flat-memory evidence).
    pub pool_stats: PoolStats,
    /// Per-client shard index lists, kept for on-demand materialization
    /// (sampled mode only; eager mode moves them into `clients`).
    shards: Vec<Vec<usize>>,
    /// Base of the per-client shard-RNG stream (`root.fork(4)`): client
    /// `i`'s generator is `clone → advance(2i) → fork(i)`, bit-equal to
    /// the eager sequential forks.
    shard_base: Pcg32,
    /// Fleet-wide `(lat_min, lat_max)` for lazy Eq. 1 depth assignment.
    lat_extremes: (f64, f64),
    /// Span/telemetry recorder (`cfg.trace`); `None` keeps the hot path
    /// free of trace work and the output shape identical to the
    /// pre-trace simulator.
    pub tracer: Option<Tracer>,
    /// Set when a SIGINT/SIGTERM arrived mid-run: the 1-based round the
    /// loop was about to start when it broke out. Partial artifacts are
    /// still flushed through the normal atomic-write path.
    pub interrupted: Option<usize>,
    /// Host wall-clock anchor (perf reporting, not simulation).
    host_t0: std::time::Instant,
}

/// High-water marks of the sampled-participation pools. Scaled runs
/// assert on these: they must track the cohort size, never the fleet.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Largest roster materialized in any round.
    pub max_cohort: usize,
    /// Most client states alive in the pool at once.
    pub max_materialized: usize,
    /// Most `f32`s held by the per-lane server/classifier buffers.
    pub max_lane_f32: usize,
}

/// The result of one experiment run.
pub struct RunResult {
    pub metrics: RunMetrics,
    /// Depth assigned to each client (Eq. 1). Under sampled
    /// participation: the depths of the final round's materialized
    /// cohort (the fleet-wide table is never built).
    pub depths: Vec<usize>,
    /// Pooled-state high-water marks (zeros under `sample=off`).
    pub pool: PoolStats,
    /// The run's recorded event stream (`--trace <path>` only; `None`
    /// under `off`/`summary`). Sim-time-only, so two traced runs of the
    /// same config match event for event at any thread count.
    pub trace: Option<TraceReport>,
}

impl Harness {
    /// Build the simulated world for a config.
    pub fn prepare(rt: &Runtime, cfg: &ExperimentConfig) -> Result<Harness> {
        // Resolve the fault schedule and the participation spec once, up
        // front (`SUPERSFL_FAULTS` / `SUPERSFL_SAMPLE` win over the
        // config — the CI chaos and scale legs pin them), so the harness
        // config, the network simulator and the round loops always agree.
        let mut cfg = cfg.clone();
        cfg.net.faults = FaultConfig::from_env_or(cfg.net.faults.clone());
        cfg.sample = SampleSpec::from_env_or(cfg.sample);
        let cfg = &cfg;
        cfg.validate()?;
        let cohort_k = cfg.sample.cohort_size(cfg.fleet.clients);
        let sampled = cohort_k.is_some();
        let m = rt.model().clone();
        let mut root = Pcg32::new(cfg.train.seed, 0xD15EA5E);

        // Task + datasets (shared prototypes across train/test).
        let spec = SyntheticSpec {
            classes: cfg.data.classes,
            image_size: m.image_size,
            channels: m.channels,
            noise: cfg.data.noise,
            max_shift: cfg.data.max_shift,
        };
        let mut data_rng = root.fork(1);
        let task = SyntheticTask::new(spec, &mut data_rng);
        let train = task.generate(cfg.data.train_per_class, &mut data_rng);
        let per_class_test = (cfg.data.test_total / cfg.data.classes).max(1);
        let test = task.generate(per_class_test, &mut data_rng);

        // Non-IID shards.
        let mut part_rng = root.fork(2);
        let shards = dirichlet_partition(
            &train.labels,
            cfg.data.classes,
            cfg.fleet.clients,
            cfg.data.dirichlet_alpha,
            &mut part_rng,
        );

        // Fleet + allocation (Eq. 1). Baselines override depths themselves.
        // The lazy `Fleet` view is anchored at the *pre-draw* stream
        // position, so `fleet.profile(i)` reproduces the eager table
        // bit for bit in either mode.
        let mut fleet_rng = root.fork(3);
        let fleet = Fleet::new(cfg.fleet.clone(), cfg.energy.clone(), fleet_rng.clone());
        let (profiles, assignments, lat_extremes) = if sampled {
            // One streaming pass for the Eq. 1 latency extremes; the
            // O(fleet) profile/assignment tables are never built.
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for i in 0..fleet.len() {
                let lat = fleet.profile(i).latency_s;
                lo = lo.min(lat);
                hi = hi.max(lat);
            }
            (Vec::new(), Vec::new(), (lo, hi))
        } else {
            let profiles = sample_fleet(&cfg.fleet, &cfg.energy, &mut fleet_rng);
            let assignments = allocation::allocate(&profiles, &cfg.alloc, m.depth);
            (profiles, assignments, (0.0, 0.0))
        };

        let server = ServerState::new(rt, cfg.data.classes, cfg.train.lr_server as f32)?;

        // Clients. Sampled mode defers construction to
        // `materialize_cohort` and keeps only the shard index lists; the
        // shard-RNG base is pinned here so lazy derivation
        // (`advance(2i)` + `fork(i)`) replays the eager fork sequence.
        let mut shard_rng = root.fork(4);
        let shard_base = shard_rng.clone();
        let mut clients = Vec::new();
        let mut kept_shards: Vec<Vec<usize>> = Vec::new();
        if sampled {
            kept_shards = shards;
        } else {
            clients.reserve(cfg.fleet.clients);
            for (i, shard_idx) in shards.into_iter().enumerate() {
                let depth = match cfg.method {
                    Method::Sfl => cfg.sfl_fixed_depth.clamp(1, m.depth - 1),
                    _ => assignments[i].depth,
                };
                let shard = ClientShard::new(shard_idx, shard_rng.fork(i as u64));
                let c = match cfg.method {
                    Method::SuperSfl => ClientState::new_ssfl(
                        rt,
                        i,
                        depth,
                        cfg.data.classes,
                        &server.enc,
                        shard,
                        cfg.train.lr_client as f32,
                    )?,
                    _ => ClientState::new_baseline(
                        rt,
                        i,
                        depth,
                        &server.enc,
                        shard,
                        cfg.train.lr_client as f32,
                    )?,
                };
                clients.push(c);
            }
        }

        let net = if sampled {
            NetworkSim::new_lazy(cfg.net.clone(), fleet.clone(), root.fork(5))
        } else {
            NetworkSim::new(cfg.net.clone(), profiles.clone(), root.fork(5))
        };
        let meter = EnergyMeter::new(cfg.fleet.clients, &cfg.energy);
        let cost = CostModel::new(ModelGeometry {
            tokens: m.tokens,
            batch: m.batch,
            embed_size: m.embed_size,
            block_size: m.block_size,
            depth: m.depth,
            clf_client_size: rt.clf_client_size(cfg.data.classes)?,
            clf_server_size: rt.clf_server_size(cfg.data.classes)?,
        });

        let eval_n = cfg.train.eval_samples.min(test.len());
        let eval_indices: Vec<usize> = (0..eval_n).collect();

        Ok(Harness {
            cfg: cfg.clone(),
            clients,
            server,
            profiles,
            assignments,
            fleet,
            net,
            meter,
            clock: SimClock::new(),
            cost,
            wire: Wire::new(WireCodecKind::from_env_or(cfg.wire)),
            train,
            test,
            eval_indices,
            records: Vec::new(),
            cohort_k,
            pool: BTreeMap::new(),
            pool_stats: PoolStats::default(),
            shards: kept_shards,
            shard_base,
            lat_extremes,
            tracer: Tracer::from_spec(&cfg.trace),
            interrupted: None,
            // audit:allow(wall-clock) -- host-side elapsed-time telemetry only; sim time drives every trajectory-visible decision.
            host_t0: std::time::Instant::now(),
        })
    }

    /// Client `id`'s device profile, independent of participation mode
    /// (eager table or lazy fleet stream — same bits either way).
    pub fn profile(&self, id: usize) -> DeviceProfile {
        if self.profiles.is_empty() {
            self.fleet.profile(id)
        } else {
            self.profiles[id]
        }
    }

    /// The ids participating this round: the whole fleet under
    /// `sample=off`, else the round's cohort — a pure function of
    /// `(seed, round)`, sorted ascending. Never depends on thread
    /// counts, fault history or prior rounds.
    pub fn roster(&self, round: usize) -> Vec<usize> {
        match self.cohort_k {
            None => (0..self.cfg.fleet.clients).collect(),
            Some(k) => sample_cohort(self.cfg.train.seed, round, self.cfg.fleet.clients, k),
        }
    }

    /// Borrow client `id`'s live state (eager vector or materialized
    /// pool entry).
    pub fn client(&self, id: usize) -> &ClientState {
        if self.cohort_k.is_none() {
            &self.clients[id]
        } else {
            self.pool.get(&id).expect("roster member materialized")
        }
    }

    /// Mutable sibling of [`Harness::client`].
    pub fn client_mut(&mut self, id: usize) -> &mut ClientState {
        if self.cohort_k.is_none() {
            &mut self.clients[id]
        } else {
            self.pool.get_mut(&id).expect("roster member materialized")
        }
    }

    /// Eq. 1 depth for client `id` without the eager assignment table.
    fn depth_of(&self, id: usize, total_layers: usize) -> usize {
        match self.cfg.method {
            Method::Sfl => self.cfg.sfl_fixed_depth.clamp(1, total_layers - 1),
            _ => {
                let p = self.fleet.profile(id);
                allocation::depth_for(
                    p.mem_gb,
                    p.latency_s,
                    self.lat_extremes.0,
                    self.lat_extremes.1,
                    &self.cfg.alloc,
                    total_layers,
                )
            }
        }
    }

    /// Ensure every roster member has live training state. A no-op under
    /// `sample=off` (all clients are eager). Under sampled participation,
    /// members of the previous cohort that were not re-drawn are evicted
    /// and new members are materialized — current global prefix, fresh
    /// φ_i, and `missed_rounds = 1` so their first participation pays
    /// the same charged (and fault-prone) resync download a crash
    /// rejoiner pays. Live state therefore stays O(cohort) regardless of
    /// the fleet size.
    pub fn materialize_cohort(&mut self, rt: &Runtime, roster: &[usize]) -> Result<()> {
        if self.cohort_k.is_none() {
            return Ok(());
        }
        let total_layers = rt.model().depth;
        self.pool.retain(|id, _| roster.binary_search(id).is_ok());
        for &id in roster {
            if self.pool.contains_key(&id) {
                continue;
            }
            let depth = self.depth_of(id, total_layers);
            let mut shard_rng = self.shard_base.clone();
            shard_rng.advance(2 * id as u64);
            let shard_rng = shard_rng.fork(id as u64);
            let shard = ClientShard::new(self.shards[id].clone(), shard_rng);
            let mut c = match self.cfg.method {
                Method::SuperSfl => ClientState::new_ssfl(
                    rt,
                    id,
                    depth,
                    self.cfg.data.classes,
                    &self.server.enc,
                    shard,
                    self.cfg.train.lr_client as f32,
                )?,
                _ => ClientState::new_baseline(
                    rt,
                    id,
                    depth,
                    &self.server.enc,
                    shard,
                    self.cfg.train.lr_client as f32,
                )?,
            };
            c.missed_rounds = 1;
            self.pool.insert(id, c);
        }
        self.pool_stats.max_cohort = self.pool_stats.max_cohort.max(roster.len());
        self.pool_stats.max_materialized = self.pool_stats.max_materialized.max(self.pool.len());
        Ok(())
    }

    /// Simulated server compute time for one suffix step of depth `d`.
    pub fn server_step_time(&self, depth: usize) -> f64 {
        self.cost
            .time_s(self.cost.server_step_flops(depth), self.cfg.fleet.server_gflops * 1e9)
    }

    /// Evaluate the current global model on the fixed test subset.
    pub fn eval_global(&mut self, rt: &Runtime) -> Result<f64> {
        let acc = self
            .server
            .evaluate(rt, &self.test, &self.eval_indices)?;
        let t = self
            .cost
            .time_s(self.cost.eval_flops(self.eval_indices.len()), self.cfg.fleet.server_gflops * 1e9);
        self.meter.server_busy(t);
        let t0 = self.clock.now();
        self.clock.advance(t);
        if let Some(tr) = self.tracer.as_mut() {
            tr.track_span(TRACK_SERVER, SpanKind::Eval, t0, t, 0, self.eval_indices.len() as u64);
        }
        Ok(acc)
    }

    /// Churn barrier, shared by all three method loops: dead roster
    /// members sit out (missed_rounds ticks); stale members — crash
    /// rejoiners, or freshly sampled cohort members — download the
    /// current global prefix as one Broadcast frame over the *faulted*
    /// exchange path (retry/backoff, drops, timeouts, corruption all
    /// apply, on a resync-salted lane stream so fault-free trajectories
    /// draw nothing new). On success the client syncs and rejoins. If
    /// the retry budget is exhausted or the frame arrives corrupt, the
    /// client stays down one more round: `missed_rounds` keeps ticking,
    /// the fault is counted, and it retries at its next roster
    /// appearance.
    ///
    /// Returns the sorted ids that failed resync (they sit out this
    /// round) and the fault counters the attempts accrued (fold these
    /// into the round's counters before `finish_round`).
    pub fn resync_roster(
        &mut self,
        round_u: u64,
        roster: &[usize],
        fc: &FaultConfig,
    ) -> (Vec<usize>, FaultCounters) {
        let mut entries: Vec<(usize, f64)> = roster.iter().map(|&id| (id, 0.0)).collect();
        let mut any = false;
        let mut faults = FaultCounters::default();
        let mut sitting_out: Vec<usize> = Vec::new();
        let keep_events = self
            .tracer
            .as_ref()
            .is_some_and(|t| t.lane_events_enabled());
        for (pos, &ci) in roster.iter().enumerate() {
            if fc.is_down(round_u, ci) {
                // Missed round: reset the loss accumulators so stale
                // means never leak into this round's metrics.
                let c = self.client_mut(ci);
                c.begin_round();
                c.missed_rounds += 1;
                continue;
            }
            if self.client(ci).missed_rounds > 0 {
                let prefix_elems = self.client(ci).enc.len();
                let mut lane = self.net.resync_lane(ci, round_u);
                if keep_events {
                    lane.enable_attempt_log();
                }
                let frame_len = self
                    .wire
                    .encode_to(
                        MsgType::Broadcast,
                        &self.server.enc[..prefix_elems],
                        0.0,
                        &mut lane.scratch,
                    )
                    .len() as u64;
                let ex = lane.faulted_download(
                    Framed {
                        wire: frame_len,
                        raw: (prefix_elems * 4) as u64,
                    },
                    0.0,
                );
                entries[pos].1 = ex.time_s();
                let mut synced = false;
                let mut corrupt = false;
                if ex.is_ok() {
                    match self.wire.decode(&lane.scratch.frame) {
                        Ok(dec) => {
                            let c = self.client_mut(ci);
                            c.sync_from_global(&dec.data);
                            c.missed_rounds = 0;
                            synced = true;
                        }
                        Err(_) => {
                            // Delivered but failed the CRC/decode: an
                            // exchange fault, not a programming error.
                            lane.faults.corruptions += 1;
                            corrupt = true;
                        }
                    }
                }
                if keep_events {
                    // Replay the resync timeline onto the client's
                    // track: a `resync` parent over the full faulted
                    // download, the per-attempt retry/backoff detail,
                    // and a corruption instant when the frame arrived
                    // but failed its CRC. The resync phase starts at
                    // the current barrier clock for every rejoiner.
                    let mut buf = TraceBuf::new(true);
                    buf.span(SpanKind::Resync, 0.0, ex.time_s(), frame_len, 0);
                    buf.exchange_spans(0.0, &lane.attempts, frame_len);
                    if corrupt {
                        buf.instant(InstantKind::Corruption, ex.time_s());
                    }
                    let t0 = self.clock.now();
                    if let Some(tr) = self.tracer.as_mut() {
                        tr.drain_lane(ci, t0, &mut buf);
                    }
                }
                if !synced {
                    let c = self.client_mut(ci);
                    c.begin_round();
                    c.missed_rounds += 1;
                    sitting_out.push(ci);
                }
                faults.add(&lane.faults);
                self.net.absorb_lane(&lane);
                any = true;
            }
        }
        if any {
            self.charge_barrier_phase(&entries);
        }
        (sitting_out, faults)
    }

    /// Drain a queue of *round-relative* completion events and return the
    /// barrier time (the straggler max). Comparison-only — f64 max over
    /// non-negative times is order-free — so the result is bit-identical
    /// to the seed's `advance_parallel` fold over the same times, while
    /// the queue is sized by the round's participants, not the fleet.
    fn drain_barrier(events: &mut EventQueue) -> f64 {
        let mut dt = 0.0f64;
        while let Some((t, _)) = events.pop() {
            if t > dt {
                dt = t;
            }
        }
        dt
    }

    /// Merge one round's lane ledgers into the shared accounting, in
    /// client-id order (the determinism contract's merge step), advance
    /// the clock by the straggler max, and return
    /// `(round_dt, busy, fallback_steps, server_steps, faults)` with
    /// `busy` as sorted `(client, busy_s)` pairs.
    ///
    /// The barrier is event-driven: each ledger schedules one
    /// `BranchDone` completion and the drain's comparison max gates the
    /// round. Ledgers for dead (churned-out) or unsampled clients simply
    /// don't exist, so they cost neither an event nor a vector slot.
    pub fn absorb_ledgers(
        &mut self,
        ledgers: &mut [RoundLedger],
    ) -> (f64, Vec<(usize, f64)>, usize, usize, FaultCounters) {
        let round_t0 = self.clock.now();
        let mut busy = Vec::with_capacity(ledgers.len());
        let mut fallback_steps = 0usize;
        let mut server_steps = 0usize;
        let mut faults = FaultCounters::default();
        let mut events = EventQueue::new();
        for l in ledgers.iter_mut() {
            events.schedule(l.branch_s, Event::BranchDone { client: l.client });
            busy.push((l.client, l.busy_s));
            self.meter.add_client_energy(l.client, l.energy_j);
            self.meter.server_busy(l.server_busy_s);
            fallback_steps += l.fallback_steps;
            server_steps += l.server_steps;
            faults.add(&l.faults);
            if let Some(tr) = self.tracer.as_mut() {
                // Lane events are branch-relative; every branch starts
                // at the barrier clock. Ledgers arrive in client-id
                // order (the merge contract), so the drained stream is
                // thread-invariant.
                tr.drain_lane(l.client, round_t0, &mut l.trace);
                tr.fold_client(l.branch_s, l.wire_bytes, l.faults.retries);
            }
        }
        let round_dt = Self::drain_barrier(&mut events);
        if let Some(tr) = self.tracer.as_mut() {
            tr.track_span(
                TRACK_BARRIER,
                SpanKind::BarrierWait,
                round_t0,
                round_dt,
                0,
                ledgers.len() as u64,
            );
        }
        self.clock.advance(round_dt);
        (round_dt, busy, fallback_steps, server_steps, faults)
    }

    /// Charge a barrier phase (resync / aggregation upload / broadcast
    /// download): each listed client transmits for its transfer time and
    /// idles until the slowest one finishes. Entries cover this round's
    /// roster (zero transfer for members that shipped nothing — they
    /// still idle at the barrier, as the eager accounting always did).
    /// Advances the clock; returns the phase dt.
    pub fn charge_barrier_phase(&mut self, entries: &[(usize, f64)]) -> f64 {
        let mut events = EventQueue::new();
        for &(id, t) in entries {
            events.schedule(t, Event::BranchDone { client: id });
        }
        let dt = Self::drain_barrier(&mut events);
        self.clock.advance(dt);
        for &(id, t) in entries {
            let p = self.profile(id);
            self.meter.client(&p, PowerState::Transmit, t);
            self.meter.client(&p, PowerState::Idle, (dt - t).max(0.0));
        }
        dt
    }

    /// Close out a round: charge roster idle, build + store the record,
    /// and return whether the accuracy target was reached. `busy` is the
    /// sorted pairs from [`Harness::absorb_ledgers`]; roster members
    /// without a pair (down, sitting out) idled the whole round.
    #[allow(clippy::too_many_arguments)]
    pub fn finish_round(
        &mut self,
        round: usize,
        round_dt: f64,
        roster: &[usize],
        busy: &[(usize, f64)],
        accuracy: f64,
        fallback_steps: usize,
        server_steps: usize,
        faults: FaultCounters,
    ) -> bool {
        let mut bi = 0usize;
        for &id in roster {
            while bi < busy.len() && busy[bi].0 < id {
                bi += 1;
            }
            let b = if bi < busy.len() && busy[bi].0 == id {
                busy[bi].1
            } else {
                0.0
            };
            let idle = (round_dt - b).max(0.0);
            let p = self.profile(id);
            self.meter.client(&p, PowerState::Idle, idle);
        }
        let mean = |xs: Vec<f64>| {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        let local_losses: Vec<f64> = roster
            .iter()
            .filter_map(|&id| self.client(id).round_local_loss.mean())
            .collect();
        let server_losses: Vec<f64> = roster
            .iter()
            .filter_map(|&id| self.client(id).round_server_loss.mean())
            .collect();
        let round_wire = self.net.round_traffic.total_bytes();
        let round_raw = self.net.round_raw_traffic.total_bytes();
        let straggler = self.tracer.as_mut().map(|t| t.finish_round());
        let rec = RoundRecord {
            round,
            sim_time_s: self.clock.now(),
            accuracy,
            mean_client_loss: mean(local_losses),
            mean_server_loss: mean(server_losses),
            comm_mb: self.net.round_traffic.total_mb(),
            cum_comm_mb: self.net.traffic.total_mb(),
            raw_mb: self.net.round_raw_traffic.total_mb(),
            cum_raw_mb: self.net.raw_traffic.total_mb(),
            compression: if round_wire > 0 {
                round_raw as f64 / round_wire as f64
            } else {
                1.0
            },
            energy_j: self.meter.total_energy_j(),
            fallback_steps,
            server_steps,
            participants: busy.len(),
            timeouts: faults.timeouts,
            drops: faults.drops,
            corruptions: faults.corruptions,
            retries: faults.retries,
            crashes: faults.crashes,
            straggler,
        };
        if self.cfg.progress {
            // Live per-round status on stderr (never stdout — artifact
            // pipes stay clean). Host-side only; no effect on any
            // deterministic output.
            eprintln!(
                "round {:>4}/{}  acc {:.3}  cum {:.2} MB  \
                 faults to:{} dr:{} cor:{} re:{} cr:{}  pool hw {}",
                round,
                self.cfg.train.rounds,
                accuracy,
                rec.cum_comm_mb,
                faults.timeouts,
                faults.drops,
                faults.corruptions,
                faults.retries,
                faults.crashes,
                self.pool_stats.max_materialized,
            );
        }
        self.records.push(rec);
        match self.cfg.train.target_accuracy {
            Some(t) => accuracy >= t,
            None => false,
        }
    }

    /// Assemble the final run metrics.
    pub fn finalize(&mut self) -> RunResult {
        self.meter.finalize(self.clock.now());
        let total = self.clock.now();
        let mut metrics = RunMetrics::from_rounds(
            &self.cfg.name,
            self.cfg.method.as_str(),
            self.records.clone(),
            self.cfg.train.target_accuracy,
            self.meter.total_energy_j(),
            self.meter.avg_power_w(total),
            self.meter.co2_g(),
        );
        metrics.host_wall_s = self.host_t0.elapsed().as_secs_f64();
        metrics.wire_codec = self.wire.label();
        metrics.straggler = self.tracer.as_ref().map(|t| t.run_straggler());
        metrics.interrupted_at = self.interrupted;
        let depths = if self.cohort_k.is_none() {
            self.clients.iter().map(|c| c.depth).collect()
        } else {
            self.pool.values().map(|c| c.depth).collect()
        };
        RunResult {
            metrics,
            depths,
            pool: self.pool_stats,
            trace: self.tracer.take().and_then(|t| {
                if t.lane_events_enabled() {
                    Some(t.into_report())
                } else {
                    None // `summary` keeps the columns, not the stream
                }
            }),
        }
    }
}

/// Run one experiment end to end (the public API).
pub fn run_experiment(rt: &Runtime, cfg: &ExperimentConfig) -> Result<RunResult> {
    let mut h = Harness::prepare(rt, cfg)?;
    match cfg.method {
        Method::SuperSfl => run_ssfl(rt, &mut h)?,
        Method::Sfl => baselines::sfl::run(rt, &mut h)?,
        Method::Dfl => baselines::dfl::run(rt, &mut h)?,
    }
    Ok(h.finalize())
}

/// One SuperSFL client's worker-thread context for a round: exclusive
/// client state, a network-lane fork, lane-local copies of the server
/// suffix + classifier it trains, and the round ledger.
struct SsflLane<'a> {
    client: &'a mut ClientState,
    profile: DeviceProfile,
    srv: &'a mut [f32],
    clf: &'a mut [f32],
    /// Simulated server compute per step for this client's depth.
    srv_time: f64,
    /// Local steps this lane actually runs this round — truncated below
    /// `cfg.train.local_steps` when the fault schedule crashes the
    /// client mid-round.
    steps: usize,
    net: NetLane,
    ledger: RoundLedger,
}

/// One round's lane roster entry, fixed before the fan-out: which client
/// runs, its (Copy) profile, how many steps, and how big its lane-local
/// server suffix is. A pure function of `(roster, fault schedule,
/// resync outcomes)` — never of thread count.
struct LaneSlot {
    ci: usize,
    profile: DeviceProfile,
    srv_len: usize,
    srv_time: f64,
    steps: usize,
}

/// The SuperSFL round loop (paper Alg. 1–3 + §II-D aggregation), executed
/// on the parallel round engine.
fn run_ssfl(rt: &Runtime, h: &mut Harness) -> Result<()> {
    let classes = h.cfg.data.classes;
    let total_layers = rt.model().depth;
    let batch_n = rt.model().batch;
    let dim = rt.model().dim;
    let local_steps = h.cfg.train.local_steps;
    let tpgf_mode = h.cfg.ssfl.tpgf_mode;
    let fuse_via_artifact = h.cfg.ssfl.fuse_via_artifact;
    let lr_server = h.cfg.train.lr_server as f32;
    let server_flops = h.cfg.fleet.server_gflops * 1e9;
    let threads = h.cfg.threads;
    let enc_len = h.server.enc.len();
    let clf_len = h.server.clf_s.len();
    let smashed = h.cost.smashed_bytes(dim);
    let smashed_elems = rt.model().smashed_elems();
    // g_z has the smashed-data shape, so its frame size is known before
    // the server computes it — the exchange timeout roll prices both
    // directions up front.
    let gz_frame_len = h.wire.frame_len(MsgType::ActGrad, smashed_elems);
    let sampled = h.cohort_k.is_some();

    // Persistent per-lane buffers, pooled to the live-lane count and
    // refreshed per round: each lane trains the round-start snapshot of
    // its suffix + classifier and the deltas are merged at the barrier
    // (engine module docs). Under `sample=off` this settles at one
    // buffer per client after round 1 — identical to the seed's eager
    // tables; under sampling it never grows past the cohort.
    let mut lane_srv: Vec<Vec<f32>> = Vec::new();
    let mut lane_clf: Vec<Vec<f32>> = Vec::new();
    let mut enc_snapshot = vec![0.0f32; enc_len];
    let mut clf_snapshot = vec![0.0f32; clf_len];
    // Reusable encode/decode buffers for the barrier frames (aggregation
    // uploads + broadcasts run on the main thread; the per-step frames
    // inside the fan-out use each lane's own scratch).
    let mut bar_scratch = WireScratch::default();
    // The fault schedule (resolved once in `prepare`; inert by default).
    // Aliveness, crash points and quorum are pure functions of
    // (round, schedule), so every fault decision below is identical for
    // any `--threads N`.
    let fc = h.cfg.net.faults.clone();
    // Whether lanes record trace events (File mode). Constant for the
    // run, captured before the fan-out borrows the harness.
    let lane_trace = h.tracer.as_ref().is_some_and(|t| t.lane_events_enabled());

    for round in 1..=h.cfg.train.rounds {
        // Graceful shutdown: a SIGINT/SIGTERM between rounds breaks out
        // here; `main` flushes the partial artifacts and reports the
        // interrupted round.
        if crate::transport::shutdown::requested() {
            h.interrupted = Some(round);
            break;
        }
        let round_u = round as u64;

        // ---- Roster + cohort state (sampled mode materializes here) ----
        let roster = h.roster(round);
        h.materialize_cohort(rt, &roster)?;
        h.net.begin_round();

        // When the server is down for the whole round every exchange
        // times out before touching the lane server state, so the
        // O(clients × |θ|) snapshot refresh + delta merge can be skipped.
        let server_up = h.net.server_available();

        // ---- Churn: dead clients sit out; rejoiners resync first ----
        // On success the client syncs and rejoins (its local classifier
        // φ_i survived the outage, so training resumes immediately —
        // Alg. 3's head is the client's own); see
        // [`Harness::resync_roster`] for the failure semantics.
        let (sitting_out, resync_faults) = h.resync_roster(round_u, &roster, &fc);

        // ---- Lane roster: who actually runs a branch this round ----
        // Down clients, failed resyncs and (under sampling past the
        // dataset size) clients with an empty shard get no lane; the
        // lane set and every surviving lane's RNG stream stay pure
        // functions of (seed, round, client).
        let mut slots: Vec<LaneSlot> = Vec::with_capacity(roster.len());
        for &ci in &roster {
            if fc.is_down(round_u, ci) || sitting_out.binary_search(&ci).is_ok() {
                continue;
            }
            let c = h.client(ci);
            if c.shard.is_empty() {
                continue;
            }
            let steps = fc
                .crash_at(round_u, ci)
                .map(|cr| cr.step.min(local_steps))
                .unwrap_or(local_steps);
            slots.push(LaneSlot {
                ci,
                profile: h.profile(ci),
                srv_len: enc_len - h.server.prefix_len(c.depth),
                srv_time: h.server_step_time(c.depth),
                steps,
            });
        }

        // Pool the lane buffers to the live-lane count and load the
        // round-start snapshots (reused allocations — the resize is a
        // no-op once sizes settle).
        if lane_srv.len() < slots.len() {
            lane_srv.resize_with(slots.len(), Vec::new);
            lane_clf.resize_with(slots.len(), Vec::new);
        }
        for (j, s) in slots.iter().enumerate() {
            lane_srv[j].resize(s.srv_len, 0.0);
            lane_clf[j].resize(clf_len, 0.0);
            if server_up {
                lane_srv[j].copy_from_slice(&h.server.enc[enc_len - s.srv_len..]);
                lane_clf[j].copy_from_slice(&h.server.clf_s);
            }
        }
        let lane_f32: usize = lane_srv[..slots.len()].iter().map(|b| b.len()).sum::<usize>()
            + lane_clf[..slots.len()].iter().map(|b| b.len()).sum::<usize>();
        h.pool_stats.max_lane_f32 = h.pool_stats.max_lane_f32.max(lane_f32);
        if server_up {
            // Round-start snapshots (reused buffers — no fresh allocations).
            enc_snapshot.copy_from_slice(&h.server.enc);
            clf_snapshot.copy_from_slice(&h.server.clf_s);
        }

        // ---- Fan out: every roster branch on a worker thread ----
        let mut ledgers: Vec<RoundLedger> = {
            let Harness {
                clients,
                pool,
                net,
                cost,
                train,
                wire,
                ..
            } = h;
            let cost = &*cost;
            let train = &*train;
            let wire = &*wire;

            // Walk the live client states and the sorted slots together
            // (both ascend by client id), pairing each slot with its
            // exclusive `&mut ClientState` and a pooled lane buffer.
            let states: Box<dyn Iterator<Item = (usize, &mut ClientState)>> = if sampled {
                Box::new(pool.iter_mut().map(|(id, c)| (*id, c)))
            } else {
                Box::new(clients.iter_mut().enumerate())
            };
            let mut lanes: Vec<SsflLane<'_>> = Vec::with_capacity(slots.len());
            let mut srv_it = lane_srv.iter_mut();
            let mut clf_it = lane_clf.iter_mut();
            let mut slot_it = slots.iter().peekable();
            for (ci, client) in states {
                let Some(s) = slot_it.peek() else { break };
                if s.ci != ci {
                    continue;
                }
                let s = slot_it.next().expect("peeked");
                let mut lane_net = net.lane(ci, round_u);
                if lane_trace {
                    lane_net.enable_attempt_log();
                }
                lanes.push(SsflLane {
                    client,
                    profile: s.profile,
                    srv: srv_it.next().expect("lane buffers pooled to slots"),
                    clf: clf_it.next().expect("lane buffers pooled to slots"),
                    srv_time: s.srv_time,
                    steps: s.steps,
                    net: lane_net,
                    ledger: RoundLedger::traced(ci, lane_trace),
                });
            }
            debug_assert!(slot_it.peek().is_none(), "every slot found its state");

            engine::run_lanes(threads, &mut lanes, |lane| {
                let depth = lane.client.depth;
                let srv_time = lane.srv_time;
                lane.client.begin_round();
                for _ in 0..lane.steps {
                    let batch = lane.client.shard.next_batch(train, batch_n);

                    // Phase 1 (always; also the entire fallback step).
                    let local = lane.client.phase1(rt, classes, &batch)?;
                    let t1 = cost.time_s(cost.client_local_flops(depth), lane.profile.flops);
                    let p1_t0 = lane.ledger.branch_s;
                    lane.ledger.work(&lane.profile, t1);
                    lane.ledger.trace.span(SpanKind::LocalUpdate, p1_t0, t1, 0, 0);

                    // Phase 2 attempt: smashed activations up, g_z down,
                    // both as wire frames — the link is charged with the
                    // encoded bytes, the analytic f32 count rides along
                    // as raw. The uplink frame is built (and charged)
                    // even when the exchange times out: the client
                    // transmitted before it could observe the failure.
                    // Frames are staged in the lane's reusable scratch —
                    // identical bytes, zero per-frame allocations.
                    let up_len = wire
                        .encode_to(MsgType::Smashed, &local.z, 0.0, &mut lane.net.scratch)
                        .len() as u64;
                    lane.ledger
                        .trace
                        .span(SpanKind::Encode, lane.ledger.branch_s, 0.0, up_len, 0);
                    let ex_t0 = lane.ledger.branch_s;
                    let ex = lane.net.exchange_framed(
                        Framed {
                            wire: up_len,
                            raw: smashed,
                        },
                        Framed {
                            wire: gz_frame_len,
                            raw: smashed,
                        },
                        srv_time,
                    );
                    lane.ledger.exchange(&lane.profile, ex.time_s(), srv_time);
                    lane.ledger
                        .trace
                        .exchange_spans(ex_t0, &lane.net.attempts, up_len);

                    if ex.is_ok() {
                        // Lane-local server step against the round-start
                        // suffix snapshot (merged at the barrier), on the
                        // server's *decoded* view of the activations.
                        //
                        // A frame that fails the CRC/decode here is an
                        // exchange fault, not a programming error: count
                        // it on the ledger and take the Alg. 3 fallback
                        // instead of aborting the run — the corruption
                        // injector exercises this path end to end.
                        if wire
                            .decode_into(&lane.net.scratch.frame, &mut lane.net.scratch.decoded)
                            .is_err()
                        {
                            lane.net.faults.corruptions += 1;
                            lane.ledger
                                .trace
                                .instant(InstantKind::Corruption, lane.ledger.branch_s);
                            lane.client.fallback_update(&local);
                            lane.ledger.fallback_steps += 1;
                            lane.ledger
                                .trace
                                .span(SpanKind::Fallback, lane.ledger.branch_s, 0.0, 0, 0);
                            continue;
                        }
                        let out = rt.server_step(
                            depth,
                            classes,
                            &*lane.srv,
                            &*lane.clf,
                            &lane.net.scratch.decoded,
                            &batch.y,
                        )?;
                        math::sgd_step(lane.srv, &out.g_srv, lr_server);
                        math::sgd_step(lane.clf, &out.g_clf_s, lr_server);
                        lane.ledger.server_step(srv_time);

                        // The activation gradient comes back as a frame
                        // too; the client backprops the decoded tensor.
                        // The exchange above already charged the link
                        // `gz_frame_len` for this response (priced from
                        // the element count before the tensor existed —
                        // wire::Wire::frame_len is a pure function of
                        // (msg type, elems), pinned by the wire tests),
                        // so a mismatch here means the billed bytes and
                        // the shipped bytes diverged: fail loudly in
                        // every build, not just debug (the seed's
                        // debug_assert silently vanished in release).
                        // aux carries the server-side loss (f32→f64 is
                        // exact) — the TCP transport's clients read
                        // l_server from this slot, and carrying it here
                        // too keeps sim and socket frames byte-equal.
                        let down_len = wire
                            .encode_to(
                                MsgType::ActGrad,
                                &out.g_z,
                                f64::from(out.loss),
                                &mut lane.net.scratch,
                            )
                            .len() as u64;
                        if down_len != gz_frame_len {
                            return Err(crate::Error::Wire(format!(
                                "ActGrad frame is {down_len} bytes but the exchange \
                                 was charged {gz_frame_len} ({smashed_elems} elems, \
                                 codec {}) — frame pricing drifted from encoding",
                                wire.label()
                            )));
                        }
                        if wire
                            .decode_into(&lane.net.scratch.frame, &mut lane.net.scratch.decoded)
                            .is_err()
                        {
                            // The server stepped but the returned g_z
                            // frame was unusable: the client falls back
                            // to its local-only update for this step.
                            lane.net.faults.corruptions += 1;
                            lane.ledger
                                .trace
                                .instant(InstantKind::Corruption, lane.ledger.branch_s);
                            lane.client.fallback_update(&local);
                            lane.ledger.fallback_steps += 1;
                            lane.ledger
                                .trace
                                .span(SpanKind::Fallback, lane.ledger.branch_s, 0.0, 0, 0);
                            continue;
                        }
                        lane.ledger.trace.span(
                            SpanKind::Decode,
                            lane.ledger.branch_s,
                            0.0,
                            gz_frame_len,
                            0,
                        );

                        // Phase 2 client backprop + Phase 3 fusion.
                        lane.client.phase2_phase3(
                            rt,
                            &batch,
                            &local,
                            &lane.net.scratch.decoded,
                            out.loss,
                            tpgf_mode,
                            fuse_via_artifact,
                            total_layers,
                        )?;
                        let t23 = cost.time_s(
                            cost.client_bwd_flops(depth) + cost.tpgf_fuse_flops(depth),
                            lane.profile.flops,
                        );
                        let f_t0 = lane.ledger.branch_s;
                        lane.ledger.work(&lane.profile, t23);
                        lane.ledger.trace.span(SpanKind::Fusion, f_t0, t23, 0, 0);
                    } else {
                        // Fault-tolerant fallback (Alg. 3): local-only update.
                        lane.client.fallback_update(&local);
                        lane.ledger.fallback_steps += 1;
                        lane.ledger
                            .trace
                            .span(SpanKind::Fallback, lane.ledger.branch_s, 0.0, 0, 0);
                    }
                }
                Ok(())
            })?;

            // Barrier: fold lane traffic + fault counters and hand the
            // ledgers out, id order. Mid-round crashers get their crash
            // stamped here, while the lane identity is still at hand.
            lanes
                .into_iter()
                .map(|lane| {
                    net.absorb_lane(&lane.net);
                    let mut ledger = lane.ledger;
                    ledger.faults.add(&lane.net.faults);
                    // Telemetry-only byte attribution: this lane's wire
                    // traffic (the authoritative accounting already
                    // flowed through `absorb_lane` above).
                    ledger.wire_bytes = lane.net.traffic.total_bytes();
                    if fc.crash_at(round_u, ledger.client).is_some() {
                        ledger.faults.crashes += 1;
                        ledger
                            .trace
                            .instant(InstantKind::Crash, ledger.branch_s);
                    }
                    ledger
                })
                .collect()
        };

        let (round_dt, busy, fallback_steps, server_steps, mut faults) =
            h.absorb_ledgers(&mut ledgers);
        faults.add(&resync_faults);

        // ---- Merge lane server deltas into the shared super-network ----
        // (id order; θ[ℓ] += (θ_lane[ℓ] − θ_snapshot[ℓ]) / n_live;
        // all-zero and skipped when the server was down this round)
        //
        // The deltas are **fleet-normalized**: every lane trains the
        // same round-start snapshot, so summing raw deltas applies n×
        // the configured lr_server to the fully-shared suffix layers
        // and the classifier in one stale-gradient step — the
        // amplification behind the server-path divergence at the
        // default lr (the other half of the fix is the τ-clip inside
        // `server_step`; see the native backend docs § server-path
        // stability). With the fixed 1/n factor a layer trained by k
        // lanes moves by (k/n)·mean-of-its-trainers: fully-shared deep
        // layers and the classifier train at exactly lr_server, while
        // shallow suffix layers (held by few lanes under heterogeneous
        // depths) and rounds with timed-out exchanges (zero deltas)
        // are proportionally attenuated — deliberate conservatism:
        // those layers' main training signal is the client-side Eq. 6–8
        // aggregation below, and a lone non-IID trainer should not move
        // a shared layer at full step size. (A per-layer 1/k holder
        // count is the sharper alternative; the validated-stable
        // trajectory uses 1/n.) Deterministic and thread-invariant
        // exactly like the sum was (fixed factor, id-order fold on
        // this thread).
        //
        // Quorum barrier: the merge proceeds only once at least a
        // `quorum` fraction of the round's *live* lanes reported a
        // server-assisted step (mid-round crashers don't report; dead,
        // sitting-out and unsampled clients have no lane). Absence is
        // participant-normalized — the divisor is the live-lane count,
        // not the fleet size — so a surviving cohort moves the shared
        // layers at its own mean step size. With the inert default
        // schedule and `sample=off` every client has a lane, making
        // this bit-identical to the unconditional 1/n merge.
        let n_live = slots.len();
        let reporting = ledgers
            .iter()
            .filter(|l| l.server_steps > 0 && fc.crash_at(round_u, l.client).is_none())
            .count();
        if server_up && n_live > 0 && fc.quorum_met(reporting, n_live) {
            let inv_n = 1.0f32 / n_live as f32;
            for (j, s) in slots.iter().enumerate() {
                if fc.crash_at(round_u, s.ci).is_some() {
                    continue;
                }
                let srv = &lane_srv[j];
                let off = enc_len - srv.len();
                let dst = &mut h.server.enc[off..];
                for ((d, &l), &p) in
                    dst.iter_mut().zip(srv.iter()).zip(enc_snapshot[off..].iter())
                {
                    *d += (l - p) * inv_n;
                }
                for ((d, &l), &p) in h
                    .server
                    .clf_s
                    .iter_mut()
                    .zip(lane_clf[j].iter())
                    .zip(clf_snapshot.iter())
                {
                    *d += (l - p) * inv_n;
                }
            }
        }

        // ---- Collaborative aggregation (Eq. 6–8) ----
        // Each client uploads its whole subnetwork — encoder prefix θ_i
        // plus auxiliary classifier φ_i — as one PrefixUpload frame, with
        // the Eq. 6 loss in the frame header (raw f64 bits: exact under
        // every codec). The server aggregates the *decoded* prefixes, so
        // lossy codecs perturb aggregation end to end. The uplink is
        // charged with the actual frame bytes, classifier included (the
        // seed accounting charged `enc_bytes()` alone).
        let mut agg_entries: Vec<(usize, f64)> = roster.iter().map(|&id| (id, 0.0)).collect();
        // (client id, prefix elems, decoded payload, header loss) per
        // participant — dead, sitting-out and mid-round-crashed clients
        // ship nothing this round (a crasher's next contribution comes
        // after the charged resync on rejoin).
        let mut uploads: Vec<(usize, usize, Vec<f32>, f64)> = Vec::with_capacity(slots.len());
        let agg_t0 = h.clock.now();
        let mut agg_bytes = 0u64;
        for s in &slots {
            let ci = s.ci;
            if fc.crash_at(round_u, ci).is_some() {
                continue;
            }
            let (payload, prefix_elems, loss) = {
                let c = h.client(ci);
                (
                    c.upload_payload(),
                    c.enc.len(),
                    c.aggregation_loss(tpgf_mode, total_layers).unwrap_or(1.0),
                )
            };
            let frame_len = h
                .wire
                .encode_to(MsgType::PrefixUpload, &payload, loss, &mut bar_scratch)
                .len() as u64;
            let t = h.net.bulk_up_framed(
                ci,
                Framed {
                    wire: frame_len,
                    raw: (payload.len() * 4) as u64,
                },
            );
            let pos = roster.binary_search(&ci).expect("slot drawn from roster");
            agg_entries[pos].1 = t;
            agg_bytes += frame_len;
            let dec = h.wire.decode(&bar_scratch.frame)?;
            uploads.push((ci, prefix_elems, dec.data, dec.aux));
        }
        h.charge_barrier_phase(&agg_entries);

        if !uploads.is_empty() {
            let updates: Vec<ClientUpdate<'_>> = uploads
                .iter()
                .map(|(ci, prefix_elems, data, loss)| {
                    let c = h.client(*ci);
                    ClientUpdate {
                        client: c.id,
                        depth: c.depth,
                        params: &data[..*prefix_elems],
                        loss: *loss,
                    }
                })
                .collect();
            h.server
                .aggregate_updates(&updates, h.cfg.ssfl.lambda, h.cfg.ssfl.eps);
            // Aggregation itself: one pass over the encoder on the server.
            let agg_compute = h.cost.time_s(2.0 * enc_len as f64, server_flops);
            h.meter.server_busy(agg_compute);
            h.clock.advance(agg_compute);
        }
        let n_uploads = uploads.len() as u64;
        let agg_dur = h.clock.now() - agg_t0;
        if let Some(tr) = h.tracer.as_mut() {
            tr.track_span(TRACK_SERVER, SpanKind::Aggregate, agg_t0, agg_dur, agg_bytes, n_uploads);
        }

        // ---- Broadcast the refreshed prefixes ----
        // One Broadcast frame per client; the client syncs from the
        // *decoded* tensor. Under fp32 this is bit-identical to syncing
        // from the borrowed global slice; lossy codecs perturb the
        // client's round-start weights here. Clients sharing a depth
        // receive byte-identical frames, so encode/decode once per
        // distinct prefix length and charge each client its copy.
        let mut bc_entries: Vec<(usize, f64)> = roster.iter().map(|&id| (id, 0.0)).collect();
        // (prefix elems, frame bytes, decoded tensor) per distinct depth.
        let mut bc_cache: Vec<(usize, u64, Vec<f32>)> = Vec::new();
        let bc_t0 = h.clock.now();
        let mut bc_bytes = 0u64;
        let mut bc_count = 0u64;
        for s in &slots {
            let ci = s.ci;
            // Dead, sitting-out and mid-round-crashed clients receive no
            // broadcast: they catch up through the charged resync when
            // they rejoin.
            if fc.crash_at(round_u, ci).is_some() {
                continue;
            }
            let prefix_elems = h.client(ci).enc.len();
            let cache_slot = match bc_cache.iter().position(|(e, _, _)| *e == prefix_elems) {
                Some(i) => i,
                None => {
                    let frame_len = h
                        .wire
                        .encode_to(
                            MsgType::Broadcast,
                            &h.server.enc[..prefix_elems],
                            0.0,
                            &mut bar_scratch,
                        )
                        .len() as u64;
                    let dec = h.wire.decode(&bar_scratch.frame)?;
                    bc_cache.push((prefix_elems, frame_len, dec.data));
                    bc_cache.len() - 1
                }
            };
            let frame_bytes = bc_cache[cache_slot].1;
            let t = h.net.bulk_down_framed(
                ci,
                Framed {
                    wire: frame_bytes,
                    raw: (prefix_elems * 4) as u64,
                },
            );
            let pos = roster.binary_search(&ci).expect("slot drawn from roster");
            bc_entries[pos].1 = t;
            bc_bytes += frame_bytes;
            bc_count += 1;
            h.client_mut(ci).sync_from_global(&bc_cache[cache_slot].2);
        }
        h.charge_barrier_phase(&bc_entries);
        let bc_dur = h.clock.now() - bc_t0;
        if let Some(tr) = h.tracer.as_mut() {
            tr.track_span(TRACK_SERVER, SpanKind::Broadcast, bc_t0, bc_dur, bc_bytes, bc_count);
        }

        // ---- Evaluate + record ----
        let acc = h.eval_global(rt)?;
        let hit = h.finish_round(
            round,
            round_dt,
            &roster,
            &busy,
            acc,
            fallback_steps,
            server_steps,
            faults,
        );
        if hit {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn runtime() -> Runtime {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Runtime::load_if_available(&dir)
    }

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default()
            .with_clients(4)
            .with_rounds(2)
            .with_seed(7);
        cfg.data.train_per_class = 20;
        cfg.data.test_total = 100;
        cfg.train.local_steps = 1;
        cfg.train.eval_samples = 100;
        cfg
    }

    #[test]
    fn prepare_builds_consistent_world() {
        let rt = runtime();
        let h = Harness::prepare(&rt, &tiny_cfg()).unwrap();
        assert_eq!(h.clients.len(), 4);
        assert_eq!(h.profiles.len(), 4);
        // Every client's prefix length matches its depth.
        for c in &h.clients {
            let expect: usize = rt.model().enc_layer_sizes[..c.depth].iter().sum();
            assert_eq!(c.enc.len(), expect);
            assert!(c.clf.is_some());
        }
        // Shards cover the training set.
        let total: usize = h.clients.iter().map(|c| c.shard.len()).sum();
        assert_eq!(total, h.train.len());
    }

    #[test]
    fn ssfl_two_rounds_produce_records() {
        let rt = runtime();
        let res = run_experiment(&rt, &tiny_cfg()).unwrap();
        assert_eq!(res.metrics.rounds.len(), 2);
        assert!(res.metrics.total_comm_mb > 0.0);
        assert!(res.metrics.total_raw_mb > 0.0);
        assert!(res.metrics.rounds[0].compression > 0.0);
        assert!(!res.metrics.wire_codec.is_empty());
        assert!(res.metrics.total_sim_time_s > 0.0);
        assert!(res.metrics.total_energy_j > 0.0);
        if std::env::var("SUPERSFL_FAULTS").is_err() {
            // Under an injected chaos schedule a short run may lose any
            // individual round's exchanges; only assert this baseline
            // property on a clean network.
            assert!(res.metrics.rounds[0].server_steps > 0);
        }
        assert!(res.metrics.host_wall_s > 0.0);
        assert_eq!(res.depths.len(), 4);
    }

    #[test]
    fn deterministic_across_runs() {
        let rt = runtime();
        let a = run_experiment(&rt, &tiny_cfg()).unwrap();
        let b = run_experiment(&rt, &tiny_cfg()).unwrap();
        assert_eq!(a.metrics.final_accuracy, b.metrics.final_accuracy);
        assert_eq!(a.metrics.total_comm_mb, b.metrics.total_comm_mb);
        assert_eq!(a.depths, b.depths);
    }

    /// The engine's headline guarantee: `--threads 1` and `--threads N`
    /// produce bit-identical results, for every method.
    #[test]
    fn thread_count_invariance_end_to_end() {
        let rt = runtime();
        for method in [Method::SuperSfl, Method::Sfl, Method::Dfl] {
            let run = |threads: usize| {
                let mut cfg = tiny_cfg().with_method(method);
                cfg.fleet.clients = 5;
                cfg.threads = threads;
                run_experiment(&rt, &cfg).unwrap()
            };
            let a = run(1);
            for threads in [2usize, 3, 8] {
                let b = run(threads);
                assert_eq!(
                    a.metrics.final_accuracy.to_bits(),
                    b.metrics.final_accuracy.to_bits(),
                    "{method:?} threads={threads}"
                );
                assert_eq!(
                    a.metrics.total_energy_j.to_bits(),
                    b.metrics.total_energy_j.to_bits(),
                    "{method:?} threads={threads}"
                );
                assert_eq!(
                    a.metrics.total_comm_mb.to_bits(),
                    b.metrics.total_comm_mb.to_bits(),
                    "{method:?} threads={threads}"
                );
                for (ra, rb) in a.metrics.rounds.iter().zip(b.metrics.rounds.iter()) {
                    assert_eq!(ra.accuracy.to_bits(), rb.accuracy.to_bits());
                    assert_eq!(ra.sim_time_s.to_bits(), rb.sim_time_s.to_bits());
                    assert_eq!(ra.energy_j.to_bits(), rb.energy_j.to_bits());
                    assert_eq!(ra.fallback_steps, rb.fallback_steps);
                    assert_eq!(ra.server_steps, rb.server_steps);
                    assert_eq!(ra.timeouts, rb.timeouts);
                    assert_eq!(ra.drops, rb.drops);
                    assert_eq!(ra.corruptions, rb.corruptions);
                    assert_eq!(ra.retries, rb.retries);
                    assert_eq!(ra.crashes, rb.crashes);
                }
            }
        }
    }

    /// Satellite regression for the aggregation/broadcast accounting fix:
    /// with the fp32 codec and a failure-free network, every round's byte
    /// total must equal exact frame arithmetic — per step one Smashed +
    /// one ActGrad frame, per barrier one PrefixUpload frame (prefix
    /// **plus client classifier**) up and one Broadcast frame (prefix)
    /// down per client. Pins both the encoded and the raw ledgers.
    #[test]
    fn ssfl_round_bytes_match_frame_arithmetic() {
        if std::env::var("SUPERSFL_WIRE").is_ok() {
            return; // the env override changes the frame sizes pinned here
        }
        if std::env::var("SUPERSFL_FAULTS").is_ok() {
            return; // injected drops/retries re-charge frames; the clean
                    // arithmetic below assumes a failure-free network
        }
        let rt = runtime();
        let cfg = tiny_cfg();
        let h = Harness::prepare(&rt, &cfg).unwrap();
        let wire = Wire::new(WireCodecKind::Fp32);
        let se = rt.model().smashed_elems();
        let steps = cfg.train.local_steps as u64;

        let mut wire_bytes = 0u64;
        let mut raw_bytes = 0u64;
        let mut wire_bytes_without_clf = 0u64;
        for c in &h.clients {
            wire_bytes += steps
                * (wire.frame_len(MsgType::Smashed, se) + wire.frame_len(MsgType::ActGrad, se))
                + wire.frame_len(MsgType::PrefixUpload, c.upload_elems())
                + wire.frame_len(MsgType::Broadcast, c.enc.len());
            raw_bytes += steps * 2 * (4 * se as u64)
                + (c.upload_elems() * 4) as u64
                + (c.enc.len() * 4) as u64;
            wire_bytes_without_clf += steps
                * (wire.frame_len(MsgType::Smashed, se) + wire.frame_len(MsgType::ActGrad, se))
                + wire.frame_len(MsgType::PrefixUpload, c.enc.len())
                + wire.frame_len(MsgType::Broadcast, c.enc.len());
        }
        // The uplink must actually include the classifier payload.
        assert!(wire_bytes > wire_bytes_without_clf);

        let res = run_experiment(&rt, &cfg).unwrap();
        let expect_mb = wire_bytes as f64 / 1e6;
        let expect_raw_mb = raw_bytes as f64 / 1e6;
        for r in &res.metrics.rounds {
            assert_eq!(
                r.comm_mb.to_bits(),
                expect_mb.to_bits(),
                "round {} encoded bytes drifted from frame arithmetic",
                r.round
            );
            assert_eq!(
                r.raw_mb.to_bits(),
                expect_raw_mb.to_bits(),
                "round {} raw bytes drifted from the analytic 4·n count",
                r.round
            );
        }
    }

    /// Acceptance: on the stabilized 3-round/8-client native scenario
    /// (the golden scenario — server-suffix τ-clip + participant-
    /// normalized merge, noise 0.4, 8 local steps) the lossy codecs must
    /// cut encoded bytes ≥ 3× while training stays sane, fp32 itself
    /// must pay only frame overhead (ratio just under 1), and int8 must
    /// land a **final accuracy within 10 points of fp32**.
    ///
    /// The final-metric criterion was weakened to "round-2 loss within
    /// 15%" while the native server path diverged at the default
    /// lr_server (pre-fix final accuracies were near-chance with ±10 pt
    /// noise, so any final-accuracy assert was a coin flip). With the
    /// divergence fixed the trajectory is stable — a numpy port of this
    /// exact loop measured fp32 finals of 0.43–0.71 across init
    /// perturbations with |int8 − fp32| ≤ 0.03 — so the real criterion
    /// is restored (10 pts ≥ 3× the observed worst gap), with the exact
    /// int8 trajectory still pinned bit-for-bit by the
    /// `native_ssfl_3r8c_int8.json` golden snapshot.
    #[test]
    fn lossy_codecs_compress_3x_and_int8_matches_fp32_final_metrics() {
        if std::env::var("SUPERSFL_WIRE").is_ok() {
            return; // the env override would pin every run to one codec
        }
        if std::env::var("SUPERSFL_FAULTS").is_ok() {
            return; // the codec-accuracy criteria assume a clean network
        }
        let rt = runtime();
        let mut base = ExperimentConfig::default()
            .with_clients(8)
            .with_rounds(3)
            .with_seed(7);
        base.data.train_per_class = 20;
        base.data.test_total = 400;
        base.data.noise = 0.4;
        base.train.local_steps = 8;
        base.train.eval_samples = 200;

        let run = |w: WireCodecKind| {
            let cfg = base.clone().with_wire(w);
            run_experiment(&rt, &cfg).unwrap().metrics
        };

        let fp32 = run(WireCodecKind::Fp32);
        assert_eq!(fp32.wire_codec, "fp32");
        assert!(
            fp32.compression > 0.99 && fp32.compression <= 1.0,
            "fp32 pays only frame overhead, got ratio {}",
            fp32.compression
        );

        for kind in [WireCodecKind::Int8, WireCodecKind::TopK(10)] {
            let m = run(kind);
            assert_eq!(m.wire_codec, kind.label());
            assert!(
                m.compression >= 3.0,
                "{}: raw {:.3} MB / encoded {:.3} MB = {:.2}× (< 3×)",
                m.wire_codec,
                m.total_raw_mb,
                m.total_comm_mb,
                m.compression
            );
            // Raw traffic is codec-independent: same protocol, same bytes.
            assert_eq!(
                m.total_raw_mb.to_bits(),
                fp32.total_raw_mb.to_bits(),
                "{}: raw ledger must not depend on the codec",
                m.wire_codec
            );
            // Training must stay sane under lossy exchange.
            for r in &m.rounds {
                assert!((0.0..=1.0).contains(&r.accuracy), "{}", m.wire_codec);
                assert!(
                    r.mean_client_loss.is_finite() && r.mean_client_loss > 0.0,
                    "{}: round {} client loss {}",
                    m.wire_codec,
                    r.round,
                    r.mean_client_loss
                );
            }
            if kind == WireCodecKind::Int8 {
                // The restored final-metric criterion (docs above).
                assert!(
                    (m.final_accuracy - fp32.final_accuracy).abs() <= 0.10,
                    "int8 final accuracy {:.3} drifted > 10 pts from fp32 {:.3}",
                    m.final_accuracy,
                    fp32.final_accuracy
                );
                let l_fp32 = fp32.rounds.last().unwrap().mean_client_loss;
                let l_int8 = m.rounds.last().unwrap().mean_client_loss;
                assert!(
                    (l_int8 / l_fp32 - 1.0).abs() <= 0.15,
                    "int8 final loss {l_int8:.4} drifted > 15% from fp32 {l_fp32:.4}"
                );
            }
        }
    }

    /// Codecs are pure functions, so the engine's bit-identity contract
    /// must survive lossy encoding: an int8 run is thread-invariant too.
    #[test]
    fn lossy_codec_runs_are_thread_invariant() {
        if std::env::var("SUPERSFL_WIRE").is_ok() {
            return;
        }
        let rt = runtime();
        let run = |threads: usize| {
            let mut cfg = tiny_cfg().with_wire(WireCodecKind::Int8);
            cfg.fleet.clients = 5;
            cfg.threads = threads;
            run_experiment(&rt, &cfg).unwrap()
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(
            a.metrics.final_accuracy.to_bits(),
            b.metrics.final_accuracy.to_bits()
        );
        assert_eq!(
            a.metrics.total_comm_mb.to_bits(),
            b.metrics.total_comm_mb.to_bits()
        );
        assert_eq!(
            a.metrics.total_raw_mb.to_bits(),
            b.metrics.total_raw_mb.to_bits()
        );
    }

    #[test]
    fn serverless_round_uses_fallback_everywhere() {
        let rt = runtime();
        let mut cfg = tiny_cfg();
        cfg.net.server_availability = 0.0;
        let res = run_experiment(&rt, &cfg).unwrap();
        for r in &res.metrics.rounds {
            assert_eq!(r.server_steps, 0);
            assert!(r.fallback_steps > 0);
        }
    }

    /// Satellite bugfix regression: a corrupted frame on the round hot
    /// path must surface as an exchange fault (ledger count + Alg. 3
    /// fallback), not abort the run. `corrupt=1` flips a payload byte of
    /// every successful uplink frame, so every step either times out or
    /// fails its CRC — and the run still completes all rounds.
    #[test]
    fn corrupted_frames_fall_back_instead_of_aborting() {
        if std::env::var("SUPERSFL_FAULTS").is_ok() {
            return; // this test pins its own schedule
        }
        let rt = runtime();
        let mut cfg = tiny_cfg();
        cfg.net.faults = FaultConfig::parse("corrupt=1").unwrap();
        let res = run_experiment(&rt, &cfg).unwrap();
        assert_eq!(res.metrics.rounds.len(), 2);
        let fallback: usize = res.metrics.rounds.iter().map(|r| r.fallback_steps).sum();
        let corruptions: u64 = res.metrics.rounds.iter().map(|r| r.corruptions).sum();
        assert!(fallback > 0, "corrupted exchanges must take the fallback");
        assert!(corruptions > 0, "CRC failures must be counted");
        assert_eq!(res.metrics.total_corruptions, corruptions);
        // No server step can survive a guaranteed-corrupt uplink.
        assert!(res.metrics.rounds.iter().all(|r| r.server_steps == 0));
    }

    /// Mid-round crash + churn + quorum: the crashed client misses the
    /// barrier, sits out its down window, rejoins via the charged resync,
    /// and the run completes with the crash stamped exactly once.
    #[test]
    fn churn_crash_rejoin_and_quorum_complete_the_run() {
        if std::env::var("SUPERSFL_FAULTS").is_ok() {
            return; // this test pins its own schedule
        }
        let rt = runtime();
        let mut cfg = tiny_cfg();
        cfg.train.rounds = 4;
        cfg.net.faults = FaultConfig::parse("crash=2:1:0:1,quorum=0.5").unwrap();
        let res = run_experiment(&rt, &cfg).unwrap();
        assert_eq!(res.metrics.rounds.len(), 4);
        let crashes: u64 = res.metrics.rounds.iter().map(|r| r.crashes).sum();
        assert_eq!(crashes, 1);
        assert_eq!(res.metrics.total_crashes, 1);
        assert_eq!(res.metrics.rounds[1].crashes, 1, "crash lands in round 2");
        // Accuracy stays a probability through churn.
        for r in &res.metrics.rounds {
            assert!((0.0..=1.0).contains(&r.accuracy));
        }
    }

    #[test]
    fn target_accuracy_stops_early() {
        let rt = runtime();
        let mut cfg = tiny_cfg();
        cfg.train.rounds = 50;
        cfg.train.target_accuracy = Some(0.0); // trivially hit at round 1
        let res = run_experiment(&rt, &cfg).unwrap();
        assert_eq!(res.metrics.rounds.len(), 1);
        assert_eq!(res.metrics.rounds_to_target, Some(1));
    }

    #[test]
    fn full_participation_reports_the_whole_fleet() {
        let rt = runtime();
        let res = run_experiment(&rt, &tiny_cfg()).unwrap();
        if std::env::var("SUPERSFL_FAULTS").is_err() && std::env::var("SUPERSFL_SAMPLE").is_err() {
            for r in &res.metrics.rounds {
                assert_eq!(r.participants, 4);
            }
            // No sampling ⇒ no pooled state.
            assert_eq!(res.pool.max_materialized, 0);
        }
    }

    /// Tentpole: a sampled run completes, each round's participants are
    /// the cohort, and every pooled high-water mark tracks the cohort
    /// size — never the fleet.
    #[test]
    fn sampled_run_completes_and_pools_to_the_cohort() {
        if std::env::var("SUPERSFL_SAMPLE").is_ok() || std::env::var("SUPERSFL_FAULTS").is_ok() {
            return; // this test pins its own participation + schedule
        }
        let rt = runtime();
        let mut cfg = tiny_cfg().with_sample(crate::config::SampleSpec::Count(3));
        cfg.fleet.clients = 8;
        cfg.train.rounds = 3;
        let res = run_experiment(&rt, &cfg).unwrap();
        assert_eq!(res.metrics.rounds.len(), 3);
        for r in &res.metrics.rounds {
            assert_eq!(r.participants, 3, "round {}: clean cohort all runs", r.round);
        }
        assert_eq!(res.pool.max_cohort, 3);
        assert_eq!(res.pool.max_materialized, 3);
        assert!(res.pool.max_lane_f32 > 0);
        assert!(res.depths.len() <= 3);
        assert!(res.metrics.total_comm_mb > 0.0);
        assert!(res.metrics.total_energy_j > 0.0);
    }

    /// The cohort (and the whole sampled trajectory) is a pure function
    /// of (seed, round): two runs are bitwise identical, and so are runs
    /// at different thread counts.
    #[test]
    fn sampled_runs_are_deterministic_and_thread_invariant() {
        if std::env::var("SUPERSFL_SAMPLE").is_ok() {
            return;
        }
        let rt = runtime();
        let run = |threads: usize| {
            let mut cfg = tiny_cfg().with_sample(crate::config::SampleSpec::Count(3));
            cfg.fleet.clients = 6;
            cfg.train.rounds = 3;
            cfg.threads = threads;
            run_experiment(&rt, &cfg).unwrap()
        };
        let a = run(1);
        let a2 = run(1);
        assert_eq!(
            a.metrics.final_accuracy.to_bits(),
            a2.metrics.final_accuracy.to_bits()
        );
        for threads in [2usize, 4] {
            let b = run(threads);
            for (ra, rb) in a.metrics.rounds.iter().zip(b.metrics.rounds.iter()) {
                assert_eq!(ra.accuracy.to_bits(), rb.accuracy.to_bits(), "threads {threads}");
                assert_eq!(ra.sim_time_s.to_bits(), rb.sim_time_s.to_bits());
                assert_eq!(ra.energy_j.to_bits(), rb.energy_j.to_bits());
                assert_eq!(ra.participants, rb.participants);
            }
            assert_eq!(
                a.metrics.total_comm_mb.to_bits(),
                b.metrics.total_comm_mb.to_bits()
            );
        }
    }

    /// Sampled participation works for the baselines too (they share the
    /// harness roster/pool machinery).
    #[test]
    fn sampled_baselines_complete() {
        if std::env::var("SUPERSFL_SAMPLE").is_ok() {
            return;
        }
        let rt = runtime();
        for method in [Method::Sfl, Method::Dfl] {
            let mut cfg = tiny_cfg()
                .with_method(method)
                .with_sample(crate::config::SampleSpec::Count(3));
            cfg.fleet.clients = 8;
            cfg.train.rounds = 3;
            let res = run_experiment(&rt, &cfg).unwrap();
            assert_eq!(res.metrics.rounds.len(), 3, "{method:?}");
            for r in &res.metrics.rounds {
                assert!(r.participants <= 3, "{method:?}");
                assert!((0.0..=1.0).contains(&r.accuracy), "{method:?}");
            }
            assert!(res.pool.max_materialized <= 3, "{method:?}");
        }
    }

    /// Satellite bugfix regression: the rejoin-resync download must ride
    /// the faulted exchange path. Under `corrupt=1` every resync frame
    /// fails its CRC, so the crashed client can never rejoin: it stays
    /// down (participants stay short), `missed_rounds` keeps ticking,
    /// and the corruption is counted — previously `wire.decode(...)?`
    /// aborted the whole run the moment a resync frame was corrupt, and
    /// the download itself was exempt from every fault.
    #[test]
    fn failed_resync_keeps_the_client_down_instead_of_aborting() {
        if std::env::var("SUPERSFL_FAULTS").is_ok() {
            return; // this test pins its own schedule
        }
        let rt = runtime();
        let mut cfg = tiny_cfg();
        cfg.train.rounds = 4;
        cfg.net.faults = FaultConfig::parse("corrupt=1,crash=2:1:0:1").unwrap();
        let res = run_experiment(&rt, &cfg).unwrap();
        assert_eq!(res.metrics.rounds.len(), 4, "the run must complete");
        let participants: Vec<usize> =
            res.metrics.rounds.iter().map(|r| r.participants).collect();
        // Round 2: crash mid-round (the lane still exists). Round 3: the
        // down window. Round 4: rejoin attempt — the resync frame is
        // corrupt, so the client sits out again.
        assert_eq!(participants, vec![4, 4, 3, 3]);
        assert_eq!(res.metrics.total_crashes, 1);
        assert!(
            res.metrics.rounds[3].corruptions >= 1,
            "the failed resync must be counted as a corruption"
        );
    }

    /// The other resync failure mode: every packet drops, the retry
    /// budget exhausts, and the client stays down with drops + retries
    /// counted (no infinite loop, no panic, no free rejoin).
    #[test]
    fn resync_retry_exhaustion_counts_and_keeps_the_client_down() {
        if std::env::var("SUPERSFL_FAULTS").is_ok() {
            return;
        }
        let rt = runtime();
        let mut cfg = tiny_cfg();
        cfg.train.rounds = 4;
        cfg.net.drop_prob = 1.0;
        cfg.net.faults = FaultConfig::parse("retry=2:0.1:2,crash=2:1:0:1").unwrap();
        let res = run_experiment(&rt, &cfg).unwrap();
        assert_eq!(res.metrics.rounds.len(), 4);
        let participants: Vec<usize> =
            res.metrics.rounds.iter().map(|r| r.participants).collect();
        assert_eq!(participants, vec![4, 4, 3, 3]);
        assert!(res.metrics.rounds[3].drops >= 1);
        assert!(res.metrics.rounds[3].retries >= 1);
    }
}
