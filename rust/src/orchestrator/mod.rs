//! The round orchestrator: experiment setup + the SuperSFL training loop.
//!
//! `run_experiment` is the single entry point used by the CLI, examples
//! and benches. It prepares the simulated world (task, non-IID shards,
//! fleet, allocation, network, energy meter, simulated clock) and then
//! dispatches to the method-specific round loop — SuperSFL here, SFL/DFL
//! in [`crate::baselines`]. All three share the same [`Harness`] so their
//! accounting (bytes, simulated time, energy) is identical by
//! construction.
//!
//! Within a round, clients run in parallel both in the modeled system and
//! on the host: each client's branch executes on a worker thread of the
//! [`engine`] (see its module docs for the ledger/lane design, the merge
//! order, and the determinism contract), accumulating its simulated branch
//! time in a private [`engine::RoundLedger`]. At the synchronized
//! aggregation barrier the ledgers are merged in client-id order and the
//! clock advances by the straggler maximum, exactly as in the paper's
//! synchronized-round setting. Results are bit-identical for any
//! `cfg.threads` value.
//!
//! The hot path is allocation-free where it matters: the refreshed global
//! prefix is broadcast to clients from a single borrowed slice of the
//! server encoder (no per-client clone of θ), aggregation runs as a fused
//! in-place per-layer pass (no scratch buffer), and lane snapshots reuse
//! their buffers across rounds.

pub mod engine;

use crate::allocation::{self, Assignment};
use crate::baselines;
use crate::client::ClientState;
use crate::config::{ExperimentConfig, Method};
use crate::data::{dirichlet_partition, ClientShard, Dataset, SyntheticSpec, SyntheticTask};
use crate::energy::{cost::ModelGeometry, CostModel, EnergyMeter, PowerState};
use crate::fedserver::ClientUpdate;
use crate::metrics::{RoundRecord, RunMetrics};
use crate::network::{sample_fleet, DeviceProfile, NetLane, NetworkSim, SimClock};
use crate::runtime::Runtime;
use crate::server::ServerState;
use crate::util::math;
use crate::util::rng::Pcg32;
use crate::Result;

use engine::RoundLedger;

/// Everything a method loop needs, pre-built by [`Harness::prepare`].
pub struct Harness {
    pub cfg: ExperimentConfig,
    pub clients: Vec<ClientState>,
    pub server: ServerState,
    pub profiles: Vec<DeviceProfile>,
    pub assignments: Vec<Assignment>,
    pub net: NetworkSim,
    pub meter: EnergyMeter,
    pub clock: SimClock,
    pub cost: CostModel,
    pub train: Dataset,
    pub test: Dataset,
    /// Fixed test subset evaluated every round.
    pub eval_indices: Vec<usize>,
    pub records: Vec<RoundRecord>,
    /// Host wall-clock anchor (perf reporting, not simulation).
    host_t0: std::time::Instant,
}

/// The result of one experiment run.
pub struct RunResult {
    pub metrics: RunMetrics,
    /// Depth assigned to each client (Eq. 1).
    pub depths: Vec<usize>,
}

impl Harness {
    /// Build the simulated world for a config.
    pub fn prepare(rt: &Runtime, cfg: &ExperimentConfig) -> Result<Harness> {
        cfg.validate()?;
        let m = rt.model().clone();
        let mut root = Pcg32::new(cfg.train.seed, 0xD15EA5E);

        // Task + datasets (shared prototypes across train/test).
        let spec = SyntheticSpec {
            classes: cfg.data.classes,
            image_size: m.image_size,
            channels: m.channels,
            noise: cfg.data.noise,
            max_shift: cfg.data.max_shift,
        };
        let mut data_rng = root.fork(1);
        let task = SyntheticTask::new(spec, &mut data_rng);
        let train = task.generate(cfg.data.train_per_class, &mut data_rng);
        let per_class_test = (cfg.data.test_total / cfg.data.classes).max(1);
        let test = task.generate(per_class_test, &mut data_rng);

        // Non-IID shards.
        let mut part_rng = root.fork(2);
        let shards = dirichlet_partition(
            &train.labels,
            cfg.data.classes,
            cfg.fleet.clients,
            cfg.data.dirichlet_alpha,
            &mut part_rng,
        );

        // Fleet + allocation (Eq. 1). Baselines override depths themselves.
        let mut fleet_rng = root.fork(3);
        let profiles = sample_fleet(&cfg.fleet, &cfg.energy, &mut fleet_rng);
        let assignments = allocation::allocate(&profiles, &cfg.alloc, m.depth);

        let server = ServerState::new(rt, cfg.data.classes, cfg.train.lr_server as f32)?;

        // Clients.
        let mut shard_rng = root.fork(4);
        let mut clients = Vec::with_capacity(cfg.fleet.clients);
        for (i, shard_idx) in shards.into_iter().enumerate() {
            let depth = match cfg.method {
                Method::Sfl => cfg.sfl_fixed_depth.clamp(1, m.depth - 1),
                _ => assignments[i].depth,
            };
            let shard = ClientShard::new(shard_idx, shard_rng.fork(i as u64));
            let c = match cfg.method {
                Method::SuperSfl => ClientState::new_ssfl(
                    rt,
                    i,
                    depth,
                    cfg.data.classes,
                    &server.enc,
                    shard,
                    cfg.train.lr_client as f32,
                )?,
                _ => ClientState::new_baseline(
                    rt,
                    i,
                    depth,
                    &server.enc,
                    shard,
                    cfg.train.lr_client as f32,
                )?,
            };
            clients.push(c);
        }

        let net = NetworkSim::new(cfg.net.clone(), profiles.clone(), root.fork(5));
        let meter = EnergyMeter::new(cfg.fleet.clients, &cfg.energy);
        let cost = CostModel::new(ModelGeometry {
            tokens: m.tokens,
            batch: m.batch,
            embed_size: m.embed_size,
            block_size: m.block_size,
            depth: m.depth,
            clf_client_size: rt.clf_client_size(cfg.data.classes)?,
            clf_server_size: rt.clf_server_size(cfg.data.classes)?,
        });

        let eval_n = cfg.train.eval_samples.min(test.len());
        let eval_indices: Vec<usize> = (0..eval_n).collect();

        Ok(Harness {
            cfg: cfg.clone(),
            clients,
            server,
            profiles,
            assignments,
            net,
            meter,
            clock: SimClock::new(),
            cost,
            train,
            test,
            eval_indices,
            records: Vec::new(),
            host_t0: std::time::Instant::now(),
        })
    }

    /// Simulated server compute time for one suffix step of depth `d`.
    pub fn server_step_time(&self, depth: usize) -> f64 {
        self.cost
            .time_s(self.cost.server_step_flops(depth), self.cfg.fleet.server_gflops * 1e9)
    }

    /// Evaluate the current global model on the fixed test subset.
    pub fn eval_global(&mut self, rt: &Runtime) -> Result<f64> {
        let acc = self
            .server
            .evaluate(rt, &self.test, &self.eval_indices)?;
        let t = self
            .cost
            .time_s(self.cost.eval_flops(self.eval_indices.len()), self.cfg.fleet.server_gflops * 1e9);
        self.meter.server_busy(t);
        self.clock.advance(t);
        Ok(acc)
    }

    /// Merge one round's lane ledgers into the shared accounting, in
    /// client-id order (the determinism contract's merge step), advance
    /// the clock by the straggler max, and return
    /// `(round_dt, busy, fallback_steps, server_steps)`.
    pub fn absorb_ledgers(&mut self, ledgers: &[RoundLedger]) -> (f64, Vec<f64>, usize, usize) {
        let n = self.clients.len();
        let mut busy = vec![0.0f64; n];
        let mut branch = vec![0.0f64; n];
        let mut fallback_steps = 0usize;
        let mut server_steps = 0usize;
        for l in ledgers {
            busy[l.client] = l.busy_s;
            branch[l.client] = l.branch_s;
            self.meter.add_client_energy(l.client, l.energy_j);
            self.meter.server_busy(l.server_busy_s);
            fallback_steps += l.fallback_steps;
            server_steps += l.server_steps;
        }
        let round_dt = self.clock.advance_parallel(&branch);
        (round_dt, busy, fallback_steps, server_steps)
    }

    /// Charge a barrier phase (aggregation upload / broadcast download):
    /// each client transmits for its transfer time and idles until the
    /// slowest client finishes. Advances the clock; returns the phase dt.
    pub fn charge_barrier_phase(&mut self, transfer_s: &[f64]) -> f64 {
        let dt = self.clock.advance_parallel(transfer_s);
        for (i, &t) in transfer_s.iter().enumerate() {
            self.meter
                .client(&self.profiles[i], PowerState::Transmit, t);
            self.meter
                .client(&self.profiles[i], PowerState::Idle, (dt - t).max(0.0));
        }
        dt
    }

    /// Close out a round: charge client idle, build + store the record,
    /// and return whether the accuracy target was reached.
    #[allow(clippy::too_many_arguments)]
    pub fn finish_round(
        &mut self,
        round: usize,
        round_dt: f64,
        busy: &[f64],
        accuracy: f64,
        fallback_steps: usize,
        server_steps: usize,
    ) -> bool {
        for (i, &b) in busy.iter().enumerate() {
            let idle = (round_dt - b).max(0.0);
            self.meter
                .client(&self.profiles[i], PowerState::Idle, idle);
        }
        let mean = |xs: Vec<f64>| {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        let local_losses: Vec<f64> = self
            .clients
            .iter()
            .filter_map(|c| c.round_local_loss.mean())
            .collect();
        let server_losses: Vec<f64> = self
            .clients
            .iter()
            .filter_map(|c| c.round_server_loss.mean())
            .collect();
        let cum_comm = self.net.traffic.total_mb();
        let rec = RoundRecord {
            round,
            sim_time_s: self.clock.now(),
            accuracy,
            mean_client_loss: mean(local_losses),
            mean_server_loss: mean(server_losses),
            comm_mb: self.net.round_traffic.total_mb(),
            cum_comm_mb: cum_comm,
            energy_j: self.meter.total_energy_j(),
            fallback_steps,
            server_steps,
        };
        self.records.push(rec);
        match self.cfg.train.target_accuracy {
            Some(t) => accuracy >= t,
            None => false,
        }
    }

    /// Assemble the final run metrics.
    pub fn finalize(&mut self) -> RunResult {
        self.meter.finalize(self.clock.now());
        let total = self.clock.now();
        let mut metrics = RunMetrics::from_rounds(
            &self.cfg.name,
            self.cfg.method.as_str(),
            self.records.clone(),
            self.cfg.train.target_accuracy,
            self.meter.total_energy_j(),
            self.meter.avg_power_w(total),
            self.meter.co2_g(),
        );
        metrics.host_wall_s = self.host_t0.elapsed().as_secs_f64();
        RunResult {
            metrics,
            depths: self.clients.iter().map(|c| c.depth).collect(),
        }
    }
}

/// Run one experiment end to end (the public API).
pub fn run_experiment(rt: &Runtime, cfg: &ExperimentConfig) -> Result<RunResult> {
    let mut h = Harness::prepare(rt, cfg)?;
    match cfg.method {
        Method::SuperSfl => run_ssfl(rt, &mut h)?,
        Method::Sfl => baselines::sfl::run(rt, &mut h)?,
        Method::Dfl => baselines::dfl::run(rt, &mut h)?,
    }
    Ok(h.finalize())
}

/// One SuperSFL client's worker-thread context for a round: exclusive
/// client state, a network-lane fork, lane-local copies of the server
/// suffix + classifier it trains, and the round ledger.
struct SsflLane<'a> {
    client: &'a mut ClientState,
    profile: &'a DeviceProfile,
    srv: &'a mut [f32],
    clf: &'a mut [f32],
    /// Simulated server compute per step for this client's depth.
    srv_time: f64,
    net: NetLane,
    ledger: RoundLedger,
}

/// The SuperSFL round loop (paper Alg. 1–3 + §II-D aggregation), executed
/// on the parallel round engine.
fn run_ssfl(rt: &Runtime, h: &mut Harness) -> Result<()> {
    let classes = h.cfg.data.classes;
    let total_layers = rt.model().depth;
    let batch_n = rt.model().batch;
    let dim = rt.model().dim;
    let local_steps = h.cfg.train.local_steps;
    let tpgf_mode = h.cfg.ssfl.tpgf_mode;
    let fuse_via_artifact = h.cfg.ssfl.fuse_via_artifact;
    let lr_server = h.cfg.train.lr_server as f32;
    let server_flops = h.cfg.fleet.server_gflops * 1e9;
    let threads = h.cfg.threads;
    let n = h.clients.len();
    let enc_len = h.server.enc.len();
    let clf_len = h.server.clf_s.len();
    let smashed = h.cost.smashed_bytes(dim);
    // SSFL depths are fixed for the run: precompute the per-client server
    // step times through the single shared helper.
    let srv_times: Vec<f64> = h
        .clients
        .iter()
        .map(|c| h.server_step_time(c.depth))
        .collect();

    // Persistent per-lane buffers, allocated once and refreshed per round:
    // each lane trains the round-start snapshot of its suffix + classifier
    // and the deltas are merged at the barrier (engine module docs).
    let mut lane_srv: Vec<Vec<f32>> = h
        .clients
        .iter()
        .map(|c| vec![0.0f32; enc_len - h.server.prefix_len(c.depth)])
        .collect();
    let mut lane_clf: Vec<Vec<f32>> = vec![vec![0.0f32; clf_len]; n];
    let mut enc_snapshot = vec![0.0f32; enc_len];
    let mut clf_snapshot = vec![0.0f32; clf_len];

    for round in 1..=h.cfg.train.rounds {
        h.net.begin_round();

        // When the server is down for the whole round every exchange
        // times out before touching the lane server state, so the
        // O(clients × |θ|) snapshot refresh + delta merge can be skipped.
        let server_up = h.net.server_available();

        if server_up {
            // Round-start snapshots (reused buffers — no fresh allocations).
            enc_snapshot.copy_from_slice(&h.server.enc);
            clf_snapshot.copy_from_slice(&h.server.clf_s);
            for (srv, clf) in lane_srv.iter_mut().zip(lane_clf.iter_mut()) {
                let off = enc_len - srv.len();
                srv.copy_from_slice(&h.server.enc[off..]);
                clf.copy_from_slice(&h.server.clf_s);
            }
        }

        // ---- Fan out: every client branch on a worker thread ----
        let ledgers: Vec<RoundLedger> = {
            let Harness {
                clients,
                profiles,
                net,
                cost,
                train,
                ..
            } = h;
            let cost = &*cost;
            let train = &*train;

            let mut lanes: Vec<SsflLane<'_>> = Vec::with_capacity(n);
            let mut srv_it = lane_srv.iter_mut();
            let mut clf_it = lane_clf.iter_mut();
            for (ci, client) in clients.iter_mut().enumerate() {
                lanes.push(SsflLane {
                    client,
                    profile: &profiles[ci],
                    srv: srv_it.next().expect("lane buffers sized to fleet"),
                    clf: clf_it.next().expect("lane buffers sized to fleet"),
                    srv_time: srv_times[ci],
                    net: net.lane(ci, round as u64),
                    ledger: RoundLedger::new(ci),
                });
            }

            engine::run_lanes(threads, &mut lanes, |lane| {
                let depth = lane.client.depth;
                let srv_time = lane.srv_time;
                lane.client.begin_round();
                for _ in 0..local_steps {
                    let batch = lane.client.shard.next_batch(train, batch_n);

                    // Phase 1 (always; also the entire fallback step).
                    let local = lane.client.phase1(rt, classes, &batch)?;
                    let t1 = cost.time_s(cost.client_local_flops(depth), lane.profile.flops);
                    lane.ledger.work(lane.profile, t1);

                    // Phase 2 attempt: smashed data up, g_z down.
                    let ex = lane.net.exchange(smashed, smashed, srv_time);
                    lane.ledger.exchange(lane.profile, ex.time_s(), srv_time);

                    if ex.is_ok() {
                        // Lane-local server step against the round-start
                        // suffix snapshot (merged at the barrier).
                        let out = rt.server_step(
                            depth,
                            classes,
                            &*lane.srv,
                            &*lane.clf,
                            &local.z,
                            &batch.y,
                        )?;
                        math::sgd_step(lane.srv, &out.g_srv, lr_server);
                        math::sgd_step(lane.clf, &out.g_clf_s, lr_server);
                        lane.ledger.server_step(srv_time);

                        // Phase 2 client backprop + Phase 3 fusion.
                        lane.client.phase2_phase3(
                            rt,
                            &batch,
                            &local,
                            &out.g_z,
                            out.loss,
                            tpgf_mode,
                            fuse_via_artifact,
                            total_layers,
                        )?;
                        let t23 = cost.time_s(
                            cost.client_bwd_flops(depth) + cost.tpgf_fuse_flops(depth),
                            lane.profile.flops,
                        );
                        lane.ledger.work(lane.profile, t23);
                    } else {
                        // Fault-tolerant fallback (Alg. 3): local-only update.
                        lane.client.fallback_update(&local);
                        lane.ledger.fallback_steps += 1;
                    }
                }
                Ok(())
            })?;

            // Barrier: fold lane traffic + hand the ledgers out, id order.
            lanes
                .into_iter()
                .map(|lane| {
                    net.absorb_lane(&lane.net);
                    lane.ledger
                })
                .collect()
        };

        let (round_dt, busy, fallback_steps, server_steps) = h.absorb_ledgers(&ledgers);

        // ---- Merge lane server deltas into the shared super-network ----
        // (id order; θ[ℓ] += θ_lane[ℓ] − θ_snapshot[ℓ]; all-zero and
        // skipped when the server was down this round)
        if server_up {
            for (ci, srv) in lane_srv.iter().enumerate() {
                let off = enc_len - srv.len();
                let dst = &mut h.server.enc[off..];
                for ((d, &l), &p) in
                    dst.iter_mut().zip(srv.iter()).zip(enc_snapshot[off..].iter())
                {
                    *d += l - p;
                }
                for ((d, &l), &p) in h
                    .server
                    .clf_s
                    .iter_mut()
                    .zip(lane_clf[ci].iter())
                    .zip(clf_snapshot.iter())
                {
                    *d += l - p;
                }
            }
        }

        // ---- Collaborative aggregation (Eq. 6–8) ----
        let mut agg_branch = vec![0.0f64; n];
        for ci in 0..n {
            agg_branch[ci] = h.net.bulk_up(ci, h.clients[ci].enc_bytes());
        }
        h.charge_barrier_phase(&agg_branch);

        {
            let updates: Vec<ClientUpdate<'_>> = h
                .clients
                .iter()
                .map(|c| ClientUpdate {
                    client: c.id,
                    depth: c.depth,
                    params: &c.enc,
                    loss: c
                        .aggregation_loss(tpgf_mode, total_layers)
                        .unwrap_or(1.0),
                })
                .collect();
            h.server
                .aggregate_updates(&updates, h.cfg.ssfl.lambda, h.cfg.ssfl.eps);
        }
        // Aggregation itself: one pass over the encoder on the server.
        let agg_compute = h.cost.time_s(2.0 * enc_len as f64, server_flops);
        h.meter.server_busy(agg_compute);
        h.clock.advance(agg_compute);

        // ---- Broadcast the refreshed prefixes ----
        // Zero-copy: every client syncs straight from the borrowed global
        // encoder slice (no per-client clone of θ).
        let mut bc_branch = vec![0.0f64; n];
        for ci in 0..n {
            bc_branch[ci] = h.net.bulk_down(ci, h.clients[ci].enc_bytes());
            h.clients[ci].sync_from_global(&h.server.enc);
        }
        h.charge_barrier_phase(&bc_branch);

        // ---- Evaluate + record ----
        let acc = h.eval_global(rt)?;
        let hit = h.finish_round(round, round_dt, &busy, acc, fallback_steps, server_steps);
        if hit {
            break;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn runtime() -> Runtime {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Runtime::load_if_available(&dir)
    }

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default()
            .with_clients(4)
            .with_rounds(2)
            .with_seed(7);
        cfg.data.train_per_class = 20;
        cfg.data.test_total = 100;
        cfg.train.local_steps = 1;
        cfg.train.eval_samples = 100;
        cfg
    }

    #[test]
    fn prepare_builds_consistent_world() {
        let rt = runtime();
        let h = Harness::prepare(&rt, &tiny_cfg()).unwrap();
        assert_eq!(h.clients.len(), 4);
        assert_eq!(h.profiles.len(), 4);
        // Every client's prefix length matches its depth.
        for c in &h.clients {
            let expect: usize = rt.model().enc_layer_sizes[..c.depth].iter().sum();
            assert_eq!(c.enc.len(), expect);
            assert!(c.clf.is_some());
        }
        // Shards cover the training set.
        let total: usize = h.clients.iter().map(|c| c.shard.len()).sum();
        assert_eq!(total, h.train.len());
    }

    #[test]
    fn ssfl_two_rounds_produce_records() {
        let rt = runtime();
        let res = run_experiment(&rt, &tiny_cfg()).unwrap();
        assert_eq!(res.metrics.rounds.len(), 2);
        assert!(res.metrics.total_comm_mb > 0.0);
        assert!(res.metrics.total_sim_time_s > 0.0);
        assert!(res.metrics.total_energy_j > 0.0);
        assert!(res.metrics.rounds[0].server_steps > 0);
        assert!(res.metrics.host_wall_s > 0.0);
        assert_eq!(res.depths.len(), 4);
    }

    #[test]
    fn deterministic_across_runs() {
        let rt = runtime();
        let a = run_experiment(&rt, &tiny_cfg()).unwrap();
        let b = run_experiment(&rt, &tiny_cfg()).unwrap();
        assert_eq!(a.metrics.final_accuracy, b.metrics.final_accuracy);
        assert_eq!(a.metrics.total_comm_mb, b.metrics.total_comm_mb);
        assert_eq!(a.depths, b.depths);
    }

    /// The engine's headline guarantee: `--threads 1` and `--threads N`
    /// produce bit-identical results, for every method.
    #[test]
    fn thread_count_invariance_end_to_end() {
        let rt = runtime();
        for method in [Method::SuperSfl, Method::Sfl, Method::Dfl] {
            let run = |threads: usize| {
                let mut cfg = tiny_cfg().with_method(method);
                cfg.fleet.clients = 5;
                cfg.threads = threads;
                run_experiment(&rt, &cfg).unwrap()
            };
            let a = run(1);
            for threads in [2usize, 3, 8] {
                let b = run(threads);
                assert_eq!(
                    a.metrics.final_accuracy.to_bits(),
                    b.metrics.final_accuracy.to_bits(),
                    "{method:?} threads={threads}"
                );
                assert_eq!(
                    a.metrics.total_energy_j.to_bits(),
                    b.metrics.total_energy_j.to_bits(),
                    "{method:?} threads={threads}"
                );
                assert_eq!(
                    a.metrics.total_comm_mb.to_bits(),
                    b.metrics.total_comm_mb.to_bits(),
                    "{method:?} threads={threads}"
                );
                for (ra, rb) in a.metrics.rounds.iter().zip(b.metrics.rounds.iter()) {
                    assert_eq!(ra.accuracy.to_bits(), rb.accuracy.to_bits());
                    assert_eq!(ra.sim_time_s.to_bits(), rb.sim_time_s.to_bits());
                    assert_eq!(ra.energy_j.to_bits(), rb.energy_j.to_bits());
                    assert_eq!(ra.fallback_steps, rb.fallback_steps);
                    assert_eq!(ra.server_steps, rb.server_steps);
                }
            }
        }
    }

    #[test]
    fn serverless_round_uses_fallback_everywhere() {
        let rt = runtime();
        let mut cfg = tiny_cfg();
        cfg.net.server_availability = 0.0;
        let res = run_experiment(&rt, &cfg).unwrap();
        for r in &res.metrics.rounds {
            assert_eq!(r.server_steps, 0);
            assert!(r.fallback_steps > 0);
        }
    }

    #[test]
    fn target_accuracy_stops_early() {
        let rt = runtime();
        let mut cfg = tiny_cfg();
        cfg.train.rounds = 50;
        cfg.train.target_accuracy = Some(0.0); // trivially hit at round 1
        let res = run_experiment(&rt, &cfg).unwrap();
        assert_eq!(res.metrics.rounds.len(), 1);
        assert_eq!(res.metrics.rounds_to_target, Some(1));
    }
}
