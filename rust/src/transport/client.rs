//! The client-process side of the TCP transport.
//!
//! `run_client` builds the same deterministic world as the server
//! (verified by the config fingerprint in the `Hello` handshake), keeps
//! exactly one [`ClientState`] of it — its own — and follows the
//! server's round protocol: Phase 1 on its shard, `Smashed` frames up,
//! `ActGrad` (or `Nack`) frames back, Phase 2/3 fusion, one
//! `PrefixUpload` + `RoundEnd` report at the barrier, then the
//! `Broadcast` resync of its prefix.
//!
//! The client holds no clock, no ledger and no fault machinery: the
//! server's replicated simulator prices everything. What the client
//! *does* own is the training math the sim ran in-process — the bytes
//! it ships are the bytes the sim would have shipped, so a fault-free
//! run is trajectory-identical across transports.
//!
//! Failure behavior mirrors Alg. 3's conservatism: a `Nack` (the
//! server's deterministic timeout pricing, or a corrupt uplink) and a
//! CRC-failed `ActGrad` both take the local-only fallback update; a
//! CRC-failed `Broadcast` keeps the stale prefix rather than aborting.
//! After a crash, re-running `run_client` re-dials, and the `HelloAck`
//! carries resume coordinates: the shard-RNG fast-forward count that
//! realigns batch draws with the server's shadow, plus the resync
//! broadcast. (The rejoiner's φ_i head is freshly initialized — the sim
//! keeps φ_i across an outage, the real world lost the process; see the
//! README's divergence notes.)

use crate::client::ClientState;
use crate::config::ExperimentConfig;
use crate::orchestrator::Harness;
use crate::runtime::Runtime;
use crate::transport::proto::{self, Hello, HelloAck, RoundEnd, RoundStart};
use crate::transport::tcp::{self, Conn};
use crate::transport::{shutdown, world_fingerprint, Transport};
use crate::wire::{MsgType, WireScratch};
use crate::{Error, Result};

/// Deterministic kill switch for the reconnect e2e tests: the client
/// process exits (code 41) at the top of the given round/step, before
/// drawing a batch or sending a frame — a reproducible stand-in for a
/// real mid-round crash.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChaosExit {
    /// 1-based round to die in.
    pub round: u32,
    /// 0-based step within that round.
    pub step: u32,
}

/// Exit code `ChaosExit` dies with, so the test harness can tell a
/// scheduled kill from a genuine failure.
pub const CHAOS_EXIT_CODE: i32 = 41;

impl ChaosExit {
    /// Parse `round:step` (e.g. `2:1` = die in round 2 before step 1).
    pub fn parse(s: &str) -> Result<ChaosExit> {
        let err = || {
            Error::Config(format!(
                "invalid --chaos-exit '{s}' (expected round:step, e.g. 2:0)"
            ))
        };
        let (r, st) = s.trim().split_once(':').ok_or_else(err)?;
        Ok(ChaosExit {
            round: r.trim().parse().map_err(|_| err())?,
            step: st.trim().parse().map_err(|_| err())?,
        })
    }
}

/// Run one client process: dial `addr`, hand-shake into the fleet, and
/// follow the server's round protocol until `Bye` (or a graceful
/// shutdown signal).
pub fn run_client(
    rt: &Runtime,
    cfg: &ExperimentConfig,
    addr: &str,
    client_id: usize,
    chaos: Option<ChaosExit>,
) -> Result<()> {
    // Build the identical deterministic world the server builds (same
    // shards, same init, same wire codec), then keep only this
    // client's slice of it.
    let h = Harness::prepare(rt, cfg)?;
    if client_id >= h.cfg.fleet.clients {
        return Err(Error::Config(format!(
            "--client-id {client_id} out of range: the fleet has {} clients",
            h.cfg.fleet.clients
        )));
    }
    let fnv = world_fingerprint(&h.cfg);
    let classes = h.cfg.data.classes;
    let batch_n = rt.model().batch;
    let total_layers = rt.model().depth;
    let tpgf_mode = h.cfg.ssfl.tpgf_mode;
    let fuse_via_artifact = h.cfg.ssfl.fuse_via_artifact;
    let Harness {
        mut clients,
        train,
        wire,
        ..
    } = h;
    let mut client: ClientState = clients.swap_remove(client_id);
    drop(clients);
    let mut scratch = WireScratch::default();
    let mut gz = Vec::new();

    let mut conn = Conn::dial(addr, tcp::DEFAULT_DIAL_TIMEOUT)?;
    conn.send(
        &Hello {
            client_id: client_id as u32,
            config_fnv: fnv,
        }
        .encode(),
    )?;
    let ack = HelloAck::decode(&conn.recv()?)?;

    // Resume coordinates: replay this shard's RNG draws up to where the
    // server's shadow stands, so the labels behind every future Smashed
    // frame match the shadow's books draw for draw.
    for _ in 0..ack.ff_draws {
        let _ = client.shard.next_batch(&train, batch_n);
    }
    if ack.resync {
        let frame = conn.recv()?;
        let dec = wire.decode(&frame)?;
        if dec.msg != MsgType::Broadcast {
            return Err(Error::Wire(format!(
                "expected the resync Broadcast after HelloAck, got {}",
                dec.msg.as_str()
            )));
        }
        client.sync_from_global(&dec.data);
    }
    eprintln!(
        "transport: client {client_id} joined at round {} (ff {} draws, resync {})",
        ack.next_round, ack.ff_draws, ack.resync
    );

    loop {
        if shutdown::requested() {
            // Graceful exit: the server sees the closed socket and takes
            // the churn path; rejoining later resumes via HelloAck.
            eprintln!("transport: client {client_id} shutting down on signal");
            return Ok(());
        }
        let frame = conn.recv()?;
        match proto::msg_of(&frame)? {
            MsgType::RoundStart => {
                let rs = RoundStart::decode(&frame)?;
                client.begin_round();
                let mut fallback_steps = 0u64;
                let mut corruptions = 0u64;
                for step in 0..rs.steps {
                    if let Some(cx) = chaos {
                        if cx.round == rs.round && cx.step == step {
                            eprintln!(
                                "transport: client {client_id} chaos-exit at \
                                 round {}:{step}",
                                rs.round
                            );
                            // Deliberate hard kill: the crash-recovery
                            // tests need a worker that dies without
                            // unwinding or flushing.
                            #[allow(clippy::exit)]
                            std::process::exit(CHAOS_EXIT_CODE);
                        }
                    }
                    let batch = client.shard.next_batch(&train, batch_n);
                    let local = client.phase1(rt, classes, &batch)?;
                    let up = wire.encode_to(MsgType::Smashed, &local.z, 0.0, &mut scratch);
                    conn.send(up)?;
                    let reply = conn.recv()?;
                    match proto::msg_of(&reply)? {
                        MsgType::ActGrad => match wire.decode_into(&reply, &mut gz) {
                            Ok(head) => {
                                // aux carries l_server (f64 holding an
                                // exact f32) — the same value the sim's
                                // in-process loop hands to the fusion.
                                client.phase2_phase3(
                                    rt,
                                    &batch,
                                    &local,
                                    &gz,
                                    head.aux as f32,
                                    tpgf_mode,
                                    fuse_via_artifact,
                                    total_layers,
                                )?;
                            }
                            Err(_) => {
                                // The gradient frame failed its CRC on a
                                // real wire: fall back, count it, keep
                                // going — never abort the run.
                                corruptions += 1;
                                client.fallback_update(&local);
                                fallback_steps += 1;
                            }
                        },
                        MsgType::Nack => {
                            // The server's deterministic pricing failed
                            // this exchange (timeout class) or the
                            // uplink arrived corrupt: Alg. 3 fallback,
                            // same as the sim twin.
                            client.fallback_update(&local);
                            fallback_steps += 1;
                        }
                        other => {
                            return Err(Error::Wire(format!(
                                "expected ActGrad or Nack mid-step, got {}",
                                other.as_str()
                            )));
                        }
                    }
                }

                // ---- Barrier: subnetwork upload + round report ----
                let payload = client.upload_payload();
                let loss = client
                    .aggregation_loss(tpgf_mode, total_layers)
                    .unwrap_or(1.0);
                let up = wire.encode_to(MsgType::PrefixUpload, &payload, loss, &mut scratch);
                conn.send(up)?;
                let (local_sum, local_n) = client.round_local_loss.raw();
                let (server_sum, server_n) = client.round_server_loss.raw();
                conn.send(
                    &RoundEnd {
                        local_sum,
                        local_n,
                        server_sum,
                        server_n,
                        fallback_steps,
                        corruptions,
                    }
                    .encode(),
                )?;
            }
            MsgType::Broadcast => match wire.decode(&frame) {
                Ok(dec) => client.sync_from_global(&dec.data),
                Err(e) => {
                    // Corrupt broadcast: train on from the stale prefix
                    // (the next round's broadcast heals it) rather than
                    // dying — mirrors the sim's resync failure path.
                    eprintln!(
                        "transport: client {client_id} kept stale weights \
                         (broadcast decode failed: {e})"
                    );
                }
            },
            MsgType::Bye => {
                eprintln!("transport: client {client_id} done (server said bye)");
                return Ok(());
            }
            other => {
                return Err(Error::Wire(format!(
                    "unexpected {} frame between rounds",
                    other.as_str()
                )));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_exit_parses_and_rejects() {
        assert_eq!(
            ChaosExit::parse("2:1").unwrap(),
            ChaosExit { round: 2, step: 1 }
        );
        assert_eq!(
            ChaosExit::parse(" 10:0 ").unwrap(),
            ChaosExit { round: 10, step: 0 }
        );
        for bad in ["", "2", "2:", ":1", "a:b", "1:2:3"] {
            assert!(ChaosExit::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }
}
