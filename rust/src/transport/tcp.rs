//! Blocking TCP connection speaking the `wire::frame` envelope.
//!
//! One [`Conn`] per peer: frames go out through the bounded
//! [`WriteBuf`] staging (plus the kernel send-buffer's own
//! backpressure), and come back through the incremental [`FrameReader`]
//! — partial reads, coalesced frames and adversarial segment boundaries
//! are all handled by the reassembler, never by ad-hoc socket logic.
//!
//! Failure surface: read timeouts, EOF (peer died), reset connections
//! and framing violations all return [`crate::Error`] — the protocol
//! loops map them onto the PR 6 fault classes (timeout/drop/crash) and
//! take the recovery path instead of aborting.

use std::io::Read;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use super::framing::{FrameReader, WriteBuf};
use super::{frame_is_control, Transport};
use crate::{Error, Result};

/// Read timeout on an established connection. Generous: a client waits
/// on `RoundStart` while the server runs eval + barriers for the whole
/// fleet; a dead peer surfaces as EOF/reset long before this fires.
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(120);
/// How long a client keeps re-dialing the server before giving up
/// (covers a server still binding, and reconnect-after-kill).
pub const DEFAULT_DIAL_TIMEOUT: Duration = Duration::from_secs(30);
/// Per-peer write staging bound (frames above this write straight
/// through; below it they coalesce into one syscall).
const WRITE_STAGE_BYTES: usize = 256 * 1024;

/// One framed peer connection.
#[derive(Debug)]
pub struct Conn {
    stream: TcpStream,
    reader: FrameReader,
    wbuf: WriteBuf,
    data_in: u64,
    data_out: u64,
    ctl_in: u64,
    ctl_out: u64,
}

impl Conn {
    pub fn new(stream: TcpStream, read_timeout: Duration) -> Result<Conn> {
        stream.set_nodelay(true).map_err(Error::Io)?;
        stream
            .set_read_timeout(Some(read_timeout))
            .map_err(Error::Io)?;
        stream
            .set_write_timeout(Some(read_timeout))
            .map_err(Error::Io)?;
        Ok(Conn {
            stream,
            reader: FrameReader::new(),
            wbuf: WriteBuf::with_capacity(WRITE_STAGE_BYTES),
            data_in: 0,
            data_out: 0,
            ctl_in: 0,
            ctl_out: 0,
        })
    }

    /// Dial `addr`, retrying with a short sleep until `timeout` elapses
    /// — the fleet races the server's bind, and a reconnecting client
    /// races the server's round boundary.
    pub fn dial(addr: &str, timeout: Duration) -> Result<Conn> {
        let deadline = Instant::now() + timeout;
        let mut last: Option<std::io::Error> = None;
        loop {
            let addrs: Vec<_> = addr
                .to_socket_addrs()
                .map_err(|e| Error::Config(format!("transport address '{addr}': {e}")))?
                .collect();
            for sa in &addrs {
                match TcpStream::connect_timeout(sa, Duration::from_secs(2)) {
                    Ok(s) => return Conn::new(s, DEFAULT_READ_TIMEOUT),
                    Err(e) => last = Some(e),
                }
            }
            if Instant::now() >= deadline {
                return Err(Error::Io(last.unwrap_or_else(|| {
                    std::io::Error::new(std::io::ErrorKind::TimedOut, "connect timed out")
                })));
            }
            std::thread::sleep(Duration::from_millis(100));
        }
    }

    /// Control-frame bytes moved (telemetry; excluded from the
    /// cross-validated data ledger).
    pub fn control_bytes(&self) -> (u64, u64) {
        (self.ctl_in, self.ctl_out)
    }

    /// Framing rejections observed on this connection.
    pub fn frame_errors(&self) -> u64 {
        self.reader.errors()
    }
}

impl Transport for Conn {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        // Stage + flush every frame: the protocol is request/response,
        // so latency beats batching; the bound still protects the
        // broadcast fan-out path if a caller queues without flushing.
        self.wbuf.queue(&mut self.stream, frame)?;
        self.wbuf.flush(&mut self.stream)?;
        if frame_is_control(frame) {
            self.ctl_out += frame.len() as u64;
        } else {
            self.data_out += frame.len() as u64;
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut chunk = [0u8; 64 * 1024];
        loop {
            if let Some(frame) = self.reader.poll()? {
                if frame_is_control(&frame) {
                    self.ctl_in += frame.len() as u64;
                } else {
                    self.data_in += frame.len() as u64;
                }
                return Ok(frame);
            }
            let n = self.stream.read(&mut chunk).map_err(Error::Io)?;
            if n == 0 {
                return Err(Error::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    if self.reader.pending() > 0 {
                        "peer closed mid-frame"
                    } else {
                        "peer closed"
                    },
                )));
            }
            self.reader.feed(&chunk[..n]);
        }
    }

    fn data_bytes_out(&self) -> u64 {
        self.data_out
    }

    fn data_bytes_in(&self) -> u64 {
        self.data_in
    }
}

/// In-memory loopback transport (a pair of byte queues), used by unit
/// tests to drive the protocol logic without sockets — the second
/// implementor that keeps the [`Transport`] surface honest.
#[derive(Debug, Default)]
pub struct Loopback {
    inbox: std::collections::VecDeque<Vec<u8>>,
    outbox: std::collections::VecDeque<Vec<u8>>,
    data_in: u64,
    data_out: u64,
}

impl Loopback {
    pub fn new() -> Loopback {
        Loopback::default()
    }

    /// Test harness side: deliver a frame into the inbox.
    pub fn deliver(&mut self, frame: Vec<u8>) {
        self.inbox.push_back(frame);
    }

    /// Test harness side: take what the code under test sent.
    pub fn take_sent(&mut self) -> Option<Vec<u8>> {
        self.outbox.pop_front()
    }
}

impl Transport for Loopback {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        if !frame_is_control(frame) {
            self.data_out += frame.len() as u64;
        }
        self.outbox.push_back(frame.to_vec());
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        match self.inbox.pop_front() {
            Some(f) => {
                if !frame_is_control(&f) {
                    self.data_in += f.len() as u64;
                }
                Ok(f)
            }
            None => Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::WouldBlock,
                "loopback inbox empty",
            ))),
        }
    }

    fn data_bytes_out(&self) -> u64 {
        self.data_out
    }

    fn data_bytes_in(&self) -> u64 {
        self.data_in
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::{write_frame, MsgType};
    use std::io::Write;
    use std::net::TcpListener;

    /// Frames survive a real socket under adversarial write chunking:
    /// the sender dribbles bytes in tiny writes, the receiver's
    /// incremental reader reassembles them byte-identically.
    #[test]
    fn socket_roundtrip_under_one_byte_writes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let frames = vec![
            write_frame(MsgType::Smashed, 0, 3, 0.5, &[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]),
            write_frame(MsgType::Hello, 0, 0, 0.0, &[0xEE; 12]),
            write_frame(MsgType::Broadcast, 2, 16, 0.0, &[0x42; 24]),
        ];
        let sent = frames.clone();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_nodelay(true).unwrap();
            for f in &sent {
                for b in f {
                    s.write_all(std::slice::from_ref(b)).unwrap();
                }
            }
        });
        let (stream, _) = listener.accept().unwrap();
        let mut conn = Conn::new(stream, Duration::from_secs(10)).unwrap();
        for want in &frames {
            let got = conn.recv().unwrap();
            assert_eq!(&got, want);
        }
        writer.join().unwrap();
        // Ledger classification: Smashed + Broadcast are data, Hello is
        // control.
        assert_eq!(
            conn.data_bytes_in(),
            (frames[0].len() + frames[2].len()) as u64
        );
        assert_eq!(conn.control_bytes().0, frames[1].len() as u64);
        assert_eq!(conn.frame_errors(), 0);
    }

    #[test]
    fn peer_death_mid_frame_is_an_error_not_a_hang() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let f = write_frame(MsgType::Smashed, 0, 4, 0.0, &[7u8; 16]);
            s.write_all(&f[..10]).unwrap(); // die mid-frame
        });
        let (stream, _) = listener.accept().unwrap();
        let mut conn = Conn::new(stream, Duration::from_secs(10)).unwrap();
        writer.join().unwrap();
        assert!(conn.recv().is_err());
    }

    #[test]
    fn dial_times_out_against_a_dead_address() {
        // Port 1 on loopback: nothing listens there in this container.
        let err = Conn::dial("127.0.0.1:1", Duration::from_millis(200));
        assert!(err.is_err());
    }

    #[test]
    fn loopback_implements_the_same_surface() {
        let mut lb = Loopback::new();
        let data = write_frame(MsgType::ActGrad, 0, 2, 0.0, &[1, 2, 3, 4, 5, 6, 7, 8]);
        lb.deliver(data.clone());
        assert_eq!(lb.recv().unwrap(), data);
        assert_eq!(lb.data_bytes_in(), data.len() as u64);
        lb.send(&super::super::proto::bye()).unwrap();
        assert_eq!(lb.data_bytes_out(), 0); // control excluded
        assert!(lb.take_sent().is_some());
        assert!(lb.recv().is_err());
    }
}
