//! The served SuperSFL round loop: real sockets under the sim's ledger.
//!
//! `run_served` is the transport twin of the orchestrator's `run_ssfl`.
//! The two processes split the work along the paper's own seam:
//!
//! * the **client process** runs the client-side math for real — Phase 1
//!   on its own shard, the Phase 2/3 fusion, its φ_i head — and ships
//!   the exact frames the simulator prices (Smashed up, subnetwork
//!   PrefixUpload at the barrier);
//! * the **server process** keeps the replicated world: the full
//!   [`Harness`] with its network simulator, energy meter, clock and
//!   fault counters, the authoritative super-network, and one *shadow*
//!   [`ClientState`] per peer. A shadow never trains θ_i — it exists to
//!   replay the deterministic parts the accounting needs: the label
//!   draws of the client's RNG stream (bit-equal by construction), the
//!   prefix geometry, and the loss accumulators injected from each
//!   round-end report.
//!
//! Every exchange the socket carries is *also* priced through the
//! simulator via [`crate::network::NetLane::exchange_observed`] — the
//! same arithmetic `exchange_framed` runs, minus the fault roll
//! (reality already decided delivery). A fault-free loopback run
//! therefore reproduces the in-process trajectory **bit for bit**:
//! same round records, same byte ledger, and the measured socket data
//! bytes equal the simulator's framed ledger
//! ([`TransportStats::sim_wire_bytes`] is stamped for the cross-check).
//!
//! Socket faults map onto the recovery vocabulary the fault-injection
//! release introduced:
//!
//! | socket event                   | recovery path                        |
//! |--------------------------------|--------------------------------------|
//! | recv/send fails mid-round      | drop + crash counters, lane stops    |
//! | dead peer at the next boundary | no lane (like a churned-out client)  |
//! | reconnect `Hello`              | charged resync via `resync_roster`   |
//! | frame fails CRC                | corruption counter + `Nack` fallback |
//! | deterministic timeout pricing  | `Nack` → client's Alg. 3 fallback    |
//! | too few lanes report           | quorum barrier gates the merge       |

use std::net::{TcpListener, TcpStream};

use crate::client::ClientState;
use crate::config::ExperimentConfig;
use crate::fedserver::ClientUpdate;
use crate::network::{DeviceProfile, Framed, NetLane};
use crate::orchestrator::engine::{self, RoundLedger};
use crate::orchestrator::{Harness, RunResult};
use crate::runtime::Runtime;
use crate::trace::{InstantKind, SpanKind, TRACK_SERVER};
use crate::transport::proto::{self, Hello, HelloAck, RoundEnd, RoundStart};
use crate::transport::tcp::{self, Conn};
use crate::transport::{shutdown, world_fingerprint, Transport};
use crate::util::json::JsonValue;
use crate::util::math;
use crate::wire::{MsgType, WireScratch};
use crate::{Error, Result};

/// Socket-side counters for one served run, reported next to the run
/// metrics and cross-validated against the simulator's byte ledger.
#[derive(Clone, Copy, Debug, Default)]
pub struct TransportStats {
    /// Data-frame bytes received over sockets (Smashed + PrefixUpload).
    pub data_bytes_in: u64,
    /// Data-frame bytes sent over sockets (ActGrad + Broadcast,
    /// including reconnect resync broadcasts).
    pub data_bytes_out: u64,
    /// Control-frame bytes both ways (Hello/HelloAck/RoundStart/
    /// RoundEnd/Bye/Nack) — protocol overhead the simulator does not
    /// price.
    pub ctl_bytes: u64,
    /// Frames the incremental readers rejected (CRC, header, bounds).
    pub frame_errors: u64,
    /// Reconnects admitted mid-run; each rides the charged
    /// `resync_roster` path the simulator's crash rejoiners pay.
    pub resyncs: u64,
    /// Rounds whose merge was gated because too few live lanes reported
    /// (the quorum barrier holding against absent peers).
    pub quorum_holds: u64,
    /// The simulator's own framed byte ledger at the end of the run
    /// (up + down). In a fault-free run
    /// `data_bytes_in + data_bytes_out == sim_wire_bytes`.
    pub sim_wire_bytes: u64,
}

impl TransportStats {
    pub fn to_json(&self, spec_label: &str) -> JsonValue {
        let n = |v: u64| JsonValue::Number(v as f64);
        let mut o = JsonValue::object();
        o.set("spec", JsonValue::String(spec_label.to_string()));
        o.set("socket_data_bytes_in", n(self.data_bytes_in));
        o.set("socket_data_bytes_out", n(self.data_bytes_out));
        o.set("socket_ctl_bytes", n(self.ctl_bytes));
        o.set("frame_errors", n(self.frame_errors));
        o.set("resyncs", n(self.resyncs));
        o.set("quorum_holds", n(self.quorum_holds));
        o.set("sim_wire_bytes", n(self.sim_wire_bytes));
        o
    }

    /// Fold a finished (or dying) connection's byte ledgers in. Called
    /// before a connection is dropped so mid-run deaths don't lose
    /// their traffic from the cross-check.
    fn retire(&mut self, conn: &Conn) {
        self.data_bytes_in += conn.data_bytes_in();
        self.data_bytes_out += conn.data_bytes_out();
        let (ci, co) = conn.control_bytes();
        self.ctl_bytes += ci + co;
        self.frame_errors += conn.frame_errors();
    }
}

/// One connected client's worker-thread context for a round: its shadow
/// state, its socket, lane-local server buffers and the round ledger —
/// the TCP twin of the orchestrator's `SsflLane`.
struct TcpLane<'a> {
    shadow: &'a mut ClientState,
    conn: &'a mut Conn,
    profile: DeviceProfile,
    srv: &'a mut Vec<f32>,
    clf: &'a mut Vec<f32>,
    srv_time: f64,
    steps: usize,
    net: NetLane,
    ledger: RoundLedger,
    round: u32,
    /// Shadow batch draws this round (folded into the server's
    /// fast-forward table so a rejoiner can resume the RNG stream).
    draws: u64,
    /// The socket died mid-round: the lane stops where the sim's
    /// mid-round crash would, and the peer is retired at the barrier.
    dead: bool,
    /// The client's PrefixUpload frame, received at end of round and
    /// consumed by the main-thread aggregation barrier.
    upload: Option<Vec<u8>>,
}

/// Round-roster entry (the TCP twin of the orchestrator's `LaneSlot`):
/// fixed before the fan-out from connectivity + shard geometry alone.
struct Slot {
    ci: usize,
    profile: DeviceProfile,
    srv_len: usize,
    srv_time: f64,
    steps: usize,
}

/// Handshake one fresh socket: read `Hello`, verify the peer built the
/// same world, reply `HelloAck`. Returns the admitted client id and its
/// connection; the caller picks the resume coordinates (`next_round`,
/// the shard-RNG fast-forward count) and whether a resync follows.
fn handshake(
    stream: TcpStream,
    fnv: u64,
    fleet: usize,
    next_round: u32,
    draws: &[u64],
) -> Result<(usize, Conn)> {
    let mut conn = Conn::new(stream, tcp::DEFAULT_READ_TIMEOUT)?;
    let hello = Hello::decode(&conn.recv()?)?;
    let ci = hello.client_id as usize;
    if ci >= fleet {
        return Err(Error::Config(format!(
            "hello from client id {ci} but the fleet has {fleet} clients"
        )));
    }
    if hello.config_fnv != fnv {
        return Err(Error::Config(format!(
            "client {ci} built a different world (config fingerprint {:016x}, server has \
             {:016x}) — every process must run the exact same config",
            hello.config_fnv, fnv
        )));
    }
    conn.send(
        &HelloAck {
            next_round,
            ff_draws: draws[ci],
            resync: next_round > 1,
        }
        .encode(),
    )?;
    Ok((ci, conn))
}

/// Run the SuperSFL experiment as the server process: bind `addr`, wait
/// for the whole fleet to say `Hello`, then drive the round protocol
/// over sockets while the replicated simulator keeps the books.
pub fn run_served(
    rt: &Runtime,
    cfg: &ExperimentConfig,
    addr: &str,
) -> Result<(RunResult, TransportStats)> {
    let mut h = Harness::prepare(rt, cfg)?;
    let fleet = h.cfg.fleet.clients;
    let fnv = world_fingerprint(&h.cfg);
    let mut stats = TransportStats::default();
    let mut draws = vec![0u64; fleet];

    let listener = TcpListener::bind(addr)?;
    eprintln!("transport: serving on {addr}, waiting for {fleet} clients (world {fnv:016x})");
    let mut conns: Vec<Option<Conn>> = (0..fleet).map(|_| None).collect();
    while conns.iter().any(|c| c.is_none()) {
        let (stream, peer) = listener.accept()?;
        // Fleet assembly is strict: a bad handshake here is a
        // misconfigured launch, not survivable churn.
        let (ci, conn) = handshake(stream, fnv, fleet, 1, &draws)?;
        if let Some(old) = conns[ci].take() {
            stats.retire(&old);
        }
        eprintln!("transport: client {ci} connected from {peer}");
        conns[ci] = Some(conn);
    }
    // Reconnects are drained non-blockingly at round boundaries.
    listener.set_nonblocking(true)?;

    // ---- The run constants, exactly as `run_ssfl` resolves them ----
    let classes = h.cfg.data.classes;
    let batch_n = rt.model().batch;
    let dim = rt.model().dim;
    let local_steps = h.cfg.train.local_steps;
    let lr_server = h.cfg.train.lr_server as f32;
    let server_flops = h.cfg.fleet.server_gflops * 1e9;
    let threads = h.cfg.threads;
    let enc_len = h.server.enc.len();
    let clf_len = h.server.clf_s.len();
    let smashed = h.cost.smashed_bytes(dim);
    let smashed_elems = rt.model().smashed_elems();
    let gz_frame_len = h.wire.frame_len(MsgType::ActGrad, smashed_elems);
    let fc = h.cfg.net.faults.clone();
    let lane_trace = h.tracer.as_ref().is_some_and(|t| t.lane_events_enabled());

    let mut lane_srv: Vec<Vec<f32>> = Vec::new();
    let mut lane_clf: Vec<Vec<f32>> = Vec::new();
    let mut enc_snapshot = vec![0.0f32; enc_len];
    let mut clf_snapshot = vec![0.0f32; clf_len];
    let mut bar_scratch = WireScratch::default();

    for round in 1..=h.cfg.train.rounds {
        if shutdown::requested() {
            h.interrupted = Some(round);
            break;
        }
        let round_u = round as u64;

        // ---- Reconnects: drain the listener at the round boundary ----
        // An admitted rejoiner got resume coordinates in its HelloAck
        // (the shard-RNG fast-forward count the shadow stands at) and
        // now receives the physical resync broadcast; flagging the
        // shadow stale makes `resync_roster` below charge exactly this
        // download — the same priced path the sim's crash rejoiners
        // take. (At round 1 nothing has moved yet: a re-dial is a plain
        // admit, no resync.)
        loop {
            let stream = match listener.accept() {
                Ok((stream, _)) => stream,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e.into()),
            };
            match handshake(stream, fnv, fleet, round as u32, &draws) {
                Ok((ci, mut conn)) => {
                    if round > 1 {
                        let prefix_elems = h.client(ci).enc.len();
                        let frame = h
                            .wire
                            .encode_to(
                                MsgType::Broadcast,
                                &h.server.enc[..prefix_elems],
                                0.0,
                                &mut bar_scratch,
                            )
                            .to_vec();
                        if let Err(e) = conn.send(&frame) {
                            eprintln!("transport: client {ci} died during resync: {e}");
                            stats.retire(&conn);
                            continue;
                        }
                        // Stale like a crash rejoiner: the charged
                        // resync below clears it (kept at 0 while
                        // disconnected so absent peers never charge
                        // phantom resyncs).
                        h.client_mut(ci).missed_rounds = 1;
                        stats.resyncs += 1;
                    }
                    if let Some(old) = conns[ci].take() {
                        stats.retire(&old);
                    }
                    conns[ci] = Some(conn);
                    eprintln!("transport: client {ci} reconnected at round {round}");
                }
                Err(e) => eprintln!("transport: rejected connection: {e}"),
            }
        }

        let roster = h.roster(round);
        h.materialize_cohort(rt, &roster)?;
        h.net.begin_round();
        let server_up = h.net.server_available();

        // Charged resync for this round's rejoiners — identical path
        // (and identical pricing) to the sim's churn barrier.
        let (sitting_out, resync_faults) = h.resync_roster(round_u, &roster, &fc);

        // ---- Lane roster: connected peers with data ----
        let mut slots: Vec<Slot> = Vec::with_capacity(roster.len());
        for &ci in &roster {
            if conns[ci].is_none() || sitting_out.binary_search(&ci).is_ok() {
                continue;
            }
            let c = h.client(ci);
            if c.shard.is_empty() {
                continue;
            }
            slots.push(Slot {
                ci,
                profile: h.profile(ci),
                srv_len: enc_len - h.server.prefix_len(c.depth),
                srv_time: h.server_step_time(c.depth),
                steps: local_steps,
            });
        }

        if lane_srv.len() < slots.len() {
            lane_srv.resize_with(slots.len(), Vec::new);
            lane_clf.resize_with(slots.len(), Vec::new);
        }
        for (j, s) in slots.iter().enumerate() {
            lane_srv[j].resize(s.srv_len, 0.0);
            lane_clf[j].resize(clf_len, 0.0);
            if server_up {
                lane_srv[j].copy_from_slice(&h.server.enc[enc_len - s.srv_len..]);
                lane_clf[j].copy_from_slice(&h.server.clf_s);
            }
        }
        let lane_f32: usize = lane_srv[..slots.len()].iter().map(|b| b.len()).sum::<usize>()
            + lane_clf[..slots.len()].iter().map(|b| b.len()).sum::<usize>();
        h.pool_stats.max_lane_f32 = h.pool_stats.max_lane_f32.max(lane_f32);
        if server_up {
            enc_snapshot.copy_from_slice(&h.server.enc);
            clf_snapshot.copy_from_slice(&h.server.clf_s);
        }

        // ---- Fan out: one lane per connected peer ----
        // Folds to `(ledger, dead, upload frame, shadow draws)` per
        // lane, slot order (== client-id order).
        let folded: Vec<(RoundLedger, bool, Option<Vec<u8>>, u64)> = {
            let Harness {
                clients,
                net,
                cost,
                train,
                wire,
                ..
            } = &mut h;
            let cost = &*cost;
            let train = &*train;
            let wire = &*wire;

            let mut lanes: Vec<TcpLane<'_>> = Vec::with_capacity(slots.len());
            let mut srv_it = lane_srv.iter_mut();
            let mut clf_it = lane_clf.iter_mut();
            let mut slot_it = slots.iter().peekable();
            for ((ci, shadow), conn) in clients.iter_mut().enumerate().zip(conns.iter_mut()) {
                let Some(s) = slot_it.peek() else { break };
                if s.ci != ci {
                    continue;
                }
                let s = slot_it.next().expect("peeked");
                let mut lane_net = net.lane(ci, round_u);
                if lane_trace {
                    lane_net.enable_attempt_log();
                }
                lanes.push(TcpLane {
                    shadow,
                    conn: conn.as_mut().expect("slots only cover connected peers"),
                    profile: s.profile,
                    srv: srv_it.next().expect("lane buffers pooled to slots"),
                    clf: clf_it.next().expect("lane buffers pooled to slots"),
                    srv_time: s.srv_time,
                    steps: s.steps,
                    net: lane_net,
                    ledger: RoundLedger::traced(ci, lane_trace),
                    round: round as u32,
                    draws: 0,
                    dead: false,
                    upload: None,
                });
            }
            debug_assert!(slot_it.peek().is_none(), "every slot found its peer");

            engine::run_lanes(threads, &mut lanes, |lane| {
                let depth = lane.shadow.depth;
                let srv_time = lane.srv_time;
                lane.shadow.begin_round();
                if lane
                    .conn
                    .send(
                        &RoundStart {
                            round: lane.round,
                            steps: lane.steps as u32,
                        }
                        .encode(),
                    )
                    .is_err()
                {
                    lane.dead = true;
                    return Ok(());
                }
                for _ in 0..lane.steps {
                    // Shadow draw: the same RNG stream the client's own
                    // shard advances, so labels (and the fast-forward
                    // count a rejoiner resumes from) stay in lockstep.
                    let batch = lane.shadow.shard.next_batch(train, batch_n);
                    lane.draws += 1;

                    // Phase 1 runs on the client process; its cost is
                    // priced here exactly as the sim prices it.
                    let t1 = cost.time_s(cost.client_local_flops(depth), lane.profile.flops);
                    let p1_t0 = lane.ledger.branch_s;
                    lane.ledger.work(&lane.profile, t1);
                    lane.ledger.trace.span(SpanKind::LocalUpdate, p1_t0, t1, 0, 0);

                    // The uplink frame size is a pure function of
                    // (msg, elems) — priced before (and whether or not)
                    // the bytes actually arrive, exactly like the sim.
                    let up_len = wire.frame_len(MsgType::Smashed, smashed_elems);
                    let up_frame = match lane.conn.recv() {
                        Ok(f) => f,
                        Err(_) => {
                            // The socket died mid-exchange: price it as
                            // the drop fault class (uplink charged, no
                            // response) and stop the lane where a sim
                            // mid-round crash would.
                            let ex = lane.net.exchange_observed(
                                Framed {
                                    wire: up_len,
                                    raw: smashed,
                                },
                                Framed {
                                    wire: gz_frame_len,
                                    raw: smashed,
                                },
                                srv_time,
                                false,
                            );
                            lane.ledger.exchange(&lane.profile, ex.time_s(), srv_time);
                            lane.dead = true;
                            return Ok(());
                        }
                    };
                    if proto::msg_of(&up_frame)? != MsgType::Smashed {
                        return Err(Error::Wire(format!(
                            "client {} sent a {} frame where Smashed was due",
                            lane.ledger.client,
                            proto::msg_of(&up_frame)?.as_str()
                        )));
                    }
                    if up_frame.len() as u64 != up_len {
                        return Err(Error::Wire(format!(
                            "client {} Smashed frame is {} bytes but the exchange is \
                             priced at {up_len} — frame pricing drifted from encoding",
                            lane.ledger.client,
                            up_frame.len()
                        )));
                    }
                    lane.ledger
                        .trace
                        .span(SpanKind::Encode, lane.ledger.branch_s, 0.0, up_len, 0);
                    let ex_t0 = lane.ledger.branch_s;
                    let ex = lane.net.exchange_observed(
                        Framed {
                            wire: up_len,
                            raw: smashed,
                        },
                        Framed {
                            wire: gz_frame_len,
                            raw: smashed,
                        },
                        srv_time,
                        true,
                    );
                    lane.ledger.exchange(&lane.profile, ex.time_s(), srv_time);
                    lane.ledger
                        .trace
                        .exchange_spans(ex_t0, &lane.net.attempts, up_len);

                    if ex.is_ok() {
                        if wire
                            .decode_into(&up_frame, &mut lane.net.scratch.decoded)
                            .is_err()
                        {
                            // Smashed frame corrupt end to end: an
                            // exchange fault, not an abort. Nack tells
                            // the client to take its Alg. 3 fallback for
                            // this step (it reports the fallback in its
                            // RoundEnd, which overwrites this ledger's
                            // fallback count below).
                            lane.net.faults.corruptions += 1;
                            lane.ledger
                                .trace
                                .instant(InstantKind::Corruption, lane.ledger.branch_s);
                            if lane.conn.send(&proto::nack()).is_err() {
                                lane.dead = true;
                                return Ok(());
                            }
                            lane.ledger
                                .trace
                                .span(SpanKind::Fallback, lane.ledger.branch_s, 0.0, 0, 0);
                            continue;
                        }
                        let out = rt.server_step(
                            depth,
                            classes,
                            &*lane.srv,
                            &*lane.clf,
                            &lane.net.scratch.decoded,
                            &batch.y,
                        )?;
                        math::sgd_step(lane.srv, &out.g_srv, lr_server);
                        math::sgd_step(lane.clf, &out.g_clf_s, lr_server);
                        lane.ledger.server_step(srv_time);
                        // aux carries l_server (f32→f64 exact) in the
                        // same slot the sim loop fills — sim and socket
                        // ActGrad frames are byte-identical.
                        let frame = wire.encode_to(
                            MsgType::ActGrad,
                            &out.g_z,
                            f64::from(out.loss),
                            &mut lane.net.scratch,
                        );
                        if frame.len() as u64 != gz_frame_len {
                            return Err(Error::Wire(format!(
                                "ActGrad frame is {} bytes but the exchange was charged \
                                 {gz_frame_len} — frame pricing drifted from encoding",
                                frame.len()
                            )));
                        }
                        if lane.conn.send(frame).is_err() {
                            lane.dead = true;
                            return Ok(());
                        }
                        lane.ledger.trace.span(
                            SpanKind::Decode,
                            lane.ledger.branch_s,
                            0.0,
                            gz_frame_len,
                            0,
                        );
                        let t23 = cost.time_s(
                            cost.client_bwd_flops(depth) + cost.tpgf_fuse_flops(depth),
                            lane.profile.flops,
                        );
                        let f_t0 = lane.ledger.branch_s;
                        lane.ledger.work(&lane.profile, t23);
                        lane.ledger.trace.span(SpanKind::Fusion, f_t0, t23, 0, 0);
                    } else {
                        // Deterministic pricing says this exchange timed
                        // out. The physical reply is withheld (Nack) so
                        // the client takes the same Alg. 3 fallback its
                        // sim twin takes — the replicated worlds stay in
                        // lockstep even under timeout-tight configs.
                        if lane.conn.send(&proto::nack()).is_err() {
                            lane.dead = true;
                            return Ok(());
                        }
                        lane.ledger
                            .trace
                            .span(SpanKind::Fallback, lane.ledger.branch_s, 0.0, 0, 0);
                    }
                }

                // ---- End of round: subnetwork upload + report ----
                let up_frame = match lane.conn.recv() {
                    Ok(f) => f,
                    Err(_) => {
                        lane.dead = true;
                        return Ok(());
                    }
                };
                if proto::msg_of(&up_frame)? != MsgType::PrefixUpload {
                    return Err(Error::Wire(format!(
                        "client {} sent a {} frame where PrefixUpload was due",
                        lane.ledger.client,
                        proto::msg_of(&up_frame)?.as_str()
                    )));
                }
                let re_frame = match lane.conn.recv() {
                    Ok(f) => f,
                    Err(_) => {
                        lane.dead = true;
                        return Ok(());
                    }
                };
                let re = RoundEnd::decode(&re_frame)?;
                // Inject the client's exact loss accumulators into the
                // shadow: `finish_round` and the Eq. 6 aggregation read
                // the same f64 folds the sim's in-process client builds.
                lane.shadow
                    .round_local_loss
                    .inject_raw(re.local_sum, re.local_n);
                lane.shadow
                    .round_server_loss
                    .inject_raw(re.server_sum, re.server_n);
                lane.ledger.fallback_steps = re.fallback_steps as usize;
                // Client-side decode failures are invisible to the
                // server's own counters; the report carries them.
                lane.net.faults.corruptions += re.corruptions;
                lane.upload = Some(up_frame);
                Ok(())
            })?;

            lanes
                .into_iter()
                .map(|lane| {
                    net.absorb_lane(&lane.net);
                    let mut ledger = lane.ledger;
                    ledger.faults.add(&lane.net.faults);
                    ledger.wire_bytes = lane.net.traffic.total_bytes();
                    if lane.dead {
                        // A mid-round socket death is the crash fault
                        // class; stamped at the barrier like the sim's
                        // schedule-driven crashers.
                        ledger.faults.crashes += 1;
                        ledger.trace.instant(InstantKind::Crash, ledger.branch_s);
                    }
                    (ledger, lane.dead, lane.upload, lane.draws)
                })
                .collect()
        };

        let mut ledgers: Vec<RoundLedger> = Vec::with_capacity(folded.len());
        let mut dead: Vec<bool> = Vec::with_capacity(folded.len());
        let mut upload_frames: Vec<Option<Vec<u8>>> = Vec::with_capacity(folded.len());
        for (ledger, d, upload, dr) in folded {
            draws[ledger.client] += dr;
            if d {
                eprintln!(
                    "transport: client {} dropped mid-round {round}; \
                     continuing via the recovery path",
                    ledger.client
                );
                if let Some(old) = conns[ledger.client].take() {
                    stats.retire(&old);
                }
            }
            ledgers.push(ledger);
            dead.push(d);
            upload_frames.push(upload);
        }

        let (round_dt, busy, fallback_steps, server_steps, mut faults) =
            h.absorb_ledgers(&mut ledgers);
        faults.add(&resync_faults);

        // ---- Merge lane server deltas (quorum-gated, 1/n_live) ----
        // Identical arithmetic to the sim loop; dead lanes play the
        // role of its mid-round crashers (no report, no merge).
        let n_live = slots.len();
        let reporting = ledgers
            .iter()
            .zip(dead.iter())
            .filter(|(l, d)| l.server_steps > 0 && !**d)
            .count();
        let quorum_ok = fc.quorum_met(reporting, n_live);
        if server_up && n_live > 0 && !quorum_ok {
            stats.quorum_holds += 1;
            eprintln!(
                "transport: quorum held at round {round} ({reporting}/{n_live} lanes reported)"
            );
        }
        if server_up && n_live > 0 && quorum_ok {
            let inv_n = 1.0f32 / n_live as f32;
            for j in 0..slots.len() {
                if dead[j] {
                    continue;
                }
                let srv = &lane_srv[j];
                let off = enc_len - srv.len();
                let dst = &mut h.server.enc[off..];
                for ((d, &l), &p) in dst.iter_mut().zip(srv.iter()).zip(enc_snapshot[off..].iter())
                {
                    *d += (l - p) * inv_n;
                }
                for ((d, &l), &p) in h
                    .server
                    .clf_s
                    .iter_mut()
                    .zip(lane_clf[j].iter())
                    .zip(clf_snapshot.iter())
                {
                    *d += (l - p) * inv_n;
                }
            }
        }

        // ---- Collaborative aggregation (Eq. 6–8) over received frames ----
        // The sim builds each PrefixUpload frame from its in-process
        // client; here the frame arrived over the socket. Pricing and
        // decode are identical — and the frame length is checked against
        // the priced length, failing loudly if the worlds diverged.
        let mut agg_entries: Vec<(usize, f64)> = roster.iter().map(|&id| (id, 0.0)).collect();
        let mut uploads: Vec<(usize, usize, Vec<f32>, f64)> = Vec::with_capacity(slots.len());
        let agg_t0 = h.clock.now();
        let mut agg_bytes = 0u64;
        for (j, s) in slots.iter().enumerate() {
            if dead[j] {
                continue;
            }
            let Some(frame) = upload_frames[j].take() else {
                continue;
            };
            let ci = s.ci;
            let (prefix_elems, upload_elems) = {
                let c = h.client(ci);
                (c.enc.len(), c.upload_elems())
            };
            let frame_len = frame.len() as u64;
            let priced = h.wire.frame_len(MsgType::PrefixUpload, upload_elems);
            if frame_len != priced {
                return Err(Error::Wire(format!(
                    "client {ci} PrefixUpload frame is {frame_len} bytes but its \
                     subnetwork prices at {priced} — replicated worlds diverged"
                )));
            }
            let t = h.net.bulk_up_framed(
                ci,
                Framed {
                    wire: frame_len,
                    raw: (upload_elems * 4) as u64,
                },
            );
            let pos = roster.binary_search(&ci).expect("slot drawn from roster");
            agg_entries[pos].1 = t;
            agg_bytes += frame_len;
            let dec = h.wire.decode(&frame)?;
            uploads.push((ci, prefix_elems, dec.data, dec.aux));
        }
        h.charge_barrier_phase(&agg_entries);

        if !uploads.is_empty() {
            let updates: Vec<ClientUpdate<'_>> = uploads
                .iter()
                .map(|(ci, prefix_elems, data, loss)| {
                    let c = h.client(*ci);
                    ClientUpdate {
                        client: c.id,
                        depth: c.depth,
                        params: &data[..*prefix_elems],
                        loss: *loss,
                    }
                })
                .collect();
            h.server
                .aggregate_updates(&updates, h.cfg.ssfl.lambda, h.cfg.ssfl.eps);
            let agg_compute = h.cost.time_s(2.0 * enc_len as f64, server_flops);
            h.meter.server_busy(agg_compute);
            h.clock.advance(agg_compute);
        }
        let n_uploads = uploads.len() as u64;
        let agg_dur = h.clock.now() - agg_t0;
        if let Some(tr) = h.tracer.as_mut() {
            tr.track_span(
                TRACK_SERVER,
                SpanKind::Aggregate,
                agg_t0,
                agg_dur,
                agg_bytes,
                n_uploads,
            );
        }

        // ---- Broadcast the refreshed prefixes, physically ----
        // Peers sharing a depth receive byte-identical frames: encode
        // once per distinct prefix length, ship each its copy, charge
        // each its copy.
        let mut bc_entries: Vec<(usize, f64)> = roster.iter().map(|&id| (id, 0.0)).collect();
        // (prefix elems, frame, decoded tensor) per distinct depth.
        let mut bc_cache: Vec<(usize, Vec<u8>, Vec<f32>)> = Vec::new();
        let bc_t0 = h.clock.now();
        let mut bc_bytes = 0u64;
        let mut bc_count = 0u64;
        for (j, s) in slots.iter().enumerate() {
            if dead[j] {
                continue;
            }
            let ci = s.ci;
            let prefix_elems = h.client(ci).enc.len();
            let cache_slot = match bc_cache.iter().position(|(e, _, _)| *e == prefix_elems) {
                Some(i) => i,
                None => {
                    let frame = h
                        .wire
                        .encode_to(
                            MsgType::Broadcast,
                            &h.server.enc[..prefix_elems],
                            0.0,
                            &mut bar_scratch,
                        )
                        .to_vec();
                    let dec = h.wire.decode(&frame)?;
                    bc_cache.push((prefix_elems, frame, dec.data));
                    bc_cache.len() - 1
                }
            };
            let frame_bytes = bc_cache[cache_slot].1.len() as u64;
            let t = h.net.bulk_down_framed(
                ci,
                Framed {
                    wire: frame_bytes,
                    raw: (prefix_elems * 4) as u64,
                },
            );
            let pos = roster.binary_search(&ci).expect("slot drawn from roster");
            bc_entries[pos].1 = t;
            bc_bytes += frame_bytes;
            bc_count += 1;
            let delivered = match conns[ci].as_mut() {
                Some(conn) => conn.send(&bc_cache[cache_slot].1).is_ok(),
                None => false,
            };
            if !delivered {
                eprintln!("transport: client {ci} died at broadcast");
                if let Some(old) = conns[ci].take() {
                    stats.retire(&old);
                }
                continue;
            }
            h.client_mut(ci).sync_from_global(&bc_cache[cache_slot].2);
        }
        h.charge_barrier_phase(&bc_entries);
        let bc_dur = h.clock.now() - bc_t0;
        if let Some(tr) = h.tracer.as_mut() {
            tr.track_span(
                TRACK_SERVER,
                SpanKind::Broadcast,
                bc_t0,
                bc_dur,
                bc_bytes,
                bc_count,
            );
        }

        // ---- Evaluate + record ----
        let acc = h.eval_global(rt)?;
        let hit = h.finish_round(
            round,
            round_dt,
            &roster,
            &busy,
            acc,
            fallback_steps,
            server_steps,
            faults,
        );
        if hit {
            break;
        }
    }

    // Teardown: every surviving peer gets a Bye; its byte ledgers fold
    // into the cross-check totals.
    for conn in conns.iter_mut().flatten() {
        let _ = conn.send(&proto::bye());
    }
    for conn in conns.into_iter().flatten() {
        stats.retire(&conn);
    }
    stats.sim_wire_bytes = h.net.traffic.total_bytes();
    Ok((h.finalize(), stats))
}
