//! Incremental frame assembly for stream transports.
//!
//! A TCP stream delivers bytes at arbitrary segment boundaries — a frame
//! can arrive one byte at a time, or three frames can land in one read.
//! [`FrameReader`] reassembles the `wire::frame` envelope incrementally:
//! callers [`FrameReader::feed`] whatever the socket produced and
//! [`FrameReader::poll`] complete, fully validated frames out.
//!
//! Hardening contract (pinned by the property tests below):
//!
//! * **Byte-identical reassembly** under every chunking — 1-byte
//!   deliveries, splits at each header/trailer boundary, multiple frames
//!   per segment — the extracted frames equal the sender's bytes.
//! * **Fail fast, allocate bounded**: the fixed header is validated as
//!   soon as its 24 bytes arrive (magic, version, message type, flags,
//!   declared length), so garbage and oversized length prefixes are
//!   rejected *before* the reader waits for — or allocates — a payload.
//!   Buffered bytes never exceed `max_frame + one feed chunk`.
//! * **Counted errors, never panics**: every rejection increments
//!   [`FrameReader::errors`] and returns [`crate::Error::Wire`]. A
//!   stream that fails validation is unrecoverable (framing sync is
//!   lost) — transports treat it as a connection fault.

use crate::wire::frame::{HEADER_LEN, MAGIC, OVERHEAD, VERSION};
use crate::wire::{read_frame, MsgType};
use crate::{Error, Result};

/// Hard cap on a single frame (header + payload + CRC). Far above any
/// tensor this repo ships (the largest is a full encoder prefix upload),
/// far below anything that could balloon memory on a hostile length
/// prefix.
pub const MAX_FRAME_LEN: usize = 1 << 28; // 256 MiB

/// Incremental, validating frame reassembler. One per connection
/// direction.
#[derive(Debug, Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    /// Bytes of `buf` already handed out as frames (compacted lazily).
    start: usize,
    /// Rejected-frame count (oversized prefixes, bad headers, CRC
    /// failures). Monotonic; the transport folds it into its fault
    /// accounting.
    errors: u64,
    max_frame: usize,
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader::with_max(MAX_FRAME_LEN)
    }

    /// Reader with a custom frame-size cap (tests shrink it to prove the
    /// bound without allocating gigabytes).
    pub fn with_max(max_frame: usize) -> FrameReader {
        FrameReader {
            buf: Vec::new(),
            start: 0,
            errors: 0,
            max_frame,
        }
    }

    /// Total rejections so far.
    pub fn errors(&self) -> u64 {
        self.errors
    }

    /// Bytes buffered but not yet returned as a frame. Nonzero at EOF
    /// means the peer died mid-frame (a truncation fault).
    pub fn pending(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Append bytes the stream delivered. Call [`FrameReader::poll`]
    /// until it returns `Ok(None)` after every feed — the buffer bound
    /// assumes frames are drained as they complete.
    pub fn feed(&mut self, bytes: &[u8]) {
        // Compact before growing: keeps the buffer bounded by
        // `max_frame + chunk` instead of the whole session's traffic.
        if self.start > 0 && (self.start == self.buf.len() || self.start >= (1 << 16)) {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    fn reject(&mut self, msg: String) -> Error {
        self.errors += 1;
        Error::Wire(msg)
    }

    /// Extract the next complete, validated frame, if one is buffered.
    ///
    /// * `Ok(Some(frame))` — one full frame (header + payload + CRC),
    ///   byte-identical to what the sender wrote.
    /// * `Ok(None)` — need more bytes.
    /// * `Err(_)` — the stream failed validation (counted); framing sync
    ///   is lost and the connection must be dropped.
    pub fn poll(&mut self) -> Result<Option<Vec<u8>>> {
        let avail = &self.buf[self.start..];
        if avail.len() < HEADER_LEN {
            // Even a partial header can be rejected early once the magic
            // bytes are wrong — don't wait for 24 bytes of garbage.
            let n = avail.len().min(4);
            if avail[..n] != MAGIC[..n] {
                return Err(self.reject("bad magic (not a SuperSFL wire frame)".into()));
            }
            return Ok(None);
        }
        if avail[..4] != MAGIC {
            return Err(self.reject("bad magic (not a SuperSFL wire frame)".into()));
        }
        if avail[4] != VERSION {
            return Err(self.reject(format!(
                "unsupported frame version {} (this build speaks {VERSION})",
                avail[4]
            )));
        }
        if let Err(e) = MsgType::from_u8(avail[5]) {
            return Err(self.reject(format!("stream framing: {e}")));
        }
        if avail[7] != 0 {
            return Err(self.reject(format!("unknown flags 0x{:02x}", avail[7])));
        }
        let payload_len = u32::from_le_bytes([avail[12], avail[13], avail[14], avail[15]]) as usize;
        let total = OVERHEAD + payload_len;
        if total > self.max_frame {
            // Oversized declared length: rejected before any payload is
            // awaited or allocated.
            return Err(self.reject(format!(
                "declared frame length {total} exceeds the {}-byte cap",
                self.max_frame
            )));
        }
        if avail.len() < total {
            return Ok(None);
        }
        let frame = self.buf[self.start..self.start + total].to_vec();
        // Full envelope validation (length echo + CRC) before the frame
        // is surfaced; a flipped byte is a counted rejection here.
        if let Err(e) = read_frame(&frame) {
            return Err(self.reject(format!("stream frame failed validation: {e}")));
        }
        self.start += total;
        Ok(Some(frame))
    }
}

/// Bounded per-peer write staging. Senders queue frames; once the queue
/// passes `cap` bytes the next [`WriteBuf::queue`] flushes synchronously
/// first — back-pressure instead of unbounded growth when a peer reads
/// slowly.
#[derive(Debug)]
pub struct WriteBuf {
    pending: Vec<u8>,
    cap: usize,
}

impl WriteBuf {
    pub fn with_capacity(cap: usize) -> WriteBuf {
        WriteBuf {
            pending: Vec::new(),
            cap: cap.max(1),
        }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Stage one frame; flushes to `w` first if the bound would be
    /// exceeded. Returns the number of bytes flushed (0 when buffered).
    pub fn queue(&mut self, w: &mut impl std::io::Write, frame: &[u8]) -> Result<usize> {
        let mut flushed = 0;
        if !self.pending.is_empty() && self.pending.len() + frame.len() > self.cap {
            flushed = self.flush(w)?;
        }
        if frame.len() > self.cap {
            // A single frame over the cap is written straight through —
            // the bound limits queue growth, not frame size.
            w.write_all(frame).map_err(Error::Io)?;
            return Ok(flushed + frame.len());
        }
        self.pending.extend_from_slice(frame);
        Ok(flushed)
    }

    /// Write everything staged. The underlying `write_all` rides the
    /// socket's own send-buffer back-pressure.
    pub fn flush(&mut self, w: &mut impl std::io::Write) -> Result<usize> {
        if self.pending.is_empty() {
            return Ok(0);
        }
        w.write_all(&self.pending).map_err(Error::Io)?;
        let n = self.pending.len();
        self.pending.clear();
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::wire::{write_frame, MsgType};

    fn sample_frames() -> Vec<Vec<u8>> {
        vec![
            write_frame(MsgType::Smashed, 0, 4, 0.0, &[1, 2, 3, 4]),
            write_frame(MsgType::ActGrad, 2, 0, -1.5, &[]),
            write_frame(MsgType::Hello, 0, 0, 0.0, &[9u8; 17]),
            write_frame(MsgType::Broadcast, 1, 64, 7.25, &vec![0xAB; 300]),
        ]
    }

    /// Drive a stream through the reader under a given chunking and
    /// collect the reassembled frames.
    fn reassemble(stream: &[u8], chunks: &[usize]) -> Vec<Vec<u8>> {
        let mut r = FrameReader::new();
        let mut out = Vec::new();
        let mut pos = 0;
        for &n in chunks {
            let end = (pos + n).min(stream.len());
            r.feed(&stream[pos..end]);
            pos = end;
            while let Some(f) = r.poll().expect("valid stream") {
                out.push(f);
            }
        }
        assert_eq!(pos, stream.len(), "chunking must cover the stream");
        assert_eq!(r.pending(), 0);
        assert_eq!(r.errors(), 0);
        out
    }

    #[test]
    fn one_byte_deliveries_reassemble_byte_identically() {
        let frames = sample_frames();
        let stream: Vec<u8> = frames.iter().flatten().copied().collect();
        let chunks = vec![1usize; stream.len()];
        assert_eq!(reassemble(&stream, &chunks), frames);
    }

    #[test]
    fn splits_at_every_header_and_trailer_boundary() {
        let frame = write_frame(MsgType::PrefixUpload, 2, 8, 3.5, &[7u8; 32]);
        // Split the single frame at every possible position, including
        // exactly at the header edge (24) and the CRC trailer edge
        // (len - 4).
        for cut in 1..frame.len() {
            let got = reassemble(&frame, &[cut, frame.len() - cut]);
            assert_eq!(got, vec![frame.clone()], "split at {cut}");
        }
    }

    #[test]
    fn prop_random_chunkings_are_byte_identical() {
        let frames = sample_frames();
        let stream: Vec<u8> = frames.iter().flatten().copied().collect();
        forall(0xC4A7, 50, |rng| {
            let mut chunks = Vec::new();
            let mut left = stream.len();
            while left > 0 {
                let n = 1 + rng.uniform_usize(left.min(97));
                chunks.push(n);
                left -= n;
            }
            assert_eq!(reassemble(&stream, &chunks), frames);
        });
    }

    #[test]
    fn multiple_frames_in_one_segment_drain_in_order() {
        let frames = sample_frames();
        let stream: Vec<u8> = frames.iter().flatten().copied().collect();
        assert_eq!(reassemble(&stream, &[stream.len()]), frames);
    }

    #[test]
    fn truncation_leaves_pending_bytes_not_a_frame() {
        let frame = write_frame(MsgType::Smashed, 0, 2, 0.0, &[1, 2]);
        for cut in 1..frame.len() {
            let mut r = FrameReader::new();
            r.feed(&frame[..cut]);
            assert!(r.poll().expect("partial valid prefix").is_none(), "cut {cut}");
            // EOF with pending > 0 is how the transport detects a peer
            // that died mid-frame.
            assert_eq!(r.pending(), cut);
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let mut frame = write_frame(MsgType::Smashed, 0, 2, 0.0, &[1, 2]);
        // Declare a payload just past the cap (CRC no longer matters —
        // the length check fires first).
        let huge = (1024 - OVERHEAD + 1) as u32;
        frame[12..16].copy_from_slice(&huge.to_le_bytes());
        let mut r = FrameReader::with_max(1024);
        r.feed(&frame[..HEADER_LEN]);
        assert!(r.poll().is_err());
        assert_eq!(r.errors(), 1);
        // The reader rejected on the header alone — it buffered 24
        // bytes, not the declared megabytes.
        assert!(r.pending() <= HEADER_LEN);
    }

    #[test]
    fn prop_bit_flips_are_counted_rejections_never_panics() {
        let frames = sample_frames();
        let stream: Vec<u8> = frames.iter().flatten().copied().collect();
        forall(0xB17F, 60, |rng| {
            let mut bad = stream.clone();
            let i = rng.uniform_usize(bad.len());
            bad[i] ^= 1 + rng.uniform_usize(255) as u8;
            let mut r = FrameReader::new();
            let mut errs = 0u64;
            // Feed in random chunks; any outcome is fine except a panic
            // or an uncounted rejection. (A flip in a later frame can
            // still yield earlier frames intact.)
            let mut pos = 0;
            'outer: while pos < bad.len() {
                let n = 1 + rng.uniform_usize((bad.len() - pos).min(64));
                r.feed(&bad[pos..pos + n]);
                pos += n;
                loop {
                    match r.poll() {
                        Ok(Some(f)) => assert!(frames.contains(&f), "flipped stream produced a frame nobody sent"),
                        Ok(None) => break,
                        Err(_) => {
                            errs += 1;
                            break 'outer; // framing sync lost: connection drops
                        }
                    }
                }
            }
            assert_eq!(r.errors(), errs);
            // A flip anywhere except inside a never-polled tail must be
            // caught; either way the error count matches what poll
            // reported.
            assert!(errs <= 1);
        });
    }

    #[test]
    fn garbage_magic_fails_before_a_full_header_arrives() {
        let mut r = FrameReader::new();
        r.feed(b"GET "); // not SSFW: rejected at 4 bytes, not 24
        assert!(r.poll().is_err());
        assert_eq!(r.errors(), 1);
    }

    #[test]
    fn write_buf_bounds_queued_bytes() {
        let mut sink: Vec<u8> = Vec::new();
        let mut wb = WriteBuf::with_capacity(64);
        let small = vec![0xAAu8; 40];
        assert_eq!(wb.queue(&mut sink, &small).unwrap(), 0);
        assert_eq!(wb.pending(), 40);
        // Next frame would exceed the 64-byte bound: the stage flushes
        // first.
        assert_eq!(wb.queue(&mut sink, &small).unwrap(), 40);
        assert_eq!(wb.pending(), 40);
        assert_eq!(sink.len(), 40);
        // Over-cap frames pass straight through after a flush.
        let big = vec![0xBBu8; 200];
        let flushed = wb.queue(&mut sink, &big).unwrap();
        assert_eq!(flushed, 40 + 200);
        assert_eq!(wb.pending(), 0);
        assert_eq!(wb.flush(&mut sink).unwrap(), 0);
        assert_eq!(sink.len(), 280);
        // Byte order preserved: 40 + 40 small then 200 big.
        assert!(sink[..80].iter().all(|&b| b == 0xAA));
        assert!(sink[80..].iter().all(|&b| b == 0xBB));
    }
}
