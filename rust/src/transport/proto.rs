//! Transport control messages.
//!
//! Control traffic rides the same `wire::frame` envelope as tensor
//! traffic (same magic/version/CRC machinery, `elems = 0`, raw-byte
//! payloads with fixed little-endian layouts) so one [`super::framing::FrameReader`]
//! per connection handles everything. Control frames are **excluded from
//! the data-byte ledger** — they are transport bookkeeping the simulator
//! never priced, and the cross-validation against `NetworkSim` counts
//! data frames only.

use crate::wire::{read_frame, write_frame, MsgType};
use crate::{Error, Result};

/// Client → server join request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hello {
    pub client_id: u32,
    /// FNV-1a of the canonical config JSON. The server refuses a peer
    /// built from a different config — in the replicated-world design
    /// both processes must derive the identical deterministic world.
    pub config_fnv: u64,
}

/// Server → client join acknowledgement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HelloAck {
    /// The next round the server will start (1-based).
    pub next_round: u32,
    /// How many `next_batch` draws this client's shard has consumed in
    /// the server's replicated world. A rejoining client fast-forwards
    /// its freshly built shard by this many draws so batch labels stay
    /// aligned with the activations it ships.
    pub ff_draws: u64,
    /// When true, a `Broadcast` resync frame (current global prefix)
    /// follows immediately — the charged `resync_roster` path made
    /// physical.
    pub resync: bool,
}

/// Server → client round kickoff.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoundStart {
    pub round: u32,
    pub steps: u32,
}

/// Client → server end-of-round report: the loss accumulators the
/// server needs to reproduce the simulator's round record, plus the
/// client-side fault tallies (ActGrad CRC failures happen client-side
/// on a real wire).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundEnd {
    pub local_sum: f64,
    pub local_n: u64,
    pub server_sum: f64,
    pub server_n: u64,
    pub fallback_steps: u64,
    pub corruptions: u64,
}

fn le_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

fn le_u64(b: &[u8], at: usize) -> u64 {
    let mut x = [0u8; 8];
    x.copy_from_slice(&b[at..at + 8]);
    u64::from_le_bytes(x)
}

fn expect(msg: MsgType, frame: &[u8], payload_len: usize) -> Result<Vec<u8>> {
    let (h, p) = read_frame(frame)?;
    if h.msg != msg {
        return Err(Error::Wire(format!(
            "expected a {} control frame, got {}",
            msg.as_str(),
            h.msg.as_str()
        )));
    }
    if p.len() != payload_len {
        return Err(Error::Wire(format!(
            "{} payload is {} bytes, expected {payload_len}",
            msg.as_str(),
            p.len()
        )));
    }
    Ok(p.to_vec())
}

impl Hello {
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(12);
        p.extend_from_slice(&self.client_id.to_le_bytes());
        p.extend_from_slice(&self.config_fnv.to_le_bytes());
        write_frame(MsgType::Hello, 0, 0, 0.0, &p)
    }

    pub fn decode(frame: &[u8]) -> Result<Hello> {
        let p = expect(MsgType::Hello, frame, 12)?;
        Ok(Hello {
            client_id: le_u32(&p, 0),
            config_fnv: le_u64(&p, 4),
        })
    }
}

impl HelloAck {
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(13);
        p.extend_from_slice(&self.next_round.to_le_bytes());
        p.extend_from_slice(&self.ff_draws.to_le_bytes());
        p.push(self.resync as u8);
        write_frame(MsgType::HelloAck, 0, 0, 0.0, &p)
    }

    pub fn decode(frame: &[u8]) -> Result<HelloAck> {
        let p = expect(MsgType::HelloAck, frame, 13)?;
        Ok(HelloAck {
            next_round: le_u32(&p, 0),
            ff_draws: le_u64(&p, 4),
            resync: p[12] != 0,
        })
    }
}

impl RoundStart {
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(8);
        p.extend_from_slice(&self.round.to_le_bytes());
        p.extend_from_slice(&self.steps.to_le_bytes());
        write_frame(MsgType::RoundStart, 0, 0, 0.0, &p)
    }

    pub fn decode(frame: &[u8]) -> Result<RoundStart> {
        let p = expect(MsgType::RoundStart, frame, 8)?;
        Ok(RoundStart {
            round: le_u32(&p, 0),
            steps: le_u32(&p, 4),
        })
    }
}

impl RoundEnd {
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(48);
        p.extend_from_slice(&self.local_sum.to_le_bytes());
        p.extend_from_slice(&self.local_n.to_le_bytes());
        p.extend_from_slice(&self.server_sum.to_le_bytes());
        p.extend_from_slice(&self.server_n.to_le_bytes());
        p.extend_from_slice(&self.fallback_steps.to_le_bytes());
        p.extend_from_slice(&self.corruptions.to_le_bytes());
        write_frame(MsgType::RoundEnd, 0, 0, 0.0, &p)
    }

    pub fn decode(frame: &[u8]) -> Result<RoundEnd> {
        let p = expect(MsgType::RoundEnd, frame, 48)?;
        Ok(RoundEnd {
            local_sum: f64::from_le_bytes(p[0..8].try_into().expect("len checked")),
            local_n: le_u64(&p, 8),
            server_sum: f64::from_le_bytes(p[16..24].try_into().expect("len checked")),
            server_n: le_u64(&p, 24),
            fallback_steps: le_u64(&p, 32),
            corruptions: le_u64(&p, 40),
        })
    }
}

/// Payload-free control frames.
pub fn bye() -> Vec<u8> {
    write_frame(MsgType::Bye, 0, 0, 0.0, &[])
}

pub fn nack() -> Vec<u8> {
    write_frame(MsgType::Nack, 0, 0, 0.0, &[])
}

/// Message type of a validated frame (for dispatch).
pub fn msg_of(frame: &[u8]) -> Result<MsgType> {
    Ok(read_frame(frame)?.0.msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_payloads_round_trip_exactly() {
        let h = Hello { client_id: 3, config_fnv: 0xDEAD_BEEF_CAFE_F00D };
        assert_eq!(Hello::decode(&h.encode()).unwrap(), h);

        let a = HelloAck { next_round: 7, ff_draws: 42, resync: true };
        assert_eq!(HelloAck::decode(&a.encode()).unwrap(), a);
        let a2 = HelloAck { next_round: 1, ff_draws: 0, resync: false };
        assert_eq!(HelloAck::decode(&a2.encode()).unwrap(), a2);

        let rs = RoundStart { round: 12, steps: 4 };
        assert_eq!(RoundStart::decode(&rs.encode()).unwrap(), rs);

        let re = RoundEnd {
            local_sum: -1.25e-3,
            local_n: 4,
            server_sum: 7.0 / 3.0,
            server_n: 3,
            fallback_steps: 1,
            corruptions: 2,
        };
        let got = RoundEnd::decode(&re.encode()).unwrap();
        assert_eq!(got.local_sum.to_bits(), re.local_sum.to_bits());
        assert_eq!(got.server_sum.to_bits(), re.server_sum.to_bits());
        assert_eq!((got.local_n, got.server_n), (re.local_n, re.server_n));
        assert_eq!((got.fallback_steps, got.corruptions), (1, 2));
    }

    #[test]
    fn wrong_type_and_wrong_length_are_rejected() {
        let h = Hello { client_id: 1, config_fnv: 2 }.encode();
        assert!(HelloAck::decode(&h).is_err());
        assert!(RoundStart::decode(&bye()).is_err());
        // A truncated payload fails the envelope's own length echo.
        let mut short = h.clone();
        short.truncate(short.len() - 6);
        assert!(Hello::decode(&short).is_err());
        assert_eq!(msg_of(&bye()).unwrap(), MsgType::Bye);
        assert_eq!(msg_of(&nack()).unwrap(), MsgType::Nack);
    }
}
