//! Real transports behind the framed wire layer.
//!
//! Until this module existed every byte in the repo flowed through the
//! in-process [`crate::network::NetworkSim`]. The wire envelope
//! ([`crate::wire::frame`]) was always transport-ready — versioned,
//! length-prefixed, CRC-checksummed — so this module puts actual sockets
//! under it: the binary splits into one server process and N client
//! processes exchanging **the exact frames the simulator prices**, while
//! the simulator keeps running server-side as the authoritative
//! cost/fault model (its ledger is cross-validated against measured
//! socket bytes — see [`server`]).
//!
//! Selection follows the `--faults`/`--sample` idiom:
//! `--transport sim|serve:<addr>|connect:<addr>`, with the
//! `SUPERSFL_TRANSPORT` env var winning over both and an invalid value
//! failing fast.
//!
//! * [`framing`] — incremental [`framing::FrameReader`] reassembly under
//!   adversarial segment boundaries, bounded write staging;
//! * [`proto`]   — the fixed-layout control payloads (Hello/HelloAck/
//!   RoundStart/RoundEnd/Bye/Nack) that ride the same envelope;
//! * [`tcp`]     — the blocking socket connection: timeouts, per-peer
//!   byte ledgers, reconnect dialing;
//! * [`server`]  — the served SuperSFL round loop (mirrors the
//!   orchestrator's sim loop step for step);
//! * [`client`]  — the client-process loop (local compute + frames);
//! * [`shutdown`] — SIGINT/SIGTERM latch for graceful artifact flush.

pub mod client;
pub mod framing;
pub mod proto;
pub mod server;
pub mod shutdown;
pub mod tcp;

use crate::{Error, Result};

/// How a run moves its frames.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum TransportSpec {
    /// Everything in-process through `NetworkSim` (the default; bitwise
    /// identical to every pre-transport release).
    #[default]
    Sim,
    /// Run as the server process: bind `addr`, wait for the fleet, drive
    /// rounds over sockets.
    Serve(String),
    /// Run as one client process: dial `addr` and follow the server's
    /// round protocol (requires `--client-id`).
    Connect(String),
}

impl TransportSpec {
    /// Parse `sim | serve:<addr> | connect:<addr>`. Fail-fast: a typo
    /// must not silently fall back to the simulator.
    pub fn parse(s: &str) -> Result<TransportSpec> {
        let t = s.trim();
        if t.eq_ignore_ascii_case("sim") || t.eq_ignore_ascii_case("off") {
            return Ok(TransportSpec::Sim);
        }
        let (kind, addr) = t.split_once(':').ok_or_else(|| {
            Error::Config(format!(
                "unknown transport '{s}' (expected sim|serve:<addr>|connect:<addr>)"
            ))
        })?;
        let addr = addr.trim();
        if addr.is_empty() || !addr.contains(':') {
            return Err(Error::Config(format!(
                "transport '{s}': address must be host:port (e.g. 127.0.0.1:7070)"
            )));
        }
        match kind.to_ascii_lowercase().as_str() {
            "serve" => Ok(TransportSpec::Serve(addr.to_string())),
            "connect" => Ok(TransportSpec::Connect(addr.to_string())),
            _ => Err(Error::Config(format!(
                "unknown transport '{s}' (expected sim|serve:<addr>|connect:<addr>)"
            ))),
        }
    }

    /// Canonical string form; round-trips through [`TransportSpec::parse`].
    pub fn label(&self) -> String {
        match self {
            TransportSpec::Sim => "sim".into(),
            TransportSpec::Serve(a) => format!("serve:{a}"),
            TransportSpec::Connect(a) => format!("connect:{a}"),
        }
    }

    /// `SUPERSFL_TRANSPORT` overrides every other selection path. An
    /// explicitly set but invalid value fails fast — a typo'd env var
    /// must not silently run in-process.
    pub fn from_env_or(fallback: TransportSpec) -> TransportSpec {
        // audit:allow(env-read) -- documented env-wins override for the CI transport matrix; invalid values fail fast.
        match std::env::var("SUPERSFL_TRANSPORT") {
            Ok(v) => match TransportSpec::parse(&v) {
                Ok(t) => t,
                Err(e) => panic!("invalid SUPERSFL_TRANSPORT value '{v}': {e}"),
            },
            Err(_) => fallback,
        }
    }

    pub fn is_sim(&self) -> bool {
        matches!(self, TransportSpec::Sim)
    }
}

/// Fingerprint of the *world* a config builds, used by the Hello
/// handshake to reject a client process whose replicated world would
/// diverge from the server's. The transport spec itself is normalized
/// to `sim` before hashing: server and client processes necessarily
/// differ in that one knob (`serve:` vs `connect:`) while building the
/// same world from everything else.
pub fn world_fingerprint(cfg: &crate::config::ExperimentConfig) -> u64 {
    let mut c = cfg.clone();
    c.transport = TransportSpec::Sim;
    crate::bench_util::fnv1a64(c.to_json().to_string_compact().as_bytes())
}

/// One peer-to-peer frame channel. Implemented by the real socket
/// connection ([`tcp::Conn`]) and by the in-process loopback used to
/// test the protocol logic without sockets — the served loop and the
/// client loop only ever talk through this surface.
pub trait Transport {
    /// Ship one complete frame (blocking; rides the write path's
    /// bounded staging + the socket's own send-buffer backpressure).
    fn send(&mut self, frame: &[u8]) -> Result<()>;
    /// Receive the next complete, validated frame (blocking up to the
    /// transport's read timeout).
    fn recv(&mut self) -> Result<Vec<u8>>;
    /// Data-frame bytes shipped so far (control frames excluded — this
    /// is the ledger cross-validated against `NetworkSim`).
    fn data_bytes_out(&self) -> u64;
    /// Data-frame bytes received so far (control frames excluded).
    fn data_bytes_in(&self) -> u64;
}

/// Whether a raw frame is a control frame (for byte-ledger
/// classification without a full decode). Truncated buffers count as
/// control so they never pollute the data ledger.
pub fn frame_is_control(frame: &[u8]) -> bool {
    frame
        .get(5)
        .and_then(|&b| crate::wire::MsgType::from_u8(b).ok())
        .map(|m| m.is_control())
        .unwrap_or(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_all_three_forms() {
        assert_eq!(TransportSpec::parse("sim").unwrap(), TransportSpec::Sim);
        assert_eq!(TransportSpec::parse("SIM").unwrap(), TransportSpec::Sim);
        assert_eq!(
            TransportSpec::parse("serve:127.0.0.1:7070").unwrap(),
            TransportSpec::Serve("127.0.0.1:7070".into())
        );
        assert_eq!(
            TransportSpec::parse("connect:localhost:9") .unwrap(),
            TransportSpec::Connect("localhost:9".into())
        );
    }

    #[test]
    fn spec_fails_fast_on_typos() {
        for bad in [
            "serv:127.0.0.1:7070",
            "tcp:127.0.0.1:7070",
            "serve:",
            "serve:nohostport",
            "connect",
            "",
            "simx",
        ] {
            assert!(TransportSpec::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn spec_labels_round_trip() {
        for t in [
            TransportSpec::Sim,
            TransportSpec::Serve("127.0.0.1:7070".into()),
            TransportSpec::Connect("10.0.0.2:443".into()),
        ] {
            assert_eq!(TransportSpec::parse(&t.label()).unwrap(), t);
        }
    }

    #[test]
    fn world_fingerprint_ignores_the_transport_knob_only() {
        let base = crate::config::ExperimentConfig::default();
        let serve = base
            .clone()
            .with_transport(TransportSpec::Serve("127.0.0.1:7070".into()));
        let connect = base
            .clone()
            .with_transport(TransportSpec::Connect("127.0.0.1:7070".into()));
        assert_eq!(world_fingerprint(&base), world_fingerprint(&serve));
        assert_eq!(world_fingerprint(&serve), world_fingerprint(&connect));
        let mut other = serve.clone();
        other.train.seed += 1;
        assert_ne!(world_fingerprint(&serve), world_fingerprint(&other));
    }

    #[test]
    fn control_frame_classifier() {
        use crate::wire::{write_frame, MsgType};
        assert!(frame_is_control(&write_frame(MsgType::Hello, 0, 0, 0.0, &[])));
        assert!(!frame_is_control(&write_frame(MsgType::Smashed, 0, 1, 0.0, &[0; 4])));
        assert!(frame_is_control(&[0u8; 3])); // truncated: never data
    }
}
