//! SIGINT/SIGTERM latch for graceful shutdown.
//!
//! A long run killed at round 40/50 used to lose everything — artifacts
//! were only written after the loop. With the latch installed, the round
//! loops (sim and served alike) check [`requested`] at each round
//! boundary and break early; `main` then flushes the partial CSV/JSON
//! artifacts through the same `util/fs` atomic-write path a completed
//! run uses and reports the interrupted round.
//!
//! Zero dependencies: the handler is registered through the C `signal`
//! interface the platform libc already links (std itself links libc on
//! unix), and does nothing but set one atomic flag — the only
//! async-signal-safe thing worth doing. Non-unix builds compile to a
//! no-op install.

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod sys {
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" fn on_signal(_sig: i32) {
        super::SHUTDOWN.store(true, std::sync::atomic::Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub(super) fn install() {
        // SAFETY: `signal` is always safe to call with a valid handler
        // pointer; `on_signal` is `extern "C"`, never unwinds, and only
        // touches one atomic — the async-signal-safe subset.
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }
}

#[cfg(not(unix))]
mod sys {
    pub(super) fn install() {}
}

/// Register the SIGINT/SIGTERM handler (idempotent).
pub fn install() {
    sys::install();
}

/// Whether a shutdown signal has arrived. Checked by the round loops at
/// round boundaries.
pub fn requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Tests (and nothing else) reset the latch.
pub fn reset() {
    SHUTDOWN.store(false, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latch_starts_clear_and_resets() {
        // The process-global latch may have been set by a sibling test's
        // raise; normalize first.
        reset();
        assert!(!requested());
        SHUTDOWN.store(true, Ordering::SeqCst);
        assert!(requested());
        reset();
        assert!(!requested());
    }
}
