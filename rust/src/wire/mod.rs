//! The wire subsystem: what actually crosses the simulated network.
//!
//! Before this module existed the repo *modeled* communication
//! analytically — `4·n` bytes per f32 tensor, nothing ever serialized —
//! so compression could not be studied and the Table I communication
//! numbers could never diverge from the formula. Now every client↔server
//! tensor exchange is routed through a real encode→decode pass:
//!
//! * [`frame`] — the versioned, length-prefixed, checksummed binary
//!   envelope with one [`frame::MsgType`] per SuperSFL exchange
//!   (smashed activations, activation gradients, encoder-prefix upload,
//!   prefix/classifier broadcast);
//! * [`codec`] — the [`codec::PayloadCodec`] implementations
//!   (`fp32`/`fp16`/`int8`/`topk:<k>`), all deterministic pure functions;
//! * [`Wire`] — the per-run policy mapping message classes to codecs and
//!   the encode/decode entry points the orchestrator and baselines use.
//!
//! The network simulator is charged with the **actual frame bytes**
//! (header + encoded payload + checksum), while the analytic `4·n` count
//! is tracked alongside as "raw" traffic — the per-round compression
//! ratio in [`crate::metrics::RoundRecord`] is their quotient. Lossy
//! codecs feed the *decoded* tensors back into training, so the
//! accuracy-vs-compression trade-off is measurable end to end.
//!
//! Selection: `cfg.wire` / `--wire-codec fp32|fp16|int8|topk:<k>`, with
//! the `SUPERSFL_WIRE` env var winning over both (CI matrix legs pin it).
//! `fp32` is the default and is bit-exact: an `fp32` run's training
//! trajectory is identical to never serializing at all.

pub mod codec;
pub mod frame;

pub use codec::{decode_by_id, decode_by_id_into, Fp16, Fp32Raw, Int8Affine, PayloadCodec, TopK};
pub use frame::{crc32, read_frame, write_frame, write_frame_into, FrameHeader, MsgType, OVERHEAD};

use crate::{Error, Result};

/// Which payload codec a run ships its tensors with (`cfg.wire`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum WireCodecKind {
    /// Raw little-endian f32 (bit-exact; the default).
    #[default]
    Fp32,
    /// IEEE binary16, round-to-nearest-even (2× smaller, ~3 decimal
    /// digits).
    Fp16,
    /// Per-tensor affine 8-bit quantization (~4× smaller).
    Int8,
    /// Keep the top `k`% of entries by magnitude on activation/gradient
    /// frames; parameter frames fall back to [`Int8Affine`] (sparsifying
    /// raw weights would zero most of the model rather than compress it).
    TopK(u8),
}

impl WireCodecKind {
    /// Parse `fp32|fp16|int8|topk[:<k>]` (k in percent, 1–100; bare
    /// `topk` means `topk:10`).
    pub fn parse(s: &str) -> Result<WireCodecKind> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "fp32" | "f32" | "raw" => Ok(WireCodecKind::Fp32),
            "fp16" | "f16" => Ok(WireCodecKind::Fp16),
            "int8" | "q8" => Ok(WireCodecKind::Int8),
            "topk" => Ok(WireCodecKind::TopK(10)),
            _ => {
                if let Some(k) = lower.strip_prefix("topk:") {
                    let k: u8 = k.parse().map_err(|_| {
                        Error::Config(format!("invalid topk ratio '{k}' (expected 1-100)"))
                    })?;
                    if !(1..=100).contains(&k) {
                        return Err(Error::Config(format!(
                            "topk ratio {k} out of range (expected 1-100 percent)"
                        )));
                    }
                    Ok(WireCodecKind::TopK(k))
                } else {
                    Err(Error::Config(format!(
                        "unknown wire codec '{s}' (expected fp32|fp16|int8|topk:<k>)"
                    )))
                }
            }
        }
    }

    /// Canonical string form (round-trips through [`WireCodecKind::parse`]).
    pub fn label(&self) -> String {
        match self {
            WireCodecKind::Fp32 => "fp32".into(),
            WireCodecKind::Fp16 => "fp16".into(),
            WireCodecKind::Int8 => "int8".into(),
            WireCodecKind::TopK(k) => format!("topk:{k}"),
        }
    }

    /// `SUPERSFL_WIRE` overrides every other selection path (used by the
    /// CI matrix). An explicitly set but invalid value fails fast — a
    /// typo'd env var must not silently run the wrong codec.
    pub fn from_env_or(fallback: WireCodecKind) -> WireCodecKind {
        // audit:allow(env-read) -- documented env-wins override for the CI wire matrix; invalid values fail fast.
        match std::env::var("SUPERSFL_WIRE") {
            Ok(v) => match WireCodecKind::parse(&v) {
                Ok(k) => k,
                Err(e) => panic!("invalid SUPERSFL_WIRE value '{v}': {e}"),
            },
            Err(_) => fallback,
        }
    }
}

/// The per-run wire policy: which codec encodes which message class,
/// plus the frame encode/decode entry points. Stateless and `Sync` — the
/// parallel round engine shares one `&Wire` across all worker lanes.
pub struct Wire {
    kind: WireCodecKind,
    /// Codec for activation/gradient frames (Smashed, ActGrad).
    act: Box<dyn PayloadCodec>,
    /// Codec for parameter frames (PrefixUpload, Broadcast).
    params: Box<dyn PayloadCodec>,
}

impl Wire {
    pub fn new(kind: WireCodecKind) -> Wire {
        let (act, params): (Box<dyn PayloadCodec>, Box<dyn PayloadCodec>) = match kind {
            WireCodecKind::Fp32 => (Box::new(Fp32Raw), Box::new(Fp32Raw)),
            WireCodecKind::Fp16 => (Box::new(Fp16), Box::new(Fp16)),
            WireCodecKind::Int8 => (Box::new(Int8Affine), Box::new(Int8Affine)),
            // Sparsification only makes sense where small-magnitude
            // entries are noise (activations, gradients); weight frames
            // quantize instead.
            WireCodecKind::TopK(percent) => (Box::new(TopK { percent }), Box::new(Int8Affine)),
        };
        Wire { kind, act, params }
    }

    pub fn kind(&self) -> WireCodecKind {
        self.kind
    }

    pub fn label(&self) -> String {
        self.kind.label()
    }

    fn codec_for(&self, msg: MsgType) -> &dyn PayloadCodec {
        if msg.is_params() {
            &*self.params
        } else {
            &*self.act
        }
    }

    /// Exact frame size for a tensor of `elems` f32s — a pure function
    /// of the element count, so response frames can be priced before the
    /// response exists (the exchange timeout roll needs both directions
    /// up front).
    pub fn frame_len(&self, msg: MsgType, elems: usize) -> u64 {
        (OVERHEAD + self.codec_for(msg).encoded_len(elems)) as u64
    }

    /// Encode one tensor into a complete frame. `aux` rides in the
    /// header as raw f64 bits (used for the Eq. 6 aggregation loss on
    /// [`MsgType::PrefixUpload`]) and is exact under every codec.
    pub fn encode(&self, msg: MsgType, data: &[f32], aux: f64) -> Vec<u8> {
        let mut scratch = WireScratch::default();
        self.encode_to(msg, data, aux, &mut scratch);
        scratch.frame
    }

    /// Encode one tensor into `scratch.frame` (reusing the scratch's
    /// payload staging buffer) and return the frame bytes. Byte-identical
    /// to [`Wire::encode`] — the per-lane round loops use this form so
    /// the steady-state encode path allocates nothing.
    pub fn encode_to<'a>(
        &self,
        msg: MsgType,
        data: &[f32],
        aux: f64,
        scratch: &'a mut WireScratch,
    ) -> &'a [u8] {
        let codec = self.codec_for(msg);
        scratch.payload.clear();
        codec.encode_into(data, &mut scratch.payload);
        frame::write_frame_into(
            msg,
            codec.id(),
            data.len(),
            aux,
            &scratch.payload,
            &mut scratch.frame,
        );
        debug_assert_eq!(scratch.frame.len() as u64, self.frame_len(msg, data.len()));
        &scratch.frame
    }

    /// Validate + decode a frame. Codec dispatch is self-describing (the
    /// frame header names its codec), so a receiver needs no knowledge
    /// of the sender's policy.
    pub fn decode(&self, buf: &[u8]) -> Result<DecodedFrame> {
        let (h, payload) = frame::read_frame(buf)?;
        let data = codec::decode_by_id(h.codec_id, payload, h.elems)?;
        Ok(DecodedFrame {
            msg: h.msg,
            codec_id: h.codec_id,
            aux: h.aux,
            data,
        })
    }

    /// Validate + decode a frame into a reusable tensor buffer (cleared
    /// first), returning the frame header. Bit-identical to
    /// [`Wire::decode`]; the per-lane round loops decode into
    /// [`WireScratch::decoded`] so the receive path allocates nothing
    /// either.
    pub fn decode_into(&self, buf: &[u8], out: &mut Vec<f32>) -> Result<FrameHeader> {
        let (h, payload) = frame::read_frame(buf)?;
        codec::decode_by_id_into(h.codec_id, payload, h.elems, out)?;
        Ok(h)
    }
}

/// Reusable per-lane encode/decode buffers. Each [`crate::network::NetLane`]
/// carries one, so the per-step frame traffic of a round (smashed
/// activations up, activation gradients down) reuses three allocations
/// for the whole round instead of building a fresh `Vec` per frame. The
/// bytes on the wire are identical either way (pinned by the frame
/// round-trip tests and the e2e frame-arithmetic test).
#[derive(Clone, Debug, Default)]
pub struct WireScratch {
    /// The most recently encoded frame (header + payload + CRC).
    pub frame: Vec<u8>,
    /// Codec payload staging area.
    payload: Vec<u8>,
    /// The most recently decoded tensor ([`Wire::decode_into`] target).
    pub decoded: Vec<f32>,
}

/// A fully decoded frame: the receiver-side view of one exchange.
#[derive(Clone, Debug)]
pub struct DecodedFrame {
    pub msg: MsgType,
    pub codec_id: u8,
    /// Header-carried scalar (aggregation loss on PrefixUpload frames).
    pub aux: f64,
    /// The decoded tensor — what the receiver trains on. Bit-identical
    /// to the sender's tensor under `fp32`, perturbed under lossy codecs.
    pub data: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn kind_parses_and_roundtrips_labels() {
        for (s, k) in [
            ("fp32", WireCodecKind::Fp32),
            ("FP16", WireCodecKind::Fp16),
            ("int8", WireCodecKind::Int8),
            ("topk", WireCodecKind::TopK(10)),
            ("topk:25", WireCodecKind::TopK(25)),
            ("TOPK:3", WireCodecKind::TopK(3)),
        ] {
            assert_eq!(WireCodecKind::parse(s).unwrap(), k);
        }
        for k in [
            WireCodecKind::Fp32,
            WireCodecKind::Fp16,
            WireCodecKind::Int8,
            WireCodecKind::TopK(7),
        ] {
            assert_eq!(WireCodecKind::parse(&k.label()).unwrap(), k);
        }
        assert!(WireCodecKind::parse("gzip").is_err());
        assert!(WireCodecKind::parse("topk:0").is_err());
        assert!(WireCodecKind::parse("topk:101").is_err());
        assert!(WireCodecKind::parse("topk:x").is_err());
    }

    #[test]
    fn fp32_wire_roundtrip_is_bit_exact_per_message_type() {
        let w = Wire::new(WireCodecKind::Fp32);
        let mut rng = Pcg32::seeded(11);
        let data: Vec<f32> = (0..257).map(|_| rng.normal() as f32).collect();
        for msg in [
            MsgType::Smashed,
            MsgType::ActGrad,
            MsgType::PrefixUpload,
            MsgType::Broadcast,
        ] {
            let buf = w.encode(msg, &data, 0.5);
            assert_eq!(buf.len() as u64, w.frame_len(msg, data.len()));
            let dec = w.decode(&buf).unwrap();
            assert_eq!(dec.msg, msg);
            assert_eq!(dec.aux, 0.5);
            for (a, b) in data.iter().zip(dec.data.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn topk_policy_quantizes_parameter_frames() {
        let w = Wire::new(WireCodecKind::TopK(10));
        let data = vec![1.0f32; 100];
        // Activation frame: sparsified (8·k% + count word + overhead).
        let act = w.encode(MsgType::Smashed, &data, 0.0);
        assert_eq!(act.len(), OVERHEAD + 4 + 8 * 10);
        // Parameter frame: int8, never topk — a weight tensor must not
        // be zeroed.
        let par = w.encode(MsgType::Broadcast, &data, 0.0);
        assert_eq!(par.len(), OVERHEAD + 8 + 100);
        let dec = w.decode(&par).unwrap();
        assert!(dec.data.iter().all(|&v| (v - 1.0).abs() < 1e-6));
    }

    /// The orchestrator prices a response frame **before the response
    /// tensor exists** (`frame_len(msg, elems)` feeds the exchange
    /// timeout roll, and the round loop now fails loudly if the encoded
    /// ActGrad frame deviates from the priced size). That is only sound
    /// if the frame length is a pure function of `(msg type, elems)` —
    /// never of the tensor's values. Pinned here for fp32/fp16/int8
    /// across all message types and randomized value distributions
    /// (zeros, huge magnitudes, duplicates — anything a size-adaptive
    /// encoding would latch onto).
    ///
    /// topk is covered too, with one documented exemption: its length
    /// is still value-independent — the kept-entry count is
    /// `max(1, ⌊n·k/100⌋)`, a function of `n` alone, *not* of how many
    /// entries are nonzero — but unlike the other codecs it is **not**
    /// message-type-independent: the policy sparsifies activation
    /// frames while parameter frames fall back to int8, so
    /// `frame_len(Smashed, n) ≠ frame_len(Broadcast, n)`. The msg type
    /// must therefore stay part of the pricing key (which is exactly
    /// the signature `frame_len` has).
    #[test]
    fn frame_len_is_a_pure_function_of_msg_type_and_elems() {
        let msgs = [
            MsgType::Smashed,
            MsgType::ActGrad,
            MsgType::PrefixUpload,
            MsgType::Broadcast,
        ];
        forall(0xF1E7, 25, |rng| {
            let n = 1 + rng.uniform_usize(400);
            // Three adversarial value distributions of the same length.
            let plain: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let huge: Vec<f32> = (0..n).map(|_| (rng.normal() * 1e30) as f32).collect();
            let sparse: Vec<f32> = (0..n)
                .map(|i| if i % 7 == 0 { rng.normal() as f32 } else { 0.0 })
                .collect();
            for kind in [WireCodecKind::Fp32, WireCodecKind::Fp16, WireCodecKind::Int8] {
                let w = Wire::new(kind);
                for &msg in &msgs {
                    let want = w.frame_len(msg, n);
                    for data in [&plain, &huge, &sparse] {
                        assert_eq!(
                            w.encode(msg, data, 0.0).len() as u64,
                            want,
                            "{}: frame length must not depend on values",
                            w.label()
                        );
                    }
                    // These codecs are also message-class-independent:
                    // the same codec serves activations and parameters.
                    assert_eq!(want, w.frame_len(MsgType::Smashed, n), "{}", w.label());
                }
            }
            // topk: value-independent per message type (the count word is
            // a function of n alone)…
            let w = Wire::new(WireCodecKind::TopK(10));
            for &msg in &msgs {
                let want = w.frame_len(msg, n);
                for data in [&plain, &huge, &sparse] {
                    assert_eq!(w.encode(msg, data, 0.0).len() as u64, want, "topk");
                }
            }
            // …but NOT message-class-independent (the documented
            // exemption): activation frames sparsify, parameter frames
            // quantize, so the same n prices differently per class.
            // (n = 4 is the one accidental coincidence: a 1-entry topk
            // payload (4+8 bytes) equals an int8 one (8+4 bytes).)
            if n != 4 {
                assert_ne!(
                    w.frame_len(MsgType::Smashed, n),
                    w.frame_len(MsgType::Broadcast, n),
                    "topk act/param frame lengths coincided at n={n} — the \
                     msg type must stay part of the pricing key"
                );
            }
        });
    }

    #[test]
    fn lossy_frame_lens_beat_fp32_by_the_expected_factors() {
        let n = 4096;
        let fp32 = Wire::new(WireCodecKind::Fp32).frame_len(MsgType::Smashed, n) as f64;
        let fp16 = Wire::new(WireCodecKind::Fp16).frame_len(MsgType::Smashed, n) as f64;
        let int8 = Wire::new(WireCodecKind::Int8).frame_len(MsgType::Smashed, n) as f64;
        let topk = Wire::new(WireCodecKind::TopK(10)).frame_len(MsgType::Smashed, n) as f64;
        assert!(fp32 / fp16 > 1.9);
        assert!(fp32 / int8 > 3.8);
        assert!(fp32 / topk > 4.5);
    }

    /// Determinism contract: encoding the same tensor twice — on any
    /// thread, in any order — yields byte-identical frames.
    #[test]
    fn prop_encode_is_a_pure_function() {
        forall(0xDE7, 20, |rng| {
            let kind = match rng.uniform_usize(4) {
                0 => WireCodecKind::Fp32,
                1 => WireCodecKind::Fp16,
                2 => WireCodecKind::Int8,
                _ => WireCodecKind::TopK(1 + rng.uniform_usize(50) as u8),
            };
            let n = 1 + rng.uniform_usize(500);
            let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let w1 = Wire::new(kind);
            let w2 = Wire::new(kind);
            let a = w1.encode(MsgType::ActGrad, &data, 1.5);
            let b = w2.encode(MsgType::ActGrad, &data, 1.5);
            assert_eq!(a, b);
            // And decode(encode(x)) is stable: re-decoding gives the
            // same tensor bit for bit.
            let d1 = w1.decode(&a).unwrap().data;
            let d2 = w2.decode(&b).unwrap().data;
            for (x, y) in d1.iter().zip(d2.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        });
    }

    /// The per-lane scratch path (encode_to / decode_into) must produce
    /// byte- and bit-identical results to the allocating path, including
    /// when the reused buffers previously held larger frames/tensors —
    /// this is what lets the round loops reuse one scratch per lane
    /// without changing a single wire byte.
    #[test]
    fn prop_scratch_encode_decode_matches_allocating_path() {
        forall(0x5C8A, 30, |rng| {
            let kind = match rng.uniform_usize(4) {
                0 => WireCodecKind::Fp32,
                1 => WireCodecKind::Fp16,
                2 => WireCodecKind::Int8,
                _ => WireCodecKind::TopK(1 + rng.uniform_usize(50) as u8),
            };
            let w = Wire::new(kind);
            let mut scratch = WireScratch::default();
            let big: Vec<f32> = (0..128 + rng.uniform_usize(300)).map(|_| rng.normal() as f32).collect();
            let small: Vec<f32> = (0..1 + rng.uniform_usize(100)).map(|_| rng.normal() as f32).collect();
            for msg in [MsgType::Smashed, MsgType::PrefixUpload] {
                // Big first, then small: the second frame must truncate
                // the reused buffers cleanly.
                for data in [&big, &small] {
                    let fresh = w.encode(msg, data, 2.5);
                    let reused = w.encode_to(msg, data, 2.5, &mut scratch).to_vec();
                    assert_eq!(fresh, reused, "{} frame bytes drifted", w.label());
                    let dec = w.decode(&fresh).unwrap();
                    let h = w.decode_into(&scratch.frame, &mut scratch.decoded).unwrap();
                    assert_eq!(h.msg, dec.msg);
                    assert_eq!(h.aux.to_bits(), dec.aux.to_bits());
                    assert_eq!(scratch.decoded.len(), dec.data.len());
                    for (a, b) in scratch.decoded.iter().zip(dec.data.iter()) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                }
            }
        });
    }

    #[test]
    fn decode_rejects_fuzzed_frames_without_panicking() {
        let w = Wire::new(WireCodecKind::Int8);
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let good = w.encode(MsgType::PrefixUpload, &data, 0.0);
        forall(0xF5, 60, |rng| {
            let mut bad = good.clone();
            match rng.uniform_usize(3) {
                0 => {
                    // Truncate at a random point.
                    let cut = rng.uniform_usize(bad.len());
                    bad.truncate(cut);
                }
                1 => {
                    // Flip a random byte.
                    let i = rng.uniform_usize(bad.len());
                    bad[i] ^= 1 + rng.uniform_usize(255) as u8;
                }
                _ => {
                    // Replace with random garbage of random length.
                    let n = rng.uniform_usize(128);
                    bad = (0..n).map(|_| rng.uniform_usize(256) as u8).collect();
                }
            }
            if bad != good {
                assert!(w.decode(&bad).is_err());
            }
        });
    }
}
