//! The SuperSFL wire frame: a versioned, length-prefixed, checksummed
//! binary envelope around one encoded tensor payload.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"SSFW"
//! 4       1     format version (currently 1)
//! 5       1     message type (MsgType)
//! 6       1     payload codec id (wire::codec)
//! 7       1     flags (reserved, must be 0)
//! 8       4     u32: element count of the original f32 tensor
//! 12      4     u32: payload byte length
//! 16      8     f64: aux scalar (aggregation loss on PrefixUpload frames;
//!               0 otherwise). Raw bits — never routed through the payload
//!               codec, so it is exact under every codec.
//! 24      …     payload (codec-specific encoding of the tensor)
//! 24+len  4     u32: CRC-32 (IEEE) over every preceding byte
//! ```
//!
//! Decoding is defensive by construction: every read is preceded by an
//! explicit length check and every header field is validated before the
//! payload is touched, so truncated or corrupted frames surface as
//! [`crate::Error::Wire`] — never as a panic. The CRC detects any
//! single-byte corruption of header or payload.

use crate::{Error, Result};

/// Frame magic: "SuperSFL Wire Frame".
pub const MAGIC: [u8; 4] = *b"SSFW";
/// Current frame format version.
pub const VERSION: u8 = 1;
/// Fixed header bytes before the payload.
pub const HEADER_LEN: usize = 24;
/// CRC trailer bytes after the payload.
pub const TRAILER_LEN: usize = 4;
/// Total framing overhead on top of the encoded payload.
pub const OVERHEAD: usize = HEADER_LEN + TRAILER_LEN;

/// The four SuperSFL client↔server exchanges (paper Alg. 2 + §II-D).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgType {
    /// Phase-2 uplink: smashed activations `z` (client → server).
    Smashed = 1,
    /// Phase-2 downlink: activation gradient `g_z` (server → client).
    ActGrad = 2,
    /// Aggregation uplink: the client subnetwork — encoder prefix θ_i
    /// followed by the auxiliary classifier φ_i when the method trains
    /// one — with the Eq. 6 aggregation loss in the aux field.
    PrefixUpload = 3,
    /// Post-aggregation downlink: the refreshed parameter broadcast
    /// (prefix for SSFL/SFL, the full backbone for DFL provisioning).
    Broadcast = 4,
    /// Transport control (TCP mode): client → server join request
    /// carrying the client id and a config fingerprint.
    Hello = 5,
    /// Transport control: server → client join acknowledgement carrying
    /// the current round and the shard fast-forward count.
    HelloAck = 6,
    /// Transport control: server → client round kickoff.
    RoundStart = 7,
    /// Transport control: client → server end-of-round report (loss
    /// accumulators, fallback/corruption counts).
    RoundEnd = 8,
    /// Transport control: orderly teardown in either direction.
    Bye = 9,
    /// Transport control: server → client negative step response (the
    /// uplink frame failed its CRC server-side; take the Alg. 3 fallback).
    Nack = 10,
}

impl MsgType {
    pub fn from_u8(v: u8) -> Result<MsgType> {
        match v {
            1 => Ok(MsgType::Smashed),
            2 => Ok(MsgType::ActGrad),
            3 => Ok(MsgType::PrefixUpload),
            4 => Ok(MsgType::Broadcast),
            5 => Ok(MsgType::Hello),
            6 => Ok(MsgType::HelloAck),
            7 => Ok(MsgType::RoundStart),
            8 => Ok(MsgType::RoundEnd),
            9 => Ok(MsgType::Bye),
            10 => Ok(MsgType::Nack),
            other => Err(Error::Wire(format!("unknown message type {other}"))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            MsgType::Smashed => "smashed",
            MsgType::ActGrad => "act_grad",
            MsgType::PrefixUpload => "prefix_upload",
            MsgType::Broadcast => "broadcast",
            MsgType::Hello => "hello",
            MsgType::HelloAck => "hello_ack",
            MsgType::RoundStart => "round_start",
            MsgType::RoundEnd => "round_end",
            MsgType::Bye => "bye",
            MsgType::Nack => "nack",
        }
    }

    /// Whether the payload is a parameter tensor (weights) rather than a
    /// per-step activation/gradient tensor. Codec policies split on this:
    /// sparsification is meaningful for activations and gradients but
    /// zeroes most of the model if applied to raw weights.
    pub fn is_params(&self) -> bool {
        matches!(self, MsgType::PrefixUpload | MsgType::Broadcast)
    }

    /// Whether this is a transport-control frame (raw-byte payload,
    /// `elems = 0`, never routed through a tensor codec and never charged
    /// to the data-frame byte ledger).
    pub fn is_control(&self) -> bool {
        matches!(
            self,
            MsgType::Hello
                | MsgType::HelloAck
                | MsgType::RoundStart
                | MsgType::RoundEnd
                | MsgType::Bye
                | MsgType::Nack
        )
    }
}

/// CRC-32 (IEEE 802.3, reflected, init/xorout `!0`) — the ubiquitous
/// variant (`zlib`, Ethernet, PNG). Table generated at compile time.
const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// A decoded frame header (payload still encoded).
#[derive(Clone, Debug)]
pub struct FrameHeader {
    pub msg: MsgType,
    pub codec_id: u8,
    pub elems: usize,
    pub payload_len: usize,
    pub aux: f64,
}

/// Serialize a frame around an already-encoded payload.
pub fn write_frame(msg: MsgType, codec_id: u8, elems: usize, aux: f64, payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(OVERHEAD + payload.len());
    write_frame_into(msg, codec_id, elems, aux, payload, &mut buf);
    buf
}

/// Serialize a frame into a reusable buffer (cleared first). Produces
/// byte-identical frames to [`write_frame`] — the scratch-buffer form
/// the per-lane hot path uses to avoid a fresh allocation per frame.
pub fn write_frame_into(
    msg: MsgType,
    codec_id: u8,
    elems: usize,
    aux: f64,
    payload: &[u8],
    buf: &mut Vec<u8>,
) {
    debug_assert!(elems <= u32::MAX as usize, "tensor too large for the frame format");
    debug_assert!(payload.len() <= u32::MAX as usize);
    buf.clear();
    buf.reserve(OVERHEAD + payload.len());
    buf.extend_from_slice(&MAGIC);
    buf.push(VERSION);
    buf.push(msg as u8);
    buf.push(codec_id);
    buf.push(0); // flags
    buf.extend_from_slice(&(elems as u32).to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&aux.to_le_bytes());
    buf.extend_from_slice(payload);
    let crc = crc32(buf);
    buf.extend_from_slice(&crc.to_le_bytes());
}

fn read_u32(buf: &[u8], at: usize) -> u32 {
    // Callers have already bounds-checked; the explicit copy keeps the
    // read panic-free even if they have not.
    let mut b = [0u8; 4];
    b.copy_from_slice(&buf[at..at + 4]);
    u32::from_le_bytes(b)
}

/// Validate the envelope and return the header + the payload slice.
/// Rejects (never panics on) truncated, oversized, corrupted, or
/// version-mismatched frames.
pub fn read_frame(buf: &[u8]) -> Result<(FrameHeader, &[u8])> {
    if buf.len() < OVERHEAD {
        return Err(Error::Wire(format!(
            "truncated frame: {} bytes < minimum {OVERHEAD}",
            buf.len()
        )));
    }
    if buf[..4] != MAGIC {
        return Err(Error::Wire("bad magic (not a SuperSFL wire frame)".into()));
    }
    if buf[4] != VERSION {
        return Err(Error::Wire(format!(
            "unsupported frame version {} (this build speaks {VERSION})",
            buf[4]
        )));
    }
    let msg = MsgType::from_u8(buf[5])?;
    let codec_id = buf[6];
    if buf[7] != 0 {
        return Err(Error::Wire(format!("unknown flags 0x{:02x}", buf[7])));
    }
    let elems = read_u32(buf, 8) as usize;
    let payload_len = read_u32(buf, 12) as usize;
    if buf.len() != OVERHEAD + payload_len {
        return Err(Error::Wire(format!(
            "length mismatch: frame is {} bytes but header declares a {payload_len}-byte payload",
            buf.len()
        )));
    }
    let body_end = HEADER_LEN + payload_len;
    let declared_crc = read_u32(buf, body_end);
    let actual_crc = crc32(&buf[..body_end]);
    if declared_crc != actual_crc {
        return Err(Error::Wire(format!(
            "checksum mismatch: frame says {declared_crc:#010x}, computed {actual_crc:#010x}"
        )));
    }
    let mut aux_b = [0u8; 8];
    aux_b.copy_from_slice(&buf[16..24]);
    let aux = f64::from_le_bytes(aux_b);
    Ok((
        FrameHeader {
            msg,
            codec_id,
            elems,
            payload_len,
            aux,
        },
        &buf[HEADER_LEN..body_end],
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // Standard IEEE CRC-32 check values.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn frame_roundtrip_preserves_header_and_payload() {
        let payload = [1u8, 2, 3, 4, 5];
        let buf = write_frame(MsgType::PrefixUpload, 2, 99, -1.25, &payload);
        assert_eq!(buf.len(), OVERHEAD + payload.len());
        let (h, p) = read_frame(&buf).unwrap();
        assert_eq!(h.msg, MsgType::PrefixUpload);
        assert_eq!(h.codec_id, 2);
        assert_eq!(h.elems, 99);
        assert_eq!(h.payload_len, 5);
        assert_eq!(h.aux, -1.25);
        assert_eq!(p, payload);
    }

    #[test]
    fn aux_scalar_is_bit_exact() {
        // The aux field bypasses the payload codec: arbitrary f64 bit
        // patterns must survive exactly.
        for v in [0.0, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE, 1e308] {
            let buf = write_frame(MsgType::Smashed, 0, 0, v, &[]);
            let (h, _) = read_frame(&buf).unwrap();
            assert_eq!(h.aux.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn truncation_at_every_length_is_rejected_not_panicking() {
        let buf = write_frame(MsgType::Broadcast, 1, 8, 0.0, &[9u8; 16]);
        for cut in 0..buf.len() {
            assert!(read_frame(&buf[..cut]).is_err(), "cut at {cut} must fail");
        }
    }

    #[test]
    fn every_single_byte_corruption_is_rejected() {
        let buf = write_frame(MsgType::ActGrad, 3, 4, 2.0, &[7u8; 32]);
        for i in 0..buf.len() {
            let mut bad = buf.clone();
            bad[i] ^= 0x5A;
            assert!(read_frame(&bad).is_err(), "flip at byte {i} must fail");
        }
    }

    #[test]
    fn version_and_msg_type_validation() {
        let mut buf = write_frame(MsgType::Smashed, 0, 1, 0.0, &[0, 0, 0, 0]);
        buf[4] = 9; // future version
        assert!(matches!(read_frame(&buf), Err(crate::Error::Wire(_))));
        assert!(MsgType::from_u8(0).is_err());
        assert!(MsgType::from_u8(11).is_err());
        assert!(MsgType::from_u8(99).is_err());
        for m in [
            MsgType::Smashed,
            MsgType::ActGrad,
            MsgType::PrefixUpload,
            MsgType::Broadcast,
            MsgType::Hello,
            MsgType::HelloAck,
            MsgType::RoundStart,
            MsgType::RoundEnd,
            MsgType::Bye,
            MsgType::Nack,
        ] {
            assert_eq!(MsgType::from_u8(m as u8).unwrap(), m);
        }
    }

    #[test]
    fn params_classification() {
        assert!(!MsgType::Smashed.is_params());
        assert!(!MsgType::ActGrad.is_params());
        assert!(MsgType::PrefixUpload.is_params());
        assert!(MsgType::Broadcast.is_params());
        for m in [
            MsgType::Hello,
            MsgType::HelloAck,
            MsgType::RoundStart,
            MsgType::RoundEnd,
            MsgType::Bye,
            MsgType::Nack,
        ] {
            assert!(m.is_control() && !m.is_params());
        }
        assert!(!MsgType::Smashed.is_control());
        assert!(!MsgType::Broadcast.is_control());
    }

    #[test]
    fn write_frame_into_reuses_buffers_without_stale_bytes() {
        let mut buf = Vec::new();
        // First use: a large frame fills the buffer...
        write_frame_into(MsgType::Smashed, 0, 64, 1.0, &[0xAB; 256], &mut buf);
        assert_eq!(buf, write_frame(MsgType::Smashed, 0, 64, 1.0, &[0xAB; 256]));
        let cap = buf.capacity();
        // ...then a smaller frame must truncate cleanly (no stale tail)
        // and reuse the allocation.
        write_frame_into(MsgType::ActGrad, 2, 3, -0.5, &[1, 2, 3], &mut buf);
        assert_eq!(buf, write_frame(MsgType::ActGrad, 2, 3, -0.5, &[1, 2, 3]));
        assert_eq!(buf.capacity(), cap, "reuse must not reallocate");
        let (h, p) = read_frame(&buf).unwrap();
        assert_eq!(h.msg, MsgType::ActGrad);
        assert_eq!(p, &[1, 2, 3]);
    }

    #[test]
    fn appended_garbage_is_rejected() {
        let mut buf = write_frame(MsgType::Smashed, 1, 1, 0.0, &[1, 2]);
        buf.push(0xFF);
        assert!(read_frame(&buf).is_err());
    }
}
