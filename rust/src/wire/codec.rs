//! Payload codecs: how a flat `f32` tensor becomes wire bytes.
//!
//! Every codec is a pure, deterministic function of its input — no RNG,
//! no global state — so the parallel round engine can encode/decode on
//! any worker thread with bit-identical results for every `--threads N`
//! (the same contract as the rest of the hot path). The encoded size is
//! a pure function of the element count ([`PayloadCodec::encoded_len`]),
//! which lets the network simulator price a response frame before the
//! response tensor exists (the timeout roll needs both directions up
//! front).
//!
//! | codec        | id | bytes/elem      | loss                         |
//! |--------------|----|-----------------|------------------------------|
//! | [`Fp32Raw`]  | 0  | 4               | none (bit-exact)             |
//! | [`Fp16`]     | 1  | 2               | round-to-nearest-even half   |
//! | [`Int8Affine`]| 2 | 1 (+8 header)   | ≤ (max−min)/510 per element  |
//! | [`TopK`]     | 3  | 8·k% (+4)       | drops all but top-k% by |x|  |

use crate::{Error, Result};

/// Codec ids as stored in the frame header.
pub const CODEC_FP32: u8 = 0;
pub const CODEC_FP16: u8 = 1;
pub const CODEC_INT8: u8 = 2;
pub const CODEC_TOPK: u8 = 3;

/// A deterministic tensor payload codec. Object-safe: the wire policy
/// stores `Box<dyn PayloadCodec>` per message class.
pub trait PayloadCodec: Send + Sync {
    /// Frame-header codec id.
    fn id(&self) -> u8;
    /// Human-readable name ("fp32", "int8", "topk:10", …).
    fn label(&self) -> String;
    /// Exact payload size for a tensor of `elems` f32s — a pure function
    /// of the element count, independent of the values.
    fn encoded_len(&self, elems: usize) -> usize;
    /// Append the encoded payload to `out`.
    fn encode_into(&self, data: &[f32], out: &mut Vec<u8>);
    /// Decode a payload back to `elems` f32s into a reusable buffer
    /// (cleared first; contents are unspecified on error). Validates the
    /// payload shape; returns [`Error::Wire`] (never panics) on
    /// malformed input.
    fn decode_into(&self, payload: &[u8], elems: usize, out: &mut Vec<f32>) -> Result<()>;
    /// Allocating convenience form of [`PayloadCodec::decode_into`].
    fn decode(&self, payload: &[u8], elems: usize) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(elems);
        self.decode_into(payload, elems, &mut out)?;
        Ok(out)
    }
}

/// Dispatch a decode on the frame's self-describing codec id (the
/// receiver does not need to know the sender's policy or TopK ratio),
/// into a reusable buffer.
pub fn decode_by_id_into(
    codec_id: u8,
    payload: &[u8],
    elems: usize,
    out: &mut Vec<f32>,
) -> Result<()> {
    match codec_id {
        CODEC_FP32 => Fp32Raw.decode_into(payload, elems, out),
        CODEC_FP16 => Fp16.decode_into(payload, elems, out),
        CODEC_INT8 => Int8Affine.decode_into(payload, elems, out),
        // The TopK ratio is encode-side only.
        CODEC_TOPK => TopK { percent: 1 }.decode_into(payload, elems, out),
        other => Err(Error::Wire(format!("unknown payload codec id {other}"))),
    }
}

/// Allocating convenience form of [`decode_by_id_into`].
pub fn decode_by_id(codec_id: u8, payload: &[u8], elems: usize) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(elems);
    decode_by_id_into(codec_id, payload, elems, &mut out)?;
    Ok(out)
}

// ---------------------------------------------------------------- fp32

/// Raw little-endian f32 — the identity codec. Bit-exact, including NaN
/// payloads and signed zeros, so an `fp32` run's training trajectory is
/// indistinguishable from never serializing at all.
#[derive(Clone, Copy, Debug, Default)]
pub struct Fp32Raw;

impl PayloadCodec for Fp32Raw {
    fn id(&self) -> u8 {
        CODEC_FP32
    }

    fn label(&self) -> String {
        "fp32".into()
    }

    fn encoded_len(&self, elems: usize) -> usize {
        4 * elems
    }

    fn encode_into(&self, data: &[f32], out: &mut Vec<u8>) {
        out.reserve(4 * data.len());
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn decode_into(&self, payload: &[u8], elems: usize, out: &mut Vec<f32>) -> Result<()> {
        if payload.len() != 4 * elems {
            return Err(Error::Wire(format!(
                "fp32 payload is {} bytes, expected {} for {elems} elems",
                payload.len(),
                4 * elems
            )));
        }
        out.clear();
        out.reserve(elems);
        for c in payload.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- fp16

/// IEEE 754 binary16 with round-to-nearest-even (hand-rolled — the
/// offline crate set has no `half`). Overflow saturates to ±∞, NaN maps
/// to the canonical quiet NaN, subnormals and signed zeros are exact.
#[derive(Clone, Copy, Debug, Default)]
pub struct Fp16;

/// f32 → binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let exp = ((b >> 23) & 0xFF) as i32;
    let man = b & 0x007F_FFFF;
    if exp == 255 {
        // Inf stays inf; every NaN becomes the canonical quiet NaN.
        return if man == 0 { sign | 0x7C00 } else { sign | 0x7E00 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow → ±inf
    }
    if unbiased >= -14 {
        // Normal half: 13 mantissa bits shift out with RNE.
        let mant = man >> 13;
        let rem = man & 0x1FFF;
        let mut h = (((unbiased + 15) as u32) << 10) | mant;
        if rem > 0x1000 || (rem == 0x1000 && (mant & 1) == 1) {
            h += 1; // carry may roll into the exponent (correct: → inf)
        }
        return sign | h as u16;
    }
    if unbiased < -25 {
        return sign; // underflows to ±0 even after rounding
    }
    // Subnormal half: shift the full 24-bit significand down with RNE.
    let full = man | 0x0080_0000;
    let shift = (13 - 14 - unbiased) as u32; // in [14, 24]
    let m = full >> shift;
    let rem = full & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let m = if rem > half || (rem == half && (m & 1) == 1) {
        m + 1 // may roll into the smallest normal — still the right bits
    } else {
        m
    };
    sign | m as u16
}

/// binary16 bits → f32 (exact widening).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = (h >> 10) & 0x1F;
    let man = (h & 0x03FF) as u32;
    match exp {
        0 => {
            // ±0 and subnormals: man · 2⁻²⁴ (exactly representable).
            let mag = man as f32 * f32::from_bits(0x3380_0000); // 2^-24
            if sign != 0 {
                -mag
            } else {
                mag
            }
        }
        31 => f32::from_bits(sign | 0x7F80_0000 | (man << 13)),
        e => f32::from_bits(sign | ((e as u32 + 112) << 23) | (man << 13)),
    }
}

impl PayloadCodec for Fp16 {
    fn id(&self) -> u8 {
        CODEC_FP16
    }

    fn label(&self) -> String {
        "fp16".into()
    }

    fn encoded_len(&self, elems: usize) -> usize {
        2 * elems
    }

    fn encode_into(&self, data: &[f32], out: &mut Vec<u8>) {
        out.reserve(2 * data.len());
        for &v in data {
            out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
        }
    }

    fn decode_into(&self, payload: &[u8], elems: usize, out: &mut Vec<f32>) -> Result<()> {
        if payload.len() != 2 * elems {
            return Err(Error::Wire(format!(
                "fp16 payload is {} bytes, expected {} for {elems} elems",
                payload.len(),
                2 * elems
            )));
        }
        out.clear();
        out.reserve(elems);
        for c in payload.chunks_exact(2) {
            out.push(f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- int8

/// Per-tensor affine 8-bit quantization: `x ≈ min + q·scale` with
/// `scale = (max−min)/255` over the tensor's finite values and
/// `q = round((x−min)/scale)` clamped to `[0, 255]`. Payload:
/// `[f32 scale][f32 min][u8 q; elems]`. Worst-case per-element error for
/// finite inputs is `scale/2 = (max−min)/510`; non-finite inputs clamp
/// to the range ends (+∞ → max, −∞/NaN → min), keeping the decode
/// finite and deterministic.
#[derive(Clone, Copy, Debug, Default)]
pub struct Int8Affine;

impl PayloadCodec for Int8Affine {
    fn id(&self) -> u8 {
        CODEC_INT8
    }

    fn label(&self) -> String {
        "int8".into()
    }

    fn encoded_len(&self, elems: usize) -> usize {
        8 + elems
    }

    fn encode_into(&self, data: &[f32], out: &mut Vec<u8>) {
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &v in data {
            if v.is_finite() {
                if v < mn {
                    mn = v;
                }
                if v > mx {
                    mx = v;
                }
            }
        }
        if mn > mx {
            // Empty tensor or no finite values: a degenerate zero range.
            mn = 0.0;
            mx = 0.0;
        }
        // Range arithmetic in f64 so a tensor spanning most of the f32
        // range (a diverging run) cannot overflow the scale to +inf —
        // which the decoder would rightly reject, aborting the whole run
        // instead of degrading like any other lossy tensor.
        let scale64 = ((mx as f64 - mn as f64) / 255.0).min(f32::MAX as f64);
        let scale = scale64 as f32;
        out.reserve(8 + data.len());
        out.extend_from_slice(&scale.to_le_bytes());
        out.extend_from_slice(&mn.to_le_bytes());
        for &v in data {
            let q = if scale > 0.0 {
                // NaN falls through both clamp bounds and casts to 0.
                ((v as f64 - mn as f64) / scale as f64).round().clamp(0.0, 255.0) as u8
            } else {
                0
            };
            out.push(q);
        }
    }

    fn decode_into(&self, payload: &[u8], elems: usize, out: &mut Vec<f32>) -> Result<()> {
        if payload.len() != 8 + elems {
            return Err(Error::Wire(format!(
                "int8 payload is {} bytes, expected {} for {elems} elems",
                payload.len(),
                8 + elems
            )));
        }
        let scale = f32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]);
        let mn = f32::from_le_bytes([payload[4], payload[5], payload[6], payload[7]]);
        if !scale.is_finite() || !mn.is_finite() || scale < 0.0 {
            return Err(Error::Wire(format!(
                "int8 header is not a valid affine map: scale {scale}, min {mn}"
            )));
        }
        out.clear();
        out.reserve(elems);
        out.extend(payload[8..].iter().map(|&q| mn + q as f32 * scale));
        Ok(())
    }
}

// ---------------------------------------------------------------- topk

/// Magnitude top-k sparsification: keep the `percent`% largest-|x|
/// entries (at least one), drop the rest to zero. Ties break toward the
/// lower index, so selection is fully deterministic. Payload:
/// `[u32 count][u32 index; count][f32 value; count]` with indices
/// strictly ascending. Values are shipped in full f32 precision — the
/// loss is the dropped mass, not quantization.
///
/// Meaningful for activation/gradient tensors only; the wire policy
/// never applies it to parameter frames (zeroing 1−k% of raw weights
/// would destroy the model, not compress it — see [`super::Wire`]).
#[derive(Clone, Copy, Debug)]
pub struct TopK {
    /// Kept fraction in percent, clamped to [1, 100] by the parser.
    pub percent: u8,
}

impl TopK {
    /// Entries kept for a tensor of `elems` values.
    pub fn count(&self, elems: usize) -> usize {
        if elems == 0 {
            0
        } else {
            (elems * self.percent as usize / 100).max(1)
        }
    }
}

impl PayloadCodec for TopK {
    fn id(&self) -> u8 {
        CODEC_TOPK
    }

    fn label(&self) -> String {
        format!("topk:{}", self.percent)
    }

    fn encoded_len(&self, elems: usize) -> usize {
        4 + 8 * self.count(elems)
    }

    fn encode_into(&self, data: &[f32], out: &mut Vec<u8>) {
        let n = data.len();
        let k = self.count(n);
        let mut order: Vec<u32> = (0..n as u32).collect();
        if k < n {
            // Total order: |x| descending, index ascending on ties — the
            // same selection on every thread and every run.
            let by_mag = |&i: &u32, &j: &u32| {
                data[j as usize]
                    .abs()
                    .total_cmp(&data[i as usize].abs())
                    .then(i.cmp(&j))
            };
            order.select_nth_unstable_by(k - 1, by_mag);
            order.truncate(k);
        }
        order.sort_unstable(); // ascending index for locality + determinism
        out.reserve(4 + 8 * k);
        out.extend_from_slice(&(k as u32).to_le_bytes());
        for &i in &order {
            out.extend_from_slice(&i.to_le_bytes());
        }
        for &i in &order {
            out.extend_from_slice(&data[i as usize].to_le_bytes());
        }
    }

    fn decode_into(&self, payload: &[u8], elems: usize, out: &mut Vec<f32>) -> Result<()> {
        if payload.len() < 4 {
            return Err(Error::Wire("topk payload shorter than its count".into()));
        }
        let count = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize;
        if payload.len() != 4 + 8 * count {
            return Err(Error::Wire(format!(
                "topk payload is {} bytes, expected {} for count {count}",
                payload.len(),
                4 + 8 * count
            )));
        }
        if count > elems {
            return Err(Error::Wire(format!(
                "topk count {count} exceeds tensor size {elems}"
            )));
        }
        let idx_bytes = &payload[4..4 + 4 * count];
        let val_bytes = &payload[4 + 4 * count..];
        out.clear();
        out.resize(elems, 0.0);
        let mut prev: Option<u32> = None;
        for (ib, vb) in idx_bytes.chunks_exact(4).zip(val_bytes.chunks_exact(4)) {
            let i = u32::from_le_bytes([ib[0], ib[1], ib[2], ib[3]]);
            if i as usize >= elems {
                return Err(Error::Wire(format!(
                    "topk index {i} out of range for {elems} elems"
                )));
            }
            if let Some(p) = prev {
                if i <= p {
                    return Err(Error::Wire(format!(
                        "topk indices not strictly ascending ({p} then {i})"
                    )));
                }
            }
            prev = Some(i);
            out[i as usize] = f32::from_le_bytes([vb[0], vb[1], vb[2], vb[3]]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    fn random_tensor(rng: &mut Pcg32, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    }

    fn roundtrip(codec: &dyn PayloadCodec, data: &[f32]) -> Vec<f32> {
        let mut payload = Vec::new();
        codec.encode_into(data, &mut payload);
        assert_eq!(
            payload.len(),
            codec.encoded_len(data.len()),
            "{} encoded_len must match the actual encoding",
            codec.label()
        );
        codec.decode(&payload, data.len()).unwrap()
    }

    /// The scratch-buffer decode path must be bit-identical to the
    /// allocating one, including when the reused buffer previously held
    /// a *larger* tensor (stale-tail truncation) under every codec id.
    #[test]
    fn prop_decode_into_reuse_matches_decode_bitwise() {
        forall(0xD2C0, 30, |rng| {
            let codecs: [&dyn PayloadCodec; 4] =
                [&Fp32Raw, &Fp16, &Int8Affine, &TopK { percent: 25 }];
            let codec = codecs[rng.uniform_usize(4)];
            let big = random_tensor(rng, 64 + rng.uniform_usize(200), 10.0);
            let small = random_tensor(rng, 1 + rng.uniform_usize(60), 10.0);
            let mut out = Vec::new();
            for data in [&big, &small] {
                let mut payload = Vec::new();
                codec.encode_into(data, &mut payload);
                decode_by_id_into(codec.id(), &payload, data.len(), &mut out).unwrap();
                let fresh = codec.decode(&payload, data.len()).unwrap();
                assert_eq!(out.len(), fresh.len(), "{}", codec.label());
                for (a, b) in out.iter().zip(fresh.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{}", codec.label());
                }
            }
        });
    }

    // ---- fp32 ----

    #[test]
    fn prop_fp32_roundtrip_is_bit_exact() {
        forall(0xF32, 40, |rng| {
            let n = rng.uniform_usize(300);
            let mut data = random_tensor(rng, n, 100.0);
            if n > 2 {
                data[0] = f32::NAN;
                data[1] = f32::NEG_INFINITY;
                data[2] = -0.0;
            }
            let dec = roundtrip(&Fp32Raw, &data);
            for (a, b) in data.iter().zip(dec.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
    }

    // ---- fp16 ----

    #[test]
    fn f16_known_values() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // max finite half
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00); // rounds to inf
        assert_eq!(f32_to_f16_bits(1e9), 0x7C00); // saturates
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xFC00);
        assert_eq!(f32_to_f16_bits(6.103_515_6e-5), 0x0400); // min normal
        assert_eq!(f32_to_f16_bits(5.960_464_5e-8), 0x0001); // min subnormal
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    /// Half → single → half is the identity for every one of the 65536
    /// bit patterns (NaNs map to NaN). The strongest possible exactness
    /// check for both conversion directions.
    #[test]
    fn f16_exhaustive_widening_roundtrip() {
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            let back = f32_to_f16_bits(x);
            if x.is_nan() {
                assert!(f16_bits_to_f32(back).is_nan(), "bits {h:#06x}");
            } else {
                assert_eq!(back, h, "bits {h:#06x} → {x} → {back:#06x}");
            }
        }
    }

    #[test]
    fn prop_fp16_roundtrip_within_half_ulp() {
        forall(0xF16, 60, |rng| {
            let n = 1 + rng.uniform_usize(200);
            let scale = 10f64.powf(rng.uniform_range(-3.0, 3.0));
            let data = random_tensor(rng, n, scale);
            let dec = roundtrip(&Fp16, &data);
            for (&x, &d) in data.iter().zip(dec.iter()) {
                // RNE half: relative error ≤ 2⁻¹¹ in the normal range,
                // absolute ≤ 2⁻²⁵ in the subnormal range.
                let bound = (x.abs() as f64 * 2f64.powi(-11)).max(2f64.powi(-25));
                assert!(
                    ((d - x) as f64).abs() <= bound,
                    "x {x} dec {d} bound {bound}"
                );
            }
        });
    }

    // ---- int8 ----

    #[test]
    fn prop_int8_roundtrip_within_analytic_bound() {
        forall(0x18, 60, |rng| {
            let n = 1 + rng.uniform_usize(300);
            let data = random_tensor(rng, n, 10f64.powf(rng.uniform_range(-2.0, 2.0)));
            let mn = data.iter().cloned().fold(f32::INFINITY, f32::min);
            let mx = data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let scale = (mx - mn) / 255.0;
            let dec = roundtrip(&Int8Affine, &data);
            // Worst case is half a quantization step; the small slack
            // absorbs the fp arithmetic of the map itself (a near-tie in
            // the round can land a hair past scale/2).
            let bound = 0.5 * scale + scale * 1e-3 + 1e-12;
            for (&x, &d) in data.iter().zip(dec.iter()) {
                assert!((d - x).abs() <= bound, "x {x} dec {d} bound {bound}");
            }
        });
    }

    #[test]
    fn int8_degenerate_and_nonfinite_inputs() {
        // Constant tensor → zero range → decodes to the constant.
        let dec = roundtrip(&Int8Affine, &[3.5; 9]);
        assert!(dec.iter().all(|&v| v == 3.5));
        // Empty tensor.
        assert!(roundtrip(&Int8Affine, &[]).is_empty());
        // Non-finite values clamp into the finite range; decode is finite.
        let data = [1.0, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -1.0];
        let dec = roundtrip(&Int8Affine, &data);
        assert!(dec.iter().all(|v| v.is_finite()));
        assert!((dec[2] - 1.0).abs() < 1e-2); // +inf → max
        assert!((dec[3] + 1.0).abs() < 1e-2); // −inf → min
        // A finite range spanning most of f32 must still produce a frame
        // the decoder accepts (scale saturates instead of overflowing).
        let wide = [-3.0e38f32, 3.0e38, 0.0];
        let dec = roundtrip(&Int8Affine, &wide);
        assert!(dec.iter().all(|v| v.is_finite()));
    }

    // ---- topk ----

    #[test]
    fn prop_topk_keeps_the_k_largest_magnitudes() {
        forall(0x70, 60, |rng| {
            let n = 1 + rng.uniform_usize(400);
            let percent = 1 + rng.uniform_usize(50) as u8;
            let codec = TopK { percent };
            let data = random_tensor(rng, n, 1.0);
            let dec = roundtrip(&codec, &data);

            // Reference selection: |x| desc, index asc on ties.
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&i, &j| data[j].abs().total_cmp(&data[i].abs()).then(i.cmp(&j)));
            let k = codec.count(n);
            let keep: std::collections::BTreeSet<usize> = order[..k].iter().copied().collect();

            for (i, (&x, &d)) in data.iter().zip(dec.iter()).enumerate() {
                if keep.contains(&i) {
                    assert_eq!(x.to_bits(), d.to_bits(), "kept entry {i} must be exact");
                } else {
                    assert_eq!(d, 0.0, "dropped entry {i} must be zero");
                }
            }
        });
    }

    #[test]
    fn topk_count_floor_is_one() {
        let c = TopK { percent: 10 };
        assert_eq!(c.count(0), 0);
        assert_eq!(c.count(1), 1);
        assert_eq!(c.count(5), 1); // 0.5 floors, then max(1)
        assert_eq!(c.count(40), 4);
        assert_eq!(TopK { percent: 100 }.count(7), 7);
    }

    #[test]
    fn topk_rejects_malformed_payloads() {
        let codec = TopK { percent: 25 };
        let mut payload = Vec::new();
        codec.encode_into(&[1.0, -5.0, 2.0, 0.5], &mut payload);
        // Valid baseline.
        assert!(codec.decode(&payload, 4).is_ok());
        // Count beyond the tensor.
        assert!(codec.decode(&payload, 0).is_err());
        // Truncated at every prefix.
        for cut in 0..payload.len() {
            assert!(codec.decode(&payload[..cut], 4).is_err());
        }
        // Out-of-range index.
        let mut bad = payload.clone();
        bad[4..8].copy_from_slice(&99u32.to_le_bytes());
        assert!(codec.decode(&bad, 4).is_err());
    }

    #[test]
    fn topk_duplicate_indices_rejected() {
        // Hand-build a payload with a repeated index.
        let mut p = Vec::new();
        p.extend_from_slice(&2u32.to_le_bytes());
        p.extend_from_slice(&1u32.to_le_bytes());
        p.extend_from_slice(&1u32.to_le_bytes());
        p.extend_from_slice(&1.0f32.to_le_bytes());
        p.extend_from_slice(&2.0f32.to_le_bytes());
        assert!(TopK { percent: 50 }.decode(&p, 4).is_err());
    }

    // ---- cross-codec ----

    #[test]
    fn decode_by_id_dispatches_every_codec() {
        let data = [0.5f32, -1.5, 2.0, 0.25];
        for codec in [
            &Fp32Raw as &dyn PayloadCodec,
            &Fp16,
            &Int8Affine,
            &TopK { percent: 50 },
        ] {
            let mut payload = Vec::new();
            codec.encode_into(&data, &mut payload);
            let dec = decode_by_id(codec.id(), &payload, data.len()).unwrap();
            assert_eq!(dec.len(), data.len());
        }
        assert!(decode_by_id(99, &[], 0).is_err());
    }

    #[test]
    fn encoded_len_is_value_independent() {
        forall(0x1E4, 20, |rng| {
            let n = rng.uniform_usize(200);
            let a = random_tensor(rng, n, 1.0);
            let b = random_tensor(rng, n, 1000.0);
            for codec in [
                &Fp32Raw as &dyn PayloadCodec,
                &Fp16,
                &Int8Affine,
                &TopK { percent: 7 },
            ] {
                let (mut pa, mut pb) = (Vec::new(), Vec::new());
                codec.encode_into(&a, &mut pa);
                codec.encode_into(&b, &mut pb);
                assert_eq!(pa.len(), pb.len());
                assert_eq!(pa.len(), codec.encoded_len(n));
            }
        });
    }
}
