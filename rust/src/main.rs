//! `supersfl` — the leader binary / launcher.
//!
//! ```text
//! supersfl train    --method ssfl --clients 50 --classes 10 --rounds 30
//! supersfl allocate --clients 50            # Eq. 1 allocation table
//! supersfl inspect                          # artifact manifest summary
//! ```
//!
//! Any config key from `config::ExperimentConfig::apply_json` can be set
//! with `--set key=value` (repeatable) or a `--config file.json`.

use std::path::PathBuf;
use std::process::ExitCode;

use supersfl::config::{BackendKind, ExperimentConfig, Method};
use supersfl::metrics::Table;
use supersfl::runtime::Runtime;
use supersfl::util::json::{self, JsonValue};
use supersfl::wire::WireCodecKind;
use supersfl::{allocation, network, orchestrator, util::rng::Pcg32, Error, Result};

mod cli;

fn main() -> ExitCode {
    // Graceful SIGINT/SIGTERM: the round loops check the latch at each
    // round boundary and break out, so a signalled run still flushes
    // its partial artifacts and reports the interrupted round.
    supersfl::transport::shutdown::install();
    let args = cli::Args::parse(std::env::args().skip(1));
    let result = match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("allocate") => cmd_allocate(&args),
        Some("inspect") => cmd_inspect(&args),
        Some(other) => {
            eprintln!("unknown command '{other}'");
            usage();
            return ExitCode::FAILURE;
        }
        None => {
            usage();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn usage() {
    eprintln!(
        "usage: supersfl <train|allocate|inspect> [--method ssfl|sfl|dfl] \
         [--clients N] [--classes 10|100] [--rounds N] [--seed N] \
         [--threads N] [--kernel-threads auto|N] [--backend auto|native|pjrt] \
         [--wire-codec fp32|fp16|int8|topk:<k>] \
         [--faults off|ge=..,outage=..,crash=..,corrupt=..,retry=..,quorum=..] \
         [--sample off|N|0.frac] \
         [--transport sim|serve:<addr>|connect:<addr>] [--client-id N] \
         [--chaos-exit round:step] \
         [--trace off|summary|FILE.trace.json] [--progress] \
         [--config file.json] [--set key=value]... [--artifacts DIR] [--out DIR]"
    );
}

fn build_config(args: &cli::Args) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    if let Some(path) = args.get("config") {
        cfg = ExperimentConfig::from_json_file(&PathBuf::from(path))?;
    }
    if let Some(m) = args.get("method") {
        cfg.method = Method::parse(m)?;
    }
    if let Some(v) = args.get("clients") {
        cfg.fleet.clients = v.parse()?;
    }
    if let Some(v) = args.get("classes") {
        cfg.data.classes = v.parse()?;
    }
    if let Some(v) = args.get("rounds") {
        cfg.train.rounds = v.parse()?;
    }
    if let Some(v) = args.get("seed") {
        cfg.train.seed = v.parse()?;
    }
    if let Some(v) = args.get("threads") {
        cfg.threads = v.parse()?;
    }
    if let Some(v) = args.get("kernel-threads") {
        cfg.kernel_threads = supersfl::config::parse_kernel_threads(v)?;
    }
    if let Some(v) = args.get("backend") {
        cfg.backend = BackendKind::parse(v)?;
    }
    if let Some(v) = args.get("wire-codec") {
        cfg.wire = WireCodecKind::parse(v)?;
    }
    if let Some(v) = args.get("faults") {
        cfg.net.faults = network::FaultConfig::parse(v)?;
    }
    if let Some(v) = args.get("sample") {
        cfg.sample = supersfl::config::SampleSpec::parse(v)?;
    }
    if let Some(v) = args.get("transport") {
        cfg.transport = supersfl::transport::TransportSpec::parse(v)?;
    }
    if args.has_flag("trace") {
        return Err(Error::Config(
            "--trace needs a value: off, summary, or a .trace.json output path".into(),
        ));
    }
    if let Some(v) = args.get("trace") {
        cfg.trace = supersfl::trace::TraceSpec::parse(v)?;
    }
    if let Some(v) = args.get("progress") {
        cfg.progress = match v {
            "true" | "on" | "1" => true,
            "false" | "off" | "0" => false,
            other => {
                return Err(Error::Config(format!(
                    "--progress takes no value (or on/off), got '{other}'"
                )))
            }
        };
    } else if args.has_flag("progress") {
        cfg.progress = true;
    }
    if let Some(v) = args.get("target") {
        cfg.train.target_accuracy = Some(v.parse()?);
    }
    if let Some(v) = args.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(v);
    }
    for kv in args.get_all("set") {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("--set expects key=value, got '{kv}'")))?;
        // Numbers and strings both arrive as text; try number first.
        let val = match v.parse::<f64>() {
            Ok(n) => JsonValue::Number(n),
            Err(_) => match v {
                "true" => JsonValue::Bool(true),
                "false" => JsonValue::Bool(false),
                _ => JsonValue::String(v.to_string()),
            },
        };
        let mut o = JsonValue::object();
        o.set(k, val);
        cfg.apply_json(&o)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &cli::Args) -> Result<()> {
    let mut cfg = build_config(args)?;
    // Env-var-wins, same idiom as SUPERSFL_FAULTS/SUPERSFL_SAMPLE; the
    // TCP-mode gates are re-checked after the override.
    cfg.transport = supersfl::transport::TransportSpec::from_env_or(cfg.transport.clone());
    cfg.validate()?;
    println!(
        "supersfl train: method={} clients={} classes={} rounds={} seed={} threads={} wire={}",
        cfg.method.as_str(),
        cfg.fleet.clients,
        cfg.data.classes,
        cfg.train.rounds,
        cfg.train.seed,
        if cfg.threads == 0 {
            "auto".to_string()
        } else {
            cfg.threads.to_string()
        },
        cfg.wire.label()
    );
    if cfg.net.faults.enabled() {
        println!("faults: {}", cfg.net.faults.to_spec());
    }
    if let Some(k) = cfg.sample.cohort_size(cfg.fleet.clients) {
        println!("sampling: {k} of {} clients per round", cfg.fleet.clients);
    }
    if !cfg.transport.is_sim() {
        println!("transport: {}", cfg.transport.label());
    }
    let rt = Runtime::from_config(&cfg)?;
    println!("backend: {}", rt.backend_name());
    let (res, tstats) = match cfg.transport.clone() {
        supersfl::transport::TransportSpec::Sim => {
            (orchestrator::run_experiment(&rt, &cfg)?, None)
        }
        supersfl::transport::TransportSpec::Serve(addr) => {
            let (res, stats) = supersfl::transport::server::run_served(&rt, &cfg, &addr)?;
            (res, Some(stats))
        }
        supersfl::transport::TransportSpec::Connect(addr) => {
            // Client process: local compute + frames only. The server
            // process owns the metrics, artifacts and reporting.
            let id: usize = args
                .get("client-id")
                .ok_or_else(|| {
                    Error::Config("--transport connect:<addr> requires --client-id N".into())
                })?
                .parse()?;
            let chaos = args
                .get("chaos-exit")
                .map(supersfl::transport::client::ChaosExit::parse)
                .transpose()?;
            supersfl::transport::client::run_client(&rt, &cfg, &addr, id, chaos)?;
            return Ok(());
        }
    };
    let wall = res.metrics.host_wall_s;

    let mut table = Table::new(&["round", "acc", "loss(c)", "loss(s)", "comm MB", "sim t(s)", "fallback"]);
    for r in &res.metrics.rounds {
        table.row(&[
            r.round.to_string(),
            format!("{:.3}", r.accuracy),
            format!("{:.3}", r.mean_client_loss),
            format!("{:.3}", r.mean_server_loss),
            format!("{:.1}", r.cum_comm_mb),
            format!("{:.1}", r.sim_time_s),
            r.fallback_steps.to_string(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "final acc {:.3} | best {:.3} | comm {:.1} MB | sim time {:.1} s | avg power {:.0} W | CO2 {:.1} g",
        res.metrics.final_accuracy,
        res.metrics.best_accuracy,
        res.metrics.total_comm_mb,
        res.metrics.total_sim_time_s,
        res.metrics.avg_power_w,
        res.metrics.co2_g
    );
    println!(
        "wire[{}]: {:.1} MB on the link for {:.1} MB raw ({:.2}x compression)",
        res.metrics.wire_codec,
        res.metrics.total_comm_mb,
        res.metrics.total_raw_mb,
        res.metrics.compression
    );
    if let Some(r) = res.metrics.rounds_to_target {
        println!("target reached at round {r}");
    }
    let st = rt.stats();
    println!(
        "runtime[{}]: {} executions, {:.2}s exec, {:.2}s marshal, {} compiles ({:.1}s), wall {:.1}s",
        st.backend, st.executions, st.exec_time_s, st.marshal_time_s, st.compile_count,
        st.compile_time_s, wall
    );
    if st.kernel_threads > 0 {
        println!(
            "kernels[{} threads]: {:.2}s in the kernel core, {:.3}s in shard merges",
            st.kernel_threads, st.kernel_time_s, st.shard_merge_time_s
        );
    }
    if let Some(reason) = &st.fallback_reason {
        println!("note: fell back to the native backend ({reason})");
    }
    if let Some(s) = &res.metrics.straggler {
        println!(
            "stragglers: round time p50 {:.2}s p95 {:.2}s p99 {:.2}s | bytes p50 {:.1} KB p99 {:.1} KB | retries p99 {:.0}",
            s.time_p50, s.time_p95, s.time_p99,
            s.bytes_p50 / 1e3, s.bytes_p99 / 1e3, s.retries_p99
        );
    }
    if let Some(ts) = &tstats {
        let socket_data = ts.data_bytes_in + ts.data_bytes_out;
        println!(
            "transport[{}]: {:.1} MB data on sockets vs {:.1} MB simulated ({}) | \
             {:.1} KB control | {} resyncs | {} quorum holds | {} frame errors",
            cfg.transport.label(),
            socket_data as f64 / 1e6,
            ts.sim_wire_bytes as f64 / 1e6,
            if socket_data == ts.sim_wire_bytes {
                "ledgers match"
            } else {
                "ledgers differ: faults rode the socket"
            },
            ts.ctl_bytes as f64 / 1e3,
            ts.resyncs,
            ts.quorum_holds,
            ts.frame_errors
        );
    }
    if let Some(r) = res.metrics.interrupted_at {
        println!(
            "interrupted by signal before round {r}: partial metrics for {} completed \
             rounds flushed below",
            res.metrics.rounds.len()
        );
    }

    // Chrome-trace export: sim-time events only; host-side numbers
    // (wall clock, runtime stats) ride the metadata block so the event
    // stream stays byte-identical across thread counts and machines.
    if let supersfl::trace::TraceSpec::File(path) = &cfg.trace {
        let report = res.trace.as_ref().ok_or_else(|| {
            Error::Config("trace file requested but the run produced no trace".into())
        })?;
        let mut meta = supersfl::bench_util::provenance(&cfg);
        let mut host = JsonValue::object();
        host.set("host_wall_s", JsonValue::Number(wall));
        host.set("backend", JsonValue::String(st.backend.to_string()));
        host.set("executions", JsonValue::Number(st.executions as f64));
        host.set("exec_time_s", JsonValue::Number(st.exec_time_s));
        host.set("kernel_time_s", JsonValue::Number(st.kernel_time_s));
        host.set(
            "kernel_threads",
            JsonValue::Number(st.kernel_threads as f64),
        );
        meta.set("host", host);
        supersfl::util::fs::atomic_write(
            path,
            report.to_chrome_json(&cfg.wire.label(), &meta).as_bytes(),
        )?;
        println!(
            "wrote trace to {} ({} events, {} dropped)",
            path.display(),
            report.events().len(),
            report.dropped()
        );
    }

    if let Some(out) = args.get("out") {
        let dir = PathBuf::from(out);
        let base = format!("{}_{}", cfg.name, cfg.method.as_str());
        res.metrics.write_csv(&dir.join(format!("{base}.csv")))?;
        // The run-summary JSON carries the shared provenance stamp, so
        // an artifact directory is self-describing.
        let mut run_json = res.metrics.to_json();
        run_json.set("provenance", supersfl::bench_util::provenance(&cfg));
        if let Some(ts) = &tstats {
            run_json.set("transport", ts.to_json(&cfg.transport.label()));
        }
        supersfl::util::fs::atomic_write(
            &dir.join(format!("{base}.json")),
            run_json.to_string_pretty().as_bytes(),
        )?;
        supersfl::util::fs::atomic_write(
            &dir.join(format!("{base}_config.json")),
            cfg.to_json().to_string_pretty().as_bytes(),
        )?;
        println!("wrote results to {}", dir.display());
    }
    Ok(())
}

fn cmd_allocate(args: &cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let rt = Runtime::from_config(&cfg)?;
    let mut rng = Pcg32::new(cfg.train.seed, 0xD15EA5E).fork(3);
    let profiles = network::sample_fleet(&cfg.fleet, &cfg.energy, &mut rng);
    let assignments = allocation::allocate(&profiles, &cfg.alloc, rt.model().depth);

    let mut table = Table::new(&["client", "mem GB", "lat ms", "GFLOP/s", "depth", "params"]);
    for (p, a) in profiles.iter().zip(assignments.iter()) {
        let params: usize = rt.model().enc_layer_sizes[..a.depth].iter().sum();
        table.row(&[
            p.id.to_string(),
            format!("{:.1}", p.mem_gb),
            format!("{:.0}", p.latency_s * 1e3),
            format!("{:.0}", p.flops / 1e9),
            a.depth.to_string(),
            params.to_string(),
        ]);
    }
    println!("{}", table.render());
    let hist = allocation::depth_histogram(&assignments, rt.model().depth);
    println!("depth histogram: {hist:?}");
    Ok(())
}

fn cmd_inspect(args: &cli::Args) -> Result<()> {
    let cfg = build_config(args)?;
    let dir = cfg.artifacts_dir.clone();
    let rt = Runtime::from_config(&cfg)?;
    let m = rt.model();
    println!("backend: {}", rt.backend_name());
    if let Some(reason) = rt.stats().fallback_reason {
        println!("  (native fallback: {reason})");
    }
    println!(
        "model: dim={} depth={} tokens={} batch={} eval_batch={} enc_params={}",
        m.dim, m.depth, m.tokens, m.batch, m.eval_batch, m.enc_full_size
    );
    println!("enc layer sizes: {:?}", m.enc_layer_sizes);
    let names = rt.artifact_names();
    println!("{} artifacts:", names.len());
    for n in names {
        println!("  {n}");
    }
    // Build metadata only exists for the AOT-artifact path.
    if rt.backend_name() == "pjrt" {
        let manifest = json::parse_file(&dir.join("manifest.json"))?;
        let profile = manifest
            .get("build")
            .and_then(|b| b.get("profile"))
            .and_then(|p| p.as_str())
            .unwrap_or("?");
        println!("artifacts dir: {}", dir.display());
        println!("build profile: {profile}");
    }
    Ok(())
}
