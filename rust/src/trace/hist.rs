//! Zero-alloc fixed-log-bucket histograms for per-client telemetry.
//!
//! [`LogHist`] is an HDR-style log-linear histogram over non-negative
//! `f64` samples: 64 octaves (binary exponents −32…31) × 4 linear
//! sub-buckets per octave, plus a dedicated zero bucket — 257 fixed
//! `u64` counters, no heap, no libm. Bucket boundaries are
//! `2^e · (1 + m/4)` (exactly representable), so indexing is pure f64
//! bit manipulation and the relative quantization error is ≤ 12.5%
//! (half a sub-bucket at the midpoint representative).
//!
//! Merging is element-wise addition — associative and commutative — so
//! per-round histograms fold into run-level ones in any grouping and
//! the result is identical (pinned by a property test below).

/// Zero bucket + 64 octaves × 4 sub-buckets.
const BUCKETS: usize = 257;

/// Fixed-size log-linear histogram (see module docs).
#[derive(Clone, Debug)]
pub struct LogHist {
    counts: [u64; BUCKETS],
    n: u64,
}

impl Default for LogHist {
    fn default() -> Self {
        LogHist {
            counts: [0; BUCKETS],
            n: 0,
        }
    }
}

/// Bucket index for a sample. Zero, negatives and NaN land in the zero
/// bucket (telemetry values are non-negative by construction; a NaN
/// must not poison the percentiles). Values below 2^−32 clamp into the
/// first real bucket, values at or above 2^32 into the last.
fn bucket_index(v: f64) -> usize {
    if !(v > 0.0) {
        return 0;
    }
    let bits = v.to_bits();
    let e = ((bits >> 52) & 0x7ff) as i64 - 1023;
    if e < -32 {
        return 1;
    }
    if e > 31 {
        return BUCKETS - 1;
    }
    let m = ((bits >> 50) & 0b11) as usize;
    (1 + (e + 32) * 4) as usize + m
}

/// `[lo, hi)` boundaries of a bucket. Bucket 0 is the zero bucket.
fn bucket_bounds(idx: usize) -> (f64, f64) {
    if idx == 0 {
        return (0.0, 0.0);
    }
    let q = idx - 1;
    let e = (q / 4) as i32 - 32;
    let m = (q % 4) as f64;
    let step = f64::exp2(e as f64) * 0.25;
    let lo = f64::exp2(e as f64) + m * step;
    (lo, lo + step)
}

/// Representative value reported for a bucket: the arithmetic midpoint
/// (so the worst-case relative error against any in-bucket sample is
/// 12.5%). The zero bucket reports exactly 0.
fn representative(idx: usize) -> f64 {
    let (lo, hi) = bucket_bounds(idx);
    (lo + hi) * 0.5
}

impl LogHist {
    pub fn new() -> LogHist {
        LogHist::default()
    }

    /// Record one sample. O(1), allocation-free.
    pub fn record(&mut self, v: f64) {
        self.counts[bucket_index(v)] += 1;
        self.n += 1;
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Fold `other` into `self` (element-wise add — associative, so
    /// round→run folding order never matters).
    pub fn merge(&mut self, other: &LogHist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.n += other.n;
    }

    /// Reset to empty (round-boundary reuse; no allocation).
    pub fn clear(&mut self) {
        self.counts = [0; BUCKETS];
        self.n = 0;
    }

    /// Nearest-rank percentile: the representative of the bucket holding
    /// the `max(1, ⌈q·n⌉)`-th smallest sample. Empty histogram → 0.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return representative(idx);
            }
        }
        representative(BUCKETS - 1)
    }
}

/// The straggler-skew signal: p50/p95/p99 of per-client round time,
/// wire bytes, and retry count across one round (or a whole run — the
/// same shape lands in `RoundRecord` and `RunMetrics`).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct StragglerStats {
    pub time_p50: f64,
    pub time_p95: f64,
    pub time_p99: f64,
    pub bytes_p50: f64,
    pub bytes_p95: f64,
    pub bytes_p99: f64,
    pub retries_p50: f64,
    pub retries_p95: f64,
    pub retries_p99: f64,
}

impl StragglerStats {
    pub fn from_hists(time: &LogHist, bytes: &LogHist, retries: &LogHist) -> StragglerStats {
        StragglerStats {
            time_p50: time.percentile(0.50),
            time_p95: time.percentile(0.95),
            time_p99: time.percentile(0.99),
            bytes_p50: bytes.percentile(0.50),
            bytes_p95: bytes.percentile(0.95),
            bytes_p99: bytes.percentile(0.99),
            retries_p50: retries.percentile(0.50),
            retries_p95: retries.percentile(0.95),
            retries_p99: retries.percentile(0.99),
        }
    }

    /// CSV column names, in emission order (appended to the metrics
    /// header only when telemetry ran — `--trace off` keeps the legacy
    /// header byte-identical).
    pub const CSV_COLUMNS: &str =
        "time_p50,time_p95,time_p99,bytes_p50,bytes_p95,bytes_p99,\
         retries_p50,retries_p95,retries_p99";

    /// Values in [`Self::CSV_COLUMNS`] order.
    pub fn csv_fields(&self) -> [f64; 9] {
        [
            self.time_p50,
            self.time_p95,
            self.time_p99,
            self.bytes_p50,
            self.bytes_p95,
            self.bytes_p99,
            self.retries_p50,
            self.retries_p95,
            self.retries_p99,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn zero_and_pathological_samples_land_in_zero_bucket() {
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-1.0), 0);
        assert_eq!(bucket_index(f64::NAN), 0);
        let mut h = LogHist::new();
        h.record(0.0);
        assert_eq!(h.percentile(0.5), 0.0);
    }

    #[test]
    fn bucket_boundaries_are_exact() {
        // Every bucket's low boundary must index into that bucket, and
        // the high boundary into the next (half-open intervals).
        forall(0xB0B5, 400, |rng: &mut Pcg32| {
            let idx = 1 + rng.uniform_usize(BUCKETS - 2); // skip zero + top catch-all
            let (lo, hi) = bucket_bounds(idx);
            assert_eq!(bucket_index(lo), idx, "lo {lo} of bucket {idx}");
            assert_eq!(bucket_index(hi), idx + 1, "hi {hi} of bucket {idx}");
            // An interior point stays put.
            let mid = lo + (hi - lo) * rng.uniform();
            if mid < hi {
                assert_eq!(bucket_index(mid), idx, "mid {mid} of bucket {idx}");
            }
        });
    }

    #[test]
    fn representative_is_within_quantization_error() {
        forall(0xC4FE, 400, |rng: &mut Pcg32| {
            // Log-uniform samples across the whole representable range.
            let e = rng.uniform() * 60.0 - 30.0;
            let v = f64::exp2(e) * (1.0 + rng.uniform());
            let h = {
                let mut h = LogHist::new();
                h.record(v);
                h
            };
            let rep = h.percentile(0.5);
            let rel = (rep - v).abs() / v;
            assert!(rel <= 0.125 + 1e-12, "v={v} rep={rep} rel={rel}");
        });
    }

    #[test]
    fn merge_is_associative_and_matches_bulk_recording() {
        forall(0xAB5, 60, |rng: &mut Pcg32| {
            let sample = |rng: &mut Pcg32, n: usize| {
                let mut h = LogHist::new();
                let mut vals = Vec::new();
                for _ in 0..n {
                    let v = f64::exp2(rng.uniform() * 40.0 - 20.0);
                    h.record(v);
                    vals.push(v);
                }
                (h, vals)
            };
            let (a, va) = sample(rng, rng.uniform_usize(20));
            let (b, vb) = sample(rng, rng.uniform_usize(20));
            let (c, vc) = sample(rng, rng.uniform_usize(20));

            // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c) == one hist of all samples.
            let mut left = a.clone();
            left.merge(&b);
            left.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            let mut bulk = LogHist::new();
            for v in va.iter().chain(&vb).chain(&vc) {
                bulk.record(*v);
            }
            assert_eq!(left.counts, right.counts);
            assert_eq!(left.counts, bulk.counts);
            assert_eq!(left.n, bulk.n);
            for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
                assert_eq!(left.percentile(q).to_bits(), right.percentile(q).to_bits());
            }
        });
    }

    /// Percentiles vs a sorted-vector nearest-rank oracle at awkward
    /// sizes. The histogram may only differ by its ≤ 12.5% bucket
    /// quantization — rank selection itself must match exactly.
    #[test]
    fn percentiles_match_sorted_vector_oracle_at_awkward_sizes() {
        for n in [0usize, 1, 2, 33] {
            forall(0x0DDB ^ n as u64, 40, |rng: &mut Pcg32| {
                let mut vals = Vec::with_capacity(n);
                let mut h = LogHist::new();
                for _ in 0..n {
                    let v = f64::exp2(rng.uniform() * 24.0 - 12.0);
                    vals.push(v);
                    h.record(v);
                }
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                for q in [0.5, 0.95, 0.99] {
                    let got = h.percentile(q);
                    if n == 0 {
                        assert_eq!(got, 0.0);
                        continue;
                    }
                    let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                    let oracle = vals[rank - 1];
                    let rel = (got - oracle).abs() / oracle;
                    assert!(
                        rel <= 0.125 + 1e-12,
                        "n={n} q={q}: oracle {oracle} vs hist {got} (rel {rel})"
                    );
                }
            });
        }
    }

    #[test]
    fn straggler_stats_fold_three_signals() {
        let mut t = LogHist::new();
        let mut b = LogHist::new();
        let mut r = LogHist::new();
        for i in 1..=100u32 {
            t.record(i as f64);
            b.record(1000.0 * i as f64);
            r.record(if i > 90 { 2.0 } else { 0.0 });
        }
        let s = StragglerStats::from_hists(&t, &b, &r);
        assert!((s.time_p50 - 50.0).abs() / 50.0 <= 0.125);
        assert!((s.time_p99 - 99.0).abs() / 99.0 <= 0.125);
        assert!(s.time_p95 <= s.time_p99);
        assert!((s.bytes_p50 - 50_000.0).abs() / 50_000.0 <= 0.125);
        assert_eq!(s.retries_p50, 0.0);
        assert!(s.retries_p99 > 1.0);
        assert_eq!(s.csv_fields().len(), 9);
    }
}
