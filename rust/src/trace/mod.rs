//! Deterministic span tracing + per-client telemetry.
//!
//! A zero-dependency structured tracing subsystem threaded through the
//! whole round path (SSFL/SFL/DFL), answering the attribution questions
//! the fleet-level aggregates cannot: which clients straggle, how much
//! of a hostile round is retry/backoff vs compute, what the split-point
//! allocator should react to.
//!
//! ## Clocks and determinism
//!
//! Every event carries **deterministic `SimClock` sim-time only**. Host
//! wall-time and backend profiling counters (`RuntimeStats`) are
//! *segregated by construction*: they ride in the caller-supplied
//! metadata block of the exported file and never into `traceEvents`, so
//! a traced run's event stream is byte-identical across `--threads` /
//! `--kernel-threads` and `--trace off` runs stay bit-identical to the
//! pre-trace goldens (no golden re-bless).
//!
//! ## Fork discipline
//!
//! Each client lane records into its own [`TraceBuf`] (riding the
//! `RoundLedger` the same way `NetLane` forks do); the harness drains
//! the buffers **in client-id order at the round barrier**, so the
//! merged event stream is independent of worker-thread interleaving.
//! `--trace off` (the default) makes every record call a
//! branch-on-bool no-op on the hot path.
//!
//! ## Outputs
//!
//! * Chrome trace-event JSON (`--trace out.trace.json`): one track per
//!   client lane plus `server` and `barrier` tracks; loadable in
//!   Perfetto / `chrome://tracing`.
//! * Per-client round summaries folded into [`hist::LogHist`]
//!   fixed-log-bucket histograms; their p50/p95/p99 (round time, wire
//!   bytes, retries) land as straggler columns in
//!   `RoundRecord`/`RunMetrics` (`--trace summary` enables this without
//!   writing an event file).

pub mod hist;

pub use hist::{LogHist, StragglerStats};

use std::path::PathBuf;

use crate::util::json::JsonValue;
use crate::{Error, Result};

/// Tracing mode (`--trace off|summary|<path>`, `trace` config key).
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum TraceSpec {
    /// No tracing (the default): zero hot-path work, output shape
    /// byte-identical to the pre-trace simulator.
    #[default]
    Off,
    /// Per-client telemetry (straggler histograms + percentile columns)
    /// without retaining the event stream.
    Summary,
    /// Full event recording, exported as Chrome trace-event JSON to the
    /// given path (plus everything `Summary` produces).
    File(PathBuf),
}

impl TraceSpec {
    pub fn parse(s: &str) -> Result<TraceSpec> {
        let t = s.trim();
        if t.is_empty() {
            return Err(Error::Config(
                "--trace expects off|summary|<path.json>".into(),
            ));
        }
        match t.to_ascii_lowercase().as_str() {
            "off" => Ok(TraceSpec::Off),
            "summary" => Ok(TraceSpec::Summary),
            _ => Ok(TraceSpec::File(PathBuf::from(t))),
        }
    }

    /// Canonical string form: `TraceSpec::parse(x.label()) == x`.
    pub fn label(&self) -> String {
        match self {
            TraceSpec::Off => "off".into(),
            TraceSpec::Summary => "summary".into(),
            TraceSpec::File(p) => p.display().to_string(),
        }
    }

    /// Whether any telemetry is recorded at all.
    pub fn enabled(&self) -> bool {
        *self != TraceSpec::Off
    }

    /// Whether the full event stream is retained for export.
    pub fn keeps_events(&self) -> bool {
        matches!(self, TraceSpec::File(_))
    }
}

/// Span categories. Names are the Chrome-trace event names.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// TPGF Phase 1 (or the baselines' client forward): the client-side
    /// local update producing smashed activations + local gradients.
    LocalUpdate,
    /// Server-side deep-suffix compute, attributed inside the exchange
    /// window of the client that requested it.
    ServerCompute,
    /// TPGF Phase 3 gradient fusion + weight update (baselines: the
    /// client backward pass).
    Fusion,
    /// Alg. 3 local-only fallback step after a failed exchange.
    Fallback,
    /// Wire-frame encode (bytes attr = encoded frame length).
    Encode,
    /// Wire-frame decode.
    Decode,
    /// One full faulted exchange including every retry and backoff.
    Exchange,
    /// A single attempt within an exchange (aux = 1-based attempt no).
    Attempt,
    /// Retry backoff sleep between attempts.
    Backoff,
    /// Crash-rejoin resync download at the round barrier.
    Resync,
    /// Aggregation uploads + merge at the barrier (server track).
    Aggregate,
    /// Global-model broadcast (server track).
    Broadcast,
    /// Round evaluation (server track).
    Eval,
    /// Straggler wait at the round barrier (barrier track).
    BarrierWait,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::LocalUpdate => "local_update",
            SpanKind::ServerCompute => "server_compute",
            SpanKind::Fusion => "fusion",
            SpanKind::Fallback => "fallback",
            SpanKind::Encode => "encode",
            SpanKind::Decode => "decode",
            SpanKind::Exchange => "exchange",
            SpanKind::Attempt => "attempt",
            SpanKind::Backoff => "backoff",
            SpanKind::Resync => "resync",
            SpanKind::Aggregate => "aggregate",
            SpanKind::Broadcast => "broadcast",
            SpanKind::Eval => "eval",
            SpanKind::BarrierWait => "barrier_wait",
        }
    }

    /// Wire-layer spans get the run's codec label as an event attr.
    fn is_wire(self) -> bool {
        matches!(
            self,
            SpanKind::Encode | SpanKind::Decode | SpanKind::Exchange | SpanKind::Attempt
        )
    }
}

/// Fault instants — one per ledger fault class, so every counted fault
/// is visible on the timeline of the client it hit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InstantKind {
    Timeout,
    Drop,
    Corruption,
    Crash,
}

impl InstantKind {
    pub fn name(self) -> &'static str {
        match self {
            InstantKind::Timeout => "timeout",
            InstantKind::Drop => "drop",
            InstantKind::Corruption => "corruption",
            InstantKind::Crash => "crash",
        }
    }
}

/// One recorded event. Times are sim-seconds; lane-local buffers store
/// branch-relative times which the harness offsets to absolute sim time
/// when draining at the barrier.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceEvent {
    Span {
        kind: SpanKind,
        t0: f64,
        dur: f64,
        /// Wire bytes attributed to the span (0 = no byte attr).
        bytes: u64,
        /// Kind-specific attr (attempt number, participant count, …).
        aux: u64,
    },
    Instant { kind: InstantKind, t: f64 },
}

impl TraceEvent {
    /// Start time (for ordering / nesting checks).
    pub fn t0(&self) -> f64 {
        match self {
            TraceEvent::Span { t0, .. } => *t0,
            TraceEvent::Instant { t, .. } => *t,
        }
    }

    fn shifted(self, dt: f64) -> TraceEvent {
        match self {
            TraceEvent::Span {
                kind,
                t0,
                dur,
                bytes,
                aux,
            } => TraceEvent::Span {
                kind,
                t0: t0 + dt,
                dur,
                bytes,
                aux,
            },
            TraceEvent::Instant { kind, t } => TraceEvent::Instant { kind, t: t + dt },
        }
    }
}

/// Per-attempt record of one faulted exchange, written by
/// `network::exchange_impl` into the lane's `NetLane` when tracing is
/// on, and replayed into spans by the call site (which owns the
/// sim-time cursor). Keeping the record here — not in `network` —
/// keeps the dependency direction `network → trace`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AttemptRec {
    /// Backoff charged before this attempt (0 for the first).
    pub backoff_s: f64,
    /// Sim-time this attempt consumed (timeout window on failure;
    /// up + server + down on success).
    pub cost_s: f64,
    /// Uplink transfer time (success only; 0 otherwise).
    pub up_s: f64,
    /// Server compute inside the exchange window (success only).
    pub server_s: f64,
    pub outcome: AttemptOutcome,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttemptOutcome {
    Ok,
    /// Server unreachable or response past the timeout window.
    Timeout,
    /// Transient link drop (GE bad state or `drop_prob`).
    Drop,
}

/// Hard cap on events one lane can record in one round — a backstop
/// against a pathological schedule ballooning memory, not a limit any
/// real round approaches (a traced round records O(steps) events).
const MAX_LANE_EVENTS: usize = 1 << 16;

/// Lane-local event buffer. Rides the `RoundLedger` through the fork /
/// absorb-in-client-id-order discipline, so traced runs stay bitwise
/// thread-invariant. When disabled every call is a branch-and-return.
#[derive(Clone, Debug, Default)]
pub struct TraceBuf {
    enabled: bool,
    events: Vec<TraceEvent>,
    dropped: u64,
}

impl TraceBuf {
    pub fn new(enabled: bool) -> TraceBuf {
        TraceBuf {
            enabled,
            events: Vec::new(),
            dropped: 0,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    fn push(&mut self, ev: TraceEvent) {
        if self.events.len() >= MAX_LANE_EVENTS {
            self.dropped += 1;
            return;
        }
        self.events.push(ev);
    }

    /// Record a span at branch-relative `t0`.
    pub fn span(&mut self, kind: SpanKind, t0: f64, dur: f64, bytes: u64, aux: u64) {
        if self.enabled {
            self.push(TraceEvent::Span {
                kind,
                t0,
                dur,
                bytes,
                aux,
            });
        }
    }

    /// Record a fault instant at branch-relative `t`.
    pub fn instant(&mut self, kind: InstantKind, t: f64) {
        if self.enabled {
            self.push(TraceEvent::Instant { kind, t });
        }
    }

    /// Replay one exchange's attempt log into spans + fault instants:
    /// an `exchange` parent span covering every retry, per-attempt
    /// `attempt` spans (server compute nested inside the successful
    /// one), `backoff` spans between attempts, and a timeout/drop
    /// instant at the point each failed attempt gave up.
    pub fn exchange_spans(&mut self, t0: f64, attempts: &[AttemptRec], bytes: u64) {
        if !self.enabled || attempts.is_empty() {
            return;
        }
        let total: f64 = attempts.iter().map(|a| a.backoff_s + a.cost_s).sum();
        self.span(SpanKind::Exchange, t0, total, bytes, attempts.len() as u64);
        let mut t = t0;
        for (i, a) in attempts.iter().enumerate() {
            if a.backoff_s > 0.0 {
                self.span(SpanKind::Backoff, t, a.backoff_s, 0, i as u64);
                t += a.backoff_s;
            }
            self.span(SpanKind::Attempt, t, a.cost_s, 0, i as u64 + 1);
            match a.outcome {
                AttemptOutcome::Ok => {
                    if a.server_s > 0.0 {
                        self.span(SpanKind::ServerCompute, t + a.up_s, a.server_s, 0, 0);
                    }
                }
                AttemptOutcome::Timeout => self.instant(InstantKind::Timeout, t + a.cost_s),
                AttemptOutcome::Drop => self.instant(InstantKind::Drop, t + a.cost_s),
            }
            t += a.cost_s;
        }
    }
}

/// Fixed Chrome-trace track ids.
pub const TRACK_SERVER: u32 = 0;
pub const TRACK_BARRIER: u32 = 1;

/// Track id for a client lane.
pub fn client_track(client: usize) -> u32 {
    2 + client as u32
}

/// The harness-owned recorder: absorbs lane buffers at the barrier,
/// folds per-client round summaries into histograms, and (in `File`
/// mode) accumulates the global event stream for export.
#[derive(Debug)]
pub struct Tracer {
    keep_events: bool,
    events: Vec<(u32, TraceEvent)>,
    dropped: u64,
    round_time: LogHist,
    round_bytes: LogHist,
    round_retries: LogHist,
    run_time: LogHist,
    run_bytes: LogHist,
    run_retries: LogHist,
}

impl Tracer {
    /// `None` when tracing is off — the round loops then skip every
    /// trace call via `Option` checks that cost one branch.
    pub fn from_spec(spec: &TraceSpec) -> Option<Tracer> {
        if !spec.enabled() {
            return None;
        }
        Some(Tracer {
            keep_events: spec.keeps_events(),
            events: Vec::new(),
            dropped: 0,
            round_time: LogHist::new(),
            round_bytes: LogHist::new(),
            round_retries: LogHist::new(),
            run_time: LogHist::new(),
            run_bytes: LogHist::new(),
            run_retries: LogHist::new(),
        })
    }

    /// Whether lane `TraceBuf`s should record events (File mode). In
    /// Summary mode lanes skip event recording entirely.
    pub fn lane_events_enabled(&self) -> bool {
        self.keep_events
    }

    /// Absorb one lane's buffer at the barrier. `round_t0` is the
    /// absolute sim time the branch started; lane events are
    /// branch-relative. MUST be called in ascending client-id order —
    /// the caller's existing absorb loop already is.
    pub fn drain_lane(&mut self, client: usize, round_t0: f64, buf: &mut TraceBuf) {
        self.dropped += buf.dropped;
        buf.dropped = 0;
        if !self.keep_events {
            buf.events.clear();
            return;
        }
        let track = client_track(client);
        for ev in buf.events.drain(..) {
            self.events.push((track, ev.shifted(round_t0)));
        }
    }

    /// Record a span on the server/barrier track at absolute sim time.
    pub fn track_span(&mut self, track: u32, kind: SpanKind, t0: f64, dur: f64, bytes: u64, aux: u64) {
        if self.keep_events {
            self.events.push((
                track,
                TraceEvent::Span {
                    kind,
                    t0,
                    dur,
                    bytes,
                    aux,
                },
            ));
        }
    }

    /// Record a fault instant on an arbitrary track at absolute sim time.
    pub fn track_instant(&mut self, track: u32, kind: InstantKind, t: f64) {
        if self.keep_events {
            self.events.push((track, TraceEvent::Instant { kind, t }));
        }
    }

    /// Fold one client's round summary into the straggler histograms.
    pub fn fold_client(&mut self, time_s: f64, wire_bytes: u64, retries: u64) {
        self.round_time.record(time_s);
        self.round_bytes.record(wire_bytes as f64);
        self.round_retries.record(retries as f64);
    }

    /// Close the round: emit its straggler percentiles, merge the round
    /// histograms into the run-level ones, and reset for the next round.
    pub fn finish_round(&mut self) -> StragglerStats {
        let stats =
            StragglerStats::from_hists(&self.round_time, &self.round_bytes, &self.round_retries);
        self.run_time.merge(&self.round_time);
        self.run_bytes.merge(&self.round_bytes);
        self.run_retries.merge(&self.round_retries);
        self.round_time.clear();
        self.round_bytes.clear();
        self.round_retries.clear();
        stats
    }

    /// Run-level straggler percentiles (merged across all rounds).
    pub fn run_straggler(&self) -> StragglerStats {
        StragglerStats::from_hists(&self.run_time, &self.run_bytes, &self.run_retries)
    }

    /// Finish the run: hand the accumulated event stream to the report.
    pub fn into_report(self) -> TraceReport {
        TraceReport {
            events: self.events,
            dropped: self.dropped,
        }
    }
}

/// The exported event stream of one run, returned on
/// `RunResult::trace` so tests can verify determinism and nesting
/// without any file I/O.
#[derive(Clone, Debug, Default)]
pub struct TraceReport {
    events: Vec<(u32, TraceEvent)>,
    dropped: u64,
}

impl TraceReport {
    pub fn events(&self) -> &[(u32, TraceEvent)] {
        &self.events
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Human label for a track id.
    pub fn track_label(track: u32) -> String {
        match track {
            TRACK_SERVER => "server".into(),
            TRACK_BARRIER => "barrier".into(),
            c => format!("client {}", c - 2),
        }
    }

    /// Serialize as Chrome trace-event JSON (the `chrome://tracing` /
    /// Perfetto format): `ph:"X"` complete events for spans, `ph:"i"`
    /// thread-scoped instants for faults, `ph:"M"` thread_name metadata
    /// for every track that appears. Timestamps are sim-time
    /// microseconds — **deterministic by construction**. Host-side
    /// context (wall time, `RuntimeStats`) belongs in `metadata`, which
    /// the caller controls; passing the same metadata yields
    /// byte-identical output for any `--threads`/`--kernel-threads`.
    pub fn to_chrome_json(&self, codec: &str, metadata: &JsonValue) -> String {
        let num = JsonValue::Number;
        let st = |s: &str| JsonValue::String(s.to_string());
        let mut root = JsonValue::object();
        root.set("displayTimeUnit", st("ms"));
        root.set("metadata", metadata.clone());
        if self.dropped > 0 {
            root.set("dropped_events", num(self.dropped as f64));
        }
        let mut evs = Vec::new();

        // One thread_name metadata event per track, in track order.
        let mut tracks: Vec<u32> = self.events.iter().map(|(t, _)| *t).collect();
        tracks.sort_unstable();
        tracks.dedup();
        for &t in &tracks {
            let mut m = JsonValue::object();
            m.set("name", st("thread_name"));
            m.set("ph", st("M"));
            m.set("pid", num(0.0));
            m.set("tid", num(t as f64));
            let mut args = JsonValue::object();
            args.set("name", JsonValue::String(Self::track_label(t)));
            m.set("args", args);
            evs.push(m);
        }

        for (track, ev) in &self.events {
            let mut o = JsonValue::object();
            match ev {
                TraceEvent::Span {
                    kind,
                    t0,
                    dur,
                    bytes,
                    aux,
                } => {
                    o.set("name", st(kind.name()));
                    o.set("ph", st("X"));
                    o.set("pid", num(0.0));
                    o.set("tid", num(*track as f64));
                    o.set("ts", num(t0 * 1e6));
                    o.set("dur", num(dur * 1e6));
                    let mut args = JsonValue::object();
                    if *bytes > 0 {
                        args.set("bytes", num(*bytes as f64));
                    }
                    if *aux > 0 {
                        args.set("n", num(*aux as f64));
                    }
                    if kind.is_wire() {
                        args.set("codec", st(codec));
                    }
                    if args.entries().map(|e| !e.is_empty()).unwrap_or(false) {
                        o.set("args", args);
                    }
                }
                TraceEvent::Instant { kind, t } => {
                    o.set("name", st(kind.name()));
                    o.set("ph", st("i"));
                    o.set("s", st("t"));
                    o.set("pid", num(0.0));
                    o.set("tid", num(*track as f64));
                    o.set("ts", num(t * 1e6));
                }
            }
            evs.push(o);
        }
        root.set("traceEvents", JsonValue::Array(evs));
        root.to_string_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_and_roundtrips() {
        assert_eq!(TraceSpec::parse("off").unwrap(), TraceSpec::Off);
        assert_eq!(TraceSpec::parse("OFF").unwrap(), TraceSpec::Off);
        assert_eq!(TraceSpec::parse("summary").unwrap(), TraceSpec::Summary);
        assert_eq!(
            TraceSpec::parse("out.trace.json").unwrap(),
            TraceSpec::File(PathBuf::from("out.trace.json"))
        );
        assert!(TraceSpec::parse("  ").is_err());
        for sp in [
            TraceSpec::Off,
            TraceSpec::Summary,
            TraceSpec::File(PathBuf::from("/tmp/t.json")),
        ] {
            assert_eq!(TraceSpec::parse(&sp.label()).unwrap(), sp);
        }
        assert!(!TraceSpec::Off.enabled());
        assert!(TraceSpec::Summary.enabled());
        assert!(!TraceSpec::Summary.keeps_events());
        assert!(TraceSpec::File(PathBuf::from("x")).keeps_events());
    }

    #[test]
    fn disabled_buf_records_nothing() {
        let mut buf = TraceBuf::new(false);
        buf.span(SpanKind::LocalUpdate, 0.0, 1.0, 10, 0);
        buf.instant(InstantKind::Crash, 0.5);
        buf.exchange_spans(
            0.0,
            &[AttemptRec {
                backoff_s: 0.0,
                cost_s: 1.0,
                up_s: 0.2,
                server_s: 0.5,
                outcome: AttemptOutcome::Ok,
            }],
            100,
        );
        assert!(buf.events.is_empty());
    }

    #[test]
    fn exchange_replay_builds_nested_retry_timeline() {
        let mut buf = TraceBuf::new(true);
        let attempts = [
            AttemptRec {
                backoff_s: 0.0,
                cost_s: 5.0,
                up_s: 0.0,
                server_s: 0.0,
                outcome: AttemptOutcome::Timeout,
            },
            AttemptRec {
                backoff_s: 0.1,
                cost_s: 5.0,
                up_s: 0.0,
                server_s: 0.0,
                outcome: AttemptOutcome::Drop,
            },
            AttemptRec {
                backoff_s: 0.2,
                cost_s: 1.0,
                up_s: 0.25,
                server_s: 0.5,
                outcome: AttemptOutcome::Ok,
            },
        ];
        buf.exchange_spans(2.0, &attempts, 4096);
        // exchange + 3 attempts + 2 backoffs + server_compute + 2 instants.
        assert_eq!(buf.events.len(), 9);
        match buf.events[0] {
            TraceEvent::Span {
                kind: SpanKind::Exchange,
                t0,
                dur,
                bytes,
                aux,
            } => {
                assert_eq!(t0, 2.0);
                assert!((dur - 11.3).abs() < 1e-12);
                assert_eq!(bytes, 4096);
                assert_eq!(aux, 3);
            }
            ref other => panic!("expected exchange parent, got {other:?}"),
        }
        // The successful attempt's server compute nests inside it.
        let server = buf
            .events
            .iter()
            .find_map(|e| match e {
                TraceEvent::Span {
                    kind: SpanKind::ServerCompute,
                    t0,
                    dur,
                    ..
                } => Some((*t0, *dur)),
                _ => None,
            })
            .unwrap();
        assert!((server.0 - (2.0 + 5.0 + 0.1 + 5.0 + 0.2 + 0.25)).abs() < 1e-12);
        assert_eq!(server.1, 0.5);
        // Fault instants: one timeout, one drop.
        let instants: Vec<_> = buf
            .events
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Instant { kind, .. } => Some(*kind),
                _ => None,
            })
            .collect();
        assert_eq!(instants, vec![InstantKind::Timeout, InstantKind::Drop]);
    }

    #[test]
    fn tracer_drains_lanes_with_round_offset_and_summary_mode_drops_events() {
        let mut tr = Tracer::from_spec(&TraceSpec::File(PathBuf::from("x"))).unwrap();
        let mut buf = TraceBuf::new(tr.lane_events_enabled());
        buf.span(SpanKind::LocalUpdate, 1.0, 2.0, 0, 0);
        tr.drain_lane(3, 100.0, &mut buf);
        let rep = tr.into_report();
        assert_eq!(rep.events().len(), 1);
        let (track, ev) = rep.events()[0];
        assert_eq!(track, client_track(3));
        assert_eq!(ev.t0(), 101.0);

        let mut tr = Tracer::from_spec(&TraceSpec::Summary).unwrap();
        assert!(!tr.lane_events_enabled());
        let mut buf = TraceBuf::new(true); // even a recording buf is discarded
        buf.span(SpanKind::LocalUpdate, 1.0, 2.0, 0, 0);
        tr.drain_lane(0, 0.0, &mut buf);
        assert!(tr.into_report().events().is_empty());
    }

    #[test]
    fn chrome_export_is_deterministic_and_parses() {
        let build = || {
            let mut tr = Tracer::from_spec(&TraceSpec::File(PathBuf::from("x"))).unwrap();
            let mut buf = TraceBuf::new(true);
            buf.span(SpanKind::Encode, 0.0, 0.0, 128, 0);
            buf.exchange_spans(
                0.0,
                &[AttemptRec {
                    backoff_s: 0.0,
                    cost_s: 0.5,
                    up_s: 0.1,
                    server_s: 0.3,
                    outcome: AttemptOutcome::Ok,
                }],
                128,
            );
            buf.instant(InstantKind::Corruption, 0.6);
            tr.drain_lane(0, 10.0, &mut buf);
            tr.track_span(TRACK_SERVER, SpanKind::Broadcast, 11.0, 0.25, 2048, 4);
            tr.into_report().to_chrome_json("fp32", &JsonValue::object())
        };
        let a = build();
        let b = build();
        assert_eq!(a, b, "chrome export must be byte-deterministic");
        let parsed = crate::util::json::parse(&a).unwrap();
        let evs = parsed.get("traceEvents").and_then(|v| v.as_array()).unwrap();
        // 2 thread_name + 5 lane events + 1 server span.
        assert_eq!(evs.len(), 8);
        // Wire spans carry the codec attr.
        let enc = evs
            .iter()
            .find(|e| e.str_at("name").ok() == Some("encode"))
            .unwrap();
        let args = enc.get("args").unwrap();
        assert_eq!(args.str_at("codec").unwrap(), "fp32");
        assert_eq!(args.f64_at("bytes").unwrap(), 128.0);
        // Instants are thread-scoped.
        let inst = evs
            .iter()
            .find(|e| e.str_at("name").ok() == Some("corruption"))
            .unwrap();
        assert_eq!(inst.str_at("ph").unwrap(), "i");
        assert_eq!(inst.str_at("s").unwrap(), "t");
    }

    #[test]
    fn straggler_fold_round_and_run_levels() {
        let mut tr = Tracer::from_spec(&TraceSpec::Summary).unwrap();
        for c in 0..10u64 {
            tr.fold_client(1.0 + c as f64, 1000 * (c + 1), c / 8);
        }
        let round = tr.finish_round();
        assert!(round.time_p99 >= round.time_p50);
        assert!(round.bytes_p50 > 0.0);
        // Second round with different samples; the run-level view must
        // cover both rounds.
        for _ in 0..10 {
            tr.fold_client(100.0, 5, 0);
        }
        let round2 = tr.finish_round();
        assert!(round2.time_p50 > round.time_p99);
        let run = tr.run_straggler();
        assert!(run.time_p50 >= round.time_p50);
        assert!(run.time_p99 >= round2.time_p50 * 0.875);
    }
}
