//! Three-Phase Gradient Fusion — the weighting rule and the fused update
//! (paper §II-B, Eq. 3–4; ablation modes from §IV).
//!
//! The fusion weight combines a structural depth factor with an
//! instantaneous inverse-loss reliability factor:
//!
//! ```text
//! w_client = d_i/(d_i+d_s) · (L_c+ε)⁻¹ / ((L_c+ε)⁻¹ + (L_s+ε)⁻¹)
//! w_server = 1 − w_client
//! θ ← θ − η (w_client·g_client + w_server·g_server)
//! ```
//!
//! Phase 3 executes either as a single-pass Rust loop (default hot path)
//! or through the per-depth Pallas `tpgf_update_d{d}` artifact — the two
//! are numerically interchangeable (`bench_fusion` compares them).

use crate::config::TpgfMode;
use crate::util::math;

pub const EPS: f64 = 1e-8;

/// Compute w_client per Eq. 3 (or an ablated variant, §IV / Fig. 6).
pub fn client_weight(
    mode: TpgfMode,
    l_client: f64,
    l_server: f64,
    d_i: usize,
    d_s: usize,
) -> f64 {
    let depth_term = d_i as f64 / (d_i + d_s) as f64;
    let inv_c = 1.0 / (l_client + EPS);
    let inv_s = 1.0 / (l_server + EPS);
    let loss_term = inv_c / (inv_c + inv_s);
    match mode {
        TpgfMode::Full => depth_term * loss_term,
        TpgfMode::NoLoss => depth_term * 0.5,
        TpgfMode::NoDepth => 0.5 * loss_term,
        TpgfMode::Equal => 0.25, // 0.5 · 0.5: both factors neutralized
    }
}

/// The paper also reuses the loss-fusion rule at aggregation time
/// (§II-D): combine a client's local and server losses with the same
/// weighting so Eq. 6 sees one fused reliability signal.
pub fn fused_loss(mode: TpgfMode, l_client: f64, l_server: f64, d_i: usize, d_s: usize) -> f64 {
    let w = client_weight(mode, l_client, l_server, d_i, d_s);
    w * l_client + (1.0 - w) * l_server
}

/// Phase 3 in Rust: θ ← θ − η(w·g_c + (1−w)·g_s), single fused pass.
pub fn fuse_update(
    theta: &mut [f32],
    g_client: &[f32],
    g_server: &[f32],
    l_client: f64,
    l_server: f64,
    d_i: usize,
    d_s: usize,
    lr: f64,
    mode: TpgfMode,
) {
    let w = client_weight(mode, l_client, l_server, d_i, d_s) as f32;
    math::fused_blend_sgd(theta, g_client, w, g_server, 1.0 - w, lr as f32);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn weight_bounds_full_mode() {
        forall(1, 100, |rng| {
            let d_i = 1 + rng.uniform_usize(7);
            let d_s = 8 - d_i;
            let lc = rng.uniform_range(1e-4, 10.0);
            let ls = rng.uniform_range(1e-4, 10.0);
            let w = client_weight(TpgfMode::Full, lc, ls, d_i, d_s);
            assert!(w > 0.0 && w < d_i as f64 / 8.0 + 1e-12);
        });
    }

    #[test]
    fn lower_client_loss_raises_client_weight() {
        let w_low = client_weight(TpgfMode::Full, 0.1, 2.0, 4, 4);
        let w_high = client_weight(TpgfMode::Full, 2.0, 0.1, 4, 4);
        assert!(w_low > w_high);
    }

    #[test]
    fn deeper_client_raises_client_weight() {
        let shallow = client_weight(TpgfMode::Full, 1.0, 1.0, 1, 7);
        let deep = client_weight(TpgfMode::Full, 1.0, 1.0, 7, 1);
        assert!(deep > shallow);
        assert!((shallow - 1.0 / 16.0).abs() < 1e-9); // (1/8)·(1/2)
        assert!((deep - 7.0 / 16.0).abs() < 1e-9);
    }

    #[test]
    fn ablation_modes_drop_their_term() {
        // NoLoss: invariant to losses.
        let a = client_weight(TpgfMode::NoLoss, 0.01, 5.0, 3, 5);
        let b = client_weight(TpgfMode::NoLoss, 5.0, 0.01, 3, 5);
        assert_eq!(a, b);
        assert!((a - 3.0 / 8.0 * 0.5).abs() < 1e-12);
        // NoDepth: invariant to depths.
        let c = client_weight(TpgfMode::NoDepth, 1.0, 3.0, 1, 7);
        let d = client_weight(TpgfMode::NoDepth, 1.0, 3.0, 7, 1);
        assert_eq!(c, d);
        // Equal: constant.
        assert_eq!(client_weight(TpgfMode::Equal, 0.1, 9.0, 1, 7), 0.25);
    }

    #[test]
    fn fuse_update_matches_manual() {
        forall(2, 50, |rng: &mut Pcg32| {
            let n = 1 + rng.uniform_usize(500);
            let theta0: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let gc: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let gs: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let (lc, ls) = (rng.uniform_range(0.01, 5.0), rng.uniform_range(0.01, 5.0));
            let d_i = 1 + rng.uniform_usize(7);
            let lr = 0.05;

            let mut theta = theta0.clone();
            fuse_update(&mut theta, &gc, &gs, lc, ls, d_i, 8 - d_i, lr, TpgfMode::Full);

            let w = client_weight(TpgfMode::Full, lc, ls, d_i, 8 - d_i) as f32;
            for i in 0..n {
                let expect = theta0[i] - lr as f32 * (w * gc[i] + (1.0 - w) * gs[i]);
                assert!((theta[i] - expect).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn identical_gradients_reduce_to_sgd() {
        // w + (1-w) = 1 ⇒ fusing g with itself is plain SGD on g.
        let mut theta = vec![1.0f32; 64];
        let g = vec![0.5f32; 64];
        fuse_update(&mut theta, &g, &g, 0.3, 1.7, 2, 6, 0.1, TpgfMode::Full);
        for t in theta {
            assert!((t - (1.0 - 0.1 * 0.5)).abs() < 1e-6);
        }
    }

    #[test]
    fn fused_loss_between_inputs() {
        forall(3, 50, |rng| {
            let lc = rng.uniform_range(0.01, 5.0);
            let ls = rng.uniform_range(0.01, 5.0);
            let f = fused_loss(TpgfMode::Full, lc, ls, 3, 5);
            assert!(f >= lc.min(ls) - 1e-12 && f <= lc.max(ls) + 1e-12);
        });
    }

    #[test]
    fn zero_losses_guarded_by_eps() {
        let w = client_weight(TpgfMode::Full, 0.0, 0.0, 4, 4);
        assert!(w.is_finite());
        assert!((w - 0.25).abs() < 1e-9);
    }
}
