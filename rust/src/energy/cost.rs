//! FLOP cost model for simulated compute time.
//!
//! Simulated per-step durations are FLOPs / device-speed. FLOPs are
//! estimated from the model geometry in the artifact manifest with the
//! standard dense-transformer rule of thumb: a forward pass costs
//! ≈ 2·P·tokens FLOPs per sample over P touched parameters, a backward
//! pass ≈ 2× the forward. Absolute accuracy is secondary — the *relative*
//! cost between split depths and methods is what drives the simulation,
//! and that is exact under this rule.

/// Model geometry snapshot (extracted from `manifest.json`).
#[derive(Clone, Debug)]
pub struct ModelGeometry {
    pub tokens: usize,
    pub batch: usize,
    pub embed_size: usize,
    pub block_size: usize,
    pub depth: usize,
    pub clf_client_size: usize,
    pub clf_server_size: usize,
}

/// FLOP estimates per protocol step.
#[derive(Clone, Debug)]
pub struct CostModel {
    geo: ModelGeometry,
}

impl CostModel {
    pub fn new(geo: ModelGeometry) -> Self {
        CostModel { geo }
    }

    pub fn geometry(&self) -> &ModelGeometry {
        &self.geo
    }

    fn enc_params(&self, depth: usize) -> f64 {
        (self.geo.embed_size + depth * self.geo.block_size) as f64
    }

    fn srv_params(&self, depth: usize) -> f64 {
        ((self.geo.depth - depth) * self.geo.block_size) as f64
    }

    fn per_batch(&self, params: f64) -> f64 {
        2.0 * params * self.geo.tokens as f64 * self.geo.batch as f64
    }

    /// Client forward to depth `d` (smashed-data production).
    pub fn client_fwd_flops(&self, depth: usize) -> f64 {
        self.per_batch(self.enc_params(depth))
    }

    /// Phase 1: forward + local head + backward through encoder+head.
    pub fn client_local_flops(&self, depth: usize) -> f64 {
        3.0 * self.per_batch(self.enc_params(depth) + self.geo.clf_client_size as f64)
    }

    /// Phase 2 client side: backward through the encoder given g_z.
    pub fn client_bwd_flops(&self, depth: usize) -> f64 {
        2.0 * self.per_batch(self.enc_params(depth))
    }

    /// Phase 2 server side: fwd+bwd through the suffix + head.
    pub fn server_step_flops(&self, depth: usize) -> f64 {
        3.0 * self.per_batch(self.srv_params(depth) + self.geo.clf_server_size as f64)
    }

    /// Phase 3: the fused update touches 4·N floats (read θ,g_c,g_s; write θ).
    pub fn tpgf_fuse_flops(&self, depth: usize) -> f64 {
        4.0 * self.enc_params(depth)
    }

    /// Full-model evaluation forward for `n` samples.
    pub fn eval_flops(&self, n: usize) -> f64 {
        2.0 * (self.enc_params(self.geo.depth) + self.geo.clf_server_size as f64)
            * self.geo.tokens as f64
            * n as f64
    }

    /// Seconds on a device of the given speed.
    pub fn time_s(&self, flops: f64, device_flops: f64) -> f64 {
        flops / device_flops.max(1.0)
    }

    /// Bytes of one smashed-data tensor `[B, T, D]` — what crosses the
    /// network per batch (f32).
    pub fn smashed_bytes(&self, dim: usize) -> u64 {
        (self.geo.batch * self.geo.tokens * dim * 4) as u64
    }

    /// Bytes of a flat f32 parameter vector.
    pub fn params_bytes(n: usize) -> u64 {
        (n * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> ModelGeometry {
        ModelGeometry {
            tokens: 17,
            batch: 32,
            embed_size: 5_000,
            block_size: 30_000,
            depth: 8,
            clf_client_size: 1_000,
            clf_server_size: 1_000,
        }
    }

    #[test]
    fn deeper_clients_cost_more() {
        let c = CostModel::new(geo());
        assert!(c.client_fwd_flops(5) > c.client_fwd_flops(1));
        assert!(c.client_local_flops(5) > c.client_local_flops(1));
        // And the server-side cost moves the other way.
        assert!(c.server_step_flops(1) > c.server_step_flops(5));
    }

    #[test]
    fn split_conservation() {
        // enc(d) + srv(d) params == full model params for every d.
        let c = CostModel::new(geo());
        let full = c.enc_params(8);
        for d in 1..8 {
            assert!((c.enc_params(d) + c.srv_params(d) - full).abs() < 1e-9);
        }
    }

    #[test]
    fn bwd_costs_twice_fwd() {
        let c = CostModel::new(geo());
        assert!((c.client_bwd_flops(3) - 2.0 * c.client_fwd_flops(3)).abs() < 1e-9);
    }

    #[test]
    fn time_inversely_proportional_to_speed() {
        let c = CostModel::new(geo());
        let f = c.client_fwd_flops(2);
        assert!((c.time_s(f, 1e9) / c.time_s(f, 2e9) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn smashed_bytes_match_tensor_size() {
        let c = CostModel::new(geo());
        assert_eq!(c.smashed_bytes(64), (32 * 17 * 64 * 4) as u64);
        assert_eq!(CostModel::params_bytes(10), 40);
    }
}
