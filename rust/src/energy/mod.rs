//! Device power states, energy integration, and carbon accounting.
//!
//! Reproduces the paper's §III-D metrics: average power, power per
//! accuracy point (W/%), total energy (power–time integration on the
//! simulated clock) and CO₂ via a grid emission factor (DESIGN.md §4.3).

pub mod cost;

pub use cost::CostModel;

use crate::network::DeviceProfile;

/// What a device is doing during an interval.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PowerState {
    Compute,
    Transmit,
    Idle,
}

/// Accumulates energy per device + the server over simulated time.
#[derive(Clone, Debug)]
pub struct EnergyMeter {
    client_energy_j: Vec<f64>,
    server_energy_j: f64,
    server_active_w: f64,
    server_idle_w: f64,
    co2_g_per_kwh: f64,
    /// Simulated server busy-time (the remainder of wall time is idle).
    server_busy_s: f64,
}

impl EnergyMeter {
    pub fn new(n_clients: usize, energy: &crate::config::EnergyConfig) -> Self {
        EnergyMeter {
            client_energy_j: vec![0.0; n_clients],
            server_energy_j: 0.0,
            server_active_w: energy.server_active_w,
            server_idle_w: energy.server_idle_w,
            co2_g_per_kwh: energy.co2_g_per_kwh,
            server_busy_s: 0.0,
        }
    }

    /// Power draw of a device in a state. Exposed so the parallel round
    /// engine's per-client ledgers integrate energy with exactly the same
    /// model, then merge via [`EnergyMeter::add_client_energy`].
    pub fn device_power_w(profile: &DeviceProfile, state: PowerState) -> f64 {
        match state {
            PowerState::Compute => profile.active_w,
            PowerState::Transmit => profile.tx_w,
            PowerState::Idle => profile.idle_w,
        }
    }

    /// Charge a client interval in the given state.
    pub fn client(&mut self, profile: &DeviceProfile, state: PowerState, dt: f64) {
        self.client_energy_j[profile.id] += Self::device_power_w(profile, state) * dt.max(0.0);
    }

    /// Merge pre-integrated client energy (a round ledger) into a device's
    /// account. Called at the aggregation barrier in client-id order.
    pub fn add_client_energy(&mut self, id: usize, joules: f64) {
        self.client_energy_j[id] += joules.max(0.0);
    }

    /// Charge server busy time (compute on behalf of clients).
    pub fn server_busy(&mut self, dt: f64) {
        self.server_busy_s += dt.max(0.0);
        self.server_energy_j += self.server_active_w * dt.max(0.0);
    }

    /// At run end: charge server idle draw for the rest of the wall time.
    pub fn finalize(&mut self, total_sim_time_s: f64) {
        let idle = (total_sim_time_s - self.server_busy_s).max(0.0);
        self.server_energy_j += self.server_idle_w * idle;
    }

    pub fn total_energy_j(&self) -> f64 {
        self.client_energy_j.iter().sum::<f64>() + self.server_energy_j
    }

    pub fn client_energy_j(&self, id: usize) -> f64 {
        self.client_energy_j[id]
    }

    pub fn server_energy_j(&self) -> f64 {
        self.server_energy_j
    }

    /// Fleet-wide average power over the run (paper Table II "Average
    /// Power"): total energy / simulated wall time.
    pub fn avg_power_w(&self, total_sim_time_s: f64) -> f64 {
        if total_sim_time_s <= 0.0 {
            return 0.0;
        }
        self.total_energy_j() / total_sim_time_s
    }

    /// Power per accuracy point, W/% (paper §III-D, after Brownlee et al.).
    pub fn power_per_acc(&self, total_sim_time_s: f64, accuracy_pct: f64) -> f64 {
        if accuracy_pct <= 0.0 {
            return f64::INFINITY;
        }
        self.avg_power_w(total_sim_time_s) / accuracy_pct
    }

    /// CO₂ grams: kWh × grid factor.
    pub fn co2_g(&self) -> f64 {
        self.total_energy_j() / 3.6e6 * self.co2_g_per_kwh
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EnergyConfig, FleetConfig};
    use crate::network::sample_fleet;
    use crate::util::rng::Pcg32;

    fn meter_and_fleet() -> (EnergyMeter, Vec<DeviceProfile>) {
        let e = EnergyConfig::default();
        let fleet = sample_fleet(
            &FleetConfig {
                clients: 3,
                ..FleetConfig::default()
            },
            &e,
            &mut Pcg32::seeded(1),
        );
        (EnergyMeter::new(3, &e), fleet)
    }

    #[test]
    fn integrates_power_times_time() {
        let (mut m, fleet) = meter_and_fleet();
        m.client(&fleet[0], PowerState::Compute, 10.0);
        let expect = fleet[0].active_w * 10.0;
        assert!((m.client_energy_j(0) - expect).abs() < 1e-9);
        assert_eq!(m.client_energy_j(1), 0.0);
    }

    #[test]
    fn states_have_distinct_draw() {
        let (mut m, fleet) = meter_and_fleet();
        m.client(&fleet[0], PowerState::Compute, 1.0);
        let compute = m.client_energy_j(0);
        m.client(&fleet[1], PowerState::Idle, 1.0);
        let idle = m.client_energy_j(1);
        assert!(compute > idle);
    }

    #[test]
    fn server_idle_fills_remaining_time() {
        let (mut m, _) = meter_and_fleet();
        m.server_busy(10.0);
        m.finalize(100.0);
        let e = EnergyConfig::default();
        let expect = e.server_active_w * 10.0 + e.server_idle_w * 90.0;
        assert!((m.server_energy_j() - expect).abs() < 1e-6);
    }

    #[test]
    fn avg_power_and_co2() {
        let (mut m, fleet) = meter_and_fleet();
        m.client(&fleet[0], PowerState::Compute, 100.0);
        m.finalize(100.0);
        let avg = m.avg_power_w(100.0);
        assert!(avg > 0.0);
        // 1 kWh at 400 g/kWh = 400 g.
        let mut m2 = EnergyMeter::new(1, &EnergyConfig::default());
        m2.server_energy_j = 3.6e6;
        assert!((m2.co2_g() - 400.0).abs() < 1e-9);
    }

    #[test]
    fn power_per_acc_guards_zero() {
        let (m, _) = meter_and_fleet();
        assert!(m.power_per_acc(10.0, 0.0).is_infinite());
    }

    #[test]
    fn ledger_merge_equals_direct_charging() {
        let (mut direct, fleet) = meter_and_fleet();
        direct.client(&fleet[1], PowerState::Compute, 3.0);
        direct.client(&fleet[1], PowerState::Transmit, 1.5);

        let (mut merged, _) = meter_and_fleet();
        let joules = EnergyMeter::device_power_w(&fleet[1], PowerState::Compute) * 3.0
            + EnergyMeter::device_power_w(&fleet[1], PowerState::Transmit) * 1.5;
        merged.add_client_energy(1, joules);

        assert_eq!(direct.client_energy_j(1), merged.client_energy_j(1));
    }

    #[test]
    fn negative_dt_clamped() {
        let (mut m, fleet) = meter_and_fleet();
        m.client(&fleet[0], PowerState::Compute, -5.0);
        assert_eq!(m.client_energy_j(0), 0.0);
    }
}
