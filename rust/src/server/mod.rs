//! The main server: hosts the weight-sharing super-network and executes
//! the deep suffix for every client (paper §II, Fig. 1).
//!
//! A single global encoder θ (all L layers) lives here. Serving client
//! `i` of depth `d_i` means running blocks `d_i+1..L` — a *slice view* of
//! the shared super-network — plus the server classifier, then applying
//! the SGD update to exactly that slice (Alg. 2 line 11). Different-depth
//! clients therefore train overlapping suffixes of one model, which is
//! what keeps all subnetworks aggregation-compatible.

use crate::data::Dataset;
use crate::fedserver::{self, ClientUpdate};
use crate::runtime::{Runtime, ServerStepOut};
use crate::util::math;
use crate::{Error, Result};

/// Global model state owned by the main server.
pub struct ServerState {
    /// Full L-layer flat encoder (the super-network θ).
    pub enc: Vec<f32>,
    /// Server classifier φ_s (final LN + CLS head).
    pub clf_s: Vec<f32>,
    pub classes: usize,
    pub lr: f32,
    layer_sizes: Vec<usize>,
}

impl ServerState {
    /// Initialize from the deterministic `init_*.bin` blobs.
    pub fn new(rt: &Runtime, classes: usize, lr: f32) -> Result<ServerState> {
        let enc = rt.load_init(&format!("init_enc_c{classes}"))?;
        let clf_s = rt.load_init(&format!("init_clf_s_c{classes}"))?;
        Ok(ServerState {
            enc,
            clf_s,
            classes,
            lr,
            layer_sizes: rt.model().enc_layer_sizes.clone(),
        })
    }

    pub fn layer_sizes(&self) -> &[usize] {
        &self.layer_sizes
    }

    /// Flat size of the depth-`d` prefix.
    pub fn prefix_len(&self, depth: usize) -> usize {
        self.layer_sizes[..depth].iter().sum()
    }

    /// The suffix slice serving a depth-`d` client.
    pub fn suffix(&self, depth: usize) -> &[f32] {
        &self.enc[self.prefix_len(depth)..]
    }

    /// The global prefix broadcast to a depth-`d` client after aggregation.
    pub fn prefix(&self, depth: usize) -> &[f32] {
        &self.enc[..self.prefix_len(depth)]
    }

    /// Collaborative aggregation (Eq. 6–8) into the super-network.
    ///
    /// Lives on `ServerState` so the encoder and the layer table — two
    /// fields of the same struct — can be borrowed disjointly; callers
    /// previously had to clone the layer table (`layer_sizes().to_vec()`)
    /// to satisfy the borrow checker. Returns per-layer contributor counts.
    pub fn aggregate_updates(
        &mut self,
        updates: &[ClientUpdate<'_>],
        lambda: f64,
        eps: f64,
    ) -> Vec<usize> {
        fedserver::aggregate(&mut self.enc, &self.layer_sizes, updates, lambda, eps)
    }

    /// Layer-aligned FedAvg with explicit weights (baseline aggregation),
    /// same borrow-friendly shape as [`ServerState::aggregate_updates`].
    /// `items` = `(depth, prefix_params, weight)`.
    pub fn fedavg_prefixes(&mut self, items: &[(usize, &[f32], f64)], lambda: f64) -> Vec<usize> {
        fedserver::aggregate_weighted(&mut self.enc, &self.layer_sizes, items, lambda)
    }

    /// TPGF Phase 2, server side (Alg. 2 lines 9–12): run the deep
    /// forward/backward for one client batch, update the shared suffix +
    /// classifier in place, and return the smashed-data gradient.
    pub fn process(
        &mut self,
        rt: &Runtime,
        depth: usize,
        z: &[f32],
        y: &[i32],
    ) -> Result<ServerStepOut> {
        let off = self.prefix_len(depth);
        let out = rt.server_step(depth, self.classes, &self.enc[off..], &self.clf_s, z, y)?;
        math::sgd_step(&mut self.enc[off..], &out.g_srv, self.lr);
        math::sgd_step(&mut self.clf_s, &out.g_clf_s, self.lr);
        Ok(out)
    }

    /// Test-set top-1 accuracy of the current global model over the given
    /// sample indices (padded to the artifact's fixed eval batch; padding
    /// rows are not scored).
    pub fn evaluate(&self, rt: &Runtime, data: &Dataset, indices: &[usize]) -> Result<f64> {
        if indices.is_empty() {
            return Err(Error::Config("evaluate: empty index set".into()));
        }
        let m = rt.model();
        let be = m.eval_batch;
        let mut hits = 0usize;
        let mut total = 0usize;
        for chunk in indices.chunks(be) {
            let mut padded: Vec<usize> = chunk.to_vec();
            while padded.len() < be {
                padded.push(chunk[0]);
            }
            let batch = data.gather(&padded);
            let logits = rt.eval_batch(self.classes, &self.enc, &self.clf_s, &batch.x)?;
            for (row, &label) in logits
                .chunks_exact(self.classes)
                .zip(batch.y.iter())
                .take(chunk.len())
            {
                if math::argmax(row) == label as usize {
                    hits += 1;
                }
                total += 1;
            }
        }
        Ok(hits as f64 / total as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn runtime() -> Runtime {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Runtime::load_if_available(&dir)
    }

    #[test]
    fn prefix_suffix_partition_encoder() {
        let rt = runtime();
        let s = ServerState::new(&rt, 10, 0.05).unwrap();
        for d in 1..rt.model().depth {
            assert_eq!(s.prefix(d).len() + s.suffix(d).len(), s.enc.len());
        }
    }

    #[test]
    fn process_updates_only_suffix() {
        let rt = runtime();
        let m = rt.model().clone();
        let mut s = ServerState::new(&rt, 10, 0.05).unwrap();
        let before = s.enc.clone();
        let clf_before = s.clf_s.clone();
        let d = 3;
        let z = vec![0.1f32; m.smashed_elems()];
        let y: Vec<i32> = (0..m.batch as i32).map(|i| i % 10).collect();
        let out = s.process(&rt, d, &z, &y).unwrap();
        assert!(out.loss > 0.0);
        assert_eq!(out.g_z.len(), z.len());
        // Prefix untouched; suffix and classifier moved.
        let cut = s.prefix_len(d);
        assert_eq!(&s.enc[..cut], &before[..cut]);
        assert!(math::max_abs_diff(&s.enc[cut..], &before[cut..]) > 0.0);
        assert!(math::max_abs_diff(&s.clf_s, &clf_before) > 0.0);
    }

    #[test]
    fn evaluate_on_random_data_near_chance() {
        let rt = runtime();
        use crate::data::{Dataset, SyntheticSpec};
        use crate::util::rng::Pcg32;
        let s = ServerState::new(&rt, 10, 0.05).unwrap();
        let spec = SyntheticSpec::default();
        let data = Dataset::generate(&spec, 30, &mut Pcg32::seeded(3));
        let idx: Vec<usize> = (0..250).collect();
        let acc = s.evaluate(&rt, &data, &idx).unwrap();
        // Untrained model ≈ chance (0.1); generous band.
        assert!(acc < 0.35, "acc {acc}");
    }
}
