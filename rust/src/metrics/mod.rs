//! Experiment recording: per-round metrics, run summaries, CSV/JSON export.

use std::io::Write;
use std::path::Path;

use crate::trace::StragglerStats;
use crate::util::fs::{atomic_write, atomic_write_with};
use crate::util::json::JsonValue;
use crate::Result;

/// One global round's measurements.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    /// Cumulative simulated time at end of round, s.
    pub sim_time_s: f64,
    /// Test accuracy in [0, 1].
    pub accuracy: f64,
    /// Mean client-side loss over the round (local heads; SSFL only).
    pub mean_client_loss: f64,
    /// Mean server-side loss over the round (when server was reachable).
    pub mean_server_loss: f64,
    /// Encoded bytes on the link this round (both directions), MB —
    /// actual wire-frame sizes under the run's `--wire-codec`.
    pub comm_mb: f64,
    /// Cumulative communication, MB (encoded).
    pub cum_comm_mb: f64,
    /// Analytic uncompressed size of the same transfers (4 B/f32), MB.
    pub raw_mb: f64,
    /// Cumulative raw communication, MB.
    pub cum_raw_mb: f64,
    /// Per-round compression ratio raw/encoded (1.0 when nothing moved;
    /// slightly below 1.0 for `fp32`, which pays frame overhead).
    pub compression: f64,
    /// Cumulative energy, J.
    pub energy_j: f64,
    /// Client steps that fell back to local-only training this round.
    pub fallback_steps: usize,
    /// Client steps with full server supervision this round.
    pub server_steps: usize,
    /// Clients that participated this round (the sampled cohort size,
    /// or the whole fleet under `sample=off`).
    pub participants: usize,
    /// Exchanges lost to server unavailability / slow links this round.
    pub timeouts: u64,
    /// Exchanges lost to transmission drops (Bernoulli or bursty-link).
    pub drops: u64,
    /// Frames whose CRC check failed at decode this round.
    pub corruptions: u64,
    /// Retry attempts spent (each recharged bytes + backoff time).
    pub retries: u64,
    /// Mid-round client crashes this round.
    pub crashes: u64,
    /// Per-client straggler percentiles for this round (branch time,
    /// wire bytes, retries), present only when telemetry is on
    /// (`--trace summary|<path>`). `None` keeps the exported shape —
    /// CSV header and JSON keys — byte-identical to the pre-trace
    /// simulator, so goldens never re-bless.
    pub straggler: Option<StragglerStats>,
}

impl RoundRecord {
    /// One round as a JSON object (used by the run summary and the
    /// golden-metrics snapshot test).
    pub fn to_json(&self) -> JsonValue {
        let n = JsonValue::Number;
        let mut o = JsonValue::object();
        o.set("round", n(self.round as f64));
        o.set("sim_time_s", n(self.sim_time_s));
        o.set("accuracy", n(self.accuracy));
        o.set("mean_client_loss", n(self.mean_client_loss));
        o.set("mean_server_loss", n(self.mean_server_loss));
        o.set("comm_mb", n(self.comm_mb));
        o.set("cum_comm_mb", n(self.cum_comm_mb));
        o.set("raw_mb", n(self.raw_mb));
        o.set("cum_raw_mb", n(self.cum_raw_mb));
        o.set("compression", n(self.compression));
        o.set("energy_j", n(self.energy_j));
        o.set("fallback_steps", n(self.fallback_steps as f64));
        o.set("server_steps", n(self.server_steps as f64));
        o.set("participants", n(self.participants as f64));
        o.set("timeouts", n(self.timeouts as f64));
        o.set("drops", n(self.drops as f64));
        o.set("corruptions", n(self.corruptions as f64));
        o.set("retries", n(self.retries as f64));
        o.set("crashes", n(self.crashes as f64));
        if let Some(s) = &self.straggler {
            o.set("straggler", straggler_json(s));
        }
        o
    }
}

/// The nine straggler percentiles as one JSON object (key order matches
/// [`StragglerStats::CSV_COLUMNS`]).
fn straggler_json(s: &StragglerStats) -> JsonValue {
    let mut o = JsonValue::object();
    for (key, v) in StragglerStats::CSV_COLUMNS.split(',').zip(s.csv_fields()) {
        o.set(key, JsonValue::Number(v));
    }
    o
}

/// Whole-run result + the per-round trajectory.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    pub name: String,
    pub method: String,
    pub rounds: Vec<RoundRecord>,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    /// First round (1-based) at which `target` was reached, if configured.
    pub rounds_to_target: Option<usize>,
    pub comm_mb_to_target: Option<f64>,
    pub sim_time_to_target: Option<f64>,
    /// Total encoded bytes on the link, MB.
    pub total_comm_mb: f64,
    /// Total analytic uncompressed bytes of the same transfers, MB.
    pub total_raw_mb: f64,
    /// Whole-run compression ratio raw/encoded.
    pub compression: f64,
    /// The wire codec the run shipped its tensors with (`cfg.wire`
    /// label; filled in by the orchestrator after construction).
    pub wire_codec: String,
    pub total_sim_time_s: f64,
    pub total_energy_j: f64,
    pub avg_power_w: f64,
    pub power_per_acc: f64,
    pub co2_g: f64,
    /// Host wall-clock seconds the run took (perf reporting for the
    /// parallel round engine; NOT simulated time). Filled in by the
    /// orchestrator after construction.
    pub host_wall_s: f64,
    /// Whole-run fault totals, summed over the per-round counters.
    pub total_timeouts: u64,
    pub total_drops: u64,
    pub total_corruptions: u64,
    pub total_retries: u64,
    pub total_crashes: u64,
    /// Run-level straggler percentiles (per-client round samples merged
    /// across every round); telemetry-gated like
    /// [`RoundRecord::straggler`]. Filled in by the orchestrator.
    pub straggler: Option<StragglerStats>,
    /// Set when a SIGINT/SIGTERM cut the run short: the 1-based round
    /// the loop was about to start. The artifacts written are the
    /// partial trajectory up to the previous round. Filled in by the
    /// orchestrator; `None` for completed runs keeps the JSON shape
    /// (and the goldens) unchanged.
    pub interrupted_at: Option<usize>,
}

impl RunMetrics {
    pub fn from_rounds(
        name: &str,
        method: &str,
        rounds: Vec<RoundRecord>,
        target: Option<f64>,
        total_energy_j: f64,
        avg_power_w: f64,
        co2_g: f64,
    ) -> RunMetrics {
        let best = rounds.iter().map(|r| r.accuracy).fold(0.0, f64::max);
        let fin = rounds.last().map(|r| r.accuracy).unwrap_or(0.0);
        let total_comm = rounds.last().map(|r| r.cum_comm_mb).unwrap_or(0.0);
        let total_raw = rounds.last().map(|r| r.cum_raw_mb).unwrap_or(0.0);
        let total_time = rounds.last().map(|r| r.sim_time_s).unwrap_or(0.0);
        let hit = target.and_then(|t| rounds.iter().find(|r| r.accuracy >= t));
        RunMetrics {
            name: name.to_string(),
            method: method.to_string(),
            rounds_to_target: hit.map(|r| r.round),
            comm_mb_to_target: hit.map(|r| r.cum_comm_mb),
            sim_time_to_target: hit.map(|r| r.sim_time_s),
            final_accuracy: fin,
            best_accuracy: best,
            total_comm_mb: total_comm,
            total_raw_mb: total_raw,
            compression: if total_comm > 0.0 {
                total_raw / total_comm
            } else {
                1.0
            },
            wire_codec: String::new(),
            total_sim_time_s: total_time,
            total_energy_j,
            avg_power_w,
            power_per_acc: if best > 0.0 {
                avg_power_w / (best * 100.0)
            } else {
                f64::INFINITY
            },
            co2_g,
            host_wall_s: 0.0,
            total_timeouts: rounds.iter().map(|r| r.timeouts).sum(),
            total_drops: rounds.iter().map(|r| r.drops).sum(),
            total_corruptions: rounds.iter().map(|r| r.corruptions).sum(),
            total_retries: rounds.iter().map(|r| r.retries).sum(),
            total_crashes: rounds.iter().map(|r| r.crashes).sum(),
            straggler: None,
            interrupted_at: None,
            rounds,
        }
    }

    /// CSV of the per-round trajectory (one file per run). Written
    /// atomically (temp sibling + rename): readers never observe a
    /// truncated artifact. The straggler percentile columns appear only
    /// when the run recorded telemetry, keeping untraced headers
    /// byte-identical to the pre-trace simulator.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let telemetry = self.rounds.iter().any(|r| r.straggler.is_some());
        atomic_write_with(path, |f| {
            write!(
                f,
                "round,sim_time_s,accuracy,mean_client_loss,mean_server_loss,comm_mb,cum_comm_mb,raw_mb,cum_raw_mb,compression,energy_j,fallback_steps,server_steps,participants,timeouts,drops,corruptions,retries,crashes"
            )?;
            if telemetry {
                writeln!(f, ",{}", StragglerStats::CSV_COLUMNS)?;
            } else {
                writeln!(f)?;
            }
            for r in &self.rounds {
                write!(
                    f,
                    "{},{:.3},{:.4},{:.4},{:.4},{:.3},{:.3},{:.3},{:.3},{:.3},{:.1},{},{},{},{},{},{},{},{}",
                    r.round,
                    r.sim_time_s,
                    r.accuracy,
                    r.mean_client_loss,
                    r.mean_server_loss,
                    r.comm_mb,
                    r.cum_comm_mb,
                    r.raw_mb,
                    r.cum_raw_mb,
                    r.compression,
                    r.energy_j,
                    r.fallback_steps,
                    r.server_steps,
                    r.participants,
                    r.timeouts,
                    r.drops,
                    r.corruptions,
                    r.retries,
                    r.crashes
                )?;
                if telemetry {
                    let s = r.straggler.unwrap_or_default();
                    for v in s.csv_fields() {
                        write!(f, ",{v:.4}")?;
                    }
                }
                writeln!(f)?;
            }
            Ok(())
        })?;
        Ok(())
    }

    /// Summary as JSON (for EXPERIMENTS.md provenance).
    pub fn to_json(&self) -> JsonValue {
        let n = JsonValue::Number;
        let mut o = JsonValue::object();
        o.set("name", JsonValue::String(self.name.clone()));
        o.set("method", JsonValue::String(self.method.clone()));
        o.set("rounds_run", n(self.rounds.len() as f64));
        o.set("final_accuracy", n(self.final_accuracy));
        o.set("best_accuracy", n(self.best_accuracy));
        match self.rounds_to_target {
            Some(r) => o.set("rounds_to_target", n(r as f64)),
            None => o.set("rounds_to_target", JsonValue::Null),
        }
        match self.comm_mb_to_target {
            Some(v) => o.set("comm_mb_to_target", n(v)),
            None => o.set("comm_mb_to_target", JsonValue::Null),
        }
        match self.sim_time_to_target {
            Some(v) => o.set("sim_time_to_target", n(v)),
            None => o.set("sim_time_to_target", JsonValue::Null),
        }
        o.set("total_comm_mb", n(self.total_comm_mb));
        o.set("total_raw_mb", n(self.total_raw_mb));
        o.set("compression", n(self.compression));
        o.set("wire_codec", JsonValue::String(self.wire_codec.clone()));
        o.set("total_sim_time_s", n(self.total_sim_time_s));
        o.set("total_energy_j", n(self.total_energy_j));
        o.set("avg_power_w", n(self.avg_power_w));
        o.set("power_per_acc", n(self.power_per_acc));
        o.set("co2_g", n(self.co2_g));
        o.set("host_wall_s", n(self.host_wall_s));
        o.set("total_timeouts", n(self.total_timeouts as f64));
        o.set("total_drops", n(self.total_drops as f64));
        o.set("total_corruptions", n(self.total_corruptions as f64));
        o.set("total_retries", n(self.total_retries as f64));
        o.set("total_crashes", n(self.total_crashes as f64));
        if let Some(s) = &self.straggler {
            o.set("straggler", straggler_json(s));
        }
        if let Some(r) = self.interrupted_at {
            o.set("interrupted_at", n(r as f64));
        }
        o.set(
            "rounds",
            JsonValue::Array(self.rounds.iter().map(|r| r.to_json()).collect()),
        );
        o
    }

    /// Atomic like [`RunMetrics::write_csv`]: a crash mid-write leaves
    /// either the previous complete file or nothing, never a torn one.
    pub fn write_json(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        atomic_write(path, self.to_json().to_string_pretty().as_bytes())?;
        Ok(())
    }
}

/// Fixed-width table printer for bench/report output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            while out.ends_with(' ') {
                out.pop();
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&mut out, &sep);
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rounds() -> Vec<RoundRecord> {
        (1..=5)
            .map(|i| RoundRecord {
                round: i,
                sim_time_s: i as f64 * 10.0,
                accuracy: 0.1 * i as f64 + 0.3,
                comm_mb: 5.0,
                cum_comm_mb: 5.0 * i as f64,
                ..RoundRecord::default()
            })
            .collect()
    }

    #[test]
    fn target_detection_first_crossing() {
        let m = RunMetrics::from_rounds("t", "ssfl", rounds(), Some(0.58), 100.0, 10.0, 1.0);
        // acc(3) = 0.6 is the first >= 0.58.
        assert_eq!(m.rounds_to_target, Some(3));
        assert_eq!(m.comm_mb_to_target, Some(15.0));
        assert_eq!(m.sim_time_to_target, Some(30.0));
    }

    #[test]
    fn no_target_gives_none() {
        let m = RunMetrics::from_rounds("t", "sfl", rounds(), Some(0.99), 1.0, 1.0, 1.0);
        assert_eq!(m.rounds_to_target, None);
        let m2 = RunMetrics::from_rounds("t", "sfl", rounds(), None, 1.0, 1.0, 1.0);
        assert_eq!(m2.rounds_to_target, None);
    }

    #[test]
    fn summary_totals_from_last_round() {
        let m = RunMetrics::from_rounds("t", "dfl", rounds(), None, 500.0, 20.0, 2.0);
        assert_eq!(m.total_comm_mb, 25.0);
        assert_eq!(m.total_sim_time_s, 50.0);
        assert!((m.final_accuracy - 0.8).abs() < 1e-12);
        assert!((m.best_accuracy - 0.8).abs() < 1e-12);
        assert!((m.power_per_acc - 20.0 / 80.0).abs() < 1e-9);
    }

    #[test]
    fn raw_vs_encoded_accounting_rolls_up() {
        let mut rs = rounds();
        for r in &mut rs {
            r.raw_mb = 20.0;
            r.cum_raw_mb = 20.0 * r.round as f64;
            r.compression = 4.0;
        }
        let m = RunMetrics::from_rounds("t", "ssfl", rs, None, 1.0, 1.0, 1.0);
        assert_eq!(m.total_raw_mb, 100.0);
        // 100 raw MB over 25 encoded MB → 4× end-to-end.
        assert!((m.compression - 4.0).abs() < 1e-12);
        let j = m.to_json();
        assert!(j.get("total_raw_mb").is_some());
        assert!(j.get("compression").is_some());
        assert!(j.get("wire_codec").is_some());
        let rounds = j.get("rounds").and_then(|r| r.as_array()).unwrap();
        assert!(rounds[0].get("raw_mb").is_some());
        assert!(rounds[0].get("compression").is_some());
    }

    #[test]
    fn csv_roundtrip_shape() {
        let m = RunMetrics::from_rounds("t", "ssfl", rounds(), None, 1.0, 1.0, 1.0);
        let tmp = std::env::temp_dir().join("supersfl_test_metrics.csv");
        m.write_csv(&tmp).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        assert_eq!(text.lines().count(), 6); // header + 5 rounds
        assert!(text.starts_with("round,"));
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn json_has_required_keys() {
        let m = RunMetrics::from_rounds("t", "ssfl", rounds(), Some(0.5), 1.0, 1.0, 1.0);
        let j = m.to_json();
        for key in [
            "name",
            "method",
            "final_accuracy",
            "rounds_to_target",
            "total_comm_mb",
            "power_per_acc",
            "co2_g",
            "rounds",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        let rounds = j.get("rounds").and_then(|r| r.as_array()).unwrap();
        assert_eq!(rounds.len(), 5);
        assert!(rounds[0].get("accuracy").is_some());
        assert!(rounds[0].get("server_steps").is_some());
        assert!(rounds[0].get("participants").is_some());
        for key in ["timeouts", "drops", "corruptions", "retries", "crashes"] {
            assert!(rounds[0].get(key).is_some(), "missing round key {key}");
        }
    }

    #[test]
    fn fault_counters_roll_up_and_export() {
        let mut rs = rounds();
        rs[1].timeouts = 3;
        rs[1].drops = 2;
        rs[2].corruptions = 1;
        rs[2].retries = 5;
        rs[3].crashes = 1;
        let m = RunMetrics::from_rounds("t", "ssfl", rs, None, 1.0, 1.0, 1.0);
        assert_eq!(m.total_timeouts, 3);
        assert_eq!(m.total_drops, 2);
        assert_eq!(m.total_corruptions, 1);
        assert_eq!(m.total_retries, 5);
        assert_eq!(m.total_crashes, 1);
        let j = m.to_json();
        assert_eq!(j.get("total_retries").and_then(|v| v.as_f64()), Some(5.0));

        let tmp = std::env::temp_dir().join("supersfl_test_fault_metrics.csv");
        m.write_csv(&tmp).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        let header = text.lines().next().unwrap();
        assert!(header.ends_with("timeouts,drops,corruptions,retries,crashes"));
        // Round 2's row carries its cause-classified counts.
        let row2: Vec<&str> = text.lines().nth(2).unwrap().split(',').collect();
        assert_eq!(&row2[row2.len() - 5..], &["3", "2", "0", "0", "0"]);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn straggler_columns_appear_only_with_telemetry() {
        // Untraced: shape identical to the pre-trace exporter.
        let m = RunMetrics::from_rounds("t", "ssfl", rounds(), None, 1.0, 1.0, 1.0);
        let j = m.to_json();
        assert!(j.get("straggler").is_none());
        let r0 = &j.get("rounds").and_then(|r| r.as_array()).unwrap()[0];
        assert!(r0.get("straggler").is_none());

        // Traced: percentile columns land in both exports.
        let mut rs = rounds();
        for r in &mut rs {
            r.straggler = Some(StragglerStats {
                time_p50: 1.5,
                time_p95: 2.0,
                time_p99: 2.5,
                bytes_p50: 1000.0,
                ..StragglerStats::default()
            });
        }
        let mut m = RunMetrics::from_rounds("t", "ssfl", rs, None, 1.0, 1.0, 1.0);
        m.straggler = m.rounds[0].straggler;
        let j = m.to_json();
        let run_s = j.get("straggler").expect("run-level straggler key");
        assert_eq!(run_s.f64_at("time_p50").unwrap(), 1.5);
        let r0 = &j.get("rounds").and_then(|r| r.as_array()).unwrap()[0];
        let s = r0.get("straggler").expect("round straggler key");
        assert_eq!(s.f64_at("bytes_p50").unwrap(), 1000.0);
        assert_eq!(s.f64_at("retries_p99").unwrap(), 0.0);

        let tmp = std::env::temp_dir().join("supersfl_test_straggler_metrics.csv");
        m.write_csv(&tmp).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        let header = text.lines().next().unwrap();
        assert!(header.ends_with(StragglerStats::CSV_COLUMNS));
        let cols = header.split(',').count();
        for line in text.lines().skip(1) {
            assert_eq!(line.split(',').count(), cols, "ragged row: {line}");
        }
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "metric"]);
        t.row(&["x".into(), "1.5".into()]);
        t.row(&["longer".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("a       metric"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
