//! Deterministic PRNG stack: PCG32 core + distribution samplers.
//!
//! Every stochastic component in the simulator (fleet profiles, Dirichlet
//! partitions, network failures, data noise) draws from a seeded [`Pcg32`]
//! so every experiment is exactly reproducible from its config seed.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small, fast, statistically solid —
/// more than enough for simulation workloads.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Different streams
    /// with the same seed are independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (used to give each client /
    /// subsystem its own stream without coupling their draws).
    pub fn fork(&mut self, stream: u64) -> Pcg32 {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Pcg32::new(seed, stream)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Jump the generator forward by `delta` steps in O(log delta)
    /// (Brown's algorithm: the LCG transition is affine, so its
    /// `delta`-fold composition folds by square-and-multiply). After
    /// `advance(k)` the generator is bit-identical to one that called
    /// [`Pcg32::next_u32`] `k` times — the property that lets a lazy
    /// fleet reproduce client *i*'s profile without drawing the first
    /// `5·i` values.
    pub fn advance(&mut self, delta: u64) {
        let mut acc_mult: u64 = 1;
        let mut acc_plus: u64 = 0;
        let mut cur_mult = PCG_MULT;
        let mut cur_plus = self.inc;
        let mut d = delta;
        while d > 0 {
            if d & 1 == 1 {
                acc_mult = acc_mult.wrapping_mul(cur_mult);
                acc_plus = acc_plus.wrapping_mul(cur_mult).wrapping_add(cur_plus);
            }
            cur_plus = cur_mult.wrapping_add(1).wrapping_mul(cur_plus);
            cur_mult = cur_mult.wrapping_mul(cur_mult);
            d >>= 1;
        }
        self.state = acc_mult.wrapping_mul(self.state).wrapping_add(acc_plus);
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u32() as f64) / (u32::MAX as f64 + 1.0)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Total: `n == 0` is a hard assert in
    /// every build profile — the old `debug_assert!` compiled away in
    /// release, leaving `% 0` to panic with an inscrutable
    /// divide-by-zero deep inside a run.
    pub fn uniform_usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "uniform_usize(0): empty range");
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.uniform();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/σ.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (2000); boosts shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.uniform().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v3 + d * v3.ln() {
                return d * v3;
            }
        }
    }

    /// Dirichlet(α·1) over `k` categories (the paper's non-IID partitioner
    /// uses concentration α = 0.5).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let s: f64 = v.iter().sum();
        for x in &mut v {
            *x /= s;
        }
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.uniform_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from an (unnormalized) weight vector. Total over
    /// its stated domain: an empty slice or a non-positive total weight
    /// is a hard assert (the old fallback underflowed on `len() - 1`),
    /// and the rounding fallback lands on the last *positive-weight*
    /// index, never on a trailing zero-weight one. Exactly one uniform
    /// draw per call, always — callers replay streams.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let u = self.uniform();
        assert!(
            !weights.is_empty() && total > 0.0,
            "categorical: need at least one positive weight (len {}, total {total})",
            weights.len()
        );
        let mut t = u * total;
        for (i, &w) in weights.iter().enumerate() {
            if w <= 0.0 {
                continue;
            }
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        // f64 rounding exhausted the scan: last positive-weight index.
        weights
            .iter()
            .rposition(|&w| w > 0.0)
            .expect("unreachable: total > 0 implies a positive weight")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg32::new(7, 1);
        let mut b = Pcg32::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Pcg32::seeded(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Pcg32::seeded(5);
        for shape in [0.5, 1.0, 2.5, 8.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(1.0),
                "shape {shape} mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_positive() {
        let mut r = Pcg32::seeded(6);
        for alpha in [0.1, 0.5, 5.0] {
            let p = r.dirichlet(alpha, 10);
            assert_eq!(p.len(), 10);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn dirichlet_low_alpha_is_skewed() {
        let mut r = Pcg32::seeded(7);
        // With α = 0.05, mass should concentrate on few categories.
        let mut maxes = 0.0;
        for _ in 0..100 {
            let p = r.dirichlet(0.05, 10);
            maxes += p.iter().cloned().fold(0.0, f64::max);
        }
        assert!(maxes / 100.0 > 0.6);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(8);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg32::seeded(9);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 2 * counts[0]);
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Pcg32::seeded(10);
        let hits = (0..10_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((hits as f64 / 10_000.0 - 0.3).abs() < 0.02);
    }

    #[test]
    #[should_panic(expected = "uniform_usize(0)")]
    fn uniform_usize_zero_is_a_hard_assert_in_every_profile() {
        // Regression: the guard was a debug_assert!, so release builds
        // fell through to `% 0` and died with a bare arithmetic panic.
        Pcg32::seeded(1).uniform_usize(0);
    }

    #[test]
    #[should_panic(expected = "categorical")]
    fn categorical_empty_weights_is_a_hard_assert() {
        // Regression: the fallback `weights.len() - 1` underflowed.
        Pcg32::seeded(1).categorical(&[]);
    }

    #[test]
    #[should_panic(expected = "categorical")]
    fn categorical_all_zero_weights_is_a_hard_assert() {
        Pcg32::seeded(1).categorical(&[0.0, 0.0, 0.0]);
    }

    #[test]
    fn categorical_never_returns_trailing_zero_weight_index() {
        // Regression: the rounding fallback used to land on
        // `weights.len() - 1` even when that weight was exactly zero.
        let w = [0.0, 2.0, 1.0, 0.0, 0.0];
        let mut r = Pcg32::seeded(11);
        for _ in 0..50_000 {
            let i = r.categorical(&w);
            assert!(w[i] > 0.0, "drew zero-weight index {i}");
        }
    }

    #[test]
    fn categorical_burns_exactly_one_draw() {
        // Replayed streams (lanes, shards) depend on the draw count
        // being one uniform per call regardless of the weight shape.
        let mut a = Pcg32::seeded(12);
        let mut b = Pcg32::seeded(12);
        for w in [vec![1.0], vec![0.0, 1.0, 0.0], vec![0.5, 0.5, 3.0]] {
            a.categorical(&w);
            let _ = b.next_u32();
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn advance_matches_sequential_draws() {
        for (seed, stream, k) in [(42u64, 0u64, 0u64), (7, 3, 1), (9, 1, 5), (123, 54, 1000)] {
            let mut seq = Pcg32::new(seed, stream);
            for _ in 0..k {
                seq.next_u32();
            }
            let mut jumped = Pcg32::new(seed, stream);
            jumped.advance(k);
            for _ in 0..8 {
                assert_eq!(seq.next_u32(), jumped.next_u32(), "seed {seed} k {k}");
            }
        }
    }

    #[test]
    fn advance_composes_additively() {
        let mut a = Pcg32::seeded(99);
        a.advance(70);
        let mut b = Pcg32::seeded(99);
        b.advance(64);
        b.advance(6);
        assert_eq!(a.next_u32(), b.next_u32());
    }
}
