//! Flat-vector math used on the L3 hot path.
//!
//! All model parameters cross the Rust/XLA boundary as flat `f32` vectors
//! (DESIGN.md §3), so the coordinator's own compute — SGD steps, TPGF
//! fusion, layer-aligned aggregation — is expressed as tight loops over
//! slices. The loops are written in a form LLVM auto-vectorizes (no
//! bounds checks in the kernel loop, chunked accumulators for reductions).

/// `y ← y - lr * g` (plain SGD step, used for classifier/server updates).
pub fn sgd_step(theta: &mut [f32], grad: &[f32], lr: f32) {
    assert_eq!(theta.len(), grad.len());
    for (t, g) in theta.iter_mut().zip(grad.iter()) {
        *t -= lr * *g;
    }
}

/// `out ← a*x + b*y` element-wise (gradient blend, Eq. 4).
pub fn blend(out: &mut [f32], x: &[f32], a: f32, y: &[f32], b: f32) {
    assert_eq!(out.len(), x.len());
    assert_eq!(out.len(), y.len());
    for i in 0..out.len() {
        out[i] = a * x[i] + b * y[i];
    }
}

/// Fused `theta ← theta - lr*(a*gx + b*gy)` — single pass, no temp buffer.
pub fn fused_blend_sgd(theta: &mut [f32], gx: &[f32], a: f32, gy: &[f32], b: f32, lr: f32) {
    assert_eq!(theta.len(), gx.len());
    assert_eq!(theta.len(), gy.len());
    for i in 0..theta.len() {
        theta[i] -= lr * (a * gx[i] + b * gy[i]);
    }
}

/// l2 norm with 8-way partial sums (accurate + auto-vectorizable).
pub fn l2_norm(x: &[f32]) -> f32 {
    let mut acc = [0.0f64; 8];
    let chunks = x.chunks_exact(8);
    let rem = chunks.remainder();
    for c in chunks {
        for i in 0..8 {
            acc[i] += (c[i] as f64) * (c[i] as f64);
        }
    }
    let mut s: f64 = acc.iter().sum();
    for &v in rem {
        s += (v as f64) * (v as f64);
    }
    s.sqrt() as f32
}

/// Scale `x` in place so its l2 norm is at most `tau` (paper §II-B).
pub fn clip_l2(x: &mut [f32], tau: f32) -> f32 {
    let norm = l2_norm(x);
    if norm > tau && norm > 0.0 {
        let s = tau / norm;
        for v in x.iter_mut() {
            *v *= s;
        }
        tau
    } else {
        norm
    }
}

/// Weighted accumulate: `acc ← acc + w*x`.
pub fn axpy(acc: &mut [f32], x: &[f32], w: f32) {
    assert_eq!(acc.len(), x.len());
    for (a, v) in acc.iter_mut().zip(x.iter()) {
        *a += w * *v;
    }
}

/// Scale in place.
pub fn scale(x: &mut [f32], s: f32) {
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// Mean of a slice.
pub fn mean(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    (x.iter().map(|&v| v as f64).sum::<f64>() / x.len() as f64) as f32
}

/// Arg-max of a logits row.
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Top-1 accuracy of `[n, classes]` row-major logits against labels.
pub fn accuracy(logits: &[f32], labels: &[i32], classes: usize) -> f64 {
    assert_eq!(logits.len(), labels.len() * classes);
    let mut hits = 0usize;
    for (row, &y) in logits.chunks_exact(classes).zip(labels.iter()) {
        if argmax(row) == y as usize {
            hits += 1;
        }
    }
    hits as f64 / labels.len().max(1) as f64
}

/// Max absolute difference between two slices (test helper).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn sgd_step_basic() {
        let mut t = vec![1.0, 2.0, 3.0];
        sgd_step(&mut t, &[1.0, -1.0, 0.5], 0.1);
        assert_eq!(t, vec![0.9, 2.1, 2.95]);
    }

    #[test]
    fn blend_weights() {
        let mut out = vec![0.0; 3];
        blend(&mut out, &[1.0, 1.0, 1.0], 0.25, &[2.0, 2.0, 2.0], 0.75);
        for v in out {
            assert!((v - 1.75).abs() < 1e-6);
        }
    }

    #[test]
    fn fused_blend_sgd_matches_two_step() {
        forall(42, 50, |rng: &mut Pcg32| {
            let n = 1 + rng.uniform_usize(200);
            let theta: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let gx: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let gy: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let (a, b, lr) = (
                rng.uniform() as f32,
                rng.uniform() as f32,
                rng.uniform() as f32,
            );

            let mut one = theta.clone();
            fused_blend_sgd(&mut one, &gx, a, &gy, b, lr);

            let mut g = vec![0.0f32; n];
            blend(&mut g, &gx, a, &gy, b);
            let mut two = theta.clone();
            sgd_step(&mut two, &g, lr);

            assert!(max_abs_diff(&one, &two) < 1e-6);
        });
    }

    #[test]
    fn l2_norm_known() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert_eq!(l2_norm(&[]), 0.0);
    }

    #[test]
    fn clip_l2_properties() {
        forall(7, 50, |rng: &mut Pcg32| {
            let n = 1 + rng.uniform_usize(300);
            let mut x: Vec<f32> = (0..n).map(|_| (rng.normal() * 3.0) as f32).collect();
            let before = l2_norm(&x);
            let tau = rng.uniform_range(0.01, 2.0) as f32;
            let dir: Vec<f32> = x.clone();
            clip_l2(&mut x, tau);
            let after = l2_norm(&x);
            // Norm bounded by tau (+fp slack).
            assert!(after <= tau * 1.0001 + 1e-6);
            // Direction preserved: x stays a non-negative multiple of dir.
            if before > tau {
                let s = after / before;
                for (a, d) in x.iter().zip(dir.iter()) {
                    assert!((a - d * s).abs() < 1e-4);
                }
            } else {
                assert_eq!(x, dir); // untouched when already inside the ball
            }
        });
    }

    #[test]
    fn accuracy_counts_correct_rows() {
        // 3 samples, 2 classes.
        let logits = [0.1, 0.9, 0.8, 0.2, 0.4, 0.6];
        let labels = [1, 0, 0];
        let acc = accuracy(&logits, &labels, 2);
        assert!((acc - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn axpy_and_scale() {
        let mut acc = vec![1.0, 1.0];
        axpy(&mut acc, &[2.0, 4.0], 0.5);
        assert_eq!(acc, vec![2.0, 3.0]);
        scale(&mut acc, 2.0);
        assert_eq!(acc, vec![4.0, 6.0]);
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-6);
    }
}
