//! A tiny property-testing harness (substitute for `proptest`, which is
//! not in the offline crate set — DESIGN.md §4.5).
//!
//! `forall(seed, cases, |rng| { ...assert!... })` runs the closure for
//! `cases` independently-seeded PRNGs; on failure it reports the case
//! index and its seed so the exact case can be replayed with
//! `replay(seed, index, f)`.

use super::rng::Pcg32;

/// Run `f` on `cases` independent random streams derived from `seed`.
///
/// Panics (propagating the assertion) with a replay banner when a case
/// fails. This deliberately does not catch unwinds — the failing assert's
/// own message plus the banner is what you debug from.
pub fn forall<F: FnMut(&mut Pcg32)>(seed: u64, cases: usize, mut f: F) {
    for idx in 0..cases {
        let mut rng = case_rng(seed, idx);
        let banner = CaseBanner { seed, idx };
        f(&mut rng);
        std::mem::forget(banner);
    }
}

/// Re-run a single failing case from a `forall` report.
pub fn replay<F: FnMut(&mut Pcg32)>(seed: u64, idx: usize, mut f: F) {
    let mut rng = case_rng(seed, idx);
    f(&mut rng);
}

fn case_rng(seed: u64, idx: usize) -> Pcg32 {
    Pcg32::new(seed ^ (idx as u64).wrapping_mul(0x9E3779B97F4A7C15), idx as u64 + 1)
}

/// Prints the replay line if the test unwinds mid-case.
struct CaseBanner {
    seed: u64,
    idx: usize,
}

impl Drop for CaseBanner {
    fn drop(&mut self) {
        eprintln!(
            "property failed: case {} (replay with util::prop::replay({}, {}, f))",
            self.idx, self.seed, self.idx
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        forall(1, 25, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn cases_see_distinct_streams() {
        let mut firsts = Vec::new();
        forall(2, 20, |rng| firsts.push(rng.next_u32()));
        firsts.sort_unstable();
        firsts.dedup();
        assert_eq!(firsts.len(), 20);
    }

    #[test]
    fn replay_reproduces_case_stream() {
        let mut captured = Vec::new();
        forall(3, 10, |rng| captured.push(rng.next_u64()));
        for (idx, &want) in captured.iter().enumerate() {
            replay(3, idx, |rng| assert_eq!(rng.next_u64(), want));
        }
    }

    #[test]
    #[should_panic]
    fn failing_case_panics() {
        forall(4, 10, |rng| assert!(rng.uniform() < 0.0));
    }
}
