//! Minimal-but-complete JSON parser and writer.
//!
//! Used for the artifact manifest, experiment configs, and metric export.
//! Implements the full JSON grammar (strings with escapes/\uXXXX, numbers,
//! nested containers); object key order is preserved so emitted files diff
//! cleanly. Hand-rolled because `serde` is not in the offline crate set.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    /// Key order preserved (insertion order).
    Object(Vec<(String, JsonValue)>),
}

impl JsonValue {
    // ---- constructors -------------------------------------------------
    pub fn object() -> JsonValue {
        JsonValue::Object(Vec::new())
    }

    /// Insert/replace a key in an object (panics on non-objects — build
    /// bug, not data error).
    pub fn set(&mut self, key: &str, value: JsonValue) {
        match self {
            JsonValue::Object(entries) => {
                if let Some(e) = entries.iter_mut().find(|(k, _)| k == key) {
                    e.1 = value;
                } else {
                    entries.push((key.to_string(), value));
                }
            }
            _ => panic!("set() on non-object"),
        }
    }

    // ---- accessors -----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(entries) => {
                entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// `get` that errors with a path-style message (config plumbing).
    pub fn req(&self, key: &str) -> Result<&JsonValue> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn entries(&self) -> Option<&[(String, JsonValue)]> {
        match self {
            JsonValue::Object(e) => Some(e),
            _ => None,
        }
    }

    /// Object entries as a map view (for lookup-heavy consumers).
    pub fn to_map(&self) -> BTreeMap<String, JsonValue> {
        match self {
            JsonValue::Object(e) => e.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }

    // ---- helpers for typed extraction ---------------------------------
    pub fn f64_at(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::Json(format!("'{key}' is not a number")))
    }

    pub fn usize_at(&self, key: &str) -> Result<usize> {
        Ok(self.f64_at(key)? as usize)
    }

    pub fn str_at(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Json(format!("'{key}' is not a string")))
    }

    // ---- serialization --------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Number(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            JsonValue::String(s) => write_escaped(out, s),
            JsonValue::Array(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(level) = indent {
                        newline_indent(out, level + 1);
                        item.write(out, Some(level + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(level) = indent {
                    newline_indent(out, level);
                }
                out.push(']');
            }
            JsonValue::Object(entries) => {
                if entries.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(level) = indent {
                        newline_indent(out, level + 1);
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(level + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(level) = indent {
                    newline_indent(out, level);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, level: usize) {
    out.push('\n');
    for _ in 0..level {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<JsonValue> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::Json(format!("trailing data at byte {}", p.pos)));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: JsonValue) -> Result<JsonValue> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<JsonValue> {
        match self.peek().ok_or_else(|| self.err("unexpected EOF"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(JsonValue::String(self.string()?)),
            b't' => self.literal("true", JsonValue::Bool(true)),
            b'f' => self.literal("false", JsonValue::Bool(false)),
            b'n' => self.literal("null", JsonValue::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            entries.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(JsonValue::Object(entries)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(JsonValue::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(s),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("bad surrogate pair"))?,
                            );
                        } else {
                            s.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad \\u"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => return Err(self.err("bad hex digit")),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(JsonValue::Number)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Parse a JSON file from disk.
pub fn parse_file(path: &std::path::Path) -> Result<JsonValue> {
    parse(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), JsonValue::Null);
        assert_eq!(parse("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), JsonValue::Number(-350.0));
        assert_eq!(
            parse("\"hi\\nthere\"").unwrap(),
            JsonValue::String("hi\nthere".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&JsonValue::Null));
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = v.entries().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn raw_utf8_passthrough() {
        let v = parse("\"héllo 😀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo 😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"a":[1,2.5,{"b":null,"c":true}],"s":"x\"y"}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn roundtrip_property_random_values() {
        // Property: parse(write(v)) == v for randomly generated values.
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(99);
        for _ in 0..200 {
            let v = random_value(&mut rng, 0);
            let text = v.to_string_compact();
            assert_eq!(parse(&text).unwrap(), v, "text: {text}");
        }
    }

    fn random_value(rng: &mut crate::util::rng::Pcg32, depth: usize) -> JsonValue {
        let choice = if depth > 3 {
            rng.uniform_usize(4)
        } else {
            rng.uniform_usize(6)
        };
        match choice {
            0 => JsonValue::Null,
            1 => JsonValue::Bool(rng.bernoulli(0.5)),
            2 => JsonValue::Number((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => JsonValue::String(
                (0..rng.uniform_usize(8))
                    .map(|_| char::from(b'a' + rng.uniform_usize(26) as u8))
                    .collect(),
            ),
            4 => JsonValue::Array(
                (0..rng.uniform_usize(4))
                    .map(|_| random_value(rng, depth + 1))
                    .collect(),
            ),
            _ => JsonValue::Object(
                (0..rng.uniform_usize(4))
                    .map(|i| (format!("k{i}"), random_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn set_and_get() {
        let mut o = JsonValue::object();
        o.set("x", JsonValue::Number(1.0));
        o.set("x", JsonValue::Number(2.0));
        assert_eq!(o.f64_at("x").unwrap(), 2.0);
        assert!(o.req("y").is_err());
    }
}
