//! Atomic artifact writes.
//!
//! Every machine-readable artifact the simulator emits (metrics CSV/JSON,
//! `BENCH_*.json`, trace files) goes through [`atomic_write`]: the bytes
//! land in a temp file *in the same directory* and are renamed into
//! place, so a killed chaos/smoke run can never leave a truncated file at
//! the destination — the reader either sees the old complete artifact or
//! the new complete one. Same-directory matters: `rename(2)` is only
//! atomic within one filesystem.

use std::fs::File;
use std::io::{self, Write};
use std::path::{Path, PathBuf};

/// Sibling temp path for `path`, unique per process so concurrent test
/// binaries writing the same artifact never clobber each other's
/// in-flight temp file.
fn temp_sibling(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(format!(".tmp.{}", std::process::id()));
    path.with_file_name(name)
}

/// Write `bytes` to `path` atomically (temp file + rename). On any
/// failure the destination is untouched and the temp file is removed.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    atomic_write_with(path, |f| f.write_all(bytes))
}

/// [`atomic_write`] with a caller-supplied producer, so large artifacts
/// can stream into the temp file instead of buffering a `String`. The
/// rename only happens if `produce` returns `Ok` — a mid-write failure
/// (the regression this module exists for) leaves no partial file at
/// `path`.
pub fn atomic_write_with<F>(path: &Path, produce: F) -> io::Result<()>
where
    F: FnOnce(&mut File) -> io::Result<()>,
{
    let tmp = temp_sibling(path);
    let mut f = File::create(&tmp)?;
    match produce(&mut f).and_then(|()| f.flush()) {
        Ok(()) => {
            drop(f);
            std::fs::rename(&tmp, path).inspect_err(|_| {
                std::fs::remove_file(&tmp).ok();
            })
        }
        Err(e) => {
            drop(f);
            std::fs::remove_file(&tmp).ok();
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("supersfl_fs_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn atomic_write_lands_full_contents() {
        let d = tdir("ok");
        let p = d.join("out.json");
        atomic_write(&p, b"{\"a\": 1}\n").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"{\"a\": 1}\n");
        // No temp debris left behind.
        let leftovers: Vec<_> = std::fs::read_dir(&d)
            .unwrap()
            .filter(|e| e.as_ref().unwrap().file_name() != "out.json")
            .collect();
        assert!(leftovers.is_empty(), "temp file leaked: {leftovers:?}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn mid_write_failure_never_leaves_a_partial_destination() {
        let d = tdir("fail");
        let p = d.join("out.json");

        // Fresh destination: a failure mid-produce must leave *nothing*.
        let err = atomic_write_with(&p, |f| {
            f.write_all(b"{\"truncat")?; // partial payload, then the crash
            Err(io::Error::other("simulated mid-write failure"))
        });
        assert!(err.is_err());
        assert!(!p.exists(), "partial file landed at the destination");

        // Existing destination: a failed rewrite must leave the old
        // complete artifact untouched.
        atomic_write(&p, b"complete-v1").unwrap();
        let err = atomic_write_with(&p, |f| {
            f.write_all(b"half-of-")?;
            Err(io::Error::other("simulated mid-write failure"))
        });
        assert!(err.is_err());
        assert_eq!(std::fs::read(&p).unwrap(), b"complete-v1");

        // And no temp debris in either case.
        assert_eq!(std::fs::read_dir(&d).unwrap().count(), 1);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn overwrite_replaces_atomically() {
        let d = tdir("replace");
        let p = d.join("out.csv");
        atomic_write(&p, b"old").unwrap();
        atomic_write(&p, b"new-and-longer").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"new-and-longer");
        std::fs::remove_dir_all(&d).ok();
    }
}
