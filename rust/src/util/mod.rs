//! Foundation utilities: PRNG, JSON, vector math, property testing.
//!
//! The offline crate set ships neither `rand`, `serde`, nor `proptest`, so
//! these substrates are implemented here from scratch (DESIGN.md §4.5) and
//! unit/property-tested like any other module.

pub mod fs;
pub mod json;
pub mod math;
pub mod prop;
pub mod rng;

pub use json::JsonValue;
pub use rng::Pcg32;
