//! The PJRT artifact backend: load AOT artifacts, compile once, execute
//! on the hot path.
//!
//! At construction the backend loads `artifacts/manifest.json`; each
//! artifact's HLO text is parsed and compiled by the PJRT CPU client
//! **lazily on first use** and cached for the rest of the process.
//! Execution marshals flat `f32`/`i32` slices into `xla::Literal`s with
//! the manifest shapes and unpacks the returned tuple back into
//! `Vec<f32>` buffers.
//!
//! The backend is `Sync`: the compile cache, stats and marshal-scratch
//! pool sit behind mutexes so the parallel round engine can dispatch
//! artifact executions from many worker threads at once. Locks are only
//! held for cache lookups and counter bumps — never across an execution.
//! Marshalling reuses pooled scratch buffers (the literal container and
//! the dims vector) instead of fresh allocations per call.
//!
//! Python never runs here — the binary is self-contained given the
//! `artifacts/` directory.

// audit:allow(unordered-iter) -- compile cache import; see the cache field below.
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use super::manifest::{Dtype, Manifest, ModelInfo, TensorSpec};
use super::{Arg, Backend, RuntimeStats};
use crate::{Error, Result};

/// Reusable marshalling buffers. Pooled on the backend so the per-call
/// literal container and dims vector keep their capacity across the
/// millions of executions a large-fleet run performs.
#[derive(Default)]
struct MarshalScratch {
    literals: Vec<xla::Literal>,
    dims: Vec<i64>,
}

/// The artifact registry + PJRT client. One per process, shared across
/// the round engine's worker threads.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    // audit:allow(unordered-iter) -- keyed lookups only; the cache is never iterated, so hash order cannot leak into the trajectory.
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<RuntimeStats>,
    scratch: Mutex<Vec<MarshalScratch>>,
}

impl PjrtBackend {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<PjrtBackend> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtBackend {
            client,
            manifest,
            // audit:allow(unordered-iter) -- constructor for the lookup-only compile cache above.
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
            scratch: Mutex::new(Vec::new()),
        })
    }

    /// Compile (or fetch from cache) an artifact's executable. The lock is
    /// not held across compilation, so two threads racing on first use may
    /// both compile; the first insert wins and the duplicate is dropped
    /// (correctness is unaffected — compilation is pure).
    fn ensure_compiled(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().expect("cache lock").get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .ok_or_else(|| Error::Manifest("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut st = self.stats.lock().expect("stats lock");
            st.compile_count += 1;
            st.compile_time_s += dt;
        }
        let mut cache = self.cache.lock().expect("cache lock");
        let entry = cache
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(exe));
        Ok(entry.clone())
    }

    fn exec_with_scratch(
        &self,
        name: &str,
        args: &[Arg<'_>],
        scratch: &mut MarshalScratch,
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self.ensure_compiled(name)?;
        let spec = self.manifest.artifact(name)?;
        if args.len() != spec.inputs.len() {
            return Err(Error::Shape(format!(
                "{name}: {} args, expected {}",
                args.len(),
                spec.inputs.len()
            )));
        }

        let t0 = std::time::Instant::now();
        scratch.literals.clear();
        for (arg, input) in args.iter().zip(spec.inputs.iter()) {
            if arg.elems() != input.elems() {
                return Err(Error::Shape(format!(
                    "{name}.{}: {} elements, expected {} (shape {:?})",
                    input.name,
                    arg.elems(),
                    input.elems(),
                    input.shape
                )));
            }
            let lit = make_literal(arg, input, &mut scratch.dims)?;
            scratch.literals.push(lit);
        }
        let marshal = t0.elapsed().as_secs_f64();

        let t1 = std::time::Instant::now();
        let result = exe.execute::<xla::Literal>(&scratch.literals)?[0][0].to_literal_sync()?;
        let exec = t1.elapsed().as_secs_f64();

        let t2 = std::time::Instant::now();
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            return Err(Error::Shape(format!(
                "{name}: {} outputs, expected {}",
                parts.len(),
                spec.outputs.len()
            )));
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.into_iter().zip(spec.outputs.iter()) {
            let v = lit.to_vec::<f32>()?;
            if v.len() != ospec.elems() {
                return Err(Error::Shape(format!(
                    "{name}.{}: got {} elements, expected {}",
                    ospec.name,
                    v.len(),
                    ospec.elems()
                )));
            }
            out.push(v);
        }
        let unmarshal = t2.elapsed().as_secs_f64();

        let mut st = self.stats.lock().expect("stats lock");
        st.executions += 1;
        st.exec_time_s += exec;
        st.marshal_time_s += marshal + unmarshal;
        Ok(out)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn model(&self) -> &ModelInfo {
        &self.manifest.model
    }

    fn clf_client_size(&self, classes: usize) -> Result<usize> {
        self.manifest.clf_client_size(classes)
    }

    fn clf_server_size(&self, classes: usize) -> Result<usize> {
        self.manifest.clf_server_size(classes)
    }

    fn load_init(&self, tag: &str) -> Result<Vec<f32>> {
        self.manifest.load_init(tag)
    }

    fn artifact_names(&self) -> Vec<String> {
        self.manifest
            .artifact_names()
            .into_iter()
            .map(|s| s.to_string())
            .collect()
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.lock().expect("stats lock").clone()
    }

    fn warm_up(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    /// Execute an artifact. Inputs are validated against the manifest
    /// signature; outputs come back as flat `Vec<f32>` in manifest order.
    ///
    /// Thread-safe: the executable handle is cloned out of the cache and
    /// no lock is held during execution, so independent client branches
    /// dispatch concurrently.
    fn exec(&self, name: &str, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
        let mut scratch = self
            .scratch
            .lock()
            .expect("scratch lock")
            .pop()
            .unwrap_or_default();
        let out = self.exec_with_scratch(name, args, &mut scratch);
        // Return the scratch buffers to the pool on every path (keeps
        // their capacity warm even across error returns).
        scratch.literals.clear();
        self.scratch.lock().expect("scratch lock").push(scratch);
        out
    }
}

fn make_literal(arg: &Arg<'_>, spec: &TensorSpec, dims: &mut Vec<i64>) -> Result<xla::Literal> {
    dims.clear();
    dims.extend(spec.shape.iter().map(|&d| d as i64));
    let lit = match (arg, spec.dtype) {
        (Arg::Scalar(v), Dtype::F32) => xla::Literal::scalar(*v),
        (Arg::F32(s), Dtype::F32) => {
            let l = xla::Literal::vec1(s);
            if dims.is_empty() {
                l.reshape(&[])?
            } else {
                l.reshape(dims)?
            }
        }
        (Arg::I32(s), Dtype::I32) => {
            let l = xla::Literal::vec1(s);
            l.reshape(dims)?
        }
        _ => {
            return Err(Error::Shape(format!(
                "{}: dtype mismatch ({:?})",
                spec.name, spec.dtype
            )))
        }
    };
    Ok(lit)
}
