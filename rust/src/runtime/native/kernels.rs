//! The native backend's kernel core: cache-tiled, register-blocked f32
//! compute primitives that are **bit-identical** to the naive per-row
//! loops they replaced.
//!
//! # The bit-identity contract
//!
//! f32 addition is not associative, so a kernel is free to re-tile the
//! independent output dimensions (M = rows, N = output features) but must
//! never reorder the reduction: for every output element, the
//! K-accumulation is a single sequential fold in the exact index order
//! of the original scalar loops. Concretely:
//!
//! * axpy-form kernels ([`gemm_bias`], [`residual_mlp2`]) keep K as the
//!   outer loop — each output cell receives its `a[r,κ]·w[κ,j]` terms in
//!   ascending κ, just like the old row-at-a-time code — and win their
//!   speed from 4-row register blocking (the `w` row is streamed once per
//!   row block) plus hoisted slices that drop per-iteration bounds checks.
//! * reduction-form kernels ([`gemm_bt`]) keep each output element a
//!   single scalar accumulator folded in ascending κ, and win their speed
//!   by computing **four independent output chains at once**: the naive
//!   loop was latency-bound on one serial FMA chain, four chains fill the
//!   FPU pipeline without touching any chain's order.
//! * accumulation kernels ([`ger_acc_rows`], [`col_sum_acc`]) add their
//!   per-row contributions in ascending row order per element — the same
//!   order the old code produced by updating parameter gradients inside
//!   its row loop — with row-blocked passes that stream the (large)
//!   gradient buffer once per block instead of once per row.
//!
//! Every kernel is a pure function of its inputs (no threading, no hidden
//! state), so the parallel round engine's `--threads N` bit-identity is
//! preserved by construction. The [`reference`] module keeps the original
//! naive implementations; property tests below assert bitwise equality on
//! awkward shapes (rows not a multiple of the block, n below the ILP
//! width, n ∈ {1, 3, 5, 8} batches), and `bench_native_kernels` measures
//! the naive-vs-tiled speedup from the same pair.
//!
//! # Deterministic shard reduction (`--kernel-threads N`)
//!
//! The `*_sharded` family parallelizes *inside* one kernel call without
//! touching the bit-identity contract. The rules:
//!
//! * **Fixed row-range shards.** [`ShardPlan`] cuts the row dimension
//!   into [`SHARD_ROWS`]-row ranges — a pure function of the shape,
//!   never of the worker count — so the decomposition is identical for
//!   every `--kernel-threads N` (including 1, which executes the same
//!   shards inline in ascending order).
//! * **Row-disjoint kernels shard transparently.** [`gemm_bias`],
//!   [`gemm_bt`], [`im2col`] and the fused [`block_fwd`] epilogues
//!   compute each output row independently, so their sharded variants
//!   are **bitwise identical to the direct kernels** for every plan —
//!   no merge exists to reorder.
//! * **Accumulation kernels merge partials in fixed shard order.**
//!   [`ger_acc_rows`], [`col_sum_acc`] and the parameter-gradient half
//!   of [`block_bwd`] fold *across* rows, so each shard folds its own
//!   row range into a zeroed partial buffer (checked out from the
//!   arena) and the partials are added into the accumulator **in
//!   ascending shard index on the caller's thread** after the pool
//!   drains. The per-element fold order is therefore a pure function of
//!   the plan — the same fold-order argument that made the tiled
//!   kernels bit-identical to the naive loops — and single-shard plans
//!   degenerate to the direct kernels (no partial, no merge).
//!
//! Consequently every sharded kernel is bitwise invariant across
//! `--kernel-threads` values (property-tested below for awkward shapes
//! and thread counts, and end to end by the golden-trajectory
//! invariance test), and only the *plan* — not the thread count — is
//! part of the numeric contract.

/// Rows processed per register block in the axpy-form kernels.
const MR: usize = 4;
/// Independent output chains per pass in the reduction-form kernels.
const NC: usize = 4;

/// `out[r,:] = bias + Σ_κ a[r,κ]·w[κ,:]` for `r < m` — row-major `a`
/// `[m,k]`, `w` `[k,n]`. K-outer axpy form, [`MR`]-row register blocks;
/// per-element terms arrive in ascending κ (bit-identical to the naive
/// per-row loop).
pub fn gemm_bias(a: &[f32], w: &[f32], bias: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(w.len(), k * n);
    assert_eq!(bias.len(), n);
    assert_eq!(out.len(), m * n);
    let mut r0 = 0;
    while r0 + MR <= m {
        let block = &mut out[r0 * n..(r0 + MR) * n];
        for row in block.chunks_exact_mut(n) {
            row.copy_from_slice(bias);
        }
        let a_blk = &a[r0 * k..(r0 + MR) * k];
        for kk in 0..k {
            let wrow = &w[kk * n..kk * n + n];
            let a0 = a_blk[kk];
            let a1 = a_blk[k + kk];
            let a2 = a_blk[2 * k + kk];
            let a3 = a_blk[3 * k + kk];
            let (b01, b23) = block.split_at_mut(2 * n);
            let (b0, b1) = b01.split_at_mut(n);
            let (b2, b3) = b23.split_at_mut(n);
            for j in 0..n {
                b0[j] += a0 * wrow[j];
                b1[j] += a1 * wrow[j];
                b2[j] += a2 * wrow[j];
                b3[j] += a3 * wrow[j];
            }
        }
        r0 += MR;
    }
    for r in r0..m {
        let row = &mut out[r * n..r * n + n];
        row.copy_from_slice(bias);
        let ar = &a[r * k..r * k + k];
        for (kk, &av) in ar.iter().enumerate() {
            let wrow = &w[kk * n..kk * n + n];
            for j in 0..n {
                row[j] += av * wrow[j];
            }
        }
    }
}

/// `out[r,j] = seed[r,j] + Σ_κ a[r,κ]·b[j,κ]` — `b` row-major `[n,k]`
/// used as Bᵀ (`seed = None` starts each fold at 0). Each element is one
/// sequential κ-ascending fold; [`NC`] independent output chains run per
/// pass for instruction-level parallelism.
pub fn gemm_bt(a: &[f32], b: &[f32], seed: Option<&[f32]>, m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(out.len(), m * n);
    if let Some(s) = seed {
        assert_eq!(s.len(), m * n);
    }
    for r in 0..m {
        let ar = &a[r * k..r * k + k];
        let orow = &mut out[r * n..r * n + n];
        let srow = seed.map(|s| &s[r * n..r * n + n]);
        let mut j = 0;
        while j + NC <= n {
            let b0 = &b[j * k..j * k + k];
            let b1 = &b[(j + 1) * k..(j + 1) * k + k];
            let b2 = &b[(j + 2) * k..(j + 2) * k + k];
            let b3 = &b[(j + 3) * k..(j + 3) * k + k];
            let (mut s0, mut s1, mut s2, mut s3) = match srow {
                Some(s) => (s[j], s[j + 1], s[j + 2], s[j + 3]),
                None => (0.0f32, 0.0, 0.0, 0.0),
            };
            for kk in 0..k {
                let av = ar[kk];
                s0 += av * b0[kk];
                s1 += av * b1[kk];
                s2 += av * b2[kk];
                s3 += av * b3[kk];
            }
            orow[j] = s0;
            orow[j + 1] = s1;
            orow[j + 2] = s2;
            orow[j + 3] = s3;
            j += NC;
        }
        while j < n {
            let brow = &b[j * k..j * k + k];
            let mut s = match srow {
                Some(s) => s[j],
                None => 0.0f32,
            };
            for kk in 0..k {
                s += ar[kk] * brow[kk];
            }
            orow[j] = s;
            j += 1;
        }
    }
}

/// Rank-`rows` update `g[i,j] += Σ_r x[r,i]·y[r,j]`, rows folded in
/// ascending order per element (`x` `[rows,m]`, `y` `[rows,n]`, `g`
/// `[m,n]`). Four-row blocks stream `g` once per block instead of once
/// per row; within a block the four terms are added sequentially, so the
/// per-element row order is untouched.
pub fn ger_acc_rows(g: &mut [f32], x: &[f32], y: &[f32], rows: usize, m: usize, n: usize) {
    assert_eq!(g.len(), m * n);
    assert_eq!(x.len(), rows * m);
    assert_eq!(y.len(), rows * n);
    let mut r0 = 0;
    while r0 + MR <= rows {
        let x0 = &x[r0 * m..r0 * m + m];
        let x1 = &x[(r0 + 1) * m..(r0 + 1) * m + m];
        let x2 = &x[(r0 + 2) * m..(r0 + 2) * m + m];
        let x3 = &x[(r0 + 3) * m..(r0 + 3) * m + m];
        let y0 = &y[r0 * n..r0 * n + n];
        let y1 = &y[(r0 + 1) * n..(r0 + 1) * n + n];
        let y2 = &y[(r0 + 2) * n..(r0 + 2) * n + n];
        let y3 = &y[(r0 + 3) * n..(r0 + 3) * n + n];
        for i in 0..m {
            let grow = &mut g[i * n..i * n + n];
            let (v0, v1, v2, v3) = (x0[i], x1[i], x2[i], x3[i]);
            for j in 0..n {
                let mut acc = grow[j];
                acc += v0 * y0[j];
                acc += v1 * y1[j];
                acc += v2 * y2[j];
                acc += v3 * y3[j];
                grow[j] = acc;
            }
        }
        r0 += MR;
    }
    for r in r0..rows {
        let xr = &x[r * m..r * m + m];
        let yr = &y[r * n..r * n + n];
        for (i, &xv) in xr.iter().enumerate() {
            let grow = &mut g[i * n..i * n + n];
            for j in 0..n {
                grow[j] += xv * yr[j];
            }
        }
    }
}

/// Column sums `acc[j] += Σ_r mat[r,j]` in ascending row order per
/// column (the bias-gradient reduction).
pub fn col_sum_acc(acc: &mut [f32], mat: &[f32], rows: usize, n: usize) {
    assert_eq!(acc.len(), n);
    assert_eq!(mat.len(), rows * n);
    for row in mat.chunks_exact(n) {
        for j in 0..n {
            acc[j] += row[j];
        }
    }
}

/// In-place ReLU — byte-for-byte the original epilogue (`-0.0` and NaN
/// pass through untouched, exactly like `if v < 0.0 { 0.0 }`).
pub fn relu_inplace(buf: &mut [f32]) {
    for v in buf.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// ReLU backward mask, in place on `du`: keep `du` where the forward
/// activation was strictly positive, zero elsewhere (NaN activations
/// zero the gradient — same as the original `if uv > 0.0` select).
pub fn relu_mask(du: &mut [f32], u: &[f32]) {
    assert_eq!(du.len(), u.len());
    for (d, &uv) in du.iter_mut().zip(u.iter()) {
        *d = if uv > 0.0 { *d } else { 0.0 };
    }
}

/// Fused second-matmul + residual epilogue of one MLP block:
/// `out[r,:] = t_in[r,:] + b2 + Σ_{h: u[r,h] ≠ 0} u[r,h]·w2[h,:]`,
/// h ascending. The zero-skip is part of the numeric contract (it is how
/// the original loop exploited ReLU sparsity), so it is preserved —
/// skipping a `+0.0` term is only observable through performance.
#[allow(clippy::too_many_arguments)]
pub fn residual_mlp2(
    u: &[f32],
    w2: &[f32],
    b2: &[f32],
    t_in: &[f32],
    rows: usize,
    hidden: usize,
    dim: usize,
    out: &mut [f32],
) {
    assert_eq!(u.len(), rows * hidden);
    assert_eq!(w2.len(), hidden * dim);
    assert_eq!(b2.len(), dim);
    assert_eq!(t_in.len(), rows * dim);
    assert_eq!(out.len(), rows * dim);
    for r in 0..rows {
        let ti = &t_in[r * dim..r * dim + dim];
        let ur = &u[r * hidden..r * hidden + hidden];
        let o = &mut out[r * dim..r * dim + dim];
        for j in 0..dim {
            o[j] = ti[j] + b2[j];
        }
        for (h, &uv) in ur.iter().enumerate() {
            if uv != 0.0 {
                let wrow = &w2[h * dim..h * dim + dim];
                for j in 0..dim {
                    o[j] += uv * wrow[j];
                }
            }
        }
    }
}

/// Batched patch gather (im2col): the `[n,H,W,C]` image tensor becomes
/// `[n·tokens, patch·patch·channels]` patch rows — row `(s,t)` holds
/// exactly the bytes the old per-(s,t) `gather_patch` produced, but each
/// is gathered once per exec call instead of once for the forward and
/// once again for the backward pass.
pub fn im2col(x: &[f32], n: usize, image: usize, patch: usize, channels: usize, out: &mut [f32]) {
    let grid = image / patch;
    let tokens = grid * grid;
    let pe = patch * patch * channels;
    assert_eq!(x.len(), n * image * image * channels);
    assert_eq!(out.len(), n * tokens * pe);
    // One source of truth for the gather: the full tensor is the
    // [0, n·tokens) row range of the shardable form below.
    im2col_rows(x, 0, n * tokens, image, patch, channels, out);
}

/// Token mean-pool: `out[s,:] = (Σ_t tok[s·T+t,:]) / T`, tokens folded in
/// ascending order, one final scale — the original head-forward order.
pub fn mean_pool(tok: &[f32], n: usize, tokens: usize, dim: usize, out: &mut [f32]) {
    assert_eq!(tok.len(), n * tokens * dim);
    assert_eq!(out.len(), n * dim);
    let inv = 1.0 / tokens as f32;
    for s in 0..n {
        let pr = &mut out[s * dim..s * dim + dim];
        pr.fill(0.0);
        for t in 0..tokens {
            let tr = &tok[(s * tokens + t) * dim..(s * tokens + t) * dim + dim];
            for j in 0..dim {
                pr[j] += tr[j];
            }
        }
        for v in pr.iter_mut() {
            *v *= inv;
        }
    }
}

/// One residual MLP block forward over `rows` token rows, whole-batch:
/// `u = relu(t_in·W₁ + b₁)` (kept for the backward pass), then the fused
/// residual epilogue. Bit-identical to [`reference::block_fwd`].
pub fn block_fwd(
    w: &[f32],
    t_in: &[f32],
    rows: usize,
    dim: usize,
    hidden: usize,
    t_out: &mut [f32],
    u_out: &mut [f32],
) {
    let (w1, rest) = w.split_at(dim * hidden);
    let (b1, rest) = rest.split_at(hidden);
    let (w2, b2) = rest.split_at(hidden * dim);
    gemm_bias(t_in, w1, b1, rows, dim, hidden, u_out);
    relu_inplace(u_out);
    residual_mlp2(u_out, w2, b2, t_in, rows, hidden, dim, t_out);
}

/// One block backward, whole-batch: given `∂L/∂t_out`, accumulate the
/// block's parameter gradients into `g_w` (same layout as `w`) and write
/// `∂L/∂t_in` into `d_in`. `du` is a `[rows·hidden]` scratch buffer
/// (overwritten). Bit-identical to [`reference::block_bwd`]: every
/// per-element reduction folds in the original (κ-ascending, then
/// row-ascending) order.
#[allow(clippy::too_many_arguments)]
pub fn block_bwd(
    w: &[f32],
    t_in: &[f32],
    u: &[f32],
    d_out: &[f32],
    rows: usize,
    dim: usize,
    hidden: usize,
    g_w: &mut [f32],
    d_in: &mut [f32],
    du: &mut [f32],
) {
    let (w1, rest) = w.split_at(dim * hidden);
    let (_b1, rest) = rest.split_at(hidden);
    let (w2, _b2) = rest.split_at(hidden * dim);
    let (gw1, grest) = g_w.split_at_mut(dim * hidden);
    let (gb1, grest) = grest.split_at_mut(hidden);
    let (gw2, gb2) = grest.split_at_mut(hidden * dim);
    // ∂b₂: column sums of the upstream gradient, rows in order.
    col_sum_acc(gb2, d_out, rows, dim);
    // du[r,h] = Σ_j d_out[r,j]·w2[h,j] — the hidden-layer gradient before
    // the ReLU mask (the original loop computed it unmasked too).
    gemm_bt(d_out, w2, None, rows, dim, hidden, du);
    // ∂W₂ += uᵀ·d_out, rows in order (zero activations contribute their
    // +0.0 terms exactly as the original unconditional update did).
    ger_acc_rows(gw2, u, d_out, rows, hidden, dim);
    // da = du masked by the forward activations.
    relu_mask(du, u);
    // ∂b₁: column sums of da, rows in order.
    col_sum_acc(gb1, du, rows, hidden);
    // ∂t_in[r,i] = d_out[r,i] (residual path) + Σ_h da[r,h]·w1[i,h].
    gemm_bt(du, w1, Some(d_out), rows, hidden, dim, d_in);
    // ∂W₁ += t_inᵀ·da, rows in order.
    ger_acc_rows(gw1, t_in, du, rows, dim, hidden);
}

/// Classifier head forward, whole-batch: mean-pool + linear map.
#[allow(clippy::too_many_arguments)]
pub fn head_fwd(
    clf: &[f32],
    classes: usize,
    tok: &[f32],
    n: usize,
    tokens: usize,
    dim: usize,
    pooled: &mut [f32],
    logits: &mut [f32],
) {
    let (w, b) = clf.split_at(dim * classes);
    mean_pool(tok, n, tokens, dim, pooled);
    gemm_bias(pooled, w, b, n, dim, classes, logits);
}

/// Classifier head backward, whole-batch: head parameter gradients plus
/// `∂L/∂tokens` (the mean-pool spreads `∂L/∂pooled` uniformly). `dp` is
/// an `[n·dim]` scratch buffer (overwritten).
#[allow(clippy::too_many_arguments)]
pub fn head_bwd(
    clf: &[f32],
    classes: usize,
    pooled: &[f32],
    dlogits: &[f32],
    n: usize,
    tokens: usize,
    dim: usize,
    g_clf: &mut [f32],
    dp: &mut [f32],
    d_tok: &mut [f32],
) {
    let (w, _b) = clf.split_at(dim * classes);
    let (gw, gb) = g_clf.split_at_mut(dim * classes);
    assert_eq!(dp.len(), n * dim);
    assert_eq!(d_tok.len(), n * tokens * dim);
    // ∂b: column sums of ∂logits, samples in order.
    col_sum_acc(gb, dlogits, n, classes);
    // ∂W += pooledᵀ·∂logits, samples in order.
    ger_acc_rows(gw, pooled, dlogits, n, dim, classes);
    // ∂pooled[s,i] = (Σ_k ∂logits[s,k]·w[i,k]) / T — fold first, one
    // final scale, exactly like the original `acc * inv`.
    gemm_bt(dlogits, w, None, n, classes, dim, dp);
    let inv = 1.0 / tokens as f32;
    for v in dp.iter_mut() {
        *v *= inv;
    }
    for s in 0..n {
        let dpr = &dp[s * dim..s * dim + dim];
        for t in 0..tokens {
            d_tok[(s * tokens + t) * dim..(s * tokens + t) * dim + dim].copy_from_slice(dpr);
        }
    }
}

/// Softmax cross-entropy: mean loss over the batch, `∂L/∂logits` written
/// into `d` (fully overwritten). Labels must be pre-validated against
/// `classes` (the backend checks them at the argument boundary).
pub fn softmax_xent(logits: &[f32], y: &[i32], classes: usize, n: usize, d: &mut [f32]) -> f32 {
    assert_eq!(logits.len(), n * classes);
    assert_eq!(y.len(), n);
    assert_eq!(d.len(), n * classes);
    let mut loss = 0.0f32;
    let inv_n = 1.0 / n as f32;
    for s in 0..n {
        let label = y[s];
        debug_assert!(label >= 0 && (label as usize) < classes, "unvalidated label");
        let row = &logits[s * classes..s * classes + classes];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut zsum = 0.0f32;
        let dr = &mut d[s * classes..s * classes + classes];
        for (k, &v) in row.iter().enumerate() {
            let e = (v - m).exp();
            dr[k] = e;
            zsum += e;
        }
        loss += (zsum.ln() + m - row[label as usize]) * inv_n;
        let inv_z = inv_n / zsum;
        for v in dr.iter_mut() {
            *v *= inv_z;
        }
        dr[label as usize] -= inv_n;
    }
    loss
}

// ---- deterministic shard reduction (module docs § kernel-threads) ------

use super::pool::ShardPool;
use std::time::Instant;

/// Rows per shard of the default plan. A pure constant: shard boundaries
/// must never depend on the worker count. 32 rows = two training samples
/// (16 tokens each) — big enough that the pool dispatch overhead is
/// amortized, small enough that a 128-row training batch still yields 4
/// shards and the 512-row eval batch 16.
pub const SHARD_ROWS: usize = 32;

/// A fixed row-range decomposition: shard `s` covers rows
/// `[s·shard_rows, min(rows, (s+1)·shard_rows))`. Pure function of the
/// row count (the worker count is *not* an input), so the decomposition —
/// and with it every merge order — is identical for every
/// `--kernel-threads N`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    rows: usize,
    shard_rows: usize,
}

impl ShardPlan {
    /// The default plan for a row count ([`SHARD_ROWS`]-row ranges).
    pub fn of(rows: usize) -> ShardPlan {
        ShardPlan::with_shard_rows(rows, SHARD_ROWS)
    }

    /// A plan with an explicit shard height (property tests exercise
    /// awkward heights — 1, off the register block, larger than `rows`).
    pub fn with_shard_rows(rows: usize, shard_rows: usize) -> ShardPlan {
        ShardPlan {
            rows,
            shard_rows: shard_rows.max(1),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn nshards(&self) -> usize {
        self.rows / self.shard_rows + usize::from(self.rows % self.shard_rows != 0)
    }

    /// Row range `[lo, hi)` of shard `s`.
    pub fn range(&self, s: usize) -> (usize, usize) {
        let lo = s * self.shard_rows;
        (lo, self.rows.min(lo + self.shard_rows))
    }
}

/// A `Send + Sync` raw-pointer wrapper for handing *disjoint* row ranges
/// of one output buffer to pool workers. Every `unsafe` block slicing
/// through it relies on the same invariant: [`ShardPlan::range`] ranges
/// are pairwise disjoint, so no two shards ever alias.
#[derive(Clone, Copy)]
struct SendMut(*mut f32);

// SAFETY: shards write pairwise-disjoint ranges (ShardPlan geometry) and
// the pool joins every shard before the owning call returns.
unsafe impl Send for SendMut {}
// SAFETY: the wrapper itself is only copied across threads; every write
// through the pointer goes via `sub_mut`, whose disjoint-range contract
// (enforced by ShardPlan geometry) rules out aliasing between workers.
unsafe impl Sync for SendMut {}

/// Slice `len` elements starting `offset` into a [`SendMut`] buffer.
///
/// # Safety
/// The `[offset, offset+len)` ranges of concurrent calls must be
/// pairwise disjoint and inside the original buffer.
#[inline]
unsafe fn sub_mut<'a>(p: SendMut, offset: usize, len: usize) -> &'a mut [f32] {
    // SAFETY: the caller upholds the function contract above — the range
    // is inside the original buffer and disjoint from every concurrent
    // call, so a unique `&mut` to it cannot alias.
    unsafe { std::slice::from_raw_parts_mut(p.0.add(offset), len) }
}

/// Row-sharded [`gemm_bias`] — bitwise identical to the direct kernel
/// for every plan and thread count (each output row's fold is untouched;
/// shards write disjoint row ranges).
#[allow(clippy::too_many_arguments)]
pub fn gemm_bias_sharded(
    pool: &ShardPool,
    plan: ShardPlan,
    a: &[f32],
    w: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(plan.rows(), m);
    assert_eq!(a.len(), m * k);
    assert_eq!(out.len(), m * n);
    if plan.nshards() <= 1 {
        return gemm_bias(a, w, bias, m, k, n, out);
    }
    let op = SendMut(out.as_mut_ptr());
    pool.run(plan.nshards(), &|s| {
        let (lo, hi) = plan.range(s);
        // SAFETY: plan ranges are disjoint (sub_mut contract).
        let orows = unsafe { sub_mut(op, lo * n, (hi - lo) * n) };
        gemm_bias(&a[lo * k..hi * k], w, bias, hi - lo, k, n, orows);
    });
}

/// Row-sharded [`gemm_bt`] — bitwise identical to the direct kernel
/// (per-element folds are row-local).
#[allow(clippy::too_many_arguments)]
pub fn gemm_bt_sharded(
    pool: &ShardPool,
    plan: ShardPlan,
    a: &[f32],
    b: &[f32],
    seed: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(plan.rows(), m);
    assert_eq!(a.len(), m * k);
    assert_eq!(out.len(), m * n);
    if let Some(s) = seed {
        assert_eq!(s.len(), m * n);
    }
    if plan.nshards() <= 1 {
        return gemm_bt(a, b, seed, m, k, n, out);
    }
    let op = SendMut(out.as_mut_ptr());
    pool.run(plan.nshards(), &|s| {
        let (lo, hi) = plan.range(s);
        // SAFETY: plan ranges are disjoint (sub_mut contract).
        let orows = unsafe { sub_mut(op, lo * n, (hi - lo) * n) };
        let seed_rows = seed.map(|sd| &sd[lo * n..hi * n]);
        gemm_bt(&a[lo * k..hi * k], b, seed_rows, hi - lo, k, n, orows);
    });
}

/// Patch-row range `[lo, hi)` of the im2col gather (row `r` feeds token
/// `r % tokens` of sample `r / tokens`). The per-row bytes are exactly
/// [`im2col`]'s — pure copies, so sharding is bitwise transparent.
fn im2col_rows(
    x: &[f32],
    lo: usize,
    hi: usize,
    image: usize,
    patch: usize,
    channels: usize,
    out_rows: &mut [f32],
) {
    let grid = image / patch;
    let tokens = grid * grid;
    let pe = patch * patch * channels;
    let img_elems = image * image * channels;
    let span = patch * channels;
    for (i, r) in (lo..hi).enumerate() {
        let (s, t) = (r / tokens, r % tokens);
        let base = s * img_elems;
        let (pi, pj) = (t / grid, t % grid);
        let orow = &mut out_rows[i * pe..i * pe + pe];
        let mut k = 0;
        for py in 0..patch {
            let gy = pi * patch + py;
            let row = base + (gy * image + pj * patch) * channels;
            orow[k..k + span].copy_from_slice(&x[row..row + span]);
            k += span;
        }
    }
}

/// Row-sharded [`im2col`] over the `n·tokens` patch rows — bitwise
/// identical to the direct gather for every plan.
#[allow(clippy::too_many_arguments)]
pub fn im2col_sharded(
    pool: &ShardPool,
    plan: ShardPlan,
    x: &[f32],
    n: usize,
    image: usize,
    patch: usize,
    channels: usize,
    out: &mut [f32],
) {
    let grid = image / patch;
    let tokens = grid * grid;
    let pe = patch * patch * channels;
    assert_eq!(plan.rows(), n * tokens);
    assert_eq!(x.len(), n * image * image * channels);
    assert_eq!(out.len(), n * tokens * pe);
    if plan.nshards() <= 1 {
        return im2col(x, n, image, patch, channels, out);
    }
    let op = SendMut(out.as_mut_ptr());
    pool.run(plan.nshards(), &|s| {
        let (lo, hi) = plan.range(s);
        // SAFETY: plan ranges are disjoint (sub_mut contract).
        let orows = unsafe { sub_mut(op, lo * pe, (hi - lo) * pe) };
        im2col_rows(x, lo, hi, image, patch, channels, orows);
    });
}

/// Row-sharded [`block_fwd`] — each shard runs the full fused
/// gemm→ReLU→residual chain on its token rows. Bitwise identical to the
/// direct kernel (all three stages are row-disjoint).
#[allow(clippy::too_many_arguments)]
pub fn block_fwd_sharded(
    pool: &ShardPool,
    plan: ShardPlan,
    w: &[f32],
    t_in: &[f32],
    rows: usize,
    dim: usize,
    hidden: usize,
    t_out: &mut [f32],
    u_out: &mut [f32],
) {
    assert_eq!(plan.rows(), rows);
    assert_eq!(t_in.len(), rows * dim);
    assert_eq!(t_out.len(), rows * dim);
    assert_eq!(u_out.len(), rows * hidden);
    if plan.nshards() <= 1 {
        return block_fwd(w, t_in, rows, dim, hidden, t_out, u_out);
    }
    let tp = SendMut(t_out.as_mut_ptr());
    let up = SendMut(u_out.as_mut_ptr());
    pool.run(plan.nshards(), &|s| {
        let (lo, hi) = plan.range(s);
        let r = hi - lo;
        // SAFETY: plan ranges are disjoint (sub_mut contract).
        let (t_sl, u_sl) = unsafe { (sub_mut(tp, lo * dim, r * dim), sub_mut(up, lo * hidden, r * hidden)) };
        block_fwd(w, &t_in[lo * dim..hi * dim], r, dim, hidden, t_sl, u_sl);
    });
}

/// Merge per-shard partial accumulators into `acc` in ascending shard
/// index — the fixed-order reduction every sharded accumulation kernel
/// ends with. Returns the host seconds spent merging (reported through
/// `RuntimeStats::shard_merge_time_s`).
fn merge_partials(acc: &mut [f32], partials: &[f32], nshards: usize) -> f64 {
    let len = acc.len();
    assert!(partials.len() >= nshards * len);
    let t0 = Instant::now();
    for part in partials[..nshards * len].chunks_exact(len) {
        for (a, p) in acc.iter_mut().zip(part.iter()) {
            *a += *p;
        }
    }
    t0.elapsed().as_secs_f64()
}

/// Row-sharded [`col_sum_acc`]: each shard folds its row range (rows
/// ascending) into a zeroed partial, partials merge in shard order.
/// `part` is scratch for `nshards · n` partial elements (zeroed here).
/// Returns merge seconds. Single-shard plans degenerate to the direct
/// kernel (no partial — bitwise the pre-shard behaviour).
pub fn col_sum_acc_sharded(
    pool: &ShardPool,
    plan: ShardPlan,
    acc: &mut [f32],
    mat: &[f32],
    rows: usize,
    n: usize,
    part: &mut [f32],
) -> f64 {
    assert_eq!(plan.rows(), rows);
    assert_eq!(acc.len(), n);
    assert_eq!(mat.len(), rows * n);
    let ns = plan.nshards();
    if ns <= 1 {
        col_sum_acc(acc, mat, rows, n);
        return 0.0;
    }
    let part = &mut part[..ns * n];
    part.fill(0.0);
    let pp = SendMut(part.as_mut_ptr());
    pool.run(ns, &|s| {
        let (lo, hi) = plan.range(s);
        // SAFETY: shard `s` owns partial slot `s` exclusively.
        let p = unsafe { sub_mut(pp, s * n, n) };
        col_sum_acc(p, &mat[lo * n..hi * n], hi - lo, n);
    });
    merge_partials(acc, part, ns)
}

/// Row-sharded [`ger_acc_rows`]: per-shard rank-`r` partials (rows
/// ascending within a shard), merged in shard order. `part` is scratch
/// for `nshards · m · n` elements. Returns merge seconds.
#[allow(clippy::too_many_arguments)]
pub fn ger_acc_rows_sharded(
    pool: &ShardPool,
    plan: ShardPlan,
    g: &mut [f32],
    x: &[f32],
    y: &[f32],
    rows: usize,
    m: usize,
    n: usize,
    part: &mut [f32],
) -> f64 {
    assert_eq!(plan.rows(), rows);
    assert_eq!(g.len(), m * n);
    assert_eq!(x.len(), rows * m);
    assert_eq!(y.len(), rows * n);
    let ns = plan.nshards();
    if ns <= 1 {
        ger_acc_rows(g, x, y, rows, m, n);
        return 0.0;
    }
    let part = &mut part[..ns * m * n];
    part.fill(0.0);
    let pp = SendMut(part.as_mut_ptr());
    pool.run(ns, &|s| {
        let (lo, hi) = plan.range(s);
        // SAFETY: shard `s` owns partial slot `s` exclusively.
        let p = unsafe { sub_mut(pp, s * m * n, m * n) };
        ger_acc_rows(p, &x[lo * m..hi * m], &y[lo * n..hi * n], hi - lo, m, n);
    });
    merge_partials(g, part, ns)
}

/// Row-sharded [`block_bwd`]: the token-gradient outputs (`d_in`, `du`)
/// are row-disjoint and written directly; the parameter gradients fold
/// into per-shard partials (zeroed slices of `gpart`, layout identical
/// to `g_w`) merged into `g_w` in ascending shard index. `gpart` must
/// hold at least `nshards · g_w.len()` elements. Returns merge seconds.
#[allow(clippy::too_many_arguments)]
pub fn block_bwd_sharded(
    pool: &ShardPool,
    plan: ShardPlan,
    w: &[f32],
    t_in: &[f32],
    u: &[f32],
    d_out: &[f32],
    rows: usize,
    dim: usize,
    hidden: usize,
    g_w: &mut [f32],
    d_in: &mut [f32],
    du: &mut [f32],
    gpart: &mut [f32],
) -> f64 {
    assert_eq!(plan.rows(), rows);
    assert_eq!(t_in.len(), rows * dim);
    assert_eq!(u.len(), rows * hidden);
    assert_eq!(d_out.len(), rows * dim);
    assert_eq!(d_in.len(), rows * dim);
    assert_eq!(du.len(), rows * hidden);
    let ns = plan.nshards();
    if ns <= 1 {
        block_bwd(w, t_in, u, d_out, rows, dim, hidden, g_w, d_in, du);
        return 0.0;
    }
    let wlen = g_w.len();
    let gpart = &mut gpart[..ns * wlen];
    gpart.fill(0.0);
    let gp = SendMut(gpart.as_mut_ptr());
    let dp = SendMut(d_in.as_mut_ptr());
    let dup = SendMut(du.as_mut_ptr());
    pool.run(ns, &|s| {
        let (lo, hi) = plan.range(s);
        let r = hi - lo;
        // SAFETY: shard `s` owns partial slot `s` and row range
        // `[lo, hi)` of d_in/du exclusively (sub_mut contract).
        let (g_s, d_s, du_s) = unsafe {
            (
                sub_mut(gp, s * wlen, wlen),
                sub_mut(dp, lo * dim, r * dim),
                sub_mut(dup, lo * hidden, r * hidden),
            )
        };
        block_bwd(
            w,
            &t_in[lo * dim..hi * dim],
            &u[lo * hidden..hi * hidden],
            &d_out[lo * dim..hi * dim],
            r,
            dim,
            hidden,
            g_s,
            d_s,
            du_s,
        );
    });
    merge_partials(g_w, gpart, ns)
}

/// The pre-kernel-core scalar implementations, kept verbatim (made
/// dimension-generic) as the bit-identity oracle. Used by the property
/// tests below and by `bench_native_kernels` for the naive-vs-tiled
/// before/after sections — which is why the module is compiled (but
/// doc-hidden) rather than `#[cfg(test)]`-gated.
#[doc(hidden)]
pub mod reference {
    /// Row-at-a-time `out[r,:] = bias + Σ_κ a[r,κ]·w[κ,:]`.
    pub fn gemm_bias(
        a: &[f32],
        w: &[f32],
        bias: &[f32],
        m: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        for r in 0..m {
            let row = &mut out[r * n..][..n];
            row.copy_from_slice(bias);
            for (kk, &av) in a[r * k..][..k].iter().enumerate() {
                let wrow = &w[kk * n..][..n];
                for j in 0..n {
                    row[j] += av * wrow[j];
                }
            }
        }
    }

    /// Copy the patch feeding token `t` of sample `s` out of the
    /// row-major `[n,H,W,C]` image tensor (order: y, x, channel).
    pub fn gather_patch(
        x: &[f32],
        s: usize,
        t: usize,
        image: usize,
        patch: usize,
        channels: usize,
        out: &mut [f32],
    ) {
        let grid = image / patch;
        let (pi, pj) = (t / grid, t % grid);
        let base = s * image * image * channels;
        let span = patch * channels;
        let mut k = 0;
        for py in 0..patch {
            let gy = pi * patch + py;
            let row = base + (gy * image + pj * patch) * channels;
            out[k..k + span].copy_from_slice(&x[row..row + span]);
            k += span;
        }
    }

    /// Patch embedding forward, one (s,t) gather + axpy at a time.
    #[allow(clippy::too_many_arguments)]
    pub fn embed_fwd(
        w: &[f32],
        b: &[f32],
        x: &[f32],
        n: usize,
        image: usize,
        patch: usize,
        channels: usize,
        dim: usize,
        out: &mut [f32],
    ) {
        let grid = image / patch;
        let tokens = grid * grid;
        let pe = patch * patch * channels;
        let mut pbuf = vec![0.0f32; pe];
        for s in 0..n {
            for t in 0..tokens {
                gather_patch(x, s, t, image, patch, channels, &mut pbuf);
                let o = &mut out[(s * tokens + t) * dim..][..dim];
                o.copy_from_slice(b);
                for (p, &xv) in pbuf.iter().enumerate() {
                    let row = &w[p * dim..][..dim];
                    for j in 0..dim {
                        o[j] += xv * row[j];
                    }
                }
            }
        }
    }

    /// Patch embedding backward, one (s,t) re-gather at a time.
    #[allow(clippy::too_many_arguments)]
    pub fn embed_bwd(
        x: &[f32],
        d_tok: &[f32],
        n: usize,
        image: usize,
        patch: usize,
        channels: usize,
        dim: usize,
        gw: &mut [f32],
        gb: &mut [f32],
    ) {
        let grid = image / patch;
        let tokens = grid * grid;
        let pe = patch * patch * channels;
        let mut pbuf = vec![0.0f32; pe];
        for s in 0..n {
            for t in 0..tokens {
                gather_patch(x, s, t, image, patch, channels, &mut pbuf);
                let d = &d_tok[(s * tokens + t) * dim..][..dim];
                for j in 0..dim {
                    gb[j] += d[j];
                }
                for (p, &xv) in pbuf.iter().enumerate() {
                    let grow = &mut gw[p * dim..][..dim];
                    for j in 0..dim {
                        grow[j] += xv * d[j];
                    }
                }
            }
        }
    }

    /// One residual MLP block forward, row at a time.
    pub fn block_fwd(
        w: &[f32],
        t_in: &[f32],
        rows: usize,
        dim: usize,
        hidden: usize,
        t_out: &mut [f32],
        u_out: &mut [f32],
    ) {
        let (w1, rest) = w.split_at(dim * hidden);
        let (b1, rest) = rest.split_at(hidden);
        let (w2, b2) = rest.split_at(hidden * dim);
        for r in 0..rows {
            let ti = &t_in[r * dim..][..dim];
            let u = &mut u_out[r * hidden..][..hidden];
            u.copy_from_slice(b1);
            for (i, &tv) in ti.iter().enumerate() {
                let row = &w1[i * hidden..][..hidden];
                for h in 0..hidden {
                    u[h] += tv * row[h];
                }
            }
            for v in u.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            let to = &mut t_out[r * dim..][..dim];
            for j in 0..dim {
                to[j] = ti[j] + b2[j];
            }
            for (h, &uv) in u.iter().enumerate() {
                if uv != 0.0 {
                    let row = &w2[h * dim..][..dim];
                    for j in 0..dim {
                        to[j] += uv * row[j];
                    }
                }
            }
        }
    }

    /// One block backward, row at a time with the interleaved du/∂W₂ and
    /// ∂t_in/∂W₁ loops of the original implementation.
    #[allow(clippy::too_many_arguments)]
    pub fn block_bwd(
        w: &[f32],
        t_in: &[f32],
        u: &[f32],
        d_out: &[f32],
        rows: usize,
        dim: usize,
        hidden: usize,
        g_w: &mut [f32],
        d_in: &mut [f32],
    ) {
        let (w1, rest) = w.split_at(dim * hidden);
        let (_b1, rest) = rest.split_at(hidden);
        let (w2, _b2) = rest.split_at(hidden * dim);
        let (gw1, grest) = g_w.split_at_mut(dim * hidden);
        let (gb1, grest) = grest.split_at_mut(hidden);
        let (gw2, gb2) = grest.split_at_mut(hidden * dim);
        let mut da = vec![0.0f32; hidden];
        for r in 0..rows {
            let dy = &d_out[r * dim..][..dim];
            let ur = &u[r * hidden..][..hidden];
            let ti = &t_in[r * dim..][..dim];
            for j in 0..dim {
                gb2[j] += dy[j];
            }
            for (h, &uv) in ur.iter().enumerate() {
                let row = &w2[h * dim..][..dim];
                let grow = &mut gw2[h * dim..][..dim];
                let mut du = 0.0f32;
                for j in 0..dim {
                    du += dy[j] * row[j];
                    grow[j] += uv * dy[j];
                }
                da[h] = if uv > 0.0 { du } else { 0.0 };
            }
            for h in 0..hidden {
                gb1[h] += da[h];
            }
            let di = &mut d_in[r * dim..][..dim];
            for (i, &tv) in ti.iter().enumerate() {
                let row = &w1[i * hidden..][..hidden];
                let grow = &mut gw1[i * hidden..][..hidden];
                let mut acc = dy[i]; // residual path
                for h in 0..hidden {
                    acc += da[h] * row[h];
                    grow[h] += tv * da[h];
                }
                di[i] = acc;
            }
        }
    }

    /// Classifier head forward, sample at a time.
    #[allow(clippy::too_many_arguments)]
    pub fn head_fwd(
        clf: &[f32],
        classes: usize,
        tok: &[f32],
        n: usize,
        tokens: usize,
        dim: usize,
        pooled: &mut [f32],
        logits: &mut [f32],
    ) {
        let (w, b) = clf.split_at(dim * classes);
        let inv = 1.0 / tokens as f32;
        for s in 0..n {
            let pr = &mut pooled[s * dim..][..dim];
            pr.fill(0.0);
            for t in 0..tokens {
                let tr = &tok[(s * tokens + t) * dim..][..dim];
                for j in 0..dim {
                    pr[j] += tr[j];
                }
            }
            for v in pr.iter_mut() {
                *v *= inv;
            }
            let lo = &mut logits[s * classes..][..classes];
            lo.copy_from_slice(b);
            for (i, &pv) in pr.iter().enumerate() {
                let row = &w[i * classes..][..classes];
                for k in 0..classes {
                    lo[k] += pv * row[k];
                }
            }
        }
    }

    /// Classifier head backward, sample at a time.
    #[allow(clippy::too_many_arguments)]
    pub fn head_bwd(
        clf: &[f32],
        classes: usize,
        pooled: &[f32],
        dlogits: &[f32],
        n: usize,
        tokens: usize,
        dim: usize,
        g_clf: &mut [f32],
        d_tok: &mut [f32],
    ) {
        let (w, _b) = clf.split_at(dim * classes);
        let (gw, gb) = g_clf.split_at_mut(dim * classes);
        let inv = 1.0 / tokens as f32;
        let mut dp = vec![0.0f32; dim];
        for s in 0..n {
            let dl = &dlogits[s * classes..][..classes];
            for k in 0..classes {
                gb[k] += dl[k];
            }
            let pr = &pooled[s * dim..][..dim];
            for (i, &pv) in pr.iter().enumerate() {
                let row = &w[i * classes..][..classes];
                let grow = &mut gw[i * classes..][..classes];
                let mut acc = 0.0f32;
                for k in 0..classes {
                    acc += dl[k] * row[k];
                    grow[k] += pv * dl[k];
                }
                dp[i] = acc * inv;
            }
            for t in 0..tokens {
                d_tok[(s * tokens + t) * dim..][..dim].copy_from_slice(&dp);
            }
        }
    }

    /// Softmax cross-entropy, allocating form.
    pub fn softmax_xent(logits: &[f32], y: &[i32], classes: usize, n: usize) -> (f32, Vec<f32>) {
        let mut d = vec![0.0f32; n * classes];
        let loss = super::softmax_xent(logits, y, classes, n, &mut d);
        (loss, d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
        }
    }

    /// Awkward row counts: below, at, straddling and off the 4-row block.
    const ROWS: [usize; 6] = [1, 3, 4, 5, 13, 16];

    #[test]
    fn prop_gemm_bias_bitwise_matches_reference() {
        forall(0x6E11, 40, |rng| {
            let m = ROWS[rng.uniform_usize(ROWS.len())];
            let k = 1 + rng.uniform_usize(48);
            let n = 1 + rng.uniform_usize(40); // includes n < 4 (ILP remainder)
            let a = randv(rng, m * k);
            let w = randv(rng, k * n);
            let bias = randv(rng, n);
            let mut tiled = vec![0.0f32; m * n];
            let mut naive = vec![0.0f32; m * n];
            gemm_bias(&a, &w, &bias, m, k, n, &mut tiled);
            reference::gemm_bias(&a, &w, &bias, m, k, n, &mut naive);
            assert_bits_eq(&tiled, &naive, "gemm_bias");
        });
    }

    #[test]
    fn prop_block_fwd_bitwise_matches_reference() {
        forall(0xB10C, 30, |rng| {
            // n ∈ {1,3,5,8} batches of 16 tokens, plus off-block rows.
            let rows = match rng.uniform_usize(6) {
                0 => 16,     // n = 1
                1 => 48,     // n = 3
                2 => 80,     // n = 5
                3 => 128,    // n = 8
                4 => 7,      // off the 4-row block
                _ => 1 + rng.uniform_usize(33),
            };
            let dim = 8 + rng.uniform_usize(12);
            let hidden = 2 * dim;
            let w = randv(rng, dim * hidden + hidden + hidden * dim + dim);
            let t_in = randv(rng, rows * dim);
            let mut t_a = vec![0.0f32; rows * dim];
            let mut u_a = vec![0.0f32; rows * hidden];
            let mut t_b = vec![0.0f32; rows * dim];
            let mut u_b = vec![0.0f32; rows * hidden];
            block_fwd(&w, &t_in, rows, dim, hidden, &mut t_a, &mut u_a);
            reference::block_fwd(&w, &t_in, rows, dim, hidden, &mut t_b, &mut u_b);
            assert_bits_eq(&u_a, &u_b, "block_fwd.u");
            assert_bits_eq(&t_a, &t_b, "block_fwd.t");
        });
    }

    #[test]
    fn prop_block_bwd_bitwise_matches_reference() {
        forall(0xB30D, 30, |rng| {
            let rows = [16usize, 48, 80, 128, 7, 1, 5][rng.uniform_usize(7)];
            let dim = 8 + rng.uniform_usize(12);
            let hidden = 2 * dim;
            let wlen = dim * hidden + hidden + hidden * dim + dim;
            let w = randv(rng, wlen);
            let t_in = randv(rng, rows * dim);
            // Run a real forward so `u` carries genuine ReLU zeros (the
            // skip/mask paths are the order-sensitive part).
            let mut t_out = vec![0.0f32; rows * dim];
            let mut u = vec![0.0f32; rows * hidden];
            block_fwd(&w, &t_in, rows, dim, hidden, &mut t_out, &mut u);
            let d_out = randv(rng, rows * dim);
            // Non-zero gradient accumulators: the kernels must *add to*
            // existing values exactly like the originals.
            let g0 = randv(rng, wlen);
            let mut g_a = g0.clone();
            let mut g_b = g0;
            let mut d_a = vec![0.0f32; rows * dim];
            let mut d_b = vec![0.0f32; rows * dim];
            let mut du = vec![0.0f32; rows * hidden];
            block_bwd(&w, &t_in, &u, &d_out, rows, dim, hidden, &mut g_a, &mut d_a, &mut du);
            reference::block_bwd(&w, &t_in, &u, &d_out, rows, dim, hidden, &mut g_b, &mut d_b);
            assert_bits_eq(&g_a, &g_b, "block_bwd.g_w");
            assert_bits_eq(&d_a, &d_b, "block_bwd.d_in");
        });
    }

    #[test]
    fn prop_embed_pair_bitwise_matches_reference() {
        forall(0xE3BD, 20, |rng| {
            let n = [1usize, 3, 5, 8][rng.uniform_usize(4)];
            let (image, patch, channels, dim) = (16usize, 4usize, 3usize, 8 + rng.uniform_usize(9));
            let grid = image / patch;
            let tokens = grid * grid;
            let pe = patch * patch * channels;
            let x = randv(rng, n * image * image * channels);
            let w = randv(rng, pe * dim);
            let b = randv(rng, dim);
            let rows = n * tokens;

            // Forward: im2col + gemm_bias vs per-(s,t) gather.
            let mut patches = vec![0.0f32; rows * pe];
            im2col(&x, n, image, patch, channels, &mut patches);
            let mut fwd_a = vec![0.0f32; rows * dim];
            gemm_bias(&patches, &w, &b, rows, pe, dim, &mut fwd_a);
            let mut fwd_b = vec![0.0f32; rows * dim];
            reference::embed_fwd(&w, &b, &x, n, image, patch, channels, dim, &mut fwd_b);
            assert_bits_eq(&fwd_a, &fwd_b, "embed_fwd");

            // Backward: col_sum + ger over patch rows vs per-(s,t) re-gather.
            let d_tok = randv(rng, rows * dim);
            let gw0 = randv(rng, pe * dim);
            let gb0 = randv(rng, dim);
            let (mut gw_a, mut gb_a) = (gw0.clone(), gb0.clone());
            let (mut gw_b, mut gb_b) = (gw0, gb0);
            col_sum_acc(&mut gb_a, &d_tok, rows, dim);
            ger_acc_rows(&mut gw_a, &patches, &d_tok, rows, pe, dim);
            reference::embed_bwd(&x, &d_tok, n, image, patch, channels, dim, &mut gw_b, &mut gb_b);
            assert_bits_eq(&gw_a, &gw_b, "embed_bwd.gw");
            assert_bits_eq(&gb_a, &gb_b, "embed_bwd.gb");
        });
    }

    #[test]
    fn prop_head_pair_bitwise_matches_reference() {
        forall(0x4EAD, 30, |rng| {
            let n = [1usize, 3, 5, 8][rng.uniform_usize(4)];
            let tokens = 1 + rng.uniform_usize(16);
            let dim = 4 + rng.uniform_usize(29);
            // Below/at/off the 4-chain ILP width, plus 10/100-class shapes.
            let classes = [1usize, 2, 3, 4, 10, 100][rng.uniform_usize(6)];
            let clf = randv(rng, dim * classes + classes);
            let tok = randv(rng, n * tokens * dim);

            let mut pooled_a = vec![0.0f32; n * dim];
            let mut logits_a = vec![0.0f32; n * classes];
            head_fwd(&clf, classes, &tok, n, tokens, dim, &mut pooled_a, &mut logits_a);
            let mut pooled_b = vec![0.0f32; n * dim];
            let mut logits_b = vec![0.0f32; n * classes];
            reference::head_fwd(&clf, classes, &tok, n, tokens, dim, &mut pooled_b, &mut logits_b);
            assert_bits_eq(&pooled_a, &pooled_b, "head_fwd.pooled");
            assert_bits_eq(&logits_a, &logits_b, "head_fwd.logits");

            let y: Vec<i32> = (0..n).map(|i| (i % classes) as i32).collect();
            let mut dlog_a = vec![0.0f32; n * classes];
            let loss_a = softmax_xent(&logits_a, &y, classes, n, &mut dlog_a);
            let (loss_b, dlog_b) = reference::softmax_xent(&logits_b, &y, classes, n);
            assert_eq!(loss_a.to_bits(), loss_b.to_bits(), "xent loss");
            assert_bits_eq(&dlog_a, &dlog_b, "xent d");

            let g0 = randv(rng, dim * classes + classes);
            let mut g_a = g0.clone();
            let mut g_b = g0;
            let mut dp = vec![0.0f32; n * dim];
            let mut dt_a = vec![0.0f32; n * tokens * dim];
            let mut dt_b = vec![0.0f32; n * tokens * dim];
            head_bwd(&clf, classes, &pooled_a, &dlog_a, n, tokens, dim, &mut g_a, &mut dp, &mut dt_a);
            reference::head_bwd(&clf, classes, &pooled_b, &dlog_b, n, tokens, dim, &mut g_b, &mut dt_b);
            assert_bits_eq(&g_a, &g_b, "head_bwd.g_clf");
            assert_bits_eq(&dt_a, &dt_b, "head_bwd.d_tok");
        });
    }

    #[test]
    fn prop_gemm_bt_seed_and_remainders() {
        forall(0x6EB7, 40, |rng| {
            let m = 1 + rng.uniform_usize(17);
            let k = 1 + rng.uniform_usize(48);
            let n = 1 + rng.uniform_usize(11); // exercises the < NC tail
            let a = randv(rng, m * k);
            let b = randv(rng, n * k);
            let seed = randv(rng, m * n);
            let use_seed = rng.bernoulli(0.5);
            let mut got = vec![0.0f32; m * n];
            let seed_arg: Option<&[f32]> = if use_seed { Some(&seed) } else { None };
            gemm_bt(&a, &b, seed_arg, m, k, n, &mut got);
            // Scalar oracle: one fold per element, κ ascending.
            for r in 0..m {
                for j in 0..n {
                    let mut s = if use_seed { seed[r * n + j] } else { 0.0f32 };
                    for kk in 0..k {
                        s += a[r * k + kk] * b[j * k + kk];
                    }
                    assert_eq!(got[r * n + j].to_bits(), s.to_bits(), "gemm_bt[{r},{j}]");
                }
            }
        });
    }

    // ---- sharded-kernel invariance (tentpole test tier) ----------------

    /// Pools shared across the property iterations (spawning threads per
    /// forall case would dominate the test's runtime).
    fn pools() -> Vec<ShardPool> {
        // 1, 2, 3 and an "auto"-like count: every path (inline, fanned,
        // more workers than shards) gets exercised.
        [1usize, 2, 3, 8].iter().map(|&t| ShardPool::new(t)).collect()
    }

    /// Awkward plans: shard height 1, off the register block, equal to
    /// the default, larger than any test row count (single shard).
    const SHARD_HEIGHTS: [usize; 5] = [1, 3, 5, SHARD_ROWS, 10_000];

    #[test]
    fn plan_geometry_covers_rows_exactly_once() {
        for rows in [0usize, 1, 3, 31, 32, 33, 128, 1024] {
            for sh in SHARD_HEIGHTS {
                let plan = ShardPlan::with_shard_rows(rows, sh);
                let mut covered = 0;
                for s in 0..plan.nshards() {
                    let (lo, hi) = plan.range(s);
                    assert_eq!(lo, covered, "ranges must be contiguous");
                    assert!(hi > lo, "empty shard in plan rows={rows} sh={sh}");
                    covered = hi;
                }
                assert_eq!(covered, rows, "plan must cover every row");
                // Never more shards than rows.
                assert!(plan.nshards() <= rows.max(1));
            }
        }
        // The default plan is a pure function of the row count alone.
        assert_eq!(ShardPlan::of(128).nshards(), 128 / SHARD_ROWS);
        assert_eq!(ShardPlan::of(1), ShardPlan::of(1));
    }

    /// Row-disjoint kernels: sharded == direct, bitwise, for every plan
    /// and every pool size — including n ∈ {1,3,5,8} batches, rows not
    /// divisible by the shard height, and shard heights above the row
    /// count (the "more shards than rows" degenerate collapses to 1).
    #[test]
    fn prop_sharded_row_disjoint_kernels_bitwise_match_direct() {
        let pools = pools();
        forall(0x5AD0, 12, |rng| {
            let n = [1usize, 3, 5, 8][rng.uniform_usize(4)];
            let tokens = 16usize;
            let rows = n * tokens + rng.uniform_usize(3); // off the sample boundary too
            let dim = 8 + rng.uniform_usize(12);
            let hidden = 2 * dim;
            let k = 1 + rng.uniform_usize(40);

            let a = randv(rng, rows * k);
            let w = randv(rng, k * dim);
            let bias = randv(rng, dim);
            let b_t = randv(rng, dim * k);
            let seed = randv(rng, rows * dim);
            let wb = randv(rng, dim * hidden + hidden + hidden * dim + dim);
            let t_in = randv(rng, rows * dim);

            let mut direct = vec![0.0f32; rows * dim];
            gemm_bias(&a, &w, &bias, rows, k, dim, &mut direct);
            let mut direct_bt = vec![0.0f32; rows * dim];
            gemm_bt(&a, &b_t, Some(&seed), rows, k, dim, &mut direct_bt);
            let mut dt = vec![0.0f32; rows * dim];
            let mut dur = vec![0.0f32; rows * hidden];
            block_fwd(&wb, &t_in, rows, dim, hidden, &mut dt, &mut dur);

            for sh in SHARD_HEIGHTS {
                let plan = ShardPlan::with_shard_rows(rows, sh);
                for pool in &pools {
                    let mut got = vec![0.0f32; rows * dim];
                    gemm_bias_sharded(pool, plan, &a, &w, &bias, rows, k, dim, &mut got);
                    assert_bits_eq(&got, &direct, "gemm_bias_sharded");

                    let mut got = vec![0.0f32; rows * dim];
                    gemm_bt_sharded(pool, plan, &a, &b_t, Some(&seed), rows, k, dim, &mut got);
                    assert_bits_eq(&got, &direct_bt, "gemm_bt_sharded");

                    let mut gt = vec![0.0f32; rows * dim];
                    let mut gu = vec![0.0f32; rows * hidden];
                    block_fwd_sharded(pool, plan, &wb, &t_in, rows, dim, hidden, &mut gt, &mut gu);
                    assert_bits_eq(&gt, &dt, "block_fwd_sharded.t");
                    assert_bits_eq(&gu, &dur, "block_fwd_sharded.u");
                }
            }
        });
    }

    #[test]
    fn prop_sharded_im2col_bitwise_matches_direct() {
        let pools = pools();
        forall(0x12C0, 8, |rng| {
            let n = [1usize, 3, 5, 8][rng.uniform_usize(4)];
            let (image, patch, channels) = (16usize, 4usize, 3usize);
            let tokens = (image / patch) * (image / patch);
            let pe = patch * patch * channels;
            let x = randv(rng, n * image * image * channels);
            let mut direct = vec![0.0f32; n * tokens * pe];
            im2col(&x, n, image, patch, channels, &mut direct);
            for sh in [1usize, 5, SHARD_ROWS, 10_000] {
                let plan = ShardPlan::with_shard_rows(n * tokens, sh);
                for pool in &pools {
                    let mut got = vec![0.0f32; n * tokens * pe];
                    im2col_sharded(pool, plan, &x, n, image, patch, channels, &mut got);
                    assert_bits_eq(&got, &direct, "im2col_sharded");
                }
            }
        });
    }

    /// Oracle for the sharded accumulators: fold each shard's row range
    /// into a zeroed partial with the *direct* kernels (themselves
    /// bitwise-pinned to the naive loops by the property tests above),
    /// then add the partials in ascending shard order — exactly the
    /// documented reduction. Every pool size must reproduce it bitwise,
    /// which is the `--kernel-threads N ≡ 1` contract at the kernel
    /// level.
    #[test]
    fn prop_sharded_accumulators_match_ordered_shard_fold_for_every_pool() {
        let pools = pools();
        forall(0xACC5, 10, |rng| {
            let rows = 1 + rng.uniform_usize(140);
            let m = 1 + rng.uniform_usize(24);
            let n = 1 + rng.uniform_usize(20);
            let x = randv(rng, rows * m);
            let y = randv(rng, rows * n);
            let g0 = randv(rng, m * n);
            let acc0 = randv(rng, n);

            for sh in SHARD_HEIGHTS {
                let plan = ShardPlan::with_shard_rows(rows, sh);
                let ns = plan.nshards();

                // Ordered shard-fold oracle (scalar loops, rows ascending
                // within a shard — same per-element order as the naive
                // reference kernels).
                let mut want_g = g0.clone();
                let mut want_acc = acc0.clone();
                if ns <= 1 {
                    // Single-shard plans degenerate to the direct kernels.
                    ger_acc_rows(&mut want_g, &x, &y, rows, m, n);
                    col_sum_acc(&mut want_acc, &y, rows, n);
                } else {
                    for s in 0..ns {
                        let (lo, hi) = plan.range(s);
                        let mut pg = vec![0.0f32; m * n];
                        ger_acc_rows(&mut pg, &x[lo * m..hi * m], &y[lo * n..hi * n], hi - lo, m, n);
                        for (a, p) in want_g.iter_mut().zip(pg.iter()) {
                            *a += *p;
                        }
                        let mut pa = vec![0.0f32; n];
                        col_sum_acc(&mut pa, &y[lo * n..hi * n], hi - lo, n);
                        for (a, p) in want_acc.iter_mut().zip(pa.iter()) {
                            *a += *p;
                        }
                    }
                }

                let mut part = vec![0.0f32; ns.max(1) * m * n];
                for pool in &pools {
                    let mut got_g = g0.clone();
                    ger_acc_rows_sharded(pool, plan, &mut got_g, &x, &y, rows, m, n, &mut part);
                    assert_bits_eq(&got_g, &want_g, "ger_acc_rows_sharded");

                    let mut got_acc = acc0.clone();
                    col_sum_acc_sharded(pool, plan, &mut got_acc, &y, rows, n, &mut part);
                    assert_bits_eq(&got_acc, &want_acc, "col_sum_acc_sharded");
                }
            }
        });
    }

    /// The full block backward under sharding: token gradients are
    /// bitwise the direct kernel's (row-disjoint); parameter gradients
    /// match the ordered per-shard reference fold; and every pool size
    /// agrees bitwise with every other.
    #[test]
    fn prop_sharded_block_bwd_matches_ordered_shard_fold() {
        let pools = pools();
        forall(0xB4D5, 8, |rng| {
            let rows = [16usize, 48, 80, 128, 7, 33][rng.uniform_usize(6)];
            let dim = 8 + rng.uniform_usize(8);
            let hidden = 2 * dim;
            let wlen = dim * hidden + hidden + hidden * dim + dim;
            let w = randv(rng, wlen);
            let t_in = randv(rng, rows * dim);
            let mut t_out = vec![0.0f32; rows * dim];
            let mut u = vec![0.0f32; rows * hidden];
            block_fwd(&w, &t_in, rows, dim, hidden, &mut t_out, &mut u);
            let d_out = randv(rng, rows * dim);
            let g0 = randv(rng, wlen);

            for sh in [1usize, 5, SHARD_ROWS, 10_000] {
                let plan = ShardPlan::with_shard_rows(rows, sh);
                let ns = plan.nshards();

                // Ordered shard-fold oracle on the direct kernel.
                let mut want_g = g0.clone();
                let mut want_d = vec![0.0f32; rows * dim];
                let mut du = vec![0.0f32; rows * hidden];
                if ns <= 1 {
                    block_bwd(&w, &t_in, &u, &d_out, rows, dim, hidden, &mut want_g, &mut want_d, &mut du);
                } else {
                    for s in 0..ns {
                        let (lo, hi) = plan.range(s);
                        let r = hi - lo;
                        let mut pg = vec![0.0f32; wlen];
                        let mut pdu = vec![0.0f32; r * hidden];
                        block_bwd(
                            &w,
                            &t_in[lo * dim..hi * dim],
                            &u[lo * hidden..hi * hidden],
                            &d_out[lo * dim..hi * dim],
                            r,
                            dim,
                            hidden,
                            &mut pg,
                            &mut want_d[lo * dim..hi * dim],
                            &mut pdu,
                        );
                        for (a, p) in want_g.iter_mut().zip(pg.iter()) {
                            *a += *p;
                        }
                    }
                }

                let mut gpart = vec![0.0f32; ns.max(1) * wlen];
                for pool in &pools {
                    let mut got_g = g0.clone();
                    let mut got_d = vec![0.0f32; rows * dim];
                    let mut got_du = vec![0.0f32; rows * hidden];
                    block_bwd_sharded(
                        pool, plan, &w, &t_in, &u, &d_out, rows, dim, hidden,
                        &mut got_g, &mut got_d, &mut got_du, &mut gpart,
                    );
                    assert_bits_eq(&got_g, &want_g, "block_bwd_sharded.g_w");
                    assert_bits_eq(&got_d, &want_d, "block_bwd_sharded.d_in");
                }
            }
        });
    }

    #[test]
    fn relu_kernels_preserve_signed_zero_and_nan_semantics() {
        let mut v = vec![-1.0f32, -0.0, 0.0, 2.0, f32::NAN];
        relu_inplace(&mut v);
        assert_eq!(v[0].to_bits(), 0.0f32.to_bits());
        assert_eq!(v[1].to_bits(), (-0.0f32).to_bits(), "-0.0 is not < 0.0");
        assert_eq!(v[3], 2.0);
        assert!(v[4].is_nan(), "NaN is not < 0.0");

        let u = vec![1.0f32, 0.0, -0.0, f32::NAN];
        let mut du = vec![5.0f32, 6.0, 7.0, 8.0];
        relu_mask(&mut du, &u);
        assert_eq!(du, vec![5.0, 0.0, 0.0, 0.0]);
    }
}
