//! A reusable scratch-buffer arena for the native backend's exec calls.
//!
//! Before the kernel core, every exec call allocated a fresh `Vec` per
//! activation layer, hidden layer and gradient staging buffer — a dozen
//! heap allocations per `client_local` and an O(depth) pile per
//! `eval_batch`, repeated for every client step of every round. The
//! arena turns that into a warm pool: buffers are checked out at the top
//! of an op, fully overwritten by the kernels, and checked back in at
//! the end, so the steady-state hot path performs **zero scratch
//! allocations** — the pool's high-water mark stabilizes after the first
//! round of each op shape (asserted in the backend's tests and surfaced
//! through `RuntimeStats::{arena_hwm_bytes, arena_allocs}`).
//!
//! The sharded backward kernels draw their per-shard parameter-gradient
//! partial buffers from the same pool (one `nshards · layer-size`
//! checkout per exec, sized by the shard plan — a pure function of the
//! op shape), so intra-client parallelism adds no steady-state
//! allocations either.
//!
//! Checkout is **best-fit**: the smallest pooled buffer whose capacity
//! covers the request wins, so large (eval-sized) buffers are not burned
//! on small (batch-sized) requests. Best-fit has the classic stability
//! property that makes the high-water mark converge: once a pass of
//! every op shape has run, each later request finds a fitting buffer and
//! nothing regrows. Returned buffers are zero-filled on checkout —
//! contents therefore never depend on which pooled buffer serves a
//! request, keeping exec bit-deterministic under any thread interleaving
//! of the parallel round engine (the backend holds the arena behind a
//! mutex; compute happens outside the lock).

/// The pool. One per [`super::NativeBackend`], shared by all worker
/// threads through a mutex; locks are held only for checkout/checkin,
/// never during kernel execution.
#[derive(Debug, Default)]
pub(crate) struct ScratchArena {
    /// Idle buffers, any order (checkout scans for best fit).
    free: Vec<Vec<f32>>,
    /// Total capacity (bytes) of every arena-managed buffer, idle or
    /// checked out.
    total_bytes: u64,
    /// Peak of `total_bytes` over the arena's lifetime.
    hwm_bytes: u64,
    /// Allocation events: new buffers plus capacity regrows. Stops
    /// moving once the pool is warm — the "zero steady-state heap
    /// allocations" invariant, asserted in tests.
    allocs: u64,
}

impl ScratchArena {
    pub(crate) fn new() -> ScratchArena {
        ScratchArena::default()
    }

    /// Check out a zero-filled buffer of exactly `elems` elements,
    /// reusing (or, on a cold path, growing) a pooled allocation.
    pub(crate) fn take(&mut self, elems: usize) -> Vec<f32> {
        if elems == 0 {
            return Vec::new();
        }
        let mut best_fit: Option<(usize, usize)> = None; // (idx, cap), min cap ≥ elems
        let mut largest: Option<(usize, usize)> = None; // (idx, cap), max cap
        for (i, b) in self.free.iter().enumerate() {
            let cap = b.capacity();
            if cap >= elems {
                match best_fit {
                    Some((_, c)) if c <= cap => {}
                    _ => best_fit = Some((i, cap)),
                }
            }
            match largest {
                Some((_, c)) if c >= cap => {}
                _ => largest = Some((i, cap)),
            }
        }
        let mut buf = match best_fit.or(largest) {
            Some((i, _)) => self.free.swap_remove(i),
            None => Vec::new(),
        };
        let before = buf.capacity();
        buf.clear();
        buf.resize(elems, 0.0);
        let after = buf.capacity();
        if after > before {
            self.allocs += 1;
            self.total_bytes += ((after - before) * std::mem::size_of::<f32>()) as u64;
            self.hwm_bytes = self.hwm_bytes.max(self.total_bytes);
        }
        buf
    }

    /// Return a buffer to the pool. Zero-capacity buffers (the `take(0)`
    /// placeholders) are dropped rather than pooled.
    pub(crate) fn put(&mut self, mut buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        buf.clear();
        self.free.push(buf);
    }

    /// Peak bytes ever held across all arena buffers.
    pub(crate) fn hwm_bytes(&self) -> u64 {
        self.hwm_bytes
    }

    /// Cumulative allocation/regrow events (stable once warm).
    pub(crate) fn alloc_events(&self) -> u64 {
        self.allocs
    }

    /// Buffers currently idle in the pool.
    pub(crate) fn pooled(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_zero_is_free_and_unpooled() {
        let mut a = ScratchArena::new();
        let b = a.take(0);
        assert_eq!(b.capacity(), 0);
        a.put(b);
        assert_eq!(a.pooled(), 0);
        assert_eq!(a.alloc_events(), 0);
        assert_eq!(a.hwm_bytes(), 0);
    }

    #[test]
    fn smaller_request_reuses_without_allocating() {
        let mut a = ScratchArena::new();
        let b = a.take(100);
        assert_eq!(b.len(), 100);
        assert_eq!(a.alloc_events(), 1);
        a.put(b);
        // A second exec shape with smaller n: same buffer, no new alloc.
        let b = a.take(50);
        assert_eq!(b.len(), 50);
        assert!(b.capacity() >= 100);
        assert_eq!(a.alloc_events(), 1);
        assert!(a.hwm_bytes() >= 400);
        a.put(b);
    }

    #[test]
    fn larger_request_regrows_and_raises_the_water_mark() {
        let mut a = ScratchArena::new();
        a.put(a.take(100));
        let hwm1 = a.hwm_bytes();
        let b = a.take(300);
        assert_eq!(b.len(), 300);
        assert_eq!(a.alloc_events(), 2, "regrow is an allocation event");
        assert!(a.hwm_bytes() > hwm1);
        a.put(b);
        // Third pass at the large size: warm, no further events.
        let hwm2 = a.hwm_bytes();
        a.put(a.take(300));
        assert_eq!(a.alloc_events(), 2);
        assert_eq!(a.hwm_bytes(), hwm2);
    }

    #[test]
    fn best_fit_spares_large_buffers_for_large_requests() {
        let mut a = ScratchArena::new();
        let big = a.take(1000);
        let small = a.take(10);
        a.put(big);
        a.put(small);
        let events = a.alloc_events();
        // The small request must take the 10-cap buffer, leaving the
        // 1000-cap one for the big request — no regrow either way.
        let s = a.take(8);
        let b = a.take(900);
        assert!(s.capacity() < 1000);
        assert!(b.capacity() >= 1000);
        assert_eq!(a.alloc_events(), events);
        a.put(s);
        a.put(b);
    }

    #[test]
    fn checkout_is_zero_filled_regardless_of_history() {
        let mut a = ScratchArena::new();
        let mut b = a.take(64);
        for v in b.iter_mut() {
            *v = 7.0;
        }
        a.put(b);
        let b = a.take(64);
        assert!(b.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn interleaved_shapes_stabilize_after_one_full_pass() {
        // Two "ops" with different buffer shapes, alternating — the
        // arena must stop allocating after each shape has run once.
        let mut a = ScratchArena::new();
        let mut pass = |a: &mut ScratchArena, sizes: &[usize]| {
            let bufs: Vec<_> = sizes.iter().map(|&s| a.take(s)).collect();
            for b in bufs {
                a.put(b);
            }
        };
        pass(&mut a, &[128, 512, 64]);
        pass(&mut a, &[1024, 32, 256]);
        let warm_events = a.alloc_events();
        let warm_hwm = a.hwm_bytes();
        for _ in 0..10 {
            pass(&mut a, &[128, 512, 64]);
            pass(&mut a, &[1024, 32, 256]);
        }
        assert_eq!(a.alloc_events(), warm_events);
        assert_eq!(a.hwm_bytes(), warm_hwm);
    }
}
